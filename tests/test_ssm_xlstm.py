"""SSD (Mamba2) and xLSTM block invariants: chunked-parallel == recurrent,
chunk-size invariance, state handoff."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as ssm_mod, xlstm as xm


@pytest.fixture(scope="module")
def zcfg():
    return get_config("zamba2-7b").reduced()


@pytest.fixture(scope="module")
def xcfg():
    return get_config("xlstm-1.3b").reduced()


@pytest.mark.slow
def test_ssd_chunk_size_invariance(zcfg):
    """The chunked scan must be algebraically independent of chunk size."""
    p = ssm_mod.init_ssm(jax.random.PRNGKey(0), zcfg)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(2, 64, zcfg.d_model)), jnp.float32)
    outs = []
    for q in (8, 16, 64):
        cfg = dataclasses.replace(
            zcfg, ssm=dataclasses.replace(zcfg.ssm, chunk_size=q))
        o, st = ssm_mod.ssm_forward(p, x, cfg)
        outs.append((o, st["ssm"]))
    for o, s in outs[1:]:
        np.testing.assert_allclose(o, outs[0][0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s, outs[0][1], rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_ssd_forward_matches_stepwise_decode(zcfg):
    p = ssm_mod.init_ssm(jax.random.PRNGKey(1), zcfg)
    r = np.random.default_rng(1)
    S = 40                                               # non-multiple of chunk
    x = jnp.asarray(r.normal(size=(1, S, zcfg.d_model)), jnp.float32)
    out_f, st_f = ssm_mod.ssm_forward(p, x, zcfg)
    st = ssm_mod.init_ssm_state(zcfg, 1, jnp.float32)
    outs = []
    for t in range(S):
        o, st = ssm_mod.ssm_decode(p, x[:, t:t + 1], st, zcfg)
        outs.append(o)
    out_r = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(out_f, out_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_f["ssm"], st["ssm"], rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_mlstm_forward_matches_recurrent(xcfg):
    p = xm.init_mlstm(jax.random.PRNGKey(2), xcfg)
    r = np.random.default_rng(2)
    S = 70
    x = jnp.asarray(r.normal(size=(1, S, xcfg.d_model)), jnp.float32)
    out_f, st_f = xm.mlstm_forward(p, x, xcfg)
    st = xm.init_mlstm_state(xcfg, 1)
    outs = []
    for t in range(S):
        o, st = xm.mlstm_decode(p, x[:, t:t + 1], st, xcfg)
        outs.append(o)
    np.testing.assert_allclose(out_f, jnp.concatenate(outs, 1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_f["C"], st["C"], rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_slstm_forward_matches_recurrent(xcfg):
    p = xm.init_slstm(jax.random.PRNGKey(3), xcfg)
    r = np.random.default_rng(3)
    x = jnp.asarray(r.normal(size=(2, 12, xcfg.d_model)), jnp.float32)
    out_f, st_f = xm.slstm_forward(p, x, xcfg)
    st = xm.init_slstm_state(xcfg, 2)
    outs = []
    for t in range(12):
        o, st = xm.slstm_decode(p, x[:, t:t + 1], st, xcfg)
        outs.append(o)
    np.testing.assert_allclose(out_f, jnp.concatenate(outs, 1),
                               rtol=2e-4, atol=2e-4)


def test_slstm_stabilizer_extreme_gates(xcfg):
    """The max-stabilizer must keep sLSTM finite under large inputs."""
    p = xm.init_slstm(jax.random.PRNGKey(4), xcfg)
    x = jnp.full((1, 20, xcfg.d_model), 30.0, jnp.float32)
    out, st = xm.slstm_forward(p, x, xcfg)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(st["c"])).all()


def test_ssm_state_no_nan_long_seq(zcfg):
    p = ssm_mod.init_ssm(jax.random.PRNGKey(5), zcfg)
    x = jnp.asarray(np.random.default_rng(5).normal(
        size=(1, 512, zcfg.d_model)), jnp.float32)
    o, st = ssm_mod.ssm_forward(p, x, zcfg)
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(st["ssm"])).all()
