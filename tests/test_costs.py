"""Level-3 flcheck cost-auditor tests (analysis/costs.py).

The acceptance pins of the static wire audit:

* the quantize-on config PROVES int8-grid + fp32-scale uploads on every
  traced execution path (vmap, flat 8-device, hier 2x4, semi-sync);
* the quantize+mask config proves the SAME int8+scale wire end-to-end —
  ring masking holds the quantized format under secure aggregation, and a
  re-widened masked upload is the FATAL ``masked_fp32_regression``;
* the committed baseline gate FAILS on an injected wire-byte change;
* the audited byte counts actually reach the latency model
  (``payload_bytes(audited_bytes=...)`` / ``link_budget(audited_up=...)``).
"""
import copy
import json
import os

import jax
import pytest

from repro.analysis import costs
from repro.analysis.cli import find_repo_root, main as cli_main
from repro.configs.base import (ForecasterConfig, SecureAggConfig,
                                TransformConfig)
from repro.core import latency

FCFG = ForecasterConfig(hidden_dim=8)
T_Q8 = TransformConfig(clip_norm=1.0, quantize_bits=8)
T_CLIP = TransformConfig(clip_norm=1.0)
SECURE = SecureAggConfig(enabled=True)

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 virtual devices (run via ./test.sh)")


@pytest.fixture(scope="module")
def report():
    return costs.cost_report(FCFG)


# ------------------------------------------------------------ wire algebra
def test_leaf_wire_bytes():
    # int8 grid: 1 byte/coordinate + one fp32 scale per leaf
    assert costs.leaf_wire_bytes(32, "int8+scale") == 36
    # sub-byte grids pack: 4-bit -> ceil(33*4/8) + 4
    assert costs.leaf_wire_bytes(33, "int4+scale") == 17 + 4
    assert costs.leaf_wire_bytes(32, "float32") == 128
    assert costs.leaf_wire_bytes(32, None) == 128


def test_model_leaf_sizes_match_param_count():
    sizes = costs.model_leaf_sizes(FCFG)
    assert sum(sizes) == FCFG.num_params()
    assert len(sizes) == 5


# --------------------------------------------------- per-path wire proofs
@pytest.mark.parametrize("path", ["vmap", "semi_sync"])
def test_quantize_wire_proved_small_paths(path):
    a = costs.audit_round(path, T_Q8, None, FCFG)
    assert a["proved"]
    assert a["wire"] == "int8+scale"
    tainted = [c for c in a["crossings"] if c["tainted"]]
    assert tainted and all(c["wire"] == "int8+scale" for c in tainted)


@needs_8_devices
@pytest.mark.parametrize("path", ["flat8", "hier2x4"])
def test_quantize_wire_proved_mesh_paths(path):
    a = costs.audit_round(path, T_Q8, None, FCFG)
    assert a["proved"]
    assert a["wire"] == "int8+scale"


def test_quantize_audited_bytes_and_scale_divergence():
    a = costs.audit_round("vmap", T_Q8, None, FCFG)
    n, leaves = FCFG.num_params(), 5
    assert a["upload_bytes_per_client"] == n + 4 * leaves
    assert a["modeled_bytes_per_client"] == n           # formula: ceil(n*8/8)
    (d,) = a["divergences"]
    assert d["kind"] == "scale_overhead"
    assert d["bytes"] == 4 * leaves
    assert d["fatal"] is False


def test_masked_upload_proves_int8_wire_end_to_end():
    """THE tentpole pin: quantize+mask ships the SAME int8+scale wire as
    quantize alone — ring masking adds zero bytes, the audited masked
    upload equals the quantized one, and no masked_fp32_regression
    divergence exists anywhere in the audit."""
    a = costs.audit_round("vmap", T_Q8, SECURE, FCFG)
    clear = costs.audit_round("vmap", T_Q8, None, FCFG)
    assert a["proved"]
    assert a["wire"] == "int8+scale"
    assert a["upload_bytes_per_client"] == clear["upload_bytes_per_client"]
    assert a["modeled_bytes_per_client"] == clear["modeled_bytes_per_client"]
    tainted = [c for c in a["crossings"] if c["tainted"]]
    assert tainted and all(c["wire"] == "int8+scale" for c in tainted)
    assert not any(d["kind"] == "masked_fp32_regression"
                   for d in a["divergences"])
    assert costs.check_report({"audits": {"vmap/quantize8_secure": a}}) == []


@needs_8_devices
@pytest.mark.parametrize("path", ["flat8", "hier2x4", "semi_sync"])
def test_masked_wire_proved_on_every_path(path):
    a = costs.audit_round(path, T_Q8, SECURE, FCFG)
    assert a["proved"]
    assert a["wire"] == "int8+scale"


def test_rewidened_masker_is_fatal_regression():
    """A masker that re-widens the masked upload to fp32 (the pre-ring
    behaviour) must now FAIL the proof-level check, by name."""
    a = costs.audit_round("vmap", T_Q8, SECURE, FCFG)
    broken = dict(a, wire="float32")
    fatal = costs.check_report({"audits": {"vmap/quantize8_secure": broken}})
    assert fatal and "masked_fp32_regression" in fatal[0]


def test_fp32_config_audited_matches_model():
    a = costs.audit_round("vmap", T_CLIP, None, FCFG)
    assert a["wire"] == "float32"
    assert a["upload_bytes_per_client"] == a["modeled_bytes_per_client"]
    assert a["divergences"] == []


def test_check_report_catches_rewidened_quantize():
    a = costs.audit_round("vmap", T_Q8, None, FCFG)
    broken = dict(a, wire="float32")
    fatal = costs.check_report({"audits": {"vmap/quantize8": broken}})
    assert fatal and "re-widened" in fatal[0]


# ------------------------------------------------------------ stage costs
def test_stage_costs_shape(report):
    stages = report["stages"]
    assert set(stages) == {"client_dispatch", "round_total",
                           "aggregate_server"}
    for st in stages.values():
        assert st["flops"] >= 0 and st["hbm_bytes"] >= 0
        assert st["roofline"]["bound"] in ("compute", "memory")
    # the vmap round strictly contains the dispatch prefix
    assert stages["round_total"]["flops"] >= \
        stages["client_dispatch"]["flops"]


# ---------------------------------------------------------- baseline gate
def test_self_diff_is_empty(report):
    errors, warnings = costs.diff_reports(report, report)
    assert errors == [] and warnings == []


def test_injected_wire_byte_change_fails_diff(report):
    """THE gate pin: a wire-byte drift without a baseline update must fail."""
    drifted = copy.deepcopy(report)
    key = next(k for k in drifted["audits"] if k.endswith("/quantize8"))
    drifted["audits"][key]["upload_bytes_per_client"] += 1
    errors, _ = costs.diff_reports(report, drifted)
    assert any("upload_bytes_per_client" in e and key in e for e in errors)


def test_injected_dtype_change_fails_diff(report):
    drifted = copy.deepcopy(report)
    key = next(iter(drifted["audits"]))
    drifted["audits"][key]["crossings"][0]["dtype"] = "float64"
    errors, _ = costs.diff_reports(report, drifted)
    assert any("crossings" in e for e in errors)


def test_injected_stage_flop_change_fails_diff(report):
    drifted = copy.deepcopy(report)
    drifted["stages"]["round_total"]["flops"] += 100
    errors, _ = costs.diff_reports(report, drifted)
    assert any("stage round_total" in e and "flops" in e for e in errors)


def test_skipped_path_is_warning_not_error(report):
    """A baseline entry the current device geometry cannot trace (flat8 /
    hier2x4 off-CI) must downgrade to a warning, never a silent pass or a
    spurious failure."""
    partial = copy.deepcopy(report)
    full = copy.deepcopy(report)
    for key in [k for k in partial["audits"] if k.startswith("flat8/")]:
        del partial["audits"][key]
    partial["skipped"]["flat8"] = "needs 8 virtual devices, have 1"
    errors, warnings = costs.diff_reports(full, partial)
    assert errors == []
    assert any("flat8/" in w for w in warnings)


def test_committed_baseline_matches_fresh_report(report):
    """The committed JSON is in sync with the code — the CI gate, as a
    test.  Regenerate with  tools/flcheck --cost --update-baseline  when a
    change intentionally moves wire bytes or stage FLOPs."""
    root = find_repo_root(os.path.dirname(__file__))
    path = os.path.join(root, costs.DEFAULT_BASELINE)
    assert os.path.exists(path), (
        f"committed baseline missing: {path} "
        "(generate with tools/flcheck --cost --update-baseline)")
    with open(path, "r", encoding="utf-8") as f:
        baseline = json.load(f)
    errors, _ = costs.diff_reports(baseline, report)
    assert errors == [], "\n".join(errors)


def test_canonical_json_is_stable(report):
    s = costs.canonical_json(report)
    assert s == costs.canonical_json(json.loads(s))
    assert s.endswith("\n")


def test_cli_cost_baseline_roundtrip(tmp_path, capsys):
    """--update-baseline writes, --baseline passes against it, and a
    corrupted baseline fails with exit 1."""
    bl = tmp_path / "round_costs.json"
    assert cli_main(["--no-lint", "--cost", "--update-baseline",
                     "--baseline", str(bl)]) == 0
    assert bl.exists()
    assert cli_main(["--no-lint", "--cost", "--baseline", str(bl)]) == 0
    data = json.loads(bl.read_text())
    key = next(k for k in data["audits"] if k.endswith("/quantize8"))
    data["audits"][key]["upload_bytes_per_client"] += 8
    bl.write_text(json.dumps(data))
    capsys.readouterr()
    assert cli_main(["--no-lint", "--cost", "--baseline", str(bl)]) == 1
    assert "DRIFT" in capsys.readouterr().out


def test_cli_baseline_without_cost_is_usage_error(capsys):
    assert cli_main(["--baseline", "x.json"]) == 2
    assert "--cost" in capsys.readouterr().err


# ------------------------------------------- latency-model audited rewiring
def test_payload_bytes_audited_override():
    assert latency.payload_bytes(1000, 8) == 1000
    assert latency.payload_bytes(1000, 8, audited_bytes=1020) == 1020.0
    assert latency.payload_bytes(1000, 0, audited_bytes=None) == 4000.0


def test_link_budget_audited_up():
    b_model = latency.link_budget(1000, 30, 3, 8)
    b_audit = latency.link_budget(1000, 30, 3, 8, audited_up=1020)
    assert b_audit["region_fanin_bytes"] == 10 * 1020
    assert b_audit["flat_cloud_ingress_bytes"] == 30 * 1020
    # region->cloud partials stay modeled fp32 in both
    assert b_audit["cloud_ingress_bytes"] == b_model["cloud_ingress_bytes"]


def test_round_engine_accepts_audited_payload():
    from repro.configs.base import FLConfig
    from repro.core import fedavg
    a = costs.audit_round("vmap", T_Q8, None, FCFG)
    flcfg = FLConfig(n_clients=4, clients_per_round=2, rounds=1, lr=0.1,
                     n_clusters=0, dp_clip=1.0, quantize_bits=8)
    eng = fedavg.RoundEngine(FCFG, flcfg,
                             audited_payload=a["upload_bytes_per_client"])
    expect = a["upload_bytes_per_client"] / \
        flcfg.async_config.latency.uplink_bytes_per_s
    assert eng.latency.uplink_s == pytest.approx(expect)
