"""FedAvg engine invariants (paper Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg, losses
from repro.core.client import local_update
from repro.data import synthetic, windows
from repro.models import forecaster


@pytest.fixture(scope="module")
def small_fl():
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=8)
    data = windows.batched_client_windows(series, fcfg.lookback, fcfg.horizon)
    return series, fcfg, data


def test_aggregate_is_mean():
    trees = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": [jnp.ones((3, 2)), jnp.zeros((3,))]}
    agg = fedavg.fedavg_aggregate(trees)
    np.testing.assert_allclose(agg["a"], jnp.arange(12.0).reshape(3, 4)
                               .mean(0))
    np.testing.assert_allclose(agg["b"][0], 1.0)


def test_single_client_round_equals_local_sgd(small_fl):
    """FedAvg with M=1 client must equal that client's plain local SGD."""
    series, fcfg, data = small_fl
    loss = losses.make_loss("mse")
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), fcfg)
    x = jnp.asarray(data["x_train"][:1])
    y = jnp.asarray(data["y_train"][:1])
    bidx = jnp.asarray(np.random.default_rng(0)
                       .integers(0, x.shape[1], size=(1, 5, 16)))
    p_fed, _ = fedavg.fedavg_round(params, x, y, bidx, 0.01, fcfg, loss)
    p_loc, _ = local_update(params, x[0], y[0], bidx[0], 0.01, fcfg, loss)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-6),
                 p_fed, p_loc)


def test_round_loss_decreases(small_fl):
    series, fcfg, data = small_fl
    loss = losses.make_loss("ew_mse", 2.0)
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), fcfg)
    x = jnp.asarray(data["x_train"])
    y = jnp.asarray(data["y_train"])
    r = np.random.default_rng(0)
    hist = []
    for t in range(8):
        bidx = jnp.asarray(r.integers(0, x.shape[1], size=(6, 10, 32)))
        params, l = fedavg.fedavg_round(params, x, y, bidx, 0.05, fcfg, loss)
        hist.append(float(l))
    assert hist[-1] < hist[0]


def test_sharded_round_matches_vmap_round(small_fl):
    """shard_map execution (1-device mesh) == pseudo-distributed vmap."""
    series, fcfg, data = small_fl
    loss = losses.make_loss("mse")
    mesh = jax.make_mesh((1,), ("clients",))
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), fcfg)
    x = jnp.asarray(data["x_train"])
    y = jnp.asarray(data["y_train"])
    bidx = jnp.asarray(np.random.default_rng(0)
                       .integers(0, x.shape[1], size=(6, 4, 16)))
    p1, l1 = fedavg.fedavg_round(params, x, y, bidx, 0.05, fcfg, loss)
    round_fn = fedavg.make_sharded_round(mesh, fcfg, loss)
    p2, l2 = round_fn(params, x, y, bidx, jnp.float32(0.05))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4,
                                                         atol=1e-6), p1, p2)


def test_run_federated_training_clusters(small_fl):
    series, fcfg, data = small_fl
    flcfg = FLConfig(n_clients=6, clients_per_round=3, rounds=2,
                     n_clusters=2, batch_size=16, cluster_days=10)
    out = fedavg.run_federated_training(series, fcfg, flcfg)
    assert set(out) == {0, 1}
    for res in out.values():
        assert res.loss_history.shape == (2,)
        assert np.isfinite(res.loss_history).all()
        assert res.cluster_assignments.shape == (6,)


def test_evaluate_global_metrics(small_fl):
    series, fcfg, data = small_fl
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), fcfg)
    x, y, stats = windows.flatten_test_windows(data)
    m = fedavg.evaluate_global(params, x, y, fcfg, stats=stats)
    assert 0.0 <= m["accuracy"] <= 100.0
    assert m["rmse"] >= 0.0
    assert m["per_horizon_accuracy"].shape == (fcfg.horizon,)
