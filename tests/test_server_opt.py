"""Round-engine properties (ISSUE 1 tentpole): aggregation weighting, server
optimizers, FedProx, client sampling, and vmap/shard_map path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg, losses, sampling, server_opt
from repro.core.client import local_update
from repro.data import partition, synthetic, windows
from repro.models import forecaster

FCFG = ForecasterConfig(cell="lstm", hidden_dim=8)
LOSS = losses.make_loss("mse")              # one object -> one jit cache entry
MESH = jax.make_mesh((1,), ("clients",))


def tree_close(a, b, rtol=1e-5, atol=1e-6):
    jax.tree.map(lambda u, v: np.testing.assert_allclose(u, v, rtol=rtol,
                                                         atol=atol), a, b)


@pytest.fixture(scope="module")
def fl_data():
    series = synthetic.generate_buildings("CA", list(range(4)), days=12)
    data = windows.batched_client_windows(series, FCFG.lookback, FCFG.horizon)
    x = jnp.asarray(data["x_train"])
    y = jnp.asarray(data["y_train"])
    bidx = jnp.asarray(np.random.default_rng(0)
                       .integers(0, x.shape[1], size=(4, 3, 16)))
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), FCFG)
    return params, x, y, bidx


def _engine_flcfg(**kw):
    return FLConfig(n_clients=4, clients_per_round=4, lr=0.05, rounds=1,
                    n_clusters=0, loss="mse", **kw)


# --------------------------------------------------- (a) weighted == uniform
@given(st.floats(0.5, 8.0), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_weighted_aggregate_equal_weights_is_uniform(c, seed):
    r = np.random.default_rng(seed)
    stacked = {"a": jnp.asarray(r.normal(size=(5, 3, 2)), jnp.float32),
               "b": [jnp.asarray(r.normal(size=(5, 4)), jnp.float32)]}
    w = jnp.full((5,), c, jnp.float32)
    tree_close(fedavg.weighted_aggregate(stacked, w),
               fedavg.fedavg_aggregate(stacked))


def test_engine_round_equal_counts_matches_uniform_round(fl_data):
    """Sample-count weighting with equal counts == paper's uniform FedAvg."""
    params, x, y, bidx = fl_data
    lr, mu = jnp.float32(0.05), jnp.float32(0.0)
    w = jnp.full((4,), 7.0, jnp.float32)
    p_w, l_w = fedavg.engine_round(params, x, y, bidx, w, lr, mu, FCFG, LOSS)
    p_u, l_u = fedavg.fedavg_round(params, x, y, bidx, lr, FCFG, LOSS)
    tree_close(p_w, p_u)
    np.testing.assert_allclose(float(l_w), float(l_u), rtol=1e-5)


def test_engine_round_unequal_weights_biases_toward_heavy_client(fl_data):
    params, x, y, bidx = fl_data
    lr, mu = jnp.float32(0.05), jnp.float32(0.0)
    heavy = jnp.asarray([1e4, 1.0, 1.0, 1.0], jnp.float32)
    p_h, _ = fedavg.engine_round(params, x, y, bidx, heavy, lr, mu, FCFG, LOSS)
    p_0, _ = local_update(params, x[0], y[0], bidx[0], lr, FCFG, LOSS)
    # nearly all weight on client 0 -> aggregate ~= client 0's local model
    tree_close(p_h, p_0, rtol=1e-3, atol=1e-4)


# --------------------------------------------------- (b) FedProx mu=0
def test_fedprox_mu0_equals_fedavg(fl_data):
    params, x, y, bidx = fl_data
    counts = np.full(4, float(x.shape[1]), np.float32)
    outs = {}
    for opt in ("fedavg_weighted", "fedprox"):
        eng = fedavg.RoundEngine(FCFG, _engine_flcfg(server_opt=opt,
                                                     prox_mu=0.0), loss=LOSS)
        state = server_opt.init_server_state(params)
        p, _, l = eng.step(params, state, x, y, bidx, counts)
        outs[opt] = (p, float(l))
    tree_close(outs["fedprox"][0], outs["fedavg_weighted"][0],
               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(outs["fedprox"][1], outs["fedavg_weighted"][1],
                               rtol=1e-6)


def test_fedprox_mu_shrinks_client_drift(fl_data):
    """The proximal term pulls local models toward the round's global model."""
    params, x, y, bidx = fl_data
    lr = jnp.float32(0.1)

    def drift(mu):
        p, _ = local_update(params, x[0], y[0], bidx[0], lr, FCFG, LOSS,
                            prox_mu=jnp.float32(mu))
        sq = jax.tree.map(lambda a, b: float(jnp.sum((a - b) ** 2)), p, params)
        return sum(jax.tree.leaves(sq))

    assert drift(10.0) < drift(0.0)


# ------------------------------------------- (c) adaptive rules, 1 client
@pytest.mark.parametrize("opt", ["fedadam", "fedyogi"])
def test_adaptive_first_step_recovers_averaging_one_client(fl_data, opt):
    """With beta1=0 and server_lr == eps >> |g|, the adaptive step collapses
    to w - g = w_agg: plain averaging of the single client."""
    params, x, y, bidx = fl_data
    flcfg = _engine_flcfg(server_opt=opt, server_beta1=0.0,
                          server_eps=1e6, server_lr=1e6)
    eng = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS)
    state = server_opt.init_server_state(params)
    p, _, _ = eng.step(params, state, x[:1], y[:1], bidx[:1],
                       np.ones(1, np.float32))
    p_loc, _ = local_update(params, x[0], y[0], bidx[0], jnp.float32(0.05),
                            FCFG, LOSS)
    tree_close(p, p_loc, rtol=1e-4, atol=1e-5)


def test_server_update_fedavg_lr1_returns_aggregate_exactly():
    w = {"a": jnp.arange(4.0), "b": jnp.ones((2, 3))}
    agg = jax.tree.map(lambda t: t + 0.5, w)
    state = server_opt.init_server_state(w)
    new, st2 = server_opt.server_update(w, agg, state,
                                        _engine_flcfg(server_opt="fedavg"))
    jax.tree.map(np.testing.assert_array_equal, new, agg)
    assert int(st2.t) == 1


def test_server_momentum_accumulates_fedavgm():
    """Constant pseudo-gradient (+1 aggregate offset) + momentum -> the
    server step grows round over round."""
    w = {"a": jnp.zeros(3)}
    flcfg = _engine_flcfg(server_opt="fedavg", server_lr=0.5,
                          server_momentum=0.9)
    state = server_opt.init_server_state(w)
    w1, state = server_opt.server_update(
        w, jax.tree.map(lambda t: t + 1.0, w), state, flcfg)
    w2, state = server_opt.server_update(
        w1, jax.tree.map(lambda t: t + 1.0, w1), state, flcfg)
    step1 = float(jnp.abs(w1["a"] - w["a"]).mean())
    step2 = float(jnp.abs(w2["a"] - w1["a"]).mean())
    assert step2 > step1


def test_server_update_rejects_unknown_opt():
    w = {"a": jnp.zeros(2)}
    with pytest.raises(ValueError):
        server_opt.server_update(w, w, server_opt.init_server_state(w),
                                 _engine_flcfg(server_opt="fedsgdfoo"))
    with pytest.raises(ValueError):
        fedavg.RoundEngine(FCFG, _engine_flcfg(server_opt="fedsgdfoo"))


# ------------------------------- (d) vmap vs shard_map, every server_opt
@pytest.mark.parametrize("opt", server_opt.SERVER_OPTS)
def test_vmap_and_shard_map_paths_agree(fl_data, opt):
    params, x, y, bidx = fl_data
    lr = {"fedadam": 0.05, "fedyogi": 0.05}.get(opt, 1.0)
    flcfg = _engine_flcfg(server_opt=opt, server_lr=lr, prox_mu=0.01)
    counts = np.full(4, float(x.shape[1]), np.float32)
    e_vmap = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS)
    e_shard = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS, mesh=MESH)
    s0 = server_opt.init_server_state(params)
    p1, s1, l1 = e_vmap.step(params, s0, x, y, bidx, counts)
    p2, s2, l2 = e_shard.step(params, s0, x, y, bidx, counts)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    tree_close(p1, p2, rtol=2e-4, atol=1e-6)
    # second round exercises the server-optimizer state on both paths
    p1b, _, _ = e_vmap.step(p1, s1, x, y, bidx, counts)
    p2b, _, _ = e_shard.step(p2, s2, x, y, bidx, counts)
    tree_close(p1b, p2b, rtol=5e-4, atol=1e-6)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs multi-device CPU (run via ./test.sh)")
def test_shard_map_multi_device_matches_vmap(fl_data):
    """2+-device mesh: cross-shard psum aggregation == pseudo-distributed."""
    params, x, y, bidx = fl_data
    mesh = jax.make_mesh((2,), ("clients",))
    flcfg = _engine_flcfg(server_opt="fedavg_weighted")
    counts = np.asarray([3.0, 1.0, 2.0, 2.0], np.float32)
    e_vmap = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS)
    e_shard = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS, mesh=mesh)
    s0 = server_opt.init_server_state(params)
    p1, _, l1 = e_vmap.step(params, s0, x, y, bidx, counts)
    p2, _, l2 = e_shard.step(params, s0, x, y, bidx, counts)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    tree_close(p1, p2, rtol=2e-4, atol=1e-6)


# ------------------------------------------------------------- sampling
def test_uniform_sampler_distinct_and_padded():
    rng = np.random.default_rng(0)
    members = np.arange(10, 16)
    sel = sampling.uniform_sampler(rng, members, 4, 0)
    assert len(sel) == 4 and len(set(sel)) == 4
    assert set(sel) <= set(members)
    sel = sampling.uniform_sampler(rng, members, 9, 0)   # m > |members|: pad
    assert len(sel) == 9 and set(sel) <= set(members)


def test_weighted_sampler_prefers_heavy_clients():
    rng = np.random.default_rng(0)
    members = np.arange(8)
    w = np.asarray([50.0] + [1.0] * 7)
    hits = sum(0 in sampling.weighted_sampler(rng, members, 2, t, w)
               for t in range(50))
    assert hits > 40                       # client 0 in nearly every round


def test_round_robin_sampler_visits_all_clients_equally():
    members = np.arange(6) + 100
    rng = np.random.default_rng(0)
    seen = np.concatenate([
        sampling.round_robin_sampler(rng, members, 2, t) for t in range(6)])
    ids, counts = np.unique(seen, return_counts=True)
    assert set(ids) == set(members)
    assert (counts == 2).all()             # 6 rounds x m=2 over 6 members


def test_weighted_sampler_handles_zero_weight_clients():
    """Zero-weight members can't break the exactly-m contract (pad path)."""
    rng = np.random.default_rng(0)
    members = np.arange(5)
    w = np.asarray([1.0, 0.0, 0.0, 0.0, 0.0])
    sel = sampling.weighted_sampler(rng, members, 3, 0, w)
    assert len(sel) == 3 and 0 in sel
    sel = sampling.weighted_sampler(rng, members, 3, 0, np.zeros(5))
    assert len(sel) == 3                   # all-zero -> uniform fallback


def test_weighted_pad_prefers_distinct_unselected_members():
    """Padding contract (ISSUE 4 fix): when the without-replacement weighted
    draw exhausts the nonzero-weight members, the remainder must be DISTINCT
    unselected members — never duplicates of already-selected clients while
    unselected ones remain."""
    members = np.arange(6)
    w = np.asarray([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    for seed in range(20):
        sel = sampling.weighted_sampler(np.random.default_rng(seed),
                                        members, 5, 0, w)
        assert len(sel) == 5
        assert len(np.unique(sel)) == 5          # all distinct
        assert {0, 1, 2} <= set(sel)             # every nonzero first


def test_weighted_pad_prefers_nonzero_weight_members():
    """With m > |members| the duplicate passes kick in only after every
    member (nonzero-weight AND zero-weight) was selected once."""
    members = np.arange(4)
    w = np.asarray([2.0, 1.0, 0.0, 0.0])
    for seed in range(10):
        sel = sampling.weighted_sampler(np.random.default_rng(seed),
                                        members, 6, 0, w)
        ids, counts = np.unique(sel, return_counts=True)
        assert set(ids) == set(members)          # everyone in before dups
        assert counts.max() <= 2


def test_uniform_pad_cycles_evenly_instead_of_resampling():
    """m > |members|: duplicates are evenly-cycled shuffles — no member
    appears k+2 times before every member appears k+1 times (the old pad
    resampled WITH replacement and could triple a member while others
    appeared once)."""
    members = np.arange(10, 16)
    for seed in range(20):
        rng = np.random.default_rng(seed)
        sel = sampling.uniform_sampler(rng, members, 9, 0)
        ids, counts = np.unique(sel, return_counts=True)
        assert len(sel) == 9
        assert set(ids) == set(members)          # every member at least once
        assert counts.max() <= 2
    sel = sampling.uniform_sampler(np.random.default_rng(0), members, 12, 0)
    ids, counts = np.unique(sel, return_counts=True)
    assert (counts == 2).all()                   # m = 2n: exactly twice each


def test_make_sampler_rejects_unknown():
    with pytest.raises(ValueError):
        sampling.make_sampler("stratified")


# ------------------------------------------------------- holdout + driver
@given(st.integers(4, 60), st.floats(0.0, 0.5), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_holdout_clients_partition(n, frac, seed):
    rng = np.random.default_rng(seed)
    train, held = partition.holdout_clients(rng, n, frac)
    assert len(train) + len(held) == n
    assert len(held) == int(round(n * frac))
    assert not set(train) & set(held)
    assert set(train) | set(held) == set(range(n))


def test_run_federated_training_with_engine_options(fl_data):
    """Driver end-to-end: holdout + weighted sampling + fedadam server."""
    series = synthetic.generate_buildings("CA", list(range(4)), days=12)
    flcfg = FLConfig(n_clients=4, clients_per_round=2, rounds=2,
                     n_clusters=0, batch_size=16, lr=0.05,
                     server_opt="fedadam", server_lr=0.05,
                     sampling="weighted", holdout_frac=0.25)
    out = fedavg.run_federated_training(series, FCFG, flcfg)
    res = out[-1]
    assert res.loss_history.shape == (2,)
    assert np.isfinite(res.loss_history).all()
    assert res.heldout_clients is not None and len(res.heldout_clients) == 1
    m = fedavg.evaluate_unseen_clients(res.params,
                                       series[res.heldout_clients], FCFG)
    assert 0.0 <= m["accuracy"] <= 100.0
    assert np.isfinite(m["rmse"])


def test_cluster_assignments_full_length_under_holdout():
    """With clustering + holdout, assignments index ALL clients (-1 = held)."""
    series = synthetic.generate_buildings("CA", list(range(6)), days=12)
    flcfg = FLConfig(n_clients=6, clients_per_round=2, rounds=1,
                     n_clusters=2, batch_size=16, cluster_days=6,
                     holdout_frac=0.34)
    out = fedavg.run_federated_training(series, FCFG, flcfg)
    res = next(iter(out.values()))
    assert res.cluster_assignments.shape == (6,)
    held = res.heldout_clients
    assert len(held) == 2
    assert (res.cluster_assignments[held] == -1).all()
    trained = np.setdiff1d(np.arange(6), held)
    assert (res.cluster_assignments[trained] >= 0).all()


def test_run_federated_training_holdout_all_raises():
    series = synthetic.generate_buildings("CA", list(range(4)), days=12)
    flcfg = FLConfig(n_clients=4, clients_per_round=4, rounds=1,
                     n_clusters=0, holdout_frac=1.0)
    with pytest.raises(ValueError):
        fedavg.run_federated_training(series, FCFG, flcfg)
