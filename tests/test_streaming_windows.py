"""Streaming ClientWindowProvider (ISSUE 2 tentpole) + satellite regressions:
provider/materialized bit-equivalence (vmap AND shard_map), ragged
count-masking, mesh pad-up, round_robin seeding, rng decorrelation, and
jnp/np MAPE-epsilon parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg, losses, sampling
from repro.data import partition, synthetic, windows
from repro.data.windows import ClientWindowProvider
from repro.models import forecaster

FCFG = ForecasterConfig(cell="lstm", hidden_dim=8)
LOSS = losses.make_loss("mse")


@pytest.fixture(scope="module")
def equal_series():
    return synthetic.generate_buildings("CA", list(range(6)), days=16)


@pytest.fixture(scope="module")
def ragged_series():
    lens = [16, 11, 14, 16, 9, 12]
    return [synthetic.generate_buildings("CA", [i], days=d)[0]
            for i, d in enumerate(lens)]


# ------------------------------------------- provider == materialized
def test_round_batch_bit_identical_to_materialized(equal_series):
    prov = ClientWindowProvider.from_series(equal_series, FCFG.lookback,
                                            FCFG.horizon)
    data = windows.batched_client_windows(equal_series, FCFG.lookback,
                                          FCFG.horizon)
    ids = [4, 0, 2]
    x, y, counts = prov.round_batch(ids)
    np.testing.assert_array_equal(x, data["x_train"][ids])
    np.testing.assert_array_equal(y, data["y_train"][ids])
    np.testing.assert_array_equal(counts, [data["x_train"].shape[1]] * 3)
    xt, yt, _, (lo, hi) = prov.test_batch(ids)
    np.testing.assert_array_equal(xt, data["x_test"][ids])
    np.testing.assert_array_equal(yt, data["y_test"][ids])
    np.testing.assert_array_equal(lo, data["stats"][0][ids])
    np.testing.assert_array_equal(hi, data["stats"][1][ids])


def test_synthetic_provider_matches_in_memory(equal_series):
    """On-demand generator variant == wrapping the pre-generated array."""
    p_mem = ClientWindowProvider.from_series(equal_series, FCFG.lookback,
                                             FCFG.horizon)
    p_gen = ClientWindowProvider.from_synthetic("CA", range(6), FCFG.lookback,
                                                FCFG.horizon, days=16)
    x1, y1, c1 = p_mem.round_batch([5, 1])
    x2, y2, c2 = p_gen.round_batch([5, 1])
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    np.testing.assert_array_equal(c1, c2)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=3, deadline=None)
def test_streamed_training_bit_identical_vmap(seed):
    """Provider-fed engine round == materialized-tensor round (vmap path)."""
    series = synthetic.generate_buildings("CA", list(range(6)), days=16)
    data = windows.batched_client_windows(series, FCFG.lookback, FCFG.horizon)
    prov = ClientWindowProvider.from_series(series, FCFG.lookback,
                                            FCFG.horizon)
    rng = np.random.default_rng(seed)
    sel = rng.choice(6, size=4, replace=False)
    n_win = data["x_train"].shape[1]
    bidx = rng.integers(0, n_win, size=(4, 3, 16))
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), FCFG)
    lr, mu = jnp.float32(0.05), jnp.float32(0.0)
    w = jnp.full((4,), float(n_win), jnp.float32)
    x, y, _ = prov.round_batch(sel)
    p_s, l_s = fedavg.engine_round(params, jnp.asarray(x), jnp.asarray(y),
                                   jnp.asarray(bidx), w, lr, mu, FCFG, LOSS)
    p_m, l_m = fedavg.engine_round(params, jnp.asarray(data["x_train"][sel]),
                                   jnp.asarray(data["y_train"][sel]),
                                   jnp.asarray(bidx), w, lr, mu, FCFG, LOSS)
    assert float(l_s) == float(l_m)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), p_s, p_m)


def test_streamed_training_bit_identical_shard_map(equal_series):
    """Provider-fed round == materialized round through shard_map too."""
    data = windows.batched_client_windows(equal_series, FCFG.lookback,
                                          FCFG.horizon)
    prov = ClientWindowProvider.from_series(equal_series, FCFG.lookback,
                                            FCFG.horizon)
    n_dev = min(2, len(jax.devices()))
    mesh = jax.make_mesh((n_dev,), ("clients",))
    round_fn = fedavg.make_sharded_engine_round(mesh, FCFG, LOSS)
    sel = np.asarray([0, 3, 1, 5])
    n_win = data["x_train"].shape[1]
    bidx = np.random.default_rng(0).integers(0, n_win, size=(4, 3, 16))
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), FCFG)
    w = jnp.full((4,), float(n_win), jnp.float32)
    x, y, _ = prov.round_batch(sel)
    args = (jnp.asarray(bidx), w, jnp.float32(0.05), jnp.float32(0.0))
    p_s, l_s = round_fn(params, jnp.asarray(x), jnp.asarray(y), *args)
    p_m, l_m = round_fn(params, jnp.asarray(data["x_train"][sel]),
                        jnp.asarray(data["y_train"][sel]), *args)
    assert float(l_s) == float(l_m)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), p_s, p_m)


def test_driver_array_and_provider_agree(equal_series):
    """run_federated_training(ndarray) == run_federated_training(provider)."""
    flcfg = FLConfig(n_clients=6, clients_per_round=3, rounds=2, n_clusters=2,
                     batch_size=16, cluster_days=8, lr=0.05)
    prov = ClientWindowProvider.from_synthetic("CA", range(6), FCFG.lookback,
                                               FCFG.horizon, days=16)
    out_a = fedavg.run_federated_training(equal_series, FCFG, flcfg)
    out_p = fedavg.run_federated_training(prov, FCFG, flcfg)
    assert set(out_a) == set(out_p)
    for cid in out_a:
        np.testing.assert_array_equal(out_a[cid].loss_history,
                                      out_p[cid].loss_history)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     out_a[cid].params, out_p[cid].params)


# ------------------------------------------------------- ragged histories
def test_ragged_counts_and_masking(ragged_series):
    prov = ClientWindowProvider.from_series(ragged_series, FCFG.lookback,
                                            FCFG.horizon)
    assert prov.train_counts.max() == prov.n_win_max
    assert len(set(prov.train_counts.tolist())) > 1
    x, y, counts = prov.round_batch([1, 4, 0])
    assert x.shape == (3, prov.n_win_max, FCFG.lookback, 1)
    for j, c in enumerate(counts.astype(int)):
        assert (x[j, c:] == 0).all() and (y[j, c:] == 0).all()
        assert (x[j, :c] != 0).any()


def test_ragged_minibatch_indices_respect_counts():
    rng = np.random.default_rng(0)
    counts = np.asarray([50, 7, 23])
    bidx = partition.ragged_minibatch_indices(rng, counts, 6, 32)
    assert bidx.shape == (3, 6, 32)
    for j, c in enumerate(counts):
        assert bidx[j].min() >= 0 and bidx[j].max() < c


def test_equal_count_indices_match_legacy_stream():
    """The fast path must reproduce the historical rng.integers draw."""
    a = partition.ragged_minibatch_indices(np.random.default_rng(3),
                                           np.full(4, 99), 5, 8)
    b = np.random.default_rng(3).integers(0, 99, size=(4, 5, 8))
    np.testing.assert_array_equal(a, b)


def test_ragged_training_and_streamed_eval(ragged_series):
    flcfg = FLConfig(n_clients=6, clients_per_round=4, rounds=2, n_clusters=0,
                     batch_size=16, lr=0.05, server_opt="fedavg_weighted",
                     sampling="weighted")
    prov = ClientWindowProvider.from_series(ragged_series, FCFG.lookback,
                                            FCFG.horizon)
    out = fedavg.run_federated_training(prov, FCFG, flcfg)[-1]
    assert np.isfinite(out.loss_history).all()
    m = fedavg.evaluate_unseen_clients(out.params, prov, FCFG, ids=[1, 4])
    assert 0.0 <= m["accuracy"] <= 100.0 and np.isfinite(m["rmse"])


def test_provider_rejects_too_short_history():
    with pytest.raises(ValueError):
        ClientWindowProvider.from_series(np.ones((2, 30), np.float32), 8, 4)


# ------------------------------------------------------- streamed eval parity
def test_streamed_eval_matches_materialized(equal_series):
    params = forecaster.init_forecaster(jax.random.PRNGKey(1), FCFG)
    data = windows.batched_client_windows(equal_series, FCFG.lookback,
                                          FCFG.horizon)
    x, y, stats = windows.flatten_test_windows(data)
    m_mat = fedavg.evaluate_global(params, x, y, FCFG, stats=stats)
    m_str = fedavg.evaluate_unseen_clients(params, equal_series, FCFG,
                                           clients_per_chunk=2)
    for k in ("rmse", "mape", "accuracy"):
        np.testing.assert_allclose(m_str[k], m_mat[k], rtol=1e-6)
    np.testing.assert_allclose(m_str["per_horizon_accuracy"],
                               m_mat["per_horizon_accuracy"], rtol=1e-6)


def test_mape_eps_parity_jnp_np(equal_series):
    """losses.mape (jnp) and evaluate_global (np) share ONE epsilon."""
    params = forecaster.init_forecaster(jax.random.PRNGKey(2), FCFG)
    data = windows.batched_client_windows(equal_series, FCFG.lookback,
                                          FCFG.horizon)
    x, y, _ = windows.flatten_test_windows(data)
    m = fedavg.evaluate_global(params, x, y, FCFG)    # normalized space
    pred = np.asarray(fedavg._predict(params, jnp.asarray(x), FCFG))
    np.testing.assert_allclose(m["mape"], float(losses.mape(pred, y)),
                               rtol=1e-5)
    np.testing.assert_allclose(m["accuracy"],
                               float(losses.accuracy(pred, y)), rtol=1e-5)


# ------------------------------------------------------- mesh pad-up fix
def test_mesh_pads_selection_up_not_down(equal_series):
    """10 configured clients on an 8-device mesh must train 10, not 8."""
    series = synthetic.generate_buildings("CA", list(range(12)), days=14)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("clients",))
    flcfg = FLConfig(n_clients=12, clients_per_round=10, rounds=2,
                     n_clusters=0, batch_size=16, lr=0.05)
    out_m = fedavg.run_federated_training(series, FCFG, flcfg, mesh=mesh)[-1]
    out_v = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    # pad clients carry weight 0, so the padded mesh round == the exact
    # 10-client vmap round (up to psum reduction order)
    np.testing.assert_allclose(out_m.loss_history, out_v.loss_history,
                               rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=2e-4,
                                                         atol=1e-6),
                 out_m.params, out_v.params)


def test_uniform_step_masks_zero_weight_pads(equal_series):
    """weights==0 rows are excluded even under uniform aggregation."""
    data = windows.batched_client_windows(equal_series, FCFG.lookback,
                                          FCFG.horizon)
    x = jnp.asarray(data["x_train"][[0, 1, 0, 0]])   # rows 2,3 = pads
    y = jnp.asarray(data["y_train"][[0, 1, 0, 0]])
    bidx = jnp.asarray(np.random.default_rng(0)
                       .integers(0, x.shape[1], size=(4, 3, 16)))
    flcfg = FLConfig(n_clients=4, clients_per_round=4, rounds=1,
                     n_clusters=0, lr=0.05, server_opt="fedavg")
    eng = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS)
    params, state = eng.init(jax.random.PRNGKey(0))
    w_pad = np.asarray([9.0, 9.0, 0.0, 0.0], np.float32)
    p_pad, _, l_pad = eng.step(params, state, x, y, bidx, w_pad)
    p_ref, _, l_ref = eng.step(params, state, x[:2], y[:2], bidx[:2],
                               np.asarray([9.0, 9.0], np.float32))
    np.testing.assert_allclose(float(l_pad), float(l_ref), rtol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5,
                                                         atol=1e-7),
                 p_pad, p_ref)


# ------------------------------------------------------- sampler + rng fixes
def test_round_robin_schedule_follows_config_seed():
    members = np.arange(30)
    rng = np.random.default_rng(0)
    s0 = sampling.make_sampler("round_robin", seed=0)
    s0b = sampling.make_sampler("round_robin", seed=0)
    s7 = sampling.make_sampler("round_robin", seed=7)
    np.testing.assert_array_equal(s0(rng, members, 5, 2),
                                  s0b(rng, members, 5, 2))
    assert not np.array_equal(s0(rng, members, 5, 2), s7(rng, members, 5, 2))
    # the per-round rng must NOT perturb the schedule
    np.testing.assert_array_equal(
        s0(np.random.default_rng(1), members, 5, 2),
        s0(np.random.default_rng(99), members, 5, 2))


def test_round_robin_exactly_m_when_oversubscribed():
    members = np.arange(4) + 50
    sel = sampling.round_robin_sampler(np.random.default_rng(0), members,
                                       10, 0, seed=3)
    assert len(sel) == 10 and set(sel) == set(members)


def test_holdout_rng_decorrelated_from_round_rng():
    hold, rnd = fedavg._seed_rngs(0)
    assert not np.array_equal(hold.permutation(64), rnd.permutation(64))
    # deterministic per seed
    h2, r2 = fedavg._seed_rngs(0)
    np.testing.assert_array_equal(fedavg._seed_rngs(0)[0].permutation(16),
                                  h2.permutation(16))
    assert not np.array_equal(h2.integers(0, 1 << 30, 8),
                              fedavg._seed_rngs(1)[0].integers(0, 1 << 30, 8))


def test_holdout_split_deterministic_through_driver(equal_series):
    flcfg = FLConfig(n_clients=6, clients_per_round=2, rounds=1, n_clusters=0,
                     batch_size=16, holdout_frac=0.34)
    a = fedavg.run_federated_training(equal_series, FCFG, flcfg)[-1]
    b = fedavg.run_federated_training(equal_series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(a.heldout_clients, b.heldout_clients)
    assert len(a.heldout_clients) == 2


# ------------------------------------------------------- clustering summary
def test_daily_summary_matches_daily_average_vector(equal_series):
    prov = ClientWindowProvider.from_series(equal_series, FCFG.lookback,
                                            FCFG.horizon)
    z_prov = prov.daily_summary(np.arange(6), days=10)
    z_mat = windows.daily_average_vector(equal_series, days=10)
    np.testing.assert_allclose(z_prov, z_mat, rtol=1e-6)


def test_daily_summary_pads_short_clients_train_period_only(ragged_series):
    """Short clients contribute only TRAIN days to z_k — the chronological
    test split must never inform cluster assignment."""
    prov = ClientWindowProvider.from_series(ragged_series, FCFG.lookback,
                                            FCFG.horizon)
    z = prov.daily_summary(np.arange(6), days=14)
    assert z.shape == (6, 14)
    assert np.isfinite(z).all()
    # client 4: 9-day history -> train cut = 6.75 days -> 6 whole train days
    d = int(prov._cuts[4]) // synthetic.STEPS_PER_DAY
    assert d == 6
    raw = np.asarray(ragged_series[4])
    np.testing.assert_allclose(
        z[4, :d], raw[:d * 96].reshape(d, 96).mean(-1), rtol=1e-6)
    np.testing.assert_allclose(z[4, d:], z[4, :d].mean(), rtol=1e-6)


def test_daily_summary_sub_day_train_period_is_finite():
    """A client whose train cut is < 1 day must yield a flat finite summary,
    not a NaN row that would poison k-means."""
    r = np.random.default_rng(0)
    series = [np.abs(r.normal(size=96)).astype(np.float32) + 1.0,   # cut = 72
              np.abs(r.normal(size=400)).astype(np.float32) + 1.0]
    prov = ClientWindowProvider.from_series(series, 8, 4)
    z = prov.daily_summary([0, 1], days=3)
    assert np.isfinite(z).all()
    np.testing.assert_allclose(z[0], series[0][:72].mean(), rtol=1e-6)


def test_evaluate_empty_ids_raises(equal_series):
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), FCFG)
    with pytest.raises(ValueError):
        fedavg.evaluate_unseen_clients(params, equal_series, FCFG, ids=[])


def test_driver_provider_caches_all_in_memory_clients(equal_series):
    """Array inputs get a full-population cache: full-participation rounds
    must not re-window every client every round through a tiny LRU."""
    prov = fedavg._as_provider(equal_series, FCFG)
    assert prov._cache_size == len(equal_series)
