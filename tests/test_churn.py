"""Client churn & dropout fault tolerance (ISSUE 6 tentpole): replayable
failure injection in the event clock, timeout-driven re-dispatch, dropout-
robust secure aggregation (cohort re-key), and bit-identical
checkpoint/resume of a killed run."""
import jax
import numpy as np
import pytest

from repro.configs.base import ChurnConfig, FLConfig, ForecasterConfig, \
    LatencyConfig
from repro.core import async_engine, fedavg, latency
from repro.data import synthetic

FCFG = ForecasterConfig(cell="lstm", hidden_dim=8)

# same golden workload as tests/test_async_engine.py (vmap-path pin,
# re-captured for the fold_in engine-init key — see tests/test_pipeline_api.py)
GOLDEN = [0.12595632672309875, 0.055874377489089966, 0.04063640534877777]


def _workload(**kw):
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    base = dict(n_clients=6, clients_per_round=4, rounds=3, n_clusters=0,
                batch_size=16, lr=0.05, loss="ew_mse", seed=0)
    base.update(kw)
    return series, FLConfig(**base)


def _spy_engines(monkeypatch):
    """Capture every RoundEngine run_federated_training builds, so tests can
    read the final SemiSyncState counters."""
    engines = []
    real = fedavg.RoundEngine

    class Spy(real):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            engines.append(self)

    monkeypatch.setattr(fedavg, "RoundEngine", Spy)
    return engines


# ------------------------------------------------- failure-injection draws
def test_straggler_draw_follows_slot_value_not_position():
    """The straggler multiplier is seeded by the slot VALUE, so permuting
    the dispatch ordering permutes the finish times with it (it used to be
    positional: slot 0 always got the round's first draw)."""
    lm = latency.LatencyModel(
        LatencyConfig(distribution="lognormal", jitter=1.0), seed=0,
        payload=4000.0)
    win = np.asarray([10.0, 10.0, 10.0])      # equal work isolates the draw
    slots = np.asarray([2, 5, 9])
    t = lm.times(1, win, epochs=1, slots=slots)
    perm = np.asarray([1, 2, 0])
    np.testing.assert_array_equal(
        t[perm], lm.times(1, win[perm], epochs=1, slots=slots[perm]))
    # and distinct slot values get decorrelated draws
    assert len(np.unique(t)) == len(t)


def test_dropout_draws_replayable_and_slot_keyed():
    lm = latency.LatencyModel(LatencyConfig(), seed=3, payload=4000.0,
                              churn=ChurnConfig(dropout_prob=0.5))
    slots = np.arange(32)
    d = lm.dropouts(2, slots)
    np.testing.assert_array_equal(d, lm.dropouts(2, slots))   # replayable
    assert d.any() and not d.all()
    assert np.any(lm.dropouts(3, slots) != d)                 # fresh / round
    assert np.any(lm.dropouts(2, slots, attempt=1) != d)      # fresh / retry
    perm = np.random.default_rng(0).permutation(32)
    np.testing.assert_array_equal(lm.dropouts(2, slots[perm]), d[perm])


def test_absence_draws_replayable_and_off_by_default():
    churn = ChurnConfig(absent_prob=0.4)
    lm = latency.LatencyModel(LatencyConfig(), seed=5, payload=1.0,
                              churn=churn)
    ids = np.arange(20)
    a = lm.available(3, ids)
    np.testing.assert_array_equal(a, lm.available(3, ids))
    assert a.any() and not a.all()
    assert np.any(lm.available(4, ids) != a)
    # the default ChurnConfig injects nothing
    off = latency.LatencyModel(LatencyConfig(), seed=5, payload=1.0)
    assert not off.churn.faulty
    assert off.available(3, ids).all()
    assert not off.dropouts(3, ids).any()


def test_churn_config_facade_and_validation():
    flcfg = FLConfig(n_clients=4, clients_per_round=2, rounds=1,
                     mode="semi_sync", dropout_prob=0.3, absent_prob=0.1,
                     timeout_rounds=3, max_retries=2)
    assert flcfg.churn == ChurnConfig(dropout_prob=0.3, absent_prob=0.1,
                                      timeout_rounds=3, max_retries=2)
    assert flcfg.churn.faulty
    with pytest.raises(ValueError):      # sync rounds block on every upload
        FLConfig(n_clients=4, clients_per_round=2, rounds=1,
                 dropout_prob=0.3)


# --------------------------------------------------- engine under dropout
CHURN = dict(mode="semi_sync", over_select=1.5, staleness_alpha=0.5,
             stragglers="lognormal", straggler_jitter=1.0, rounds=6,
             dropout_prob=0.3, timeout_rounds=1)


def test_churn_off_semi_sync_stays_bit_identical_to_pr5():
    """dropout_prob = 0 must not perturb the event schedule: the buffer_k=m'
    zero-jitter semi-sync run still reproduces the sync golden pin."""
    series, flcfg = _workload(mode="semi_sync")
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(res.loss_history,
                                  np.asarray(GOLDEN, np.float64))


def test_dropout_run_trains_and_counts_failures(monkeypatch):
    """Injected dropouts surface in the books (abandoned / retried work),
    and the run still reaches a finite loss."""
    engines = _spy_engines(monkeypatch)
    series, flcfg = _workload(**CHURN, buffer_k=4)
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    assert np.isfinite(fedavg.final_loss(res))
    ss = engines[-1].async_state
    assert ss.abandoned > 0 or any(p.retries > 0 for p in ss.pending)
    # replayable: same seed, same schedule, same losses
    res2 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(res.loss_history, res2.loss_history)
    np.testing.assert_array_equal(res.sim_times, res2.sim_times)


def test_masked_equals_clear_under_dropout_rekey(monkeypatch):
    """The PR 5 masked == clear pin survives churn: timeout scheduling and
    re-keying run identically for any cohort-atomic fold, and the re-masked
    survivor uploads cancel over the surviving set — losses match the
    unmasked run to float tolerance on the SAME event schedule, and the
    recovery path is actually exercised (rekeys > 0)."""
    engines = _spy_engines(monkeypatch)
    series, clear_cfg = _workload(**CHURN, cohort_atomic=True)
    _, masked_cfg = _workload(**CHURN, secure_agg=True)
    r_clear = fedavg.run_federated_training(series, FCFG, clear_cfg)[-1]
    r_masked = fedavg.run_federated_training(series, FCFG, masked_cfg)[-1]
    assert engines[-1].async_state.rekeys > 0
    np.testing.assert_array_equal(r_clear.sim_times, r_masked.sim_times)
    fin = np.isfinite(r_clear.loss_history)
    np.testing.assert_array_equal(fin, np.isfinite(r_masked.loss_history))
    np.testing.assert_allclose(r_clear.loss_history[fin],
                               r_masked.loss_history[fin],
                               rtol=1e-4, atol=1e-6)


def test_ring_masked_equals_clear_under_dropout_rekey(monkeypatch):
    """The ISSUE 10 ring pin under churn: with quantize+mask the re-key
    mask correction runs in the integer ring mod 2^b
    (``delta - old + new`` wrapped), so the masked run is BIT-identical —
    not float-close — to the ring-clear run on the same event schedule,
    with the recovery path exercised (rekeys > 0)."""
    engines = _spy_engines(monkeypatch)
    ring = dict(CHURN, quantize_bits=8, dp_clip=1.0)
    series, clear_cfg = _workload(**ring, quantize_ring=True,
                                  cohort_atomic=True)
    _, masked_cfg = _workload(**ring, secure_agg=True)
    r_clear = fedavg.run_federated_training(series, FCFG, clear_cfg)[-1]
    r_masked = fedavg.run_federated_training(series, FCFG, masked_cfg)[-1]
    assert engines[-1].async_state.rekeys > 0
    np.testing.assert_array_equal(r_clear.sim_times, r_masked.sim_times)
    np.testing.assert_array_equal(r_clear.loss_history,
                                  r_masked.loss_history)
    assert np.isfinite(r_clear.loss_history).any()
    jax.tree.map(np.testing.assert_array_equal, r_clear.params,
                 r_masked.params)


def test_membership_churn_excludes_absent_clients():
    series, flcfg = _workload(mode="semi_sync", absent_prob=0.3, rounds=4,
                              stragglers="lognormal", straggler_jitter=1.0,
                              buffer_k=4)
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    assert np.isfinite(fedavg.final_loss(res))
    res2 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(res.loss_history, res2.loss_history)


# ------------------------------------------------ SemiSyncState lifecycle
def test_semi_sync_state_reset_clears_everything():
    ss = async_engine.SemiSyncState()
    ss.pending.append(async_engine.PendingUpdate(
        delta={"w": np.zeros(2)}, weight=1.0, loss=0.1, dispatch_round=0,
        finish_time=1.0, slot=2))
    ss.clock = 5.0
    ss.cohort_sizes[0] = 3
    ss.cohort_w[0] = np.ones(3, np.float32)
    ss.cohort_gen[0] = 2
    ss.late_folds, ss.max_staleness = 1, 2
    ss.empty_flushes, ss.rekeys, ss.abandoned = 3, 4, 5
    ss.reset()
    assert not ss.pending and ss.clock == 0.0
    assert not ss.cohort_sizes and not ss.cohort_w and not ss.cohort_gen
    assert (ss.late_folds, ss.max_staleness, ss.empty_flushes, ss.rekeys,
            ss.abandoned) == (0, 0, 0, 0, 0)


def test_cohort_books_swept_in_plain_semi_sync(monkeypatch):
    """Leak fix: cohort bookkeeping used to grow one entry per round forever
    in plain semi-sync.  After any run, the books hold exactly the dispatch
    rounds some pending update still references."""
    engines = _spy_engines(monkeypatch)
    series, flcfg = _workload(mode="semi_sync", over_select=1.5, buffer_k=4,
                              staleness_alpha=0.5, stragglers="lognormal",
                              straggler_jitter=1.0, rounds=12)
    fedavg.run_federated_training(series, FCFG, flcfg)
    ss = engines[-1].async_state
    assert set(ss.cohort_sizes) == {p.dispatch_round for p in ss.pending}
    assert len(ss.cohort_sizes) <= 12


def test_time_to_target_and_final_loss_skip_nan_flushes():
    """Empty cohort-atomic flushes record nan; neither readout may trip on
    them (nan <= target is False; final_loss anchors at the last FINITE)."""
    res = fedavg.FLResult(
        params=None,
        loss_history=np.asarray([np.nan, 0.5, np.nan, 0.2, np.nan]),
        sim_times=np.asarray([1.0, 2.0, 3.0, 4.0, 5.0]))
    assert fedavg.time_to_target(res, 0.5) == 2.0
    assert fedavg.time_to_target(res, 0.3) == 4.0
    assert np.isnan(fedavg.time_to_target(res, 0.1))
    assert fedavg.final_loss(res) == 0.2


# ------------------------------------------------- checkpoint/resume pins
RESUME = dict(mode="semi_sync", over_select=1.5, staleness_alpha=0.5,
              stragglers="lognormal", straggler_jitter=1.0, rounds=6,
              n_clusters=2, secure_agg=True, server_opt="fedadam",
              server_lr=0.05, dp_clip=1.0, dp_noise=0.5,
              dropout_prob=0.15, timeout_rounds=1)


def test_kill_and_resume_bit_identical(tmp_path):
    """The acceptance pin: a run killed mid-training (mid-cluster, with
    in-flight masked uploads, Adam server state, a live accountant and a
    churned event clock) resumes from its checkpoint and lands bit-identical
    to the uninterrupted run — losses, event times, eps history, params."""
    series, flcfg = _workload(**RESUME)
    full = fedavg.run_federated_training(series, FCFG, flcfg)
    ck = tmp_path / "resume_ck"          # no .npz suffix: save/load normalize
    part = fedavg.run_federated_training(series, FCFG, flcfg,
                                         checkpoint_path=ck,
                                         stop_after_rounds=8)
    assert len(part) < len(full) or any(
        len(part[c].loss_history) < flcfg.rounds for c in part)
    resumed = fedavg.run_federated_training(series, FCFG, flcfg,
                                            checkpoint_path=ck)
    assert sorted(resumed) == sorted(full)
    for cid in full:
        np.testing.assert_array_equal(full[cid].loss_history,
                                      resumed[cid].loss_history)
        np.testing.assert_array_equal(full[cid].sim_times,
                                      resumed[cid].sim_times)
        np.testing.assert_array_equal(full[cid].eps_history,
                                      resumed[cid].eps_history)
        jax.tree.map(np.testing.assert_array_equal, full[cid].params,
                     resumed[cid].params)
        assert full[cid].privacy == resumed[cid].privacy


def test_ring_kill_and_resume_bit_identical(tmp_path):
    """Same acceptance pin with the RING wire on (quantize 8 + masking):
    the checkpoint round-trips the per-cohort ring metadata (cohort base
    weights W0) that the host-side ring decode needs, so the resumed run
    still lands bit-identical through late ring folds and re-keys."""
    series, flcfg = _workload(**dict(RESUME, quantize_bits=8))
    full = fedavg.run_federated_training(series, FCFG, flcfg)
    ck = tmp_path / "ring_ck"
    fedavg.run_federated_training(series, FCFG, flcfg, checkpoint_path=ck,
                                  stop_after_rounds=8)
    resumed = fedavg.run_federated_training(series, FCFG, flcfg,
                                            checkpoint_path=ck)
    assert sorted(resumed) == sorted(full)
    for cid in full:
        np.testing.assert_array_equal(full[cid].loss_history,
                                      resumed[cid].loss_history)
        np.testing.assert_array_equal(full[cid].sim_times,
                                      resumed[cid].sim_times)
        jax.tree.map(np.testing.assert_array_equal, full[cid].params,
                     resumed[cid].params)
        assert full[cid].privacy == resumed[cid].privacy


CENTRAL = dict(mode="semi_sync", over_select=1.5, staleness_alpha=0.5,
               stragglers="lognormal", straggler_jitter=1.0, rounds=6,
               n_clusters=2, secure_agg=True, quantize_bits=8,
               dp_clip=1.0, dp_noise=0.5, dropout_prob=0.3,
               timeout_rounds=1)


def test_central_accounting_shrinks_under_rekey_and_resumes(tmp_path):
    """A Bonawitz re-key folds a survivor-only sum, so the central
    accountant (ring masking + uniform aggregation) re-prices the whole
    run at z*sqrt(min survivors): epsilon is never smaller than the
    churn-free run's and strictly larger wherever a re-key shrank the
    cohort.  The shrunk cohort is run history (not derivable from the
    configs), so kill/resume must restore it per cluster — including
    already-finished clusters — for bit-identical privacy reports."""
    series, flcfg = _workload(**CENTRAL)
    full = fedavg.run_federated_training(series, FCFG, flcfg)
    _, clean_cfg = _workload(**dict(CENTRAL, dropout_prob=0.0))
    clean = fedavg.run_federated_training(series, FCFG, clean_cfg)
    for cid in full:
        assert full[cid].privacy["mode"] == "central:secure-agg"
        assert full[cid].privacy["cohort"] <= clean[cid].privacy["cohort"]
        assert (full[cid].privacy["epsilon"]
                >= clean[cid].privacy["epsilon"] - 1e-12)
    assert any(full[cid].privacy["cohort"] < clean[cid].privacy["cohort"]
               for cid in full)                 # a re-key really shrank one
    assert any(full[cid].privacy["epsilon"] > clean[cid].privacy["epsilon"]
               for cid in full)
    ck = tmp_path / "central_ck"
    fedavg.run_federated_training(series, FCFG, flcfg, checkpoint_path=ck,
                                  stop_after_rounds=8)
    resumed = fedavg.run_federated_training(series, FCFG, flcfg,
                                            checkpoint_path=ck)
    assert sorted(resumed) == sorted(full)
    for cid in full:
        np.testing.assert_array_equal(full[cid].eps_history,
                                      resumed[cid].eps_history)
        assert full[cid].privacy == resumed[cid].privacy


def test_resume_rejects_config_mismatch(tmp_path):
    series, flcfg = _workload(mode="semi_sync", rounds=2)
    ck = tmp_path / "ck"
    fedavg.run_federated_training(series, FCFG, flcfg, checkpoint_path=ck,
                                  stop_after_rounds=1)
    _, other = _workload(mode="semi_sync", rounds=2, lr=0.01)
    with pytest.raises(ValueError, match="different"):
        fedavg.run_federated_training(series, FCFG, other,
                                      checkpoint_path=ck)
