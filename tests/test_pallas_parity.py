"""Tier-1 parity smoke for the fused Pallas recurrent cells on the federated
round path (ROADMAP "Pallas client kernel", first wiring step).

``local_update`` differentiates through the forecaster, and ``pallas_call``
has no autodiff rule — ``kernels/ops.py`` closes the gap with a
``custom_vjp`` (fused forward, reference-VJP backward), which is what these
tests pin: one full client local-update step with ``cell_impl="pallas"``
(interpret mode on CPU) must match the pure-jnp oracle path.  Skips cleanly
where Pallas is unavailable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("jax.experimental.pallas",
                    reason="Pallas not available in this jax build")

from repro.configs.base import ForecasterConfig
from repro.core import losses
from repro.core.client import local_update
from repro.models import forecaster

LOSS = losses.make_loss("mse")


def _data(rng, n_win=12, lookback=8, horizon=4):
    x = jnp.asarray(rng.random((n_win, lookback, 1)), jnp.float32)
    y = jnp.asarray(rng.random((n_win, horizon)), jnp.float32)
    bidx = jnp.asarray(rng.integers(0, n_win, (2, 8)))
    return x, y, bidx


@pytest.mark.parametrize("cell", ["lstm", "gru"])
def test_local_update_pallas_matches_jnp(cell):
    """One ClientUpdate (2 SGD steps) through the fused cell == jnp oracle."""
    fcfg = ForecasterConfig(cell=cell, hidden_dim=8)
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), fcfg)
    x, y, bidx = _data(np.random.default_rng(0))
    p_jnp, l_jnp = local_update(params, x, y, bidx, 0.05, fcfg, LOSS, "jnp")
    p_pal, l_pal = local_update(params, x, y, bidx, 0.05, fcfg, LOSS,
                                "pallas")
    np.testing.assert_allclose(float(l_jnp), float(l_pal), rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4,
                                                         atol=1e-5),
                 p_jnp, p_pal)


def test_forecast_pallas_matches_jnp():
    """Inference path parity (no grad): fused forward == jnp forward."""
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=8)
    params = forecaster.init_forecaster(jax.random.PRNGKey(1), fcfg)
    x, _, _ = _data(np.random.default_rng(1))
    f_jnp = forecaster.forecast(params, x, fcfg, "jnp")
    f_pal = forecaster.forecast(params, x, fcfg, "pallas")
    np.testing.assert_allclose(np.asarray(f_jnp), np.asarray(f_pal),
                               rtol=1e-5, atol=1e-6)
