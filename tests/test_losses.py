"""Loss-function properties (paper §3.3) — hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import losses

F = st.floats(-10, 10, allow_nan=False, width=32)


@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_ewmse_beta1_is_mse(h, n, seed):
    r = np.random.default_rng(seed)
    p = jnp.asarray(r.normal(size=(n, h)), jnp.float32)
    y = jnp.asarray(r.normal(size=(n, h)), jnp.float32)
    np.testing.assert_allclose(losses.ew_mse(p, y, beta=1.0),
                               losses.mse(p, y), rtol=1e-6)


@given(st.floats(1.0, 4.0), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_ewmse_weights_later_errors_more(beta, h, seed):
    """An error at the last horizon step costs >= the same error at step 0."""
    r = np.random.default_rng(seed)
    y = jnp.asarray(r.normal(size=(4, h)), jnp.float32)
    e = jnp.zeros((4, h)).at[:, 0].set(1.0)
    l_first = losses.ew_mse(y + e, y, beta)
    e = jnp.zeros((4, h)).at[:, -1].set(1.0)
    l_last = losses.ew_mse(y + e, y, beta)
    assert float(l_last) >= float(l_first) - 1e-6


def test_ewmse_matches_paper_formula():
    """EW-MSE = (1/N) Σ β^{i-1} (y_i - ŷ_i)² — checked against a loop."""
    r = np.random.default_rng(1)
    p, y = r.normal(size=(3, 4)), r.normal(size=(3, 4))
    beta = 2.0
    want = np.mean([[beta ** i * (p[b, i] - y[b, i]) ** 2 for i in range(4)]
                    for b in range(3)])
    got = float(losses.ew_mse(jnp.asarray(p, jnp.float32),
                              jnp.asarray(y, jnp.float32), beta))
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(st.integers(1, 6), st.integers(2, 32), st.integers(4, 40),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_weighted_ce_beta1_is_plain_ce(b, s, v, seed):
    r = np.random.default_rng(seed)
    logits = jnp.asarray(r.normal(size=(b, s, v)), jnp.float32)
    labels = jnp.asarray(r.integers(0, v, size=(b, s)), jnp.int32)
    got = losses.weighted_ce(logits, labels, beta=1.0)
    logp = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(st.sampled_from([1, 2, 4]), st.floats(1.0, 3.0),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_chunked_ce_matches_unchunked(nc, beta, seed):
    r = np.random.default_rng(seed)
    B, S, d, V = 2, 8 * nc, 16, 24
    h = jnp.asarray(r.normal(size=(B, S, d)), jnp.float32)
    w = jnp.asarray(r.normal(size=(d, V)) * 0.1, jnp.float32)
    labels = jnp.asarray(r.integers(0, V, size=(B, S)), jnp.int32)
    mask = jnp.asarray(r.integers(0, 2, size=(B, S)), bool)
    want = losses.weighted_ce(h @ w, labels, beta, mask)
    got = losses.chunked_weighted_ce(h, w, labels, beta, mask, chunk=S // nc)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_accuracy_is_100_minus_mape():
    r = np.random.default_rng(2)
    y = jnp.asarray(np.abs(r.normal(size=(100, 4))) + 1.0, jnp.float32)
    p = y * 1.1
    acc = float(losses.accuracy(p, y))
    mape = float(losses.mape(p, y))
    np.testing.assert_allclose(acc, 100.0 - mape, rtol=1e-5)
    np.testing.assert_allclose(mape, 10.0, rtol=1e-3)


def test_per_horizon_accuracy_shape():
    y = jnp.ones((50, 4)) * 2.0
    p = y.at[:, 3].mul(1.5)
    ph = losses.per_horizon_accuracy(p, y)
    assert ph.shape == (4,)
    np.testing.assert_allclose(ph[:3], 100.0, atol=1e-4)
    np.testing.assert_allclose(ph[3], 50.0, atol=1e-3)
