"""Docs honesty (ISSUE 5 satellites): the README quickstart snippet is
EXECUTED (extracted from the markdown, not duplicated) so the documented
entrypoint cannot rot, and intra-repo markdown links must resolve.  The CI
docs job runs exactly this file."""
import pathlib
import re

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
README = REPO / "README.md"
# the user-facing docs whose links CI guarantees (ISSUE/PAPERS/SNIPPETS are
# internal working notes and may cite external repo paths)
DOC_FILES = [README, *sorted((REPO / "docs").glob("**/*.md"))]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def extract_python_snippets(path):
    return _FENCE.findall(path.read_text())


def test_readme_exists_with_quickstart_fence():
    assert README.exists(), "README.md is a deliverable (ISSUE 5)"
    assert (REPO / "docs" / "privacy.md").exists()
    assert extract_python_snippets(README), "README lost its quickstart"


def test_readme_quickstart_snippet_runs():
    """Execute the FIRST ```python fence of the README verbatim.  It must
    train end-to-end and surface the privacy subsystem it advertises."""
    snippet = extract_python_snippets(README)[0]
    ns = {}
    exec(compile(snippet, str(README), "exec"), ns)   # noqa: S102
    result = ns["result"]
    assert np.isfinite(result.loss_history).all()
    # the snippet turns on clip + noise + secure aggregation: the
    # accountant must certify a finite epsilon
    assert result.privacy["enabled"]
    assert np.isfinite(result.privacy["epsilon"])
    assert result.privacy["rounds"] == len(result.loss_history)
    assert np.isfinite(result.eps_history).all()


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_intra_repo_markdown_links_resolve(doc):
    """Every relative link in the user-facing docs points at a real file
    (http/mailto/anchors are out of scope)."""
    missing = []
    for target in _LINK.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (doc.parent / rel).resolve().exists():
            missing.append(target)
    assert not missing, f"{doc.name}: broken intra-repo links {missing}"


def test_docs_mention_every_e2e_flag():
    """The README flag table tracks the actual e2e driver argparse: any
    flag added to the driver must be documented (and vice versa is caught
    by the driver rejecting unknown flags)."""
    driver = (REPO / "examples" / "fl_forecasting_e2e.py").read_text()
    flags = set(re.findall(r'add_argument\("(--[\w-]+)"', driver))
    readme = README.read_text()
    undocumented = {f for f in flags if f"`{f}`" not in readme}
    assert not undocumented, (
        f"README flag table is missing {sorted(undocumented)}")


def test_serving_doc_exists_and_readme_lists_the_tier():
    """docs/serving.md is a deliverable (ISSUE 8) and the README layout
    table names the serving package."""
    assert (REPO / "docs" / "serving.md").exists()
    readme = README.read_text()
    assert "src/repro/serving/" in readme
    assert "docs/serving.md" in readme


@pytest.mark.parametrize("driver", [
    REPO / "src" / "repro" / "launch" / "serve.py",
    REPO / "benchmarks" / "bench_serving.py",
], ids=lambda p: p.name)
def test_serving_doc_mentions_every_driver_flag(driver):
    """docs/serving.md flag tables track the serving drivers' argparse —
    same honesty contract the README holds for the e2e driver."""
    flags = set(re.findall(r'add_argument\("(--[\w-]+)"', driver.read_text()))
    doc = (REPO / "docs" / "serving.md").read_text()
    undocumented = {f for f in flags if f"`{f}`" not in doc}
    assert not undocumented, (
        f"docs/serving.md is missing {sorted(undocumented)} "
        f"from {driver.name}")


def test_static_analysis_doc_mentions_every_flcheck_flag():
    """docs/static_analysis.md tracks the flcheck CLI argparse: adding a
    flag to analysis/cli.py without documenting it fails here."""
    cli = (REPO / "src" / "repro" / "analysis" / "cli.py").read_text()
    flags = set(re.findall(r'add_argument\("(--[\w-]+)"', cli))
    doc = (REPO / "docs" / "static_analysis.md").read_text()
    undocumented = {f for f in flags if f"{f}" not in doc}
    assert not undocumented, (
        f"docs/static_analysis.md is missing {sorted(undocumented)}")


def test_static_analysis_doc_catalogs_every_rule():
    """Every FLC rule registered in analysis/rules.py has a row in the
    docs/static_analysis.md catalog, and the README names the current
    catalog range (ISSUE 9: FLC006-FLC009 + cost audit)."""
    rules = (REPO / "src" / "repro" / "analysis" / "rules.py").read_text()
    codes = set(re.findall(r'Rule\("(FLC\d+)"', rules))
    assert codes, "rule registry went empty?"
    doc = (REPO / "docs" / "static_analysis.md").read_text()
    missing = {c for c in codes if f"| {c} |" not in doc}
    assert not missing, (
        f"docs/static_analysis.md rule catalog is missing {sorted(missing)}")
    readme = README.read_text()
    assert "FLC009" in readme, (
        "README should name the full FLC catalog range (FLC001-FLC009)")
    assert "--cost" in readme or "cost audit" in readme, (
        "README should mention the level-3 cost audit gate")
