"""Composable federated pipeline API (ISSUE 3 tentpole): typed stage
configs + FLConfig facade, delta-transform stack (clip / DP noise /
quantize), pluggable aggregators (flat + hierarchical edge->region->cloud),
and the bit-identity regression pin for default-config runs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import (AggregationConfig, FLConfig, ForecasterConfig,
                                SamplingConfig, ServerOptConfig,
                                TransformConfig)
from repro.core import aggregation, fedavg, losses, server_opt, transforms
from repro.data import synthetic

FCFG = ForecasterConfig(cell="lstm", hidden_dim=8)
LOSS = losses.make_loss("mse")


def tree_close(a, b, rtol=1e-5, atol=1e-6):
    jax.tree.map(lambda u, v: np.testing.assert_allclose(u, v, rtol=rtol,
                                                         atol=atol), a, b)


def random_tree(rng, scale=1.0):
    """A params-shaped pytree with leaves of mixed rank."""
    return {"layers": [{"wx": jnp.asarray(rng.normal(size=(3, 8)) * scale,
                                          jnp.float32),
                        "b": jnp.asarray(rng.normal(size=(8,)) * scale,
                                         jnp.float32)}],
            "head": {"w": jnp.asarray(rng.normal(size=(8, 4)) * scale,
                                      jnp.float32)}}


@pytest.fixture(scope="module")
def fl_data():
    series = synthetic.generate_buildings("CA", list(range(4)), days=12)
    from repro.data import windows
    data = windows.batched_client_windows(series, FCFG.lookback, FCFG.horizon)
    x = jnp.asarray(data["x_train"])
    y = jnp.asarray(data["y_train"])
    bidx = jnp.asarray(np.random.default_rng(0)
                       .integers(0, x.shape[1], size=(4, 3, 16)))
    from repro.models import forecaster
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), FCFG)
    return params, x, y, bidx


# ------------------------------------------------------ config facade
def test_facade_builds_typed_stage_views():
    cfg = FLConfig(lr=0.03, local_epochs=2, batch_size=32, loss="mse",
                   prox_mu=0.1, sampling="weighted", seed=7,
                   server_opt="fedadam", server_lr=0.05, dp_clip=1.5,
                   dp_noise=0.5, quantize_bits=8,
                   aggregation="hierarchical", n_regions=2)
    assert cfg.sampling_config == SamplingConfig(strategy="weighted", seed=7)
    assert cfg.client_opt.lr == 0.03 and cfg.client_opt.batch_size == 32
    assert cfg.client_opt.prox_mu == 0.1 and cfg.client_opt.loss == "mse"
    assert cfg.transform == TransformConfig(clip_norm=1.5,
                                            noise_multiplier=0.5,
                                            quantize_bits=8)
    assert cfg.aggregation_config == AggregationConfig(kind="hierarchical",
                                                       n_regions=2)
    assert cfg.server.name == "fedadam" and cfg.server.lr == 0.05


def test_facade_default_transform_is_identity():
    cfg = FLConfig()
    assert cfg.transform.is_identity
    assert cfg.aggregation_config.kind == "flat"


@pytest.mark.parametrize("kw,needle", [
    (dict(server_opt="fedsgdfoo"), "fedavg"),
    (dict(sampling="stratified"), "uniform"),
    (dict(aggregation="ring"), "flat"),
    (dict(loss="mae"), "ew_mse"),
    (dict(dp_clip=-1.0), "clip_norm"),
    (dict(dp_noise=-0.5), "noise_multiplier"),
    (dict(quantize_bits=1), "quantize_bits"),
    (dict(quantize_bits=16), "quantize_bits"),
    (dict(n_regions=-2), "n_regions"),
])
def test_facade_validates_eagerly_with_choices(kw, needle):
    """Typo'd stage names / bad knobs fail AT CONSTRUCTION, naming the
    valid choices — not rounds-deep inside server_update."""
    with pytest.raises(ValueError) as ei:
        FLConfig(**kw)
    assert needle in str(ei.value)


def test_sub_configs_validate_directly():
    with pytest.raises(ValueError):
        ServerOptConfig(name="sgd")
    with pytest.raises(ValueError):
        SamplingConfig(strategy="all")
    with pytest.raises(ValueError):
        AggregationConfig(kind="tree")


# --------------------------------------------------------- transforms
@given(st.floats(0.1, 5.0), st.integers(0, 2 ** 31 - 1),
       st.floats(0.1, 10.0))
@settings(max_examples=8, deadline=None)
def test_clip_bounds_delta_norm(clip, seed, scale):
    """Post-clip global L2 norm <= C for random pytrees; small deltas pass
    through untouched."""
    rng = np.random.default_rng(seed)
    delta = random_tree(rng, scale)
    clipped = transforms.L2Clip(clip)(delta, jax.random.PRNGKey(0))
    assert float(transforms.global_l2_norm(clipped)) <= clip * (1 + 1e-5)
    if float(transforms.global_l2_norm(delta)) <= clip:
        tree_close(clipped, delta)


@given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_quantize_round_trip_error_bound(bits, seed):
    """Dequantized leaves differ from the input by at most one grid step
    ``max|x| / (2^(b-1)-1)`` per coordinate; zero leaves survive exactly."""
    rng = np.random.default_rng(seed)
    delta = random_tree(rng)
    delta["layers"][0]["b"] = jnp.zeros_like(delta["layers"][0]["b"])
    q = transforms.StochasticQuantize(bits)(delta, jax.random.PRNGKey(seed))
    levels = 2 ** (bits - 1) - 1
    for orig, deq in zip(jax.tree.leaves(delta), jax.tree.leaves(q)):
        step = float(jnp.max(jnp.abs(orig))) / levels
        assert float(jnp.max(jnp.abs(deq - orig))) <= step + 1e-6
    np.testing.assert_array_equal(q["layers"][0]["b"], 0.0)


def test_quantize_is_unbiased_in_expectation():
    x = {"w": jnp.full((2000,), 0.3, jnp.float32)}
    q = transforms.StochasticQuantize(8)
    outs = [q(x, jax.random.PRNGKey(i))["w"].mean() for i in range(8)]
    np.testing.assert_allclose(float(jnp.mean(jnp.stack(outs))), 0.3,
                               atol=2e-4)


def test_dp_noise_deterministic_under_fixed_key():
    rng = np.random.default_rng(0)
    delta = random_tree(rng)
    noise = transforms.GaussianNoise(sigma=0.5)
    k = jax.random.PRNGKey(42)
    a, b = noise(delta, k), noise(delta, k)
    jax.tree.map(lambda u, v: np.testing.assert_array_equal(u, v), a, b)
    c = noise(delta, jax.random.PRNGKey(43))
    assert float(jnp.max(jnp.abs(a["head"]["w"] - c["head"]["w"]))) > 0


def test_make_stack_order_and_identity():
    assert transforms.make_stack(TransformConfig()).is_identity
    stack = transforms.make_stack(TransformConfig(
        clip_norm=1.0, noise_multiplier=0.5, quantize_bits=8))
    kinds = [type(t).__name__ for t in stack.transforms]
    assert kinds == ["L2Clip", "GaussianNoise", "StochasticQuantize"]
    # noise sigma honors the clip sensitivity: z * C
    assert stack.transforms[1].sigma == pytest.approx(0.5)


def test_prng_streams_invariant_to_toggling_other_stages():
    """Stage keys fold in a STABLE per-transform tag (ISSUE 4 fix): turning
    clipping on/off must not shift the Gaussian-noise or quantize streams.
    With a delta small enough that the clip is a no-op, stacks with and
    without the clip stage must agree BITWISE."""
    rng = np.random.default_rng(3)
    delta = random_tree(rng, scale=0.01)         # well inside clip_norm
    key = jax.random.PRNGKey(11)
    noop_clip = transforms.L2Clip(1e6)
    for tail in ([transforms.GaussianNoise(0.5)],
                 [transforms.StochasticQuantize(8)],
                 [transforms.GaussianNoise(0.5),
                  transforms.StochasticQuantize(8)]):
        bare = transforms.TransformStack(tuple(tail))(delta, key)
        clipped = transforms.TransformStack((noop_clip, *tail))(delta, key)
        jax.tree.map(np.testing.assert_array_equal, bare, clipped)
    # and via the config path: clip_norm toggled, same facade noise knob
    # (clip sensitivity 1.0 keeps sigma identical across the two stacks)
    s_off = transforms.make_stack(TransformConfig(noise_multiplier=0.5))
    s_on = transforms.make_stack(TransformConfig(clip_norm=1.0,
                                                 noise_multiplier=0.5))
    jax.tree.map(np.testing.assert_array_equal,
                 s_off(delta, key), s_on(delta, key))
    # repeated same-kind stages must still draw INDEPENDENT streams (the
    # per-kind tag is disambiguated by occurrence): two noise stages add
    # two different samples, not the same sample twice
    twice = transforms.TransformStack(
        (transforms.GaussianNoise(0.5), transforms.GaussianNoise(0.5)))
    once = transforms.TransformStack((transforms.GaussianNoise(0.5),))
    doubled = jax.tree.map(lambda d, s: 2 * s - d, delta, once(delta, key))
    got = twice(delta, key)
    assert float(jnp.max(jnp.abs(got["head"]["w"] -
                                 doubled["head"]["w"]))) > 0


def test_engine_dp_noise_replays_under_fixed_seed(fl_data):
    """Same seed + round_idx -> bit-identical noised round; different
    round_idx -> different noise."""
    params, x, y, bidx = fl_data
    flcfg = FLConfig(n_clients=4, clients_per_round=4, lr=0.05, rounds=1,
                     n_clusters=0, loss="mse", dp_clip=1.0, dp_noise=0.5)
    eng = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS)
    counts = np.full(4, float(x.shape[1]), np.float32)
    s0 = server_opt.init_server_state(params)
    p1, _, l1 = eng.step(params, s0, x, y, bidx, counts, round_idx=3)
    p2, _, l2 = eng.step(params, s0, x, y, bidx, counts, round_idx=3)
    jax.tree.map(lambda u, v: np.testing.assert_array_equal(u, v), p1, p2)
    p3, _, _ = eng.step(params, s0, x, y, bidx, counts, round_idx=4)
    assert float(jnp.max(jnp.abs(p1["head"]["w"] - p3["head"]["w"]))) > 0
    # concurrent trainings sharing one seed (per-cluster streams) must NOT
    # reuse noise — otherwise differencing two released aggregates would
    # cancel the DP protection
    p4, _, _ = eng.step(params, s0, x, y, bidx, counts, round_idx=3,
                        stream=1)
    assert float(jnp.max(jnp.abs(p1["head"]["w"] - p4["head"]["w"]))) > 0


# --------------------------------------------------------- aggregation
def test_make_aggregator_local_flat_hier():
    assert isinstance(aggregation.make_aggregator(None, None),
                      aggregation.LocalAggregator)
    mesh = jax.make_mesh((1,), ("clients",))
    assert isinstance(aggregation.make_aggregator("flat", mesh),
                      aggregation.FlatAggregator)
    with pytest.raises(ValueError):          # 1-D mesh can't go hierarchical
        aggregation.make_aggregator("hierarchical", mesh)


def test_make_mesh_shapes():
    n_dev = len(jax.devices())
    flat = aggregation.make_mesh()
    assert tuple(flat.axis_names) == ("clients",)
    hier = aggregation.make_mesh(AggregationConfig(kind="hierarchical"))
    assert tuple(hier.axis_names) == ("region", "clients")
    assert hier.shape["region"] * hier.shape["clients"] == n_dev
    if n_dev == 8:                           # test.sh geometry: 2x4 grid
        assert hier.shape["region"] == 2 and hier.shape["clients"] == 4
    with pytest.raises(ValueError):
        aggregation.make_mesh(AggregationConfig(kind="hierarchical",
                                                n_regions=n_dev + 1))


def test_engine_rejects_hierarchical_without_mesh():
    flcfg = FLConfig(n_clients=4, clients_per_round=4, rounds=1,
                     n_clusters=0, loss="mse", aggregation="hierarchical")
    with pytest.raises(ValueError):
        fedavg.RoundEngine(FCFG, flcfg, loss=LOSS)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
@pytest.mark.parametrize("tcfg", [
    TransformConfig(),
    TransformConfig(clip_norm=0.5),          # linear per-client transform
])
def test_hierarchical_matches_flat_on_2x4_mesh(fl_data, tcfg):
    """Edge->region->cloud psum pair over the 2x4 (region, clients) grid ==
    flat one-psum aggregation, for identity and linear transforms."""
    params, x, y, bidx = fl_data
    flat_mesh = jax.make_mesh((8,), ("clients",))
    hier_mesh = jax.make_mesh((2, 4), ("region", "clients"))
    kw = dict(n_clients=4, clients_per_round=8, rounds=1, n_clusters=0,
              loss="mse", lr=0.05, dp_clip=tcfg.clip_norm)
    e_flat = fedavg.RoundEngine(FCFG, FLConfig(**kw), loss=LOSS,
                                mesh=flat_mesh)
    e_hier = fedavg.RoundEngine(
        FCFG, FLConfig(**kw, aggregation="hierarchical", n_regions=2),
        loss=LOSS, mesh=hier_mesh)
    # 8 slots over 4 clients: cycle + mark the duplicates weight-0, exactly
    # like the driver's mesh-divisibility padding
    idx = np.resize(np.arange(4), 8)
    counts = np.full(8, float(x.shape[1]), np.float32)
    counts[4:] = 0.0
    s0 = server_opt.init_server_state(params)
    args = (params, s0, x[idx], y[idx], bidx[idx], counts)
    p_f, _, l_f = e_flat.step(*args, round_idx=0)
    p_h, _, l_h = e_hier.step(*args, round_idx=0)
    np.testing.assert_allclose(float(l_f), float(l_h), rtol=1e-6)
    tree_close(p_f, p_h, rtol=1e-6, atol=1e-7)


def test_full_pipeline_round_runs_and_is_finite(fl_data):
    """DP clip + noise + int8 quantize + (1-region) hierarchical topology:
    one engine round stays finite and actually changes the params."""
    params, x, y, bidx = fl_data
    n_dev = len(jax.devices())
    r = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    mesh = jax.make_mesh((r, n_dev // r), ("region", "clients"))
    flcfg = FLConfig(n_clients=4, clients_per_round=4, rounds=1,
                     n_clusters=0, loss="mse", lr=0.05, dp_clip=1.0,
                     dp_noise=0.5, quantize_bits=8,
                     aggregation="hierarchical", n_regions=r)
    eng = fedavg.RoundEngine(FCFG, flcfg, loss=LOSS, mesh=mesh)
    m = -(-4 // n_dev) * n_dev
    idx = np.resize(np.arange(4), m)
    counts = np.full(m, float(x.shape[1]), np.float32)
    counts[4:] = 0.0
    s0 = server_opt.init_server_state(params)
    p, _, l = eng.step(params, s0, x[idx], y[idx], bidx[idx], counts,
                       round_idx=0)
    assert np.isfinite(float(l))
    assert all(np.isfinite(w).all() for w in jax.tree.leaves(p))
    assert float(jnp.max(jnp.abs(p["head"]["w"] -
                                 params["head"]["w"]))) > 0


# ------------------------------------------------- bit-identity regression
# Golden loss histories for FLConfig defaults on this exact tiny workload,
# re-pinned when the engine-init key derivation moved from
# PRNGKey(seed + cid) to fold_in(PRNGKey(seed), cid) (flcheck FLC003:
# additive seeds collide across (seed, cid) pairs).  Each execution path
# must reproduce its pin bit-for-bit.  The vmap and shard_map pins differ
# in rounds 1 and 3 by one f32 ulp: the vmap path sums the 4 selected
# clients sequentially while the 8-shard psum reduces in tree order, and
# with these init values the two roundings no longer coincide (they
# happened to, bitwise, for the pre-fold_in values — summation ORDER is
# the only difference, pinned per path below).
GOLDEN = [0.12595632672309875, 0.055874377489089966, 0.04063640534877777]
GOLDEN_SHARD = [0.12595631182193756, 0.055874377489089966,
                0.04063640907406807]
GOLDEN_FEDADAM = [0.1233379915356636, 0.08418796956539154,
                  0.052974801510572433]


def _golden_workload():
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    flcfg = FLConfig(n_clients=6, clients_per_round=4, rounds=3,
                     n_clusters=0, batch_size=16, lr=0.05, loss="ew_mse",
                     seed=0)
    return series, flcfg


def test_default_config_loss_history_bit_identical_vmap():
    series, flcfg = _golden_workload()
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(res.loss_history,
                                  np.asarray(GOLDEN, np.float64))


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
def test_default_config_loss_history_bit_identical_shard_map():
    series, flcfg = _golden_workload()
    mesh = jax.make_mesh((8,), ("clients",))
    res = fedavg.run_federated_training(series, FCFG, flcfg, mesh=mesh)[-1]
    np.testing.assert_array_equal(res.loss_history,
                                  np.asarray(GOLDEN_SHARD, np.float64))


def test_engine_options_loss_history_bit_identical():
    """fedadam + weighted sampling + holdout, legacy flat construction."""
    series, _ = _golden_workload()
    flcfg = FLConfig(n_clients=6, clients_per_round=4, rounds=3,
                     n_clusters=0, batch_size=16, lr=0.05, loss="ew_mse",
                     seed=0, server_opt="fedadam", server_lr=0.05,
                     sampling="weighted", holdout_frac=0.2)
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(res.loss_history,
                                  np.asarray(GOLDEN_FEDADAM, np.float64))


def test_pipeline_round_identity_equals_legacy_engine_round(fl_data):
    """The pipeline round with the identity stack IS the legacy round,
    bitwise — vmap and (1-device) shard_map paths."""
    params, x, y, bidx = fl_data
    w = jnp.full((4,), 7.0, jnp.float32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(jax.random.PRNGKey(0),
                                                   jnp.arange(4))
    lr, mu = jnp.float32(0.05), jnp.float32(0.0)
    p_new, l_new = fedavg.pipeline_round(params, x, y, bidx, w, keys, lr,
                                         mu, FCFG, LOSS, TransformConfig())
    p_old, l_old = fedavg.engine_round(params, x, y, bidx, w, lr, mu,
                                       FCFG, LOSS)
    jax.tree.map(np.testing.assert_array_equal, p_new, p_old)
    np.testing.assert_array_equal(np.asarray(l_new), np.asarray(l_old))

    mesh = jax.make_mesh((1,), ("clients",))
    new_fn = fedavg.make_pipeline_round(mesh, FCFG, LOSS)
    old_fn = fedavg.make_sharded_engine_round(mesh, FCFG, LOSS)
    p_new, l_new = new_fn(params, x, y, bidx, w, keys, lr, mu)
    p_old, l_old = old_fn(params, x, y, bidx, w, lr, mu)
    jax.tree.map(np.testing.assert_array_equal, p_new, p_old)
    np.testing.assert_array_equal(np.asarray(l_new), np.asarray(l_old))


def test_run_federated_training_auto_builds_hierarchical_mesh():
    """aggregation="hierarchical" with mesh=None builds the (region,
    clients) grid itself and trains end-to-end."""
    series = synthetic.generate_buildings("CA", list(range(4)), days=12)
    flcfg = FLConfig(n_clients=4, clients_per_round=4, rounds=2,
                     n_clusters=0, batch_size=16, lr=0.05, loss="mse",
                     dp_clip=1.0, quantize_bits=8,
                     aggregation="hierarchical")
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    assert res.loss_history.shape == (2,)
    assert np.isfinite(res.loss_history).all()
