"""Hypothesis shim: real ``hypothesis`` when installed, otherwise a tiny
deterministic stand-in so property tests still collect and run.

Test modules import ``given / settings / strategies`` from here instead of
from ``hypothesis``.  When hypothesis is available those are simply
re-exported.  When it is not (the CI image does not ship it), the fallback
runs each property test over a fixed number of seeded pseudo-random examples:
every strategy draws from one ``numpy`` generator seeded by the test name, so
failures are reproducible run-to-run, and the first example pins each
strategy to its lower bound (hypothesis-style boundary probing, cheaply).

The fallback honours ``settings(max_examples=...)`` but caps it at
``_MAX_EXAMPLES`` to keep the tier-1 suite fast.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _MAX_EXAMPLES = 4

    class _Strategy:
        def __init__(self, draw, lo=None):
            self._draw = draw
            self._lo = lo                   # boundary value for example 0

        def example_from(self, rng, i):
            if i == 0 and self._lo is not None:
                return self._lo
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                lo=min_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                lo=float(min_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(
                lambda rng: seq[int(rng.integers(len(seq)))], lo=seq[0])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)), lo=False)

    strategies = _Strategies()

    def settings(**kw):
        """Record settings on the test fn; ``given`` reads max_examples."""
        def deco(fn):
            fn._compat_settings = kw
            return fn
        return deco

    def given(*strats, **kwstrats):
        def deco(fn):
            n = min(getattr(fn, "_compat_settings", {})
                    .get("max_examples", _MAX_EXAMPLES), _MAX_EXAMPLES)

            # NOTE: no functools.wraps — pytest must see the zero-arg
            # signature, not the original one (whose params look like
            # fixtures).
            def wrapper():
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    ex = [s.example_from(rng, i) for s in strats]
                    kw = {name: s.example_from(rng, i)
                          for name, s in kwstrats.items()}
                    fn(*ex, **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
