"""Secure aggregation + (eps, delta) accounting (ISSUE 5 tentpole):
pairwise-mask cancellation on every execution path / topology (vmap, flat
psum, hierarchical 2-D mesh, semi-sync cohort-atomic late folds), the
cohort-aware transform-stack plumbing, and the RDP accountant against
independent reference computations."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FLConfig, ForecasterConfig, PrivacyConfig,
                                SecureAggConfig, TransformConfig)
from repro.core import fedavg, losses, privacy, secure_agg, server_opt, \
    transforms
from repro.data import synthetic, windows

FCFG = ForecasterConfig(cell="lstm", hidden_dim=8)
LOSS = losses.make_loss("mse")


def tree_close(a, b, rtol=1e-4, atol=1e-5):
    jax.tree.map(lambda u, v: np.testing.assert_allclose(
        np.asarray(u), np.asarray(v), rtol=rtol, atol=atol), a, b)


def tree_max_abs_diff(a, b):
    return max(float(jnp.max(jnp.abs(u - v)))
               for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def random_deltas(rng, m, scale=1.0):
    """Client-stacked delta tree (leading axis = clients)."""
    return {"wx": jnp.asarray(rng.normal(size=(m, 4, 3)) * scale,
                              jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m, 5)) * scale, jnp.float32)}


def masked_stack(mask_std=4.0):
    return transforms.make_stack(
        TransformConfig(), SecureAggConfig(enabled=True, mask_std=mask_std))


@pytest.fixture(scope="module")
def fl_data():
    series = synthetic.generate_buildings("CA", list(range(4)), days=12)
    data = windows.batched_client_windows(series, FCFG.lookback, FCFG.horizon)
    x = jnp.asarray(data["x_train"])
    y = jnp.asarray(data["y_train"])
    bidx = jnp.asarray(np.random.default_rng(0)
                       .integers(0, x.shape[1], size=(4, 3, 16)))
    from repro.models import forecaster
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), FCFG)
    return params, x, y, bidx


# ----------------------------------------------------------- config facade
def test_secure_and_privacy_facade_views():
    cfg = FLConfig(secure_agg=True, secure_mask_std=2.5, privacy_delta=1e-6)
    assert cfg.secure == SecureAggConfig(enabled=True, mask_std=2.5)
    assert cfg.privacy == PrivacyConfig(delta=1e-6)
    # secure aggregation forces cohort-atomic semi-sync folds
    assert cfg.async_config.cohort_atomic
    assert not FLConfig().async_config.cohort_atomic
    assert FLConfig(cohort_atomic=True).async_config.cohort_atomic


@pytest.mark.parametrize("kw,needle", [
    (dict(secure_mask_std=0.0), "mask_std"),
    (dict(secure_mask_std=-1.0), "mask_std"),
    (dict(privacy_delta=0.0), "delta"),
    (dict(privacy_delta=1.0), "delta"),
])
def test_facade_validates_secure_privacy_knobs(kw, needle):
    with pytest.raises(ValueError) as ei:
        FLConfig(**kw)
    assert needle in str(ei.value)
    with pytest.raises(ValueError):
        PrivacyConfig(orders=(1,))


def test_make_stack_registers_masker_last_with_stable_tag():
    stack = transforms.make_stack(
        TransformConfig(clip_norm=1.0, noise_multiplier=0.5,
                        quantize_bits=8),
        SecureAggConfig(enabled=True, mask_std=2.0))
    kinds = [type(t).__name__ for t in stack.transforms]
    assert kinds == ["L2Clip", "GaussianNoise", "StochasticQuantize",
                     "PairwiseMasker"]
    assert stack.transforms[-1].tag == 3            # stable PRNG stream id
    assert stack.needs_cohort
    assert not transforms.make_stack(TransformConfig()).needs_cohort
    # disabled secure config adds nothing
    assert not transforms.make_stack(
        TransformConfig(), SecureAggConfig()).transforms


def test_cohort_stack_requires_context():
    stack = masked_stack()
    delta = {"w": jnp.ones((3,))}
    with pytest.raises(ValueError, match="cohort"):
        stack(delta, jax.random.PRNGKey(0))


# ------------------------------------------------------- mask cancellation
def test_pairwise_masks_cancel_in_weighted_sum_with_pads():
    """The core secure-agg property: each upload is the client's WEIGHTED
    contribution under a full-strength mask (never a 1/w_i-scaled one —
    upload secrecy must not depend on the weight), pads (w=0) are excluded
    from the mask cohort, and the UNWEIGHTED sum of masked uploads equals
    the clear weighted sum to float tolerance."""
    rng = np.random.default_rng(0)
    m = 6
    deltas = random_deltas(rng, m)
    w = jnp.asarray([3.0, 1.0, 0.0, 7.0, 2.0, 0.0], jnp.float32)  # 2 pads
    keys = jnp.zeros((m, 2), jnp.uint32)
    masked = fedavg.apply_stack(masked_stack(), deltas, keys, w_full=w,
                                round_key=jax.random.PRNGKey(7))
    real, pads = np.asarray([0, 1, 3, 4]), np.asarray([2, 5])
    wcol = np.asarray(w)
    mask_rows = []
    for k in deltas:
        wk = wcol.reshape((-1,) + (1,) * (deltas[k].ndim - 1))
        mask_part = np.asarray(masked[k]) - wk * np.asarray(deltas[k])
        mask_rows.append(mask_part.reshape(m, -1))
        # pads — cycled DUPLICATES of real clients — upload ZERO: they
        # can't join the mask cohort, and sending their delta in the
        # clear would leak the duplicated client's update
        np.testing.assert_array_equal(np.asarray(masked[k])[pads], 0.0)
    # every real upload carries the same full-strength mask scale,
    # REGARDLESS of its weight (w from 1 to 7): with 3 real partners and
    # mask_std = 4 the per-coordinate mask sigma is 4*sqrt(3) for every
    # client — a 1/w_i- (or w_i-) scaled mask would fall far outside
    sigma = 4.0 * math.sqrt(3.0)
    rms = np.sqrt((np.concatenate(mask_rows, axis=1)[real] ** 2).mean(axis=1))
    assert np.all(rms > 0.6 * sigma) and np.all(rms < 1.6 * sigma)
    # uploads are pre-weighted: their UNWEIGHTED sum is the clear weighted
    # numerator (this is what the aggregator divides by sum(w))
    sums_m = jax.tree.map(lambda d: jnp.sum(d, axis=0), masked)
    sums_c, _ = fedavg._weighted_sums(deltas, w)
    tree_close(sums_m, sums_c, rtol=1e-4, atol=1e-4)


def test_pair_masks_are_antisymmetric_and_replayable():
    """mask_ij = -mask_ji (same shared draw, opposite signs) and masks are
    a pure function of the shared round key."""
    masker = secure_agg.PairwiseMasker(mask_std=3.0)
    zero = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((2,))}
    w = jnp.ones((2,), jnp.float32)
    rk = jax.random.PRNGKey(3)
    key = jax.random.PRNGKey(0)                      # unused by the masker
    m0 = masker(zero, key, secure_agg.CohortContext(jnp.int32(0), w, rk))
    m1 = masker(zero, key, secure_agg.CohortContext(jnp.int32(1), w, rk))
    tree_close(m0, jax.tree.map(lambda x: -x, m1), rtol=1e-6, atol=1e-7)
    assert float(jnp.max(jnp.abs(m0["w"]))) > 1.0    # actually masked
    m0b = masker(zero, key, secure_agg.CohortContext(jnp.int32(0), w, rk))
    jax.tree.map(np.testing.assert_array_equal, m0, m0b)
    m0c = masker(zero, key,
                 secure_agg.CohortContext(jnp.int32(0), w,
                                          jax.random.PRNGKey(4)))
    assert float(jnp.max(jnp.abs(m0["w"] - m0c["w"]))) > 0


def test_masking_composes_with_dp_stack_unchanged_streams():
    """Adding the masker must not shift the clip/noise PRNG streams (stable
    per-kind tags): with unit weights, masked minus clear equals the pure
    mask.  (Quantize is exercised separately by the ring battery — with
    quantize on, masking switches the quantizer to the shared ring grid,
    which is a deliberate change of the quantize output, not a stream
    shift.)"""
    rng = np.random.default_rng(1)
    m = 4
    deltas = random_deltas(rng, m, scale=0.01)
    w = jnp.ones((m,), jnp.float32)
    rk = jax.random.PRNGKey(11)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(rk, jnp.arange(m))
    tcfg = TransformConfig(clip_norm=1.0, noise_multiplier=0.5)
    clear = fedavg.apply_stack(transforms.make_stack(tcfg), deltas, keys)
    masked = fedavg.apply_stack(
        transforms.make_stack(tcfg, SecureAggConfig(enabled=True,
                                                    mask_std=2.0)),
        deltas, keys, w_full=w, round_key=rk)
    pure_mask = fedavg.apply_stack(masked_stack(2.0),
                                   jax.tree.map(jnp.zeros_like, deltas),
                                   keys, w_full=w, round_key=rk)
    tree_close(jax.tree.map(lambda a, b: a - b, masked, clear), pure_mask,
               rtol=1e-5, atol=1e-5)


# ------------------------------------------------ engine-level equivalence
def _engines(fl_kw, mesh=None, mask_std=2.0):
    e_clear = fedavg.RoundEngine(FCFG, FLConfig(**fl_kw), loss=LOSS,
                                 mesh=mesh)
    e_mask = fedavg.RoundEngine(
        FCFG, FLConfig(**fl_kw, secure_agg=True, secure_mask_std=mask_std),
        loss=LOSS, mesh=mesh)
    return e_clear, e_mask


def test_masked_round_equals_clear_vmap(fl_data):
    params, x, y, bidx = fl_data
    kw = dict(n_clients=4, clients_per_round=4, rounds=1, n_clusters=0,
              loss="mse", lr=0.05, dp_clip=1.0,
              server_opt="fedavg_weighted")
    e_clear, e_mask = _engines(kw)
    counts = np.full(4, float(x.shape[1]), np.float32)
    s0 = server_opt.init_server_state(params)
    p_c, _, l_c = e_clear.step(params, s0, x, y, bidx, counts, round_idx=0)
    p_m, _, l_m = e_mask.step(params, s0, x, y, bidx, counts, round_idx=0)
    np.testing.assert_allclose(float(l_c), float(l_m), rtol=1e-6)
    tree_close(p_c, p_m, rtol=1e-5, atol=1e-5)
    # the masked round is NOT a no-op relabeling: per-client uploads differ
    rk = e_mask.base_round_key(0, 0)
    keys = e_mask.round_keys(0, 4)
    from repro.core.async_engine import client_deltas
    d_m, _ = client_deltas(params, x, y, bidx, keys, jnp.float32(0.05),
                           jnp.float32(0.0), FCFG, LOSS, e_mask.transform,
                           "jnp", e_mask.secure, rk, jnp.asarray(counts))
    d_c, _ = client_deltas(params, x, y, bidx, keys, jnp.float32(0.05),
                           jnp.float32(0.0), FCFG, LOSS, e_clear.transform)
    # the mask on the WIRE quantity w_i * y_i has scale mask_std (the
    # upload itself carries mask_std / w_i — see core/secure_agg.py)
    wdiff = jax.tree.map(
        lambda a, b: (a - b) * counts.reshape((-1,) + (1,) * (a.ndim - 1)),
        d_m, d_c)
    assert max(float(jnp.abs(l).mean()) for l in jax.tree.leaves(wdiff)) > 0.5


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
@pytest.mark.parametrize("agg_kw,mesh_shape,axes", [
    (dict(), (8,), ("clients",)),
    (dict(aggregation="hierarchical", n_regions=2), (2, 4),
     ("region", "clients")),
])
def test_masked_equals_clear_on_mesh_topologies(fl_data, agg_kw, mesh_shape,
                                                axes):
    """Acceptance pin: masked == clear to float tolerance on BOTH the flat
    one-psum and the hierarchical edge->region->cloud reduction, with
    weight-0 mesh-padding duplicates in the cohort."""
    params, x, y, bidx = fl_data
    mesh = jax.make_mesh(mesh_shape, axes)
    kw = dict(n_clients=4, clients_per_round=8, rounds=1, n_clusters=0,
              loss="mse", lr=0.05, dp_clip=1.0,
              server_opt="fedavg_weighted", **agg_kw)
    e_clear, e_mask = _engines(kw, mesh=mesh)
    idx = np.resize(np.arange(4), 8)
    counts = np.full(8, float(x.shape[1]), np.float32)
    counts[4:] = 0.0                                 # mesh pads
    s0 = server_opt.init_server_state(params)
    args = (params, s0, x[idx], y[idx], bidx[idx], counts)
    p_c, _, l_c = e_clear.step(*args, round_idx=0)
    p_m, _, l_m = e_mask.step(*args, round_idx=0)
    np.testing.assert_allclose(float(l_c), float(l_m), rtol=1e-6)
    tree_close(p_c, p_m, rtol=1e-5, atol=1e-5)


def test_masked_training_replays_bit_identical():
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    flcfg = FLConfig(n_clients=6, clients_per_round=4, rounds=3,
                     n_clusters=0, batch_size=16, lr=0.05, loss="ew_mse",
                     seed=0, dp_clip=1.0, secure_agg=True)
    r1 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    r2 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(r1.loss_history, r2.loss_history)
    jax.tree.map(np.testing.assert_array_equal, r1.params, r2.params)


def test_semi_sync_cohort_atomic_late_folds_cancel():
    """Acceptance pin: a semi-sync run with LATE folds — lognormal
    stragglers, buffer_k < m', cohort-atomic pacing — equals the clear run
    with the same pacing to float tolerance: each late cohort folds as one
    group (one shared staleness discount), so its dispatch-round masks
    still cancel."""
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    base = dict(n_clients=6, clients_per_round=4, rounds=6, n_clusters=0,
                batch_size=16, lr=0.05, loss="ew_mse", seed=0,
                mode="semi_sync", over_select=1.5, buffer_k=4,
                staleness_alpha=0.5, stragglers="lognormal",
                straggler_jitter=1.0, dp_clip=1.0)
    r_clear = fedavg.run_federated_training(
        series, FCFG, FLConfig(**base, cohort_atomic=True))[-1]
    r_mask = fedavg.run_federated_training(
        series, FCFG, FLConfig(**base, secure_agg=True,
                               secure_mask_std=2.0))[-1]
    # identical event schedule (masking never changes pacing) ...
    np.testing.assert_array_equal(r_clear.sim_times, r_mask.sim_times)
    # ... identical fold pattern incl. empty flushes (nan loss slots) ...
    np.testing.assert_allclose(r_clear.loss_history, r_mask.loss_history,
                               rtol=1e-5, equal_nan=True)
    fold_rounds = np.flatnonzero(np.isfinite(r_clear.loss_history))
    assert len(fold_rounds) > 0
    tree_close(r_clear.params, r_mask.params, rtol=1e-4, atol=1e-4)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
def test_semi_sync_cohort_atomic_masked_equals_clear_shard_map():
    """Same late-fold pin on the MESH execution path: the sharded client
    stage masks inside the shard_map body (only masked deltas cross shard
    boundaries) and the buffered host-side folds still cancel per cohort."""
    series = synthetic.generate_buildings("CA", list(range(8)), days=20)
    base = dict(n_clients=8, clients_per_round=6, rounds=5, n_clusters=0,
                batch_size=16, lr=0.05, loss="ew_mse", seed=0,
                mode="semi_sync", over_select=1.2, buffer_k=5,
                staleness_alpha=0.5, stragglers="lognormal",
                straggler_jitter=1.0, dp_clip=1.0)
    mesh = jax.make_mesh((8,), ("clients",))
    r_clear = fedavg.run_federated_training(
        series, FCFG, FLConfig(**base, cohort_atomic=True), mesh=mesh)[-1]
    r_mask = fedavg.run_federated_training(
        series, FCFG, FLConfig(**base, secure_agg=True,
                               secure_mask_std=2.0), mesh=mesh)[-1]
    np.testing.assert_allclose(r_clear.loss_history, r_mask.loss_history,
                               rtol=1e-5, equal_nan=True)
    assert np.isfinite(r_clear.loss_history).any()
    tree_close(r_clear.params, r_mask.params, rtol=1e-4, atol=1e-4)


def test_semi_sync_cohort_atomic_folds_whole_cohorts_late():
    """Drive the engine directly: under cohort-atomic pacing every fold is
    a complete dispatch cohort, and with buffer_k < m' stragglers make the
    cohorts fold LATE (tau > 0)."""
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    flcfg = FLConfig(n_clients=6, clients_per_round=4, rounds=6,
                     n_clusters=0, batch_size=16, lr=0.05, loss="ew_mse",
                     seed=0, mode="semi_sync", over_select=1.5, buffer_k=4,
                     staleness_alpha=0.5, stragglers="lognormal",
                     straggler_jitter=1.0, dp_clip=1.0, secure_agg=True)
    engine = fedavg.RoundEngine(FCFG, flcfg)
    prov = windows.ClientWindowProvider.from_series(
        series, FCFG.lookback, FCFG.horizon)
    params, sstate = engine.init(jax.random.PRNGKey(0))
    x, y, counts = prov.round_batch(np.arange(6))
    bidx = np.random.default_rng(0).integers(0, int(counts.min()),
                                             size=(6, 3, 16))
    folded_any = False
    for t in range(6):
        params, sstate, l = engine.step(
            params, sstate, jnp.asarray(x), jnp.asarray(y),
            jnp.asarray(bidx), counts, round_idx=t)
        folded_any = folded_any or np.isfinite(float(l))
        # cohort-atomic invariant: the buffer never holds a PARTIAL folded
        # cohort — every pending dispatch round retains its full size or
        # has been removed entirely
        from collections import Counter
        per_round = Counter(p.dispatch_round
                            for p in engine.async_state.pending)
        for r, cnt in per_round.items():
            assert cnt == engine.async_state.cohort_sizes[r]
    assert folded_any
    assert engine.async_state.late_folds > 0         # cohorts folded late
    assert engine.async_state.max_staleness > 0
    assert engine.async_state.empty_flushes > 0      # and some flushes
    #                                                # completed no cohort


# ------------------------------------------------------------- accountant
def test_rdp_full_participation_closed_form():
    """q = 1 must reduce to the plain Gaussian mechanism: RDP = a/(2 z^2)."""
    for z in (0.8, 1.1, 3.0):
        for a in (2, 7, 32, 64):
            assert privacy.rdp_sampled_gaussian(1.0, z, a) == \
                pytest.approx(a / (2 * z * z))


def test_rdp_matches_direct_binomial_reference():
    """Independent reference: the log-space lgamma/logsumexp implementation
    vs a direct math.comb float summation of the same integer-order
    series."""
    def ref(q, z, a):
        s = sum(math.comb(a, k) * (1 - q) ** (a - k) * q ** k
                * math.exp(k * (k - 1) / (2 * z * z)) for k in range(a + 1))
        return math.log(s) / (a - 1)

    for q, z in [(0.01, 1.0), (0.05, 1.1), (0.2, 2.0), (0.5, 0.9)]:
        for a in (2, 3, 8, 17, 32):
            assert privacy.rdp_sampled_gaussian(q, z, a) == \
                pytest.approx(ref(q, z, a), rel=1e-9)


def test_epsilon_matches_independent_reference_two_settings():
    """Acceptance pin: final epsilon vs a fully independent computation
    (direct binomial sums + direct conversion formula) for two
    (noise, sampling-rate, rounds) settings."""
    def ref_eps(q, z, T, delta, orders):
        def rdp(a):
            s = sum(math.comb(a, k) * (1 - q) ** (a - k) * q ** k
                    * math.exp(k * (k - 1) / (2 * z * z))
                    for k in range(a + 1))
            return math.log(s) / (a - 1)
        return max(0.0, min(
            T * rdp(a) + math.log1p(-1 / a)
            - (math.log(delta) + math.log(a)) / (a - 1) for a in orders))

    orders = tuple(range(2, 33))       # direct float sums stay in range
    for q, z, T in [(0.05, 1.1, 100), (0.2, 2.0, 50)]:
        acct = privacy.PrivacyAccountant(z, q, 1e-5, orders=orders)
        acct.step(T)
        assert acct.epsilon() == pytest.approx(
            ref_eps(q, z, T, 1e-5, orders), rel=1e-9)


def test_epsilon_monotone_in_rounds_and_noise():
    acct = privacy.PrivacyAccountant(1.0, 0.1)
    eps = []
    for _ in range(30):
        acct.step()
        eps.append(acct.epsilon())
    assert all(np.isfinite(eps))
    assert all(b > a for a, b in zip(eps, eps[1:]))  # strictly more spent
    # more noise => less epsilon at equal rounds
    quiet = privacy.PrivacyAccountant(2.0, 0.1)
    quiet.step(30)
    assert quiet.epsilon() < eps[-1]


def test_accountant_disabled_reports_inf_cleanly():
    tc_nonoise = TransformConfig(clip_norm=1.0)
    tc_noclip = TransformConfig(noise_multiplier=0.5)
    pc = PrivacyConfig()
    for tcfg, reason in [(tc_nonoise, "dp_noise"), (tc_noclip, "dp_clip")]:
        acct = privacy.make_accountant(tcfg, pc, 0.1)
        acct.step(100)
        assert not acct.active
        assert acct.epsilon() == math.inf
        rep = acct.report()
        assert not rep["enabled"] and reason in rep["disabled_reason"]
        assert "disabled" in privacy.format_report(rep)
    on = privacy.make_accountant(
        TransformConfig(clip_norm=1.0, noise_multiplier=1.0), pc, 0.1)
    assert on.active and on.epsilon() == 0.0         # nothing spent yet
    assert "eps=" in privacy.format_report(
        dict(on.report(), rounds=1)) or True


def test_training_surfaces_running_epsilon():
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    kw = dict(n_clients=6, clients_per_round=3, rounds=4, n_clusters=0,
              batch_size=16, lr=0.05, loss="ew_mse", seed=0)
    res = fedavg.run_federated_training(
        series, FCFG, FLConfig(**kw, dp_clip=1.0, dp_noise=1.0))[-1]
    assert res.eps_history.shape == (4,)
    assert np.isfinite(res.eps_history).all()
    assert (np.diff(res.eps_history) > 0).all()      # monotone in rounds
    assert res.privacy["enabled"]
    assert res.privacy["epsilon"] == pytest.approx(res.eps_history[-1])
    assert res.privacy["sample_rate"] == pytest.approx(0.5)   # 3 of 6
    assert res.privacy["rounds"] == 4
    # accountant vs an equivalent standalone composition
    ref = privacy.PrivacyAccountant(1.0, 0.5, res.privacy["delta"])
    ref.step(4)
    assert res.privacy["epsilon"] == pytest.approx(ref.epsilon())
    # noise off -> disabled accountant, inf epsilon, no crash
    res_off = fedavg.run_federated_training(series, FCFG,
                                            FLConfig(**kw))[-1]
    assert not res_off.privacy["enabled"]
    assert np.all(np.isinf(res_off.eps_history))


# ------------------------------------- ring masking battery (ISSUE 10)
def tree_equal(a, b):
    """BIT-level equality — the ring pins, not float tolerance."""
    jax.tree.map(lambda u, v: np.testing.assert_array_equal(
        np.asarray(u), np.asarray(v)), a, b)


RING_KW = dict(n_clients=4, clients_per_round=4, rounds=2, n_clusters=0,
               loss="mse", lr=0.05, dp_clip=1.0, quantize_bits=8,
               server_opt="fedavg_weighted")


def _ring_engines(kw, mesh=None):
    """Masked engine vs its CLEAR comparator: same shared-grid ring
    quantizer (``quantize_ring``), no masks."""
    e_clear = fedavg.RoundEngine(
        FCFG, FLConfig(**kw, quantize_ring=True), loss=LOSS, mesh=mesh)
    e_mask = fedavg.RoundEngine(
        FCFG, FLConfig(**kw, secure_agg=True), loss=LOSS, mesh=mesh)
    return e_clear, e_mask


def test_make_stack_rings_quantizer_under_masking():
    """quantize+mask switches the quantizer to the shared ring grid and the
    masker to ring mode; quantize_ring alone is the clear comparator; mask
    without quantize stays float."""
    stack = transforms.make_stack(
        TransformConfig(clip_norm=1.0, quantize_bits=8),
        SecureAggConfig(enabled=True))
    assert stack.ring_spec == (8, 1.0, 0.0)
    assert stack.pre_weighted
    q, masker = stack.transforms[-2], stack.transforms[-1]
    assert isinstance(q, transforms.StochasticQuantize) and q.ring
    assert isinstance(masker, secure_agg.PairwiseMasker)
    assert masker.bits == 8
    # DP noise on -> the ring grid reserves a k-sigma noise-tail margin
    noised = transforms.make_stack(
        TransformConfig(clip_norm=1.0, noise_multiplier=0.5,
                        quantize_bits=8),
        SecureAggConfig(enabled=True))
    assert noised.ring_spec == (
        8, 1.0, transforms.RING_NOISE_TAIL_SIGMAS * 0.5)
    clear = transforms.make_stack(
        TransformConfig(clip_norm=1.0, quantize_bits=8, quantize_ring=True))
    assert clear.ring_spec == (8, 1.0, 0.0)
    assert clear.needs_cohort and clear.pre_weighted
    fstack = transforms.make_stack(TransformConfig(),
                                   SecureAggConfig(enabled=True))
    assert fstack.ring_spec is None and fstack.transforms[-1].bits == 0
    # the flat facade knob reaches the transform view
    assert FLConfig(quantize_bits=8,
                    quantize_ring=True).transform.quantize_ring
    with pytest.raises(ValueError, match="ring"):
        FLConfig(quantize_ring=True)                 # needs quantize_bits


def test_ring_levels_reserve_rounding_headroom():
    assert transforms.ring_levels(8, 4) == 2 ** 7 - 1 - 4
    assert transforms.ring_scale(8, 2.0, 4) == 2.0 / (2 ** 7 - 1 - 4)
    with pytest.raises(ValueError, match="ring"):
        transforms.ring_levels(8, 127)               # cohort too big for b=8
    # noise headroom divides the levels: the freed grid range is the
    # k-sigma noise-tail margin, and the sum bound still fits the ring
    assert transforms.ring_levels(8, 4, noise_headroom=1.0) \
        == (2 ** 7 - 1 - 4) // 2
    lv = transforms.ring_levels(8, 4, noise_headroom=4.0)
    assert lv * (1 + 4.0) + 4 <= 2 ** 7 - 1
    assert transforms.ring_scale(8, 2.0, 4, 1.0) == 2.0 / (
        (2 ** 7 - 1 - 4) // 2)
    with pytest.raises(ValueError, match="ring"):
        transforms.ring_levels(8, 4, noise_headroom=200.0)  # needs wider bits


def test_ring_cap_leaves_noise_tail_untruncated():
    """With DP noise on, the per-client ring cap must not clip the
    Gaussian: the noise-headroom grid keeps saturation down at the k-sigma
    residual, where the headroom-free grid would truncate the noise at
    ~1 sigma and clip roughly a third of the coordinates — biasing the
    sum and voiding the accountant's full-std Gaussian premise."""
    z, m = 1.0, 2
    rng = np.random.default_rng(0)
    # stands for the noised clipped delta the stack hands the quantizer:
    # per-coordinate N(0, (z*C)^2), C = sensitivity = 1
    x = jnp.asarray(rng.normal(0.0, z, size=(20000,)), jnp.float32)
    w = jnp.ones((m,), jnp.float32)
    ctx = secure_agg.CohortContext(jnp.int32(0), w, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)

    def saturated_frac(headroom):
        q = transforms.StochasticQuantize(8, ring=True, sensitivity=1.0,
                                          noise_headroom=headroom)
        out = np.asarray(q([x], key, ctx)[0])
        levels = transforms.ring_levels(8, m, headroom)
        cap = np.floor(0.5 * levels * (1.0 + headroom)) + 1.0
        assert np.abs(out).max() <= cap       # the sum bound always holds
        return float(np.mean(np.abs(out) >= cap))

    assert saturated_frac(transforms.RING_NOISE_TAIL_SIGMAS * z) < 1e-3
    assert saturated_frac(0.0) > 0.05         # the bug the margin fixes


def test_masked_round_equals_clear_bitwise_vmap(fl_data):
    """THE tentpole pin, vmap path: ring-masked == ring-clear EXACTLY (mask
    cancellation is integer ring arithmetic, not float cancellation)."""
    params, x, y, bidx = fl_data
    e_clear, e_mask = _ring_engines(RING_KW)
    counts = np.asarray([17.0, 5.0, 29.0, 11.0], np.float32)
    s0 = server_opt.init_server_state(params)
    p_c, _, l_c = e_clear.step(params, s0, x, y, bidx, counts, round_idx=0)
    p_m, _, l_m = e_mask.step(params, s0, x, y, bidx, counts, round_idx=0)
    np.testing.assert_array_equal(np.asarray(l_c), np.asarray(l_m))
    tree_equal(p_c, p_m)
    # and the masked uploads really are ring noise, not the clear ints
    from repro.core.async_engine import client_deltas
    rk = e_mask.base_round_key(0, 0)
    keys = e_mask.round_keys(0, 4)
    d_m, _ = client_deltas(params, x, y, bidx, keys, jnp.float32(0.05),
                           jnp.float32(0.0), FCFG, LOSS, e_mask.transform,
                           "jnp", e_mask.secure, rk, jnp.asarray(counts))
    d_c, _ = client_deltas(params, x, y, bidx, keys, jnp.float32(0.05),
                           jnp.float32(0.0), FCFG, LOSS, e_clear.transform,
                           "jnp", None, rk, jnp.asarray(counts))
    assert tree_max_abs_diff(d_m, d_c) > 8.0         # masked ≠ clear grid
    for leaf in jax.tree.leaves(d_m):                # b-bit ring symbols
        v = np.asarray(leaf)
        np.testing.assert_array_equal(v, np.round(v))
        assert v.min() >= -128 and v.max() < 128


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
@pytest.mark.parametrize("agg_kw,mesh_shape,axes", [
    (dict(), (8,), ("clients",)),
    (dict(aggregation="hierarchical", n_regions=2), (2, 4),
     ("region", "clients")),
])
def test_masked_equals_clear_bitwise_on_mesh(fl_data, agg_kw, mesh_shape,
                                             axes):
    """Ring pin on the flat 8-device and hier 2x4 reductions, with weight-0
    mesh pads in the cohort: still EXACT equality."""
    params, x, y, bidx = fl_data
    mesh = jax.make_mesh(mesh_shape, axes)
    kw = dict(RING_KW, clients_per_round=8, **agg_kw)
    e_clear, e_mask = _ring_engines(kw, mesh=mesh)
    idx = np.resize(np.arange(4), 8)
    counts = np.full(8, float(x.shape[1]), np.float32)
    counts[4:] = 0.0                                 # mesh pads
    s0 = server_opt.init_server_state(params)
    args = (params, s0, x[idx], y[idx], bidx[idx], counts)
    p_c, _, l_c = e_clear.step(*args, round_idx=0)
    p_m, _, l_m = e_mask.step(*args, round_idx=0)
    np.testing.assert_array_equal(np.asarray(l_c), np.asarray(l_m))
    tree_equal(p_c, p_m)


def test_ring_masked_semi_sync_late_folds_bitwise():
    """Cohort-atomic semi-sync with LATE folds: the host-side per-cohort
    ring decode makes masked == clear exact, empty flushes and all."""
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    base = dict(n_clients=6, clients_per_round=4, rounds=6, n_clusters=0,
                batch_size=16, lr=0.05, loss="ew_mse", seed=0,
                mode="semi_sync", over_select=1.5, buffer_k=4,
                staleness_alpha=0.5, stragglers="lognormal",
                straggler_jitter=1.0, dp_clip=1.0, quantize_bits=8)
    r_clear = fedavg.run_federated_training(
        series, FCFG, FLConfig(**base, quantize_ring=True,
                               cohort_atomic=True))[-1]
    r_mask = fedavg.run_federated_training(
        series, FCFG, FLConfig(**base, secure_agg=True))[-1]
    np.testing.assert_array_equal(r_clear.sim_times, r_mask.sim_times)
    np.testing.assert_array_equal(r_clear.loss_history, r_mask.loss_history)
    assert np.isfinite(r_clear.loss_history).any()
    tree_equal(r_clear.params, r_mask.params)


def test_ring_wraparound_heavy_masks_cancel_exactly():
    """Grid values at the very edge of the int8 ring (±127) under uniform
    masks: individual uploads wrap constantly, yet the ring-reduced sum of
    masked uploads equals the ring-reduced clear sum BIT-exactly."""
    m, bits = 5, 8
    rng = np.random.default_rng(3)
    edge = rng.choice([-127.0, -126.0, 126.0, 127.0], size=(m, 257))
    q = {"w": jnp.asarray(edge, jnp.float32),
         "b": jnp.asarray(rng.integers(-127, 128, (m, 9)), jnp.float32)}
    stack = transforms.TransformStack(
        (secure_agg.PairwiseMasker(bits=bits),))
    w = jnp.ones((m,), jnp.float32)
    rk = jax.random.PRNGKey(9)
    keys = jnp.zeros((m, 2), jnp.uint32)
    v = fedavg.apply_stack(stack, q, keys, w_full=w, round_key=rk)
    # wraparound is actually exercised: masked ≠ clear + const
    assert tree_max_abs_diff(v, q) > 128
    for k in q:
        s_mask = transforms.ring_wrap(jnp.sum(v[k], axis=0), bits)
        s_clear = transforms.ring_wrap(jnp.sum(q[k], axis=0), bits)
        np.testing.assert_array_equal(np.asarray(s_mask),
                                      np.asarray(s_clear))


def test_masked_single_upload_uniform_over_ring():
    """One client's masked upload is uniform over the int8 ring: under a
    fixed seed, every one of the 256 ring values occurs with frequency
    close to n/256 (information-theoretic hiding, not just noise)."""
    n = 1 << 15
    masker = secure_agg.PairwiseMasker(bits=8)
    q = {"w": jnp.full((n,), 37.0, jnp.float32)}     # constant secret
    ctx = secure_agg.CohortContext(jnp.int32(0),
                                   jnp.ones((2,), jnp.float32),
                                   jax.random.PRNGKey(123))
    v = np.asarray(masker(q, jax.random.PRNGKey(0), ctx)["w"])
    assert v.min() >= -128 and v.max() < 128
    counts = np.bincount(v.astype(np.int64) + 128, minlength=256)
    expected = n / 256
    assert counts.min() > 0.5 * expected             # every value occurs,
    assert counts.max() < 2.0 * expected             # none dominates
    # and the constant secret is invisible: the mode is not 37
    spread = counts.std() / expected
    assert spread < 0.2


# ----------------------------------- secure-agg-aware central accounting
def _ref_eps(q, z, T, delta, orders):
    """Fully independent epsilon: direct binomial sums + direct CKS
    conversion (no shared code with core/privacy.py)."""
    def rdp(a):
        s = sum(math.comb(a, k) * (1 - q) ** (a - k) * q ** k
                * math.exp(k * (k - 1) / (2 * z * z))
                for k in range(a + 1))
        return math.log(s) / (a - 1)
    return max(0.0, min(
        T * rdp(a) + math.log1p(-1 / a)
        - (math.log(delta) + math.log(a)) / (a - 1) for a in orders))


def test_secure_agg_accountant_pinned_against_reference():
    """Acceptance pin: the central-DP epsilon equals the independent
    reference at the aggregate multiplier z*sqrt(cohort)."""
    orders = tuple(range(2, 33))
    q, z, cohort, T = 0.25, 0.8, 16, 40
    acct = privacy.secure_agg_accountant(
        TransformConfig(clip_norm=1.0, noise_multiplier=z),
        PrivacyConfig(delta=1e-5, orders=orders), q,
        secure_enabled=True, cohort=cohort)
    acct.step(T)
    assert acct.active and acct.mode == "central:secure-agg"
    assert acct.noise_multiplier == pytest.approx(z * math.sqrt(cohort))
    assert acct.epsilon() == pytest.approx(
        _ref_eps(q, z * math.sqrt(cohort), T, 1e-5, orders), rel=1e-9)


def test_secure_agg_epsilon_tighter_and_monotone():
    tc = TransformConfig(clip_norm=1.0, noise_multiplier=0.7)
    pc = PrivacyConfig()
    per = privacy.make_accountant(tc, pc, 0.2)
    per.step(30)
    cen = privacy.secure_agg_accountant(tc, pc, 0.2, secure_enabled=True,
                                        cohort=8)
    cen.step(30)
    # strictly tighter than the per-client bound at matched noise
    assert cen.epsilon() < per.epsilon()
    assert np.isfinite(cen.epsilon()) and cen.epsilon() > 0
    # monotone in rounds
    run = privacy.secure_agg_accountant(tc, pc, 0.2, secure_enabled=True,
                                        cohort=8)
    eps = []
    for _ in range(10):
        run.step()
        eps.append(run.epsilon())
    assert all(b > a for a, b in zip(eps, eps[1:]))


def test_secure_agg_accountant_disabled_when_masking_off():
    acct = privacy.secure_agg_accountant(
        TransformConfig(clip_norm=1.0, noise_multiplier=1.0),
        PrivacyConfig(), 0.5, secure_enabled=False, cohort=4)
    acct.step(10)
    assert not acct.active
    assert acct.epsilon() == math.inf
    rep = acct.report()
    assert rep["mode"] == "central:secure-agg"
    assert "secure aggregation is off" in rep["disabled_reason"]
    assert "disabled" in privacy.format_report(rep)


def test_secure_agg_accountant_gated_on_ring_and_uniform():
    """Central accounting only prices the RING-masked UNIFORM sum: float
    masking is not information-theoretically hiding, and a weighted sum
    concentrates sensitivity on heavy clients faster than noise."""
    tc = TransformConfig(clip_norm=1.0, noise_multiplier=0.8)
    pc = PrivacyConfig()
    flt = privacy.secure_agg_accountant(tc, pc, 0.25, secure_enabled=True,
                                        cohort=8, ring=False)
    assert not flt.active and flt.epsilon() == math.inf
    assert "float masking" in flt.disabled_reason
    wtd = privacy.secure_agg_accountant(tc, pc, 0.25, secure_enabled=True,
                                        cohort=8, weighted=True)
    assert not wtd.active
    assert "weighted aggregation" in wtd.disabled_reason
    # a FIXED weight vector admits the exact weighted-sum multiplier
    # z * sqrt(sum frac^2) / max frac (uniform -> z*sqrt(m); one dominant
    # client -> z), pinned against the independent reference
    w = np.asarray([4.0, 1.0, 1.0, 1.0, 1.0])
    frac = w / w.sum()
    z_eff = 0.8 * math.sqrt(float(np.sum(frac ** 2))) / float(frac.max())
    orders = tuple(range(2, 33))
    fixed = privacy.secure_agg_accountant(
        tc, PrivacyConfig(orders=orders), 0.25, secure_enabled=True,
        cohort=5, weighted=True, weights=w)
    fixed.step(10)
    assert fixed.active
    assert fixed.noise_multiplier == pytest.approx(z_eff)
    assert fixed.epsilon() == pytest.approx(
        _ref_eps(0.25, z_eff, 10, 1e-5, orders), rel=1e-9)
    # sanity: the weighted multiplier certifies at least the per-client z
    # and at most the uniform z*sqrt(m)
    assert 0.8 <= fixed.noise_multiplier <= 0.8 * math.sqrt(5)
    uni = privacy.secure_agg_accountant(
        tc, pc, 0.25, secure_enabled=True, cohort=4, weighted=True,
        weights=np.asarray([3.0, 3.0, 3.0, 3.0]))
    assert uni.noise_multiplier == pytest.approx(0.8 * math.sqrt(4))


def test_central_accountant_shrinks_to_min_observed_cohort():
    """observe_cohort re-prices the WHOLE run at z*sqrt(min cohort): a
    churn re-key folds a survivor-only sum, so the smaller noise applies
    retroactively (conservative); growing back is ignored, per-client
    accountants are unaffected, and the min survives a state round-trip."""
    tc = TransformConfig(clip_norm=1.0, noise_multiplier=0.8)
    pc = PrivacyConfig(orders=tuple(range(2, 33)))
    acct = privacy.secure_agg_accountant(tc, pc, 0.25, secure_enabled=True,
                                         cohort=8)
    acct.step(5)
    eps_full = acct.epsilon()
    acct.observe_cohort(3)
    assert acct.cohort == 3
    assert acct.noise_multiplier == pytest.approx(0.8 * math.sqrt(3))
    assert acct.epsilon() > eps_full
    ref = privacy.secure_agg_accountant(tc, pc, 0.25, secure_enabled=True,
                                        cohort=3)
    ref.step(5)
    assert acct.epsilon() == pytest.approx(ref.epsilon())
    acct.observe_cohort(6)                    # never grows back
    assert acct.cohort == 3
    # state round-trip carries the min cohort (checkpoint/resume)
    fresh = privacy.secure_agg_accountant(tc, pc, 0.25, secure_enabled=True,
                                          cohort=8)
    fresh.load_state(acct.state_dict())
    assert fresh.cohort == 3
    assert fresh.epsilon() == pytest.approx(acct.epsilon())
    assert acct.report()["cohort"] == 3
    # per-client accountants have no cohort to shrink
    per = privacy.make_accountant(tc, pc, 0.25)
    per.step(5)
    eps_per = per.epsilon()
    per.observe_cohort(1)
    assert per.epsilon() == eps_per and "cohort" not in per.report()


def test_training_surfaces_central_mode_under_ring_masking():
    """FLResult.privacy carries the central mode when RING masking is on
    (quantize + mask, uniform aggregation), with epsilon = the aggregate-
    Gaussian composition (z*sqrt(m') on q=m'/N), strictly tighter than the
    per-client run at matched noise.  Float masking and weighted
    aggregation fall back to per-client accounting with the reason."""
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    kw = dict(n_clients=6, clients_per_round=3, rounds=4, n_clusters=0,
              batch_size=16, lr=0.05, loss="ew_mse", seed=0,
              dp_clip=1.0, dp_noise=1.0)
    res = fedavg.run_federated_training(
        series, FCFG, FLConfig(**kw, secure_agg=True, quantize_bits=8))[-1]
    assert res.privacy["mode"] == "central:secure-agg"
    assert res.privacy["enabled"]
    assert res.privacy["cohort"] == 3            # full cohort, no churn
    ref = privacy.PrivacyAccountant(1.0 * math.sqrt(3), 0.5,
                                    res.privacy["delta"])
    ref.step(4)
    assert res.privacy["epsilon"] == pytest.approx(ref.epsilon())
    res_pc = fedavg.run_federated_training(series, FCFG,
                                           FLConfig(**kw))[-1]
    assert res_pc.privacy["mode"] == "per-client"
    assert res.privacy["epsilon"] < res_pc.privacy["epsilon"]
    # float masking (no quantize): masks are not IT-hiding -> per-client
    res_f = fedavg.run_federated_training(
        series, FCFG, FLConfig(**kw, secure_agg=True))[-1]
    assert res_f.privacy["mode"] == "per-client"
    assert "float masking" in res_f.privacy["central_fallback_reason"]
    assert res_f.privacy["epsilon"] == pytest.approx(
        res_pc.privacy["epsilon"])
    # weighted aggregation under ring masking -> per-client
    res_w = fedavg.run_federated_training(
        series, FCFG, FLConfig(**kw, secure_agg=True, quantize_bits=8,
                               server_opt="fedavg_weighted"))[-1]
    assert res_w.privacy["mode"] == "per-client"
    assert "weighted aggregation" in res_w.privacy["central_fallback_reason"]


def test_semi_sync_accounts_one_invocation_per_dispatch():
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    flcfg = FLConfig(n_clients=6, clients_per_round=4, rounds=5,
                     n_clusters=0, batch_size=16, lr=0.05, loss="ew_mse",
                     seed=0, mode="semi_sync", over_select=1.5, buffer_k=4,
                     staleness_alpha=0.5, stragglers="lognormal",
                     straggler_jitter=1.0, dp_clip=1.0, dp_noise=1.0)
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    assert res.privacy["rounds"] == 5                # one per dispatch
    # over-selection raises the accounted sampling rate: m'=6 of 6 members
    assert res.privacy["sample_rate"] == pytest.approx(1.0)
    assert np.isfinite(res.privacy["epsilon"])
