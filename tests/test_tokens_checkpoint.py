"""Token pipeline + checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_config
from repro.configs.base import ForecasterConfig
from repro.data import tokens
from repro.models import forecaster


def test_delay_pattern_roundtrip():
    rng = np.random.default_rng(0)
    codes = rng.integers(1, 100, (2, 4, 16)).astype(np.int32)
    d = tokens.apply_delay_pattern(codes)
    u = tokens.undelay_pattern(d)
    # positions that survive the shift round-trip exactly
    for k in range(4):
        np.testing.assert_array_equal(u[:, k, :16 - k], codes[:, k, :16 - k])
    # codebook k is delayed by k steps
    np.testing.assert_array_equal(d[:, 2, 2:], codes[:, 2, :-2])


def test_zipf_tokens_in_vocab():
    rng = np.random.default_rng(0)
    t = tokens.zipf_tokens(rng, (4, 128), vocab=50)
    assert t.min() >= 0 and t.max() < 50
    # low ids should dominate (Zipf)
    assert (t < 10).mean() > 0.35


@pytest.mark.parametrize("arch", ["qwen3-14b", "musicgen-medium",
                                  "llava-next-34b"])
def test_make_lm_batch_layouts(arch):
    cfg = get_config(arch).reduced()
    b = tokens.make_lm_batch(cfg, 2, 64)
    if cfg.arch_type == "audio":
        assert b["tokens"].shape == (2, cfg.frontend.n_codebooks, 64)
    elif cfg.arch_type == "vlm":
        nm = cfg.frontend.n_media_tokens
        assert b["tokens"].shape == (2, 64 - nm)
        assert b["media"].shape == (2, nm, cfg.frontend.embed_dim)
        assert b["labels"].shape == (2, 64)
    else:
        assert b["tokens"].shape == (2, 64)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    cfg = ForecasterConfig(hidden_dim=16)
    params = forecaster.init_forecaster(jax.random.PRNGKey(0), cfg)
    p = tmp_path / "ckpt.npz"
    checkpoint.save(p, params, metadata={"round": 7})
    like = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.restore(p, like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 params, restored)
    assert checkpoint.metadata(p) == {"round": 7}


def test_checkpoint_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                             jnp.bfloat16)}
    p = tmp_path / "b.npz"
    checkpoint.save(p, tree)
    out = checkpoint.restore(p, tree)
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    p = tmp_path / "c.npz"
    checkpoint.save(p, tree)
    with pytest.raises(ValueError):
        checkpoint.restore(p, {"w": jnp.zeros((5,))})


def test_checkpoint_path_suffix_normalization(tmp_path):
    """``np.savez`` appends ``.npz`` to suffix-less paths, so save and load
    used to disagree about the file's name: ``save("ck")`` wrote ``ck.npz``
    but ``restore("ck")`` looked for ``ck``.  Both now normalize the same
    way, and an explicit ``.npz`` is never doubled."""
    tree = {"w": jnp.arange(4.0)}
    stem = tmp_path / "ck"
    checkpoint.save(stem, tree, metadata={"round": 3})
    assert (tmp_path / "ck.npz").exists()
    assert not stem.exists()
    out = checkpoint.restore(stem, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(out["w"], tree["w"])
    assert checkpoint.metadata(stem) == {"round": 3}
    # explicit .npz stays as-is (no ck.npz.npz)
    checkpoint.save(tmp_path / "ck2.npz", tree)
    assert (tmp_path / "ck2.npz").exists()
    assert not (tmp_path / "ck2.npz.npz").exists()


def test_checkpoint_metadata_key_collision_raises(tmp_path):
    """A tree leaf named ``__metadata__`` would silently overwrite (or be
    shadowed by) the metadata record in the flat archive."""
    with pytest.raises(ValueError, match="__metadata__"):
        checkpoint.save(tmp_path / "m.npz",
                        {"__metadata__": jnp.zeros(2)})


def test_checkpoint_separator_key_collision_raises(tmp_path):
    """Two distinct tree paths that flatten to the same ``/``-joined key
    (a dict key containing the separator) used to silently drop one of the
    two leaves in the archive."""
    tree = {"a": {"b": jnp.zeros(2)}, "a/b": jnp.ones(2)}
    with pytest.raises(ValueError, match="a/b"):
        checkpoint.save(tmp_path / "d.npz", tree)
