"""flcheck static-analysis tests: the lint rules against good/bad fixtures,
the jaxpr taint proofs against the REAL round bodies (and a deliberately
broken mask-after-psum pipeline), and the hot-path guards.

The taint proofs here are the load-bearing privacy regression: they fail if
anyone reorders a transform stage past the aggregation collective on ANY
topology, even when every numeric pin still passes (e.g. masks that cancel
in the sum regardless of where they were applied).
"""
import os
from functools import partial

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import recompile, taint
from repro.analysis.cli import find_repo_root, lint_file, main as cli_main
from repro.analysis.rules import RULES, Suppressions
from repro.analysis.concurrency import check_source as conc_check
from repro.analysis.determinism import check_source as det_check
from repro.analysis.dtypes import check_source as dt_check
from repro.analysis.prng_lint import check_source as prng_check
from repro.configs.base import SecureAggConfig, TransformConfig
from repro.core import transforms as transforms_mod
from repro.sharding import shard_map

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "flcheck")
# pretend scope paths: FLC004/FLC005 only fire under core/, FLC006-FLC009
# only under serving/ (see rules.py)
CORE_REL = "src/repro/core/fixture.py"
SERVING_REL = "src/repro/serving/fixture.py"
# which pretend path exercises each rule's scope
FIXTURE_REL = {"FLC006": SERVING_REL, "FLC007": SERVING_REL,
               "FLC008": SERVING_REL, "FLC009": SERVING_REL}

ALL_CHECKS = (prng_check, det_check, dt_check, conc_check)


def _run_all(source: str, rel: str = CORE_REL):
    return [f for check in ALL_CHECKS for f in check(source, rel)
            if RULES[f.code].in_scope(rel)]


def _fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name), "r", encoding="utf-8") as f:
        return f.read()


# ------------------------------------------------------------- level-2 lint
@pytest.mark.parametrize("code", ["FLC001", "FLC002", "FLC003", "FLC004",
                                  "FLC005", "FLC006", "FLC007", "FLC008",
                                  "FLC009"])
def test_bad_fixture_triggers_exactly_its_rule(code):
    rel = FIXTURE_REL.get(code, CORE_REL)
    findings = _run_all(_fixture(f"bad_{code.lower()}.py"), rel)
    assert findings, f"bad fixture for {code} produced no findings"
    assert {f.code for f in findings} == {code}, (
        f"bad fixture for {code} leaked other codes: "
        f"{[(f.code, f.line, f.message) for f in findings]}")
    assert not any(f.suppressed for f in findings)


def test_good_fixture_is_clean():
    findings = _run_all(_fixture("good_clean.py"))
    assert findings == [], [(f.code, f.line, f.message) for f in findings]


def test_good_serving_fixture_is_clean():
    findings = _run_all(_fixture("good_serving.py"), SERVING_REL)
    assert findings == [], [(f.code, f.line, f.message) for f in findings]


def test_scoped_rules_do_not_fire_outside_scope():
    # the FLC004/FLC005 fixtures are clean when the file lives in launch/,
    # and the serving-concurrency fixtures are clean OUTSIDE serving/
    rel = "src/repro/launch/fixture.py"
    for name in ("bad_flc004.py", "bad_flc005.py", "bad_flc006.py",
                 "bad_flc007.py", "bad_flc008.py", "bad_flc009.py"):
        findings = _run_all(_fixture(name), rel)
        assert findings == [], (name, [(f.code, f.line) for f in findings])


def test_suppression_with_rationale_suppresses():
    src = ("import jax\n"
           "k = jax.random.PRNGKey(0)  "
           "# flcheck: disable=FLC001 (test fixture)\n")
    (f,) = prng_check(src, CORE_REL)
    assert f.suppressed and f.suppress_reason == "test fixture"


def test_suppression_without_rationale_is_fatal():
    src = ("import jax\n"
           "k = jax.random.PRNGKey(0)  # flcheck: disable=FLC001\n")
    (f,) = prng_check(src, CORE_REL)
    assert not f.suppressed            # no rationale -> not suppressed
    assert Suppressions(src).missing_reason == [2]


def test_suppression_on_line_above():
    src = ("import jax\n"
           "# flcheck: disable=FLC001 (covers next line)\n"
           "k = jax.random.PRNGKey(0)\n")
    (f,) = prng_check(src, CORE_REL)
    assert f.suppressed


def test_key_reuse_not_flagged_for_split_rebind():
    src = ("import jax\n"
           "def f(key):\n"
           "    key, sub = jax.random.split(key)\n"
           "    a = jax.random.normal(sub, (2,))\n"
           "    key, sub = jax.random.split(key)\n"
           "    b = jax.random.normal(sub, (2,))\n"
           "    return a + b\n")
    assert prng_check(src, CORE_REL) == []


def test_repo_src_tree_is_flcheck_clean():
    """The shipped source tree has zero unsuppressed findings and every
    suppression carries a rationale — the CI lint gate, as a test."""
    root = find_repo_root(os.path.dirname(__file__))
    src_dir = os.path.join(root, "src")
    bad = []
    for dirpath, _, filenames in os.walk(src_dir):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            findings, errors = lint_file(os.path.join(dirpath, fn), root)
            bad.extend(errors)
            bad.extend(f.render() for f in findings if not f.suppressed)
    assert bad == [], "\n".join(bad)


def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert cli_main([str(clean)]) == 0
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import jax\nk = jax.random.PRNGKey(7)\n")
    assert cli_main([str(dirty)]) == 1
    out = capsys.readouterr().out
    assert "FLC001" in out


def test_cli_missing_path_is_fatal(tmp_path, capsys):
    """A typo'd lint target must exit 2 with a clear message, never pass
    as 'clean' (the satellite fix: missing != nothing-to-lint)."""
    missing = tmp_path / "no_such_dir"
    assert cli_main([str(missing)]) == 2
    assert "does not exist" in capsys.readouterr().err


def test_cli_empty_dir_is_fatal(tmp_path, capsys):
    empty = tmp_path / "empty"
    empty.mkdir()
    assert cli_main([str(empty)]) == 2
    assert "no Python files" in capsys.readouterr().err


def test_cli_non_python_file_is_fatal(tmp_path, capsys):
    txt = tmp_path / "notes.txt"
    txt.write_text("hello\n")
    assert cli_main([str(txt)]) == 2
    assert "not a Python file" in capsys.readouterr().err


def test_cli_list_rules_covers_catalog(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in RULES:
        assert code in out


# --------------------------------------------------------- level-1: taint
# int8: the ring quantizer under masking reserves cohort-size rounding
# headroom, and the 8-device flat/hier traces dispatch a cohort of 8 —
# too big for an int4 ring (2^3 - 1 - 8 < 1), fine in int8 (119 levels)
FULL_T = TransformConfig(clip_norm=1.0, noise_multiplier=0.5,
                         quantize_bits=8)
SECURE = SecureAggConfig(enabled=True)


def test_taint_proves_vmap_full_stack():
    rep = taint.verify_pipeline("vmap", FULL_T, SECURE)
    assert rep.proved, rep.render()
    assert rep.required == frozenset({"clip", "noise", "quantize", "mask"})
    assert rep.checked > 0 and rep.sources > 0    # non-vacuous


def test_taint_proves_semi_sync_dispatch_path():
    rep = taint.verify_pipeline("semi_sync", FULL_T, SECURE)
    assert rep.proved, rep.render()


def test_taint_proves_clip_only_config():
    rep = taint.verify_pipeline("vmap", TransformConfig(clip_norm=1.0))
    assert rep.proved, rep.render()
    assert rep.required == frozenset({"clip"})


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
def test_taint_proves_flat_psum_topology():
    rep = taint.verify_pipeline("flat", FULL_T, SECURE)
    assert rep.proved, rep.render()


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
def test_taint_proves_hierarchical_topology():
    rep = taint.verify_pipeline("hier", FULL_T, SECURE)
    assert rep.proved, rep.render()
    # hierarchical = two chained psums; both crossings were checked
    assert rep.checked >= 2


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
def test_taint_rejects_mask_after_psum():
    """The regression the whole pass exists for: a pipeline that aggregates
    FIRST and sanitizes after must be rejected — numerically the masks
    would still cancel in the sum, so no loss pin can catch this."""
    mesh = jax.make_mesh((8,), ("clients",))
    stack = transforms_mod.make_stack(TransformConfig(clip_norm=1.0), None)

    def broken(deltas, keys):
        deltas = taint.tag_private(deltas)
        summed = jax.tree.map(lambda d: jax.lax.psum(d, "clients"), deltas)
        return jax.vmap(stack)(summed, keys)

    fn = shard_map(broken, mesh=mesh,
                   in_specs=(P("clients"), P("clients")),
                   out_specs=P("clients"), check_vma=False)
    with taint.analysis_mode():
        jx = jax.make_jaxpr(fn)(jnp.zeros((8, 3)),
                                jnp.zeros((8, 2), jnp.uint32))
    rep = taint.analyze_closed(jx, frozenset({"clip"}))
    assert not rep.ok
    assert any(v.primitive == "psum" and "clip" in v.missing
               for v in rep.violations), rep.render()


def test_taint_rejects_missing_stage_label():
    """A pipeline that clips but skips noising fails a clip+noise policy."""
    stack = transforms_mod.make_stack(TransformConfig(clip_norm=1.0), None)

    def partial_pipeline(deltas, keys):
        deltas = taint.tag_private(deltas)
        deltas = jax.vmap(stack)(deltas, keys)       # clip only
        return taint.boundary(jnp.sum(deltas, axis=0))

    with taint.analysis_mode():
        jx = jax.make_jaxpr(partial_pipeline)(
            jnp.zeros((4, 3)), jnp.zeros((4, 2), jnp.uint32))
    rep = taint.analyze_closed(jx, frozenset({"clip", "noise"}))
    assert not rep.ok
    assert all(v.missing == frozenset({"noise"}) for v in rep.violations)


def test_taint_label_meet_on_mixing():
    """Mixing a sanitized value with an unsanitized one weakens the labels
    to the intersection — the mixed value must NOT count as sanitized."""
    def mix(x):
        priv = taint.tag_private(x)
        cleaned = taint.declassify(priv * 2.0, "clip")
        mixed = cleaned + priv                       # re-contaminated
        return taint.boundary(jnp.sum(mixed))

    with taint.analysis_mode():
        jx = jax.make_jaxpr(mix)(jnp.zeros((3,)))
    rep = taint.analyze_closed(jx, frozenset({"clip"}))
    assert not rep.ok and rep.violations[0].missing == frozenset({"clip"})


def test_taint_markers_are_production_noops():
    """Outside analysis_mode the markers add NOTHING to the jaxpr and the
    traced math is unchanged."""
    def f(x):
        x = taint.tag_private(x)
        x = taint.declassify(x, "clip")
        return taint.boundary(x) * 2.0

    jx = jax.make_jaxpr(f)(jnp.ones((2,)))
    prims = {e.primitive.name for e in jx.jaxpr.eqns}
    assert not any(p.startswith("flcheck_") for p in prims), prims
    assert float(jax.jit(f)(jnp.ones(()))) == 2.0


def test_taint_scan_fixpoint_catches_loop_carried_taint():
    """Taint flowing through a scan carry (accumulated over iterations)
    still reaches the boundary check — the interpreter iterates the body
    to a fixpoint instead of analyzing it once."""
    def f(x):
        priv = taint.tag_private(x)

        def step(carry, _):
            return carry + priv, None                # taint enters carry

        acc, _ = jax.lax.scan(step, jnp.zeros_like(x), None, length=3)
        return taint.boundary(jnp.sum(acc))

    with taint.analysis_mode():
        jx = jax.make_jaxpr(f)(jnp.zeros((3,)))
    rep = taint.analyze_closed(jx, frozenset({"clip"}))
    assert not rep.ok, "loop-carried taint escaped the scan fixpoint"


def test_untagged_loss_release_is_not_flagged():
    """The weighted scalar loss release (the accepted disclosure in
    docs/privacy.md) carries no taint, so an empty-required policy on the
    identity config stays clean AND non-vacuous for the model tree."""
    rep = taint.verify_pipeline("vmap", TransformConfig())
    assert rep.proved, rep.render()
    assert rep.required == frozenset()


# ----------------------------------------------------- hot-path guards
@pytest.mark.slow
def test_round_hot_path_no_recompiles_no_transfers():
    report, transfer_err = recompile.check_round_hot_path()
    assert report.ok, report.render()
    assert transfer_err is None, transfer_err


def test_recompile_guard_catches_static_arg_abuse():
    """A per-step value threaded through a STATIC argnum (instead of being
    traced) retraces every step — exactly what the guard must flag."""
    @partial(jax.jit, static_argnums=(1,))
    def poisoned(x, n):
        return x * n

    def step(i):
        return poisoned(jnp.ones(()), i)   # i static -> new trace each step

    rep = recompile.count_recompiles(step, steps=2,
                                     cache_size=poisoned._cache_size)
    assert not rep.ok and rep.new_entries_per_step == [1, 1]
