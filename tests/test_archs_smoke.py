"""Per-architecture smoke tests (deliverable f): every assigned arch,
REDUCED variant (2 layers, d_model ≤ 512, ≤ 4 experts), one forward/train
step on CPU — output shapes + no NaNs — plus prefill→decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as tf

B, S = 2, 64

# the widest reduced configs still cost ~10s of XLA compile each on CPU;
# they run under -m slow, the rest stay in the fast default suite
HEAVY_ARCHS = {"deepseek-v3-671b", "xlstm-1.3b", "codeqwen1.5-7b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in HEAVY_ARCHS
               else a for a in ARCH_IDS]


def _batch(cfg, rng, with_labels=True):
    if cfg.arch_type == "audio":
        K = cfg.frontend.n_codebooks
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, K, S)),
                           jnp.int32)
        d = {"tokens": toks}
        if with_labels:
            d["labels"] = toks
        return d
    if cfg.arch_type == "vlm":
        nm = cfg.frontend.n_media_tokens
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - nm)),
                           jnp.int32)
        d = {"tokens": toks,
             "media": jnp.asarray(rng.normal(size=(B, nm,
                                                   cfg.frontend.embed_dim)),
                                  jnp.float32)}
        if with_labels:
            d["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)
        return d
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    d = {"tokens": toks}
    if with_labels:
        d["labels"] = toks
    return d


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_invariants(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    full = get_config(arch)
    assert full.arch_type == cfg.arch_type
    assert full.num_params() > cfg.num_params()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    params = tf.init_model(jax.random.PRNGKey(0), cfg)
    opt = optim.adam()
    step = tf.make_train_step(cfg, opt, dtype=jnp.float32)
    p2, st2, m = jax.jit(step)(params, opt.init(params), _batch(cfg, rng),
                               1e-3)
    assert np.isfinite(float(m["loss"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(1)
    params = tf.init_model(jax.random.PRNGKey(1), cfg)
    logits, aux, _ = tf.forward(params, _batch(cfg, rng, with_labels=False),
                                cfg, dtype=jnp.float32, remat=False)
    if cfg.arch_type == "audio":
        assert logits.shape == (B, cfg.frontend.n_codebooks, S,
                                cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step against a prefilled cache == full forward's last logits."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:   # capacity drops make bit-exactness impossible
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = np.random.default_rng(2)
    params = tf.init_model(jax.random.PRNGKey(2), cfg)
    batch = _batch(cfg, rng, with_labels=False)
    logits_full, _, _ = tf.forward(params, batch, cfg, dtype=jnp.float32,
                                   remat=False)
    nm = cfg.frontend.n_media_tokens if cfg.arch_type == "vlm" else 0
    toks = batch["tokens"]
    if cfg.arch_type == "audio":
        pre = {"tokens": toks[:, :, :S - 1]}
        dec = {"tokens": toks[:, :, S - 1:]}
    elif cfg.arch_type == "vlm":
        pre = {"tokens": toks[:, :S - 1 - nm], "media": batch["media"]}
        dec = {"tokens": toks[:, S - 1 - nm:S - nm]}
    else:
        pre = {"tokens": toks[:, :S - 1]}
        dec = {"tokens": toks[:, S - 1:]}
    caches = tf.init_cache(cfg, B, S, dtype=jnp.float32)
    _, _, (caches2, _, _) = tf.forward(params, pre, cfg, dtype=jnp.float32,
                                       caches=caches, remat=False)
    logits_dec, _ = tf.decode_step(params, caches2, dec, jnp.int32(S - 1),
                                   cfg, dtype=jnp.float32)
    a = logits_full[:, :, -1] if cfg.arch_type == "audio" \
        else logits_full[:, -1]
    b = logits_dec[:, :, 0] if cfg.arch_type == "audio" else logits_dec[:, 0]
    scale = float(jnp.max(jnp.abs(a))) + 1e-6
    assert float(jnp.max(jnp.abs(a - b))) < 1e-2 * max(scale, 1.0)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-72b", "zamba2-7b", "xlstm-1.3b"])
def test_multi_step_decode(arch):
    """Three consecutive decode steps track the full forward."""
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(3)
    params = tf.init_model(jax.random.PRNGKey(3), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_full, _, _ = tf.forward(params, {"tokens": toks}, cfg,
                                   dtype=jnp.float32, remat=False)
    caches = tf.init_cache(cfg, B, S, dtype=jnp.float32)
    k = 3
    _, _, (caches, _, _) = tf.forward(params, {"tokens": toks[:, :S - k]},
                                      cfg, dtype=jnp.float32, caches=caches,
                                      remat=False)
    for t in range(S - k, S):
        logits_dec, caches = tf.decode_step(
            params, caches, {"tokens": toks[:, t:t + 1]}, jnp.int32(t), cfg,
            dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(logits_full[:, t] - logits_dec[:, 0])))
        assert err < 5e-2, (t, err)


@pytest.mark.slow
def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer sliding-window decode == full forward with same window."""
    cfg = get_config("qwen3-14b").reduced()
    win = 16
    rng = np.random.default_rng(4)
    params = tf.init_model(jax.random.PRNGKey(4), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_full, _, _ = tf.forward(params, {"tokens": toks}, cfg,
                                   dtype=jnp.float32, remat=False, window=win)
    caches = tf.init_cache(cfg, B, win, dtype=jnp.float32)   # ring buffer
    _, _, (caches, _, _) = tf.forward(params, {"tokens": toks[:, :win]}, cfg,
                                      dtype=jnp.float32, caches=caches,
                                      remat=False, window=win)
    for t in range(win, S):
        logits_dec, caches = tf.decode_step(
            params, caches, {"tokens": toks[:, t:t + 1]}, jnp.int32(t), cfg,
            dtype=jnp.float32, window=win)
    err = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, 0])))
    assert err < 5e-2, err
