"""Test session setup.

Locks the jax backend BEFORE any test module can import something that
fiddles with XLA_FLAGS mid-session (the dry-run launcher sets
--xla_force_host_platform_device_count=512 for itself; tests must never pick
that up after the fact).  The device count itself comes from the
environment: plain ``pytest`` runs single-device, while ``./test.sh``
exports ``--xla_force_host_platform_device_count=8`` up front so the
multi-device shard_map tests run on real (virtual) meshes.
"""
import jax

jax.devices()                                            # lock backend now

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
