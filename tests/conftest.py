"""Test session setup.

Locks the jax backend to the single real CPU device BEFORE any test module
can import something that fiddles with XLA_FLAGS (the dry-run launcher sets
--xla_force_host_platform_device_count=512 for itself; tests must never see
that).
"""
import jax

jax.devices()                                            # lock backend now

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
