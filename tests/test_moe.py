"""MoE dispatch/combine invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_mod


@pytest.fixture(scope="module")
def cfg():
    return get_config("dbrx-132b").reduced()


def test_capacity_formula(cfg):
    e = cfg.moe
    c = moe_mod.capacity(e, 64)
    assert c >= e.top_k
    assert c >= e.capacity_factor * 64 * e.top_k / e.n_experts - 1


def test_choose_group_divides():
    assert moe_mod._choose_group(126, 64) == 63
    assert moe_mod._choose_group(128, 64) == 64
    assert moe_mod._choose_group(7, 64) == 7


def test_moe_output_finite_and_shaped(cfg):
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)),
                    jnp.float32)
    out, aux = moe_mod.moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0


def test_aux_loss_balanced_router_is_one():
    """Uniform routing ⇒ GShard aux loss → E·Σ (1/E)(1/E)·E = 1·weight."""
    cfg = get_config("dbrx-132b").reduced()
    p = moe_mod.init_moe(jax.random.PRNGKey(1), cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))      # uniform probs
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 64, cfg.d_model)),
                    jnp.float32)
    _, aux = moe_mod.moe_ffn(p, x, cfg)
    # ties in top_k make the top-1 frac degenerate but bounded
    assert 0.0 <= float(aux) <= 2.0 * cfg.moe.router_aux_weight * 4


def test_high_capacity_equals_dense_expert_mixture(cfg):
    """With capacity high enough to never drop, output = Σ_k gate_k·FFN_k(x)."""
    big = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = moe_mod.init_moe(jax.random.PRNGKey(2), big)
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(1, 16, big.d_model)), jnp.float32)
    out, _ = moe_mod.moe_ffn(p, x, big)

    # reference: route per token without capacity
    e = big.moe
    xg = x.reshape(-1, big.d_model)
    logits = xg @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, experts = jax.lax.top_k(probs, e.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    want = np.zeros_like(xg)
    for t in range(xg.shape[0]):
        for kk in range(e.top_k):
            ei = int(experts[t, kk])
            h = xg[t] @ p["moe_w_in"][ei]
            g = xg[t] @ p["moe_w_gate"][ei]
            y = (h * jax.nn.silu(g)) @ p["moe_w_out"][ei]
            want[t] += float(gates[t, kk]) * np.asarray(y)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, big.d_model),
                               want, rtol=2e-3, atol=2e-3)


def test_capacity_drop_degrades_gracefully(cfg):
    tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    p = moe_mod.init_moe(jax.random.PRNGKey(3), tiny)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 64, tiny.d_model)),
                    jnp.float32)
    out, _ = moe_mod.moe_ffn(p, x, tiny)
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens produce zero routed output; norm is below no-drop norm
    big = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    out_big, _ = moe_mod.moe_ffn(p, x, big)
    assert float(jnp.abs(out).sum()) <= float(jnp.abs(out_big).sum()) + 1e-3


def test_shared_experts_added():
    ds = get_config("deepseek-v3-671b").reduced()
    p = moe_mod.init_moe(jax.random.PRNGKey(4), ds)
    assert "shared_w_in" in p
    x = jnp.asarray(np.random.default_rng(4).normal(size=(1, 16, ds.d_model)),
                    jnp.float32)
    out, _ = moe_mod.moe_ffn(p, x, ds)
    # zeroing the shared expert changes the output
    p0 = dict(p, shared_w_out=jnp.zeros_like(p["shared_w_out"]))
    out0, _ = moe_mod.moe_ffn(p0, x, ds)
    assert float(jnp.abs(out - out0).max()) > 1e-6
