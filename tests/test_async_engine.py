"""Semi-synchronous buffered rounds (ISSUE 4 tentpole): latency model,
staleness discounting, buffer-flush determinism, and the
semi_sync(buffer_k=m', zero-jitter) == sync bit-equivalence pins on both
execution paths."""
import dataclasses

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import (AsyncConfig, FLConfig, ForecasterConfig,
                                LatencyConfig)
from repro.core import async_engine, fedavg, latency
from repro.data import synthetic

FCFG = ForecasterConfig(cell="lstm", hidden_dim=8)

# same golden workload + constants as tests/test_pipeline_api.py — vmap and
# shard_map pins re-captured for the fold_in engine-init key (the two paths
# differ by one f32 ulp of summation order on these values; see the
# GOLDEN comment in tests/test_pipeline_api.py)
GOLDEN = [0.12595632672309875, 0.055874377489089966, 0.04063640534877777]
GOLDEN_SHARD = [0.12595631182193756, 0.055874377489089966,
                0.04063640907406807]


def _workload(**kw):
    series = synthetic.generate_buildings("CA", list(range(6)), days=20)
    base = dict(n_clients=6, clients_per_round=4, rounds=3, n_clusters=0,
                batch_size=16, lr=0.05, loss="ew_mse", seed=0)
    base.update(kw)
    return series, FLConfig(**base)


# ------------------------------------------------------------ config facade
def test_async_config_facade_and_validation():
    cfg = FLConfig(mode="semi_sync", over_select=1.5, buffer_k=3,
                   staleness_alpha=0.25, stragglers="lognormal",
                   straggler_jitter=0.7)
    acfg = cfg.async_config
    assert acfg == AsyncConfig(mode="semi_sync", over_select=1.5, buffer_k=3,
                               staleness_alpha=0.25,
                               latency=LatencyConfig(distribution="lognormal",
                                                     jitter=0.7))
    assert FLConfig().async_config.mode == "sync"
    for kw in (dict(mode="async"), dict(over_select=0.5), dict(buffer_k=-1),
               dict(staleness_alpha=-0.1), dict(stragglers="uniform"),
               dict(straggler_jitter=-1.0)):
        with pytest.raises(ValueError):
            FLConfig(**kw)


def test_buffer_frac_validates_and_buffers_stragglers():
    """Relative flush threshold: mutually exclusive with buffer_k, in
    [0, 1], and actually sheds stragglers (resolved against each round's
    dispatch size, so it cannot silently degrade on small memberships)."""
    with pytest.raises(ValueError):
        FLConfig(buffer_k=3, buffer_frac=0.5)
    with pytest.raises(ValueError):
        FLConfig(buffer_frac=1.5)
    kw = dict(mode="semi_sync", over_select=1.5, buffer_frac=0.5,
              stragglers="lognormal", straggler_jitter=1.0, rounds=4)
    series, flcfg = _workload(**kw)
    _, sync_cfg = _workload(stragglers="lognormal", straggler_jitter=1.0,
                            rounds=4)
    r1 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    r2 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    r_sync = fedavg.run_federated_training(series, FCFG, sync_cfg)[-1]
    np.testing.assert_array_equal(r1.loss_history, r2.loss_history)
    assert np.isfinite(r1.loss_history).all()
    assert r1.sim_times[-1] < r_sync.sim_times[-1]


def test_engine_rejects_unreachable_buffer_k():
    _, flcfg = _workload(mode="semi_sync", buffer_k=99)
    with pytest.raises(ValueError) as ei:
        fedavg.RoundEngine(FCFG, flcfg)
    assert "buffer_k" in str(ei.value)


# -------------------------------------------------------- staleness weights
@given(st.floats(0.0, 4.0), st.integers(0, 20), st.integers(1, 20))
@settings(max_examples=20, deadline=None)
def test_staleness_discount_monotone_and_alpha0(alpha, tau, dtau):
    """Larger tau => smaller weight; alpha=0 => no discount; fresh updates
    are never discounted."""
    d1 = float(async_engine.staleness_discount(tau, alpha))
    d2 = float(async_engine.staleness_discount(tau + dtau, alpha))
    assert 0.0 < d1 <= 1.0
    assert d2 <= d1
    if alpha > 0:
        assert d2 < d1
    assert async_engine.staleness_discount(tau, 0.0) == 1.0
    assert async_engine.staleness_discount(0, alpha) == 1.0


# ------------------------------------------------------------ latency model
def test_latency_model_deterministic_and_scales_with_work():
    win = np.asarray([10.0, 20.0, 40.0])
    det = latency.LatencyModel(LatencyConfig(), seed=0,
                               payload=latency.payload_bytes(1000))
    t = det.times(0, win, epochs=2)
    # compute scales linearly with windows x epochs on top of a fixed uplink
    assert t[2] - t[1] == pytest.approx(2 * (t[1] - t[0]))
    np.testing.assert_array_equal(t, det.times(0, win, epochs=2))

    logn = latency.LatencyModel(
        LatencyConfig(distribution="lognormal", jitter=1.0), seed=0,
        payload=latency.payload_bytes(1000))
    a, b = logn.times(3, win, 2), logn.times(3, win, 2)
    np.testing.assert_array_equal(a, b)          # replayable per (seed, round)
    assert np.any(logn.times(4, win, 2) != a)    # but fresh per round


def test_latency_zero_jitter_collapses_to_deterministic():
    win = np.asarray([5.0, 9.0])
    kw = dict(seed=1, payload=4000.0)
    t0 = latency.LatencyModel(LatencyConfig(), **kw).times(0, win, 1)
    for dist in ("lognormal", "heavy_tail"):
        cfg = LatencyConfig(distribution=dist, jitter=0.0)
        np.testing.assert_array_equal(
            latency.LatencyModel(cfg, **kw).times(0, win, 1), t0)


def test_payload_bytes_and_link_budget():
    assert latency.payload_bytes(1000) == 4000.0
    assert latency.payload_bytes(1000, 8) == 1000       # int8 = 4x smaller
    b = latency.link_budget(1000, m_clients=30, n_regions=3,
                            quantize_bits=8)
    assert b["region_fanin_bytes"] == 10 * 1000         # m/R quantized uploads
    assert b["cloud_ingress_bytes"] == 3 * 4000         # R fp32 partials
    assert b["flat_cloud_ingress_bytes"] == 30 * 1000
    flat = latency.link_budget(1000, 30, 1, 8)
    assert flat["cloud_ingress_bytes"] == flat["flat_cloud_ingress_bytes"]
    with pytest.raises(ValueError):
        latency.link_budget(1000, 30, 0)


# ------------------------------------------- sync equivalence + golden pin
def test_sync_mode_golden_loss_pin():
    """mode="sync" (the default) stays bit-identical to the pre-async
    engine on the golden workload."""
    series, flcfg = _workload(mode="sync")
    res = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(res.loss_history,
                                  np.asarray(GOLDEN, np.float64))
    assert res.sim_times.shape == (3,)
    assert (np.diff(res.sim_times) > 0).all()   # event clock advances


def test_semi_sync_wait_for_all_zero_jitter_equals_sync_vmap():
    """buffer_k = m' (the 0 default) + deterministic latency: every flush is
    a complete fresh dispatch set, so the semi-sync engine must be
    BIT-identical to sync — params and loss history."""
    series, sync_cfg = _workload()
    _, semi_cfg = _workload(mode="semi_sync")
    r_sync = fedavg.run_federated_training(series, FCFG, sync_cfg)[-1]
    r_semi = fedavg.run_federated_training(series, FCFG, semi_cfg)[-1]
    np.testing.assert_array_equal(r_sync.loss_history, r_semi.loss_history)
    jax.tree.map(np.testing.assert_array_equal, r_sync.params, r_semi.params)
    np.testing.assert_array_equal(r_sync.sim_times, r_semi.sim_times)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (run via ./test.sh)")
def test_semi_sync_wait_for_all_zero_jitter_equals_sync_shard_map():
    series, sync_cfg = _workload()
    _, semi_cfg = _workload(mode="semi_sync")
    mesh = jax.make_mesh((8,), ("clients",))
    r_sync = fedavg.run_federated_training(series, FCFG, sync_cfg,
                                           mesh=mesh)[-1]
    r_semi = fedavg.run_federated_training(series, FCFG, semi_cfg,
                                           mesh=mesh)[-1]
    np.testing.assert_array_equal(r_sync.loss_history, r_semi.loss_history)
    jax.tree.map(np.testing.assert_array_equal, r_sync.params, r_semi.params)
    # and the shard_map semi-sync run equals the shard_map golden pin
    np.testing.assert_array_equal(r_semi.loss_history,
                                  np.asarray(GOLDEN_SHARD, np.float64))


# ------------------------------------------------------- buffered path
STRAG = dict(mode="semi_sync", over_select=1.5, buffer_k=4,
             staleness_alpha=0.5, stragglers="lognormal",
             straggler_jitter=1.0, rounds=4)


def test_buffer_flush_deterministic_under_fixed_seed():
    series, flcfg = _workload(**STRAG)
    r1 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    r2 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    np.testing.assert_array_equal(r1.loss_history, r2.loss_history)
    np.testing.assert_array_equal(r1.sim_times, r2.sim_times)
    jax.tree.map(np.testing.assert_array_equal, r1.params, r2.params)
    assert np.isfinite(r1.loss_history).all()


def test_semi_sync_beats_sync_wall_clock_under_stragglers():
    """The acceptance property: with lognormal stragglers, flushing at
    buffer_k < m' cuts simulated wall-clock vs waiting for the max."""
    series, semi_cfg = _workload(**STRAG)
    _, sync_cfg = _workload(stragglers="lognormal", straggler_jitter=1.0,
                            rounds=4)
    r_semi = fedavg.run_federated_training(series, FCFG, semi_cfg)[-1]
    r_sync = fedavg.run_federated_training(series, FCFG, sync_cfg)[-1]
    assert r_semi.sim_times[-1] < r_sync.sim_times[-1]
    assert np.isfinite(r_semi.loss_history).all()


def test_stragglers_fold_late_with_staleness_discount():
    """Drive the engine directly: a buffer_k < m' flush leaves stragglers
    pending, and they fold into a later round discounted."""
    series, flcfg = _workload(**STRAG)
    engine = fedavg.RoundEngine(FCFG, flcfg)
    assert engine.buffer_k == 4 and engine.dispatch_m(4) == 6
    from repro.data import windows as windows_mod
    prov = windows_mod.ClientWindowProvider.from_series(
        series, FCFG.lookback, FCFG.horizon)
    params, sstate = engine.init(jax.random.PRNGKey(0))
    x, y, counts = prov.round_batch(np.arange(6))
    bidx = np.random.default_rng(0).integers(
        0, int(counts.min()), size=(6, 3, 16))
    import jax.numpy as jnp
    for t in range(3):
        params, sstate, l = engine.step(
            params, sstate, jnp.asarray(x), jnp.asarray(y),
            jnp.asarray(bidx), counts, round_idx=t)
        assert np.isfinite(float(l))
    # 6 dispatched/round, flush at 4 => ~2 stragglers buffered per round
    assert engine.async_state.late_folds > 0 or len(
        engine.async_state.pending) > 0
    assert engine.async_state.max_staleness >= 0
    # reset_pacing clears the event state between trainings
    engine.reset_pacing()
    assert engine.sim_time == 0.0 and not engine.async_state.pending


def test_transform_stack_flows_through_buffered_path():
    """DP clip + noise + quantize on the buffered (slow) path: finite, and
    bit-replayable under the same seed (dispatch-round transform keys)."""
    series, flcfg = _workload(**STRAG, dp_clip=1.0, dp_noise=0.5,
                              quantize_bits=8)
    r1 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    r2 = fedavg.run_federated_training(series, FCFG, flcfg)[-1]
    assert np.isfinite(r1.loss_history).all()
    jax.tree.map(np.testing.assert_array_equal, r1.params, r2.params)
