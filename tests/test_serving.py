"""Serving-tier contracts (ISSUE 8): padded-bucket batching, zero
steady-state recompiles, hot-swap atomicity, int8 parity, checkpoint
publish/poll generations, and unseen-consumer cluster routing.

The load-bearing pins:

* **Zero steady-state jit-cache growth** — after ``warmup()`` a stream of
  ragged request counts WITH a mid-stream hot-swap must add no entries,
  probed via ``analysis.recompile.count_recompiles`` against
  ``ServingEngine.jit_cache_size`` (the acceptance-criteria invariant).
* **Ragged-tail regression** for ``launch/serve.py::serve_forecaster``:
  tails pad to a power-of-two bucket instead of retracing per count.
* **Hot-swap atomicity**: a publish racing a flush lands at the NEXT flush
  boundary — one batch never mixes generations.
* **int8 parity**: the serving quantizer is bit-identical to the uplink
  ``transforms.StochasticQuantize`` grid, and fp32-vs-int8 forecasts agree
  within a pinned MAPE delta.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.analysis import recompile
from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, fedavg
from repro.core.transforms import StochasticQuantize
from repro.data import synthetic, windows
from repro.launch import serve
from repro.models import forecaster
from repro.serving import (GLOBAL_SLOT, ClusterRouter, ModelRegistry,
                           ServingEngine, bucket_for, bucket_ladder,
                           daily_summary_of, dequantize_params,
                           quantize_params)

CFG = ForecasterConfig(hidden_dim=8)
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return forecaster.init_forecaster(jax.random.fold_in(KEY, 1), CFG)


def _manual_kwh(p, window, lo, hi, cfg=CFG):
    """Reference path: normalize -> jitted forward -> denormalize."""
    scale = max(hi - lo, 1e-9)
    xn = (np.asarray(window, np.float32) - lo) / scale
    out = forecaster.forecast(p, jnp.asarray(xn)[None, :, None], cfg)
    return np.asarray(out)[0] * scale + lo


# ------------------------------------------------------------------ buckets
def test_bucket_for_rounds_up_to_clamped_power_of_two():
    assert bucket_for(1, 8, 256) == 8
    assert bucket_for(8, 8, 256) == 8
    assert bucket_for(9, 8, 256) == 16
    assert bucket_for(129, 8, 256) == 256
    assert bucket_for(3, 1, 256) == 4
    with pytest.raises(ValueError):
        bucket_for(0, 8, 256)
    with pytest.raises(ValueError):
        bucket_for(257, 8, 256)


def test_bucket_ladder_is_bounded():
    assert bucket_ladder(8, 64) == [8, 16, 32, 64]
    assert bucket_ladder(16, 16) == [16]


def test_engine_rejects_non_power_of_two_buckets(params):
    reg = ModelRegistry()
    reg.publish(params, CFG, generation=1)
    with pytest.raises(ValueError):
        ServingEngine(reg, max_batch=100)
    with pytest.raises(ValueError):
        ServingEngine(reg, max_batch=8, min_bucket=16)


# ----------------------------------------------------------------- registry
def test_registry_publish_is_strictly_monotone(params):
    reg = ModelRegistry()
    reg.publish(params, CFG, generation=1)
    with pytest.raises(ValueError):
        reg.publish(params, CFG, generation=1)       # stale: not newer
    assert reg.publish(params, CFG, generation=0, if_newer=True) is None
    reg.publish(params, CFG, generation=5)
    assert reg.generation() == 5
    assert reg.generation(slot=3) == -1              # empty slot, no fallback


def test_registry_global_fallback(params):
    reg = ModelRegistry()
    with pytest.raises(KeyError):
        reg.handle(0)                                # nothing published yet
    reg.publish(params, CFG, generation=1)           # GLOBAL_SLOT
    assert reg.handle(3).slot == GLOBAL_SLOT         # unserved cluster
    reg.publish(params, CFG, slot=3, generation=1)
    assert reg.handle(3).slot == 3
    assert reg.slots() == [GLOBAL_SLOT, 3]


def test_registry_int8_publish_requires_key(params):
    reg = ModelRegistry()
    with pytest.raises(ValueError):
        reg.publish(params, CFG, generation=1, weights="int8")
    with pytest.raises(ValueError):
        reg.publish(params, CFG, generation=1, weights="fp16")


# --------------------------------------------------------------------- int8
def test_quantize_matches_uplink_transform_bit_for_bit(params):
    """dequantize(quantize_params(p, k)) == StochasticQuantize(8)(p, k):
    the serving grid IS the wire grid, not a lookalike."""
    k = jax.random.fold_in(KEY, 4)
    deq = dequantize_params(quantize_params(params, k))
    ref = StochasticQuantize(bits=8)(params, k)
    for a, b in zip(jax.tree.leaves(deq), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantize_roundtrip_error_within_one_grid_step(params):
    k = jax.random.fold_in(KEY, 5)
    q = quantize_params(params, k)
    deq = dequantize_params(q)
    for x, d in zip(jax.tree.leaves(params), jax.tree.leaves(deq)):
        step = float(np.max(np.abs(np.asarray(x)))) / 127.0
        assert float(np.max(np.abs(np.asarray(d) - np.asarray(x)))) \
            <= step + 1e-7


def test_int8_vs_fp32_serving_parity_mape_bound(params):
    """fp32-parity pin: int8 serving weights shift forecasts < 2% MAPE
    (measured ~0.4% — the bound leaves quantization-noise headroom)."""
    hist = synthetic.generate_buildings("CA", list(range(8)), days=5)

    def run(weights):
        reg = ModelRegistry()
        reg.publish(params, CFG, generation=1, weights=weights,
                    key=(jax.random.fold_in(KEY, 3)
                         if weights == "int8" else None))
        eng = ServingEngine(reg, max_batch=8, min_bucket=8, auto_flush=False)
        reqs = [eng.submit(i, h[-CFG.lookback:], history=h)
                for i, h in enumerate(hist)]
        eng.flush()
        return np.stack([r.result for r in reqs])

    f32, i8 = run("fp32"), run("int8")
    mape = np.mean(np.abs(i8 - f32) / np.maximum(np.abs(f32), 1e-6))
    assert mape < 0.02, f"int8 serving MAPE delta {mape:.4f} exceeds 2%"


# ------------------------------------------------------------------- engine
def test_engine_forecast_matches_manual_normalization(params):
    """Raw watt-hours in, kWh out: the engine's in-jit normalize/denormalize
    equals the by-hand normalize -> forecast -> denormalize path."""
    reg = ModelRegistry()
    reg.publish(params, CFG, generation=1)
    eng = ServingEngine(reg, max_batch=16, min_bucket=8)
    hist = synthetic.generate_buildings("CA", [7], days=5)[0]
    req = eng.submit(7, hist[-CFG.lookback:], history=hist)
    eng.flush()
    manual = _manual_kwh(params, hist[-CFG.lookback:],
                         float(hist.min()), float(hist.max()))
    np.testing.assert_allclose(req.result, manual, rtol=2e-5, atol=1e-5)


def test_engine_validates_window_length(params):
    reg = ModelRegistry()
    reg.publish(params, CFG, generation=1)
    eng = ServingEngine(reg, max_batch=8, min_bucket=8)
    with pytest.raises(ValueError, match="lookback"):
        eng.submit(0, np.ones(CFG.lookback + 1, np.float32))


def test_engine_auto_flush_at_max_batch(params):
    reg = ModelRegistry()
    reg.publish(params, CFG, generation=1)
    eng = ServingEngine(reg, max_batch=8, min_bucket=8, auto_flush=True)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(None, rng.random(CFG.lookback, np.float32) + 1.0)
            for _ in range(8)]
    assert all(r.done for r in reqs)                 # 8th submit flushed
    assert eng.pending() == 0 and eng.stats.flushes == 1
    assert eng.stats.by_bucket == {8: 1}


def test_consumer_cache_routing_and_window_fallback(params):
    series = synthetic.generate_buildings("CA", list(range(6)), days=4)
    z = windows.daily_average_vector(series, days=3)
    cents, _, _ = clustering.kmeans(z, 2, seed=0)
    router = ClusterRouter(cents)
    reg = ModelRegistry()
    for s in (GLOBAL_SLOT, 0, 1):
        reg.publish(params, CFG, slot=s, generation=1)
    eng = ServingEngine(reg, router, max_batch=8, min_bucket=8,
                        auto_flush=False)
    h = series[0]
    r1 = eng.submit(0, h[-CFG.lookback:], history=h)
    assert r1.slot == router.route(h)                # routed at first contact
    r2 = eng.submit(0, h[-CFG.lookback:])            # cache hit: no history
    assert (r2.slot, r2.lo, r2.hi) == (r1.slot, r1.lo, r1.hi)
    r3 = eng.submit(None, h[-CFG.lookback:])         # anonymous fallback
    assert r3.slot == GLOBAL_SLOT
    assert r3.lo == float(h[-CFG.lookback:].min())   # window-only stats
    eng.flush()
    assert all(r.done for r in (r1, r2, r3))


def test_warmup_compiles_one_program_per_bucket_and_weights(params):
    reg = ModelRegistry()
    reg.publish(params, CFG, generation=1)                       # fp32
    reg.publish(params, CFG, slot=0, generation=1, weights="int8",
                key=jax.random.fold_in(KEY, 6))
    eng = ServingEngine(reg, max_batch=32, min_bucket=8, auto_flush=False)
    ladder = bucket_ladder(8, 32)
    assert eng.warmup() == 2 * len(ladder)           # fp32 + int8 kinds


# ------------------------------------------------- steady-state recompiles
@pytest.mark.parametrize("weights", ["fp32", "int8"])
def test_zero_steady_state_recompiles(params, weights):
    """THE acceptance-criteria invariant: after warmup, ragged request
    streams + a hot-swap add zero jit-cache entries (params are traced,
    shapes are bucketed)."""
    reg = ModelRegistry()
    key = jax.random.fold_in(KEY, 7) if weights == "int8" else None
    reg.publish(params, CFG, generation=1, weights=weights, key=key)
    eng = ServingEngine(reg, max_batch=32, min_bucket=8, auto_flush=False)
    eng.warmup()
    p2 = jax.tree.map(lambda a: a * 1.01, params)
    rng = np.random.default_rng(1)

    def step(i):
        if i == 2:                                   # mid-stream hot-swap
            reg.publish(p2, CFG, generation=1 + i, weights=weights,
                        key=key, if_newer=True)
        for n in (1, 5, 8, 17, 32):                  # ragged, spans ladder
            for _ in range(n):
                eng.submit(None, rng.random(CFG.lookback, np.float32) + 1.0)
            eng.flush()

    rep = recompile.count_recompiles(step, steps=3,
                                     cache_size=eng.jit_cache_size)
    assert rep.ok, rep.render()
    assert eng.stats.swaps_seen >= 1                 # the swap really landed


def test_serve_forecaster_ragged_tail_does_not_retrace(params):
    """Regression (satellite 1): the batch loop pads ragged tails to a
    power-of-two bucket, so once the ≤ log2(batch)+1 bucket shapes are
    compiled, arbitrary request counts reuse them — pinned against the
    jitted forward's own cache."""
    rng = np.random.default_rng(2)
    for b in bucket_ladder(1, 64):                   # warm every bucket once
        serve.serve_forecaster(
            params, CFG, rng.random((b, CFG.lookback)).astype(np.float32),
            batch=64)
    warm = forecaster.forecast._cache_size()
    for n in (65, 67, 70, 93, 127, 130, 200):        # ragged tails galore
        out = serve.serve_forecaster(
            params, CFG, rng.random((n, CFG.lookback)).astype(np.float32),
            batch=64)
        assert out.shape == (n, CFG.horizon)
    assert forecaster.forecast._cache_size() == warm, \
        "ragged final batches retraced the jitted forward"


# -------------------------------------------------------- hot-swap atomicity
class _SwapOnHandle(ModelRegistry):
    """Adversarial registry: fires a publish the instant a flush fetches its
    handle — models a checkpoint poller racing the batch executor."""

    def __init__(self):
        super().__init__()
        self.armed = None

    def handle(self, slot=GLOBAL_SLOT):
        h = super().handle(slot)
        if self.armed is not None:
            fire, self.armed = self.armed, None
            fire()
        return h


def test_hot_swap_never_mixes_params_within_a_batch(params):
    reg = _SwapOnHandle()
    reg.publish(params, CFG, generation=1)
    eng = ServingEngine(reg, max_batch=16, min_bucket=8, auto_flush=False)
    p2 = jax.tree.map(lambda a: a + 1.0, params)     # grossly different
    rng = np.random.default_rng(3)
    wins = (rng.random((10, CFG.lookback)) * 3 + 1).astype(np.float32)
    reqs = [eng.submit(None, w) for w in wins]
    reg.armed = lambda: reg.publish(p2, CFG, generation=2)
    stats = eng.flush()
    # the publish landed immediately after the flush's snapshot: the WHOLE
    # batch must still serve generation 1 — never a gen-1/gen-2 mix
    assert [fs.generation for fs in stats] == [1]
    for r, w in zip(reqs, wins):
        manual = _manual_kwh(params, w, float(w.min()), float(w.max()))
        np.testing.assert_allclose(r.result, manual, rtol=2e-5, atol=1e-5)
    # ... and the NEXT flush boundary observes the new generation
    eng.submit(None, wins[0])
    assert [fs.generation for fs in eng.flush()] == [2]
    assert eng.stats.swaps_seen == 1


# -------------------------------------------------- checkpoint publish/poll
def test_checkpoint_generation_metadata_only(tmp_path):
    tree = {"w": np.arange(3, dtype=np.float32)}
    checkpoint.save(tmp_path / "a", tree, metadata={"generation": 4})
    checkpoint.save(tmp_path / "b", tree, metadata={"rounds_done": 2})
    checkpoint.save(tmp_path / "c", tree)
    assert checkpoint.generation(tmp_path / "a") == 4
    assert checkpoint.generation(tmp_path / "b") == 2    # legacy fallback
    assert checkpoint.generation(tmp_path / "c") == -1   # no metadata


def test_checkpoint_latest_orders_by_generation(tmp_path):
    tree = {"w": np.zeros(2, np.float32)}
    for name, gen in [("r1", 1), ("r3", 3), ("r2", 2)]:
        checkpoint.save(tmp_path / name, tree, metadata={"generation": gen})
    (tmp_path / "half.npz").write_bytes(b"not a zip archive")  # torn write
    path, gen = checkpoint.latest(str(tmp_path / "*.npz"))
    assert (path.name, gen) == ("r3.npz", 3)
    assert checkpoint.latest(str(tmp_path / "missing*.npz")) is None
    # ties break toward the lexicographically LAST path (poller agreement)
    checkpoint.save(tmp_path / "r4", tree, metadata={"generation": 3})
    assert checkpoint.latest(str(tmp_path / "*.npz"))[0].name == "r4.npz"


def test_fl_run_publishes_and_registry_polls_and_serves(tmp_path):
    """End-to-end FL-rounds-as-publisher: train with ``checkpoint_path``,
    poll the glob into a registry (generation = global executed rounds),
    then serve an unseen window off the polled model."""
    flcfg = FLConfig(n_clients=4, clients_per_round=4, rounds=2,
                     n_clusters=0, seed=0, lr=0.05)
    series = synthetic.generate_buildings("CA", list(range(4)), days=4)
    fedavg.run_federated_training(series, CFG, flcfg,
                                  checkpoint_path=tmp_path / "fl",
                                  checkpoint_every=1)
    reg = ModelRegistry()
    updated = reg.poll_checkpoint(str(tmp_path / "*.npz"), CFG)
    assert [h.slot for h in updated] == [GLOBAL_SLOT]
    assert reg.generation(GLOBAL_SLOT) == flcfg.rounds
    # watermark: an unchanged glob is a cheap no-op on the next poll
    assert reg.poll_checkpoint(str(tmp_path / "*.npz"), CFG) == []
    eng = ServingEngine(reg, max_batch=8, min_bucket=8)
    req = eng.submit(0, series[0][-CFG.lookback:], history=series[0])
    eng.flush()
    assert req.done and req.result.shape == (CFG.horizon,)
    assert np.isfinite(req.result).all()


# ------------------------------------------------------------------- router
def test_router_matches_training_side_assignment():
    series = synthetic.generate_buildings("CA", list(range(6)), days=4)
    days = 3
    z = windows.daily_average_vector(series, days=days)
    cents, _, _ = clustering.kmeans(z, 2, seed=0)
    router = ClusterRouter(cents)
    assert router.enabled and router.days == days
    for s in series:
        expect = int(clustering.assign(daily_summary_of(s, days)[None, :],
                                       cents)[0])
        assert router.route(s) == expect
    np.testing.assert_array_equal(router.route_summaries(z),
                                  clustering.assign(z, cents))


def test_router_disabled_maps_everything_global():
    r = ClusterRouter(None)
    assert not r.enabled
    assert r.route(np.ones(10)) == GLOBAL_SLOT
    np.testing.assert_array_equal(r.route_summaries(np.zeros((3, 5))),
                                  [GLOBAL_SLOT] * 3)


def test_daily_summary_pads_ragged_histories():
    # 1.5 days of history: day 1 contributes, the rest pads with its mean
    s = np.concatenate([np.full(96, 2.0), np.full(48, 4.0)])
    np.testing.assert_allclose(daily_summary_of(s, 4), [2.0, 2.0, 2.0, 2.0])
    # sub-day history degenerates to a flat summary
    np.testing.assert_allclose(daily_summary_of(np.full(10, 3.0), 3), 3.0)
    np.testing.assert_allclose(daily_summary_of(np.empty(0), 2), 0.0)
