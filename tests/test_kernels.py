"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gru_cell import gru_cell
from repro.kernels.lstm_cell import lstm_cell

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("B,I,H,bb,bh", [
    (8, 1, 16, 8, 16), (64, 8, 64, 32, 32), (128, 4, 128, 128, 128),
    (32, 16, 256, 16, 64),
])
def test_lstm_cell_sweep(B, I, H, bb, bh, dt):
    r = np.random.default_rng(B + I + H)
    x = jnp.asarray(r.normal(size=(B, I)), dt)
    h = jnp.asarray(r.normal(size=(B, H)), dt)
    c = jnp.asarray(r.normal(size=(B, H)), dt)
    wx = jnp.asarray(r.normal(size=(I, 4 * H)) * 0.2, dt)
    wh = jnp.asarray(r.normal(size=(H, 4 * H)) * 0.2, dt)
    b = jnp.asarray(r.normal(size=(4 * H,)) * 0.2, dt)
    h1, c1 = lstm_cell(x, h, c, wx, wh, b, block_b=bb, block_h=bh,
                       interpret=True)
    h2, c2 = ref.lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **_tol(dt))
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), **_tol(dt))


@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("B,I,H,bb,bh", [
    (8, 1, 16, 8, 16), (64, 8, 64, 32, 32), (128, 4, 128, 128, 128),
])
def test_gru_cell_sweep(B, I, H, bb, bh, dt):
    r = np.random.default_rng(B + I + H + 1)
    x = jnp.asarray(r.normal(size=(B, I)), dt)
    h = jnp.asarray(r.normal(size=(B, H)), dt)
    wx = jnp.asarray(r.normal(size=(I, 3 * H)) * 0.2, dt)
    wh = jnp.asarray(r.normal(size=(H, 3 * H)) * 0.2, dt)
    b = jnp.asarray(r.normal(size=(3 * H,)) * 0.2, dt)
    h1 = gru_cell(x, h, wx, wh, b, block_b=bb, block_h=bh, interpret=True)
    h2 = ref.gru_cell_ref(x, h, wx, wh, b)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **_tol(dt))


@pytest.mark.parametrize("dt", [jnp.float32])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,win,bq,bk", [
    (2, 128, 4, 4, 32, 0, 64, 64),          # MHA
    (2, 256, 8, 2, 64, 0, 128, 128),        # GQA 4:1
    (1, 256, 4, 1, 64, 0, 128, 64),         # MQA
    (1, 512, 2, 2, 32, 128, 128, 128),      # sliding window
    (3, 384, 6, 2, 16, 0, 128, 128),        # odd head count / small hd
])
def test_flash_attention_sweep(B, S, Hq, Hkv, hd, win, bq, bk, dt):
    r = np.random.default_rng(S + Hq)
    q = jnp.asarray(r.normal(size=(B, S, Hq, hd)), dt)
    k = jnp.asarray(r.normal(size=(B, S, Hkv, hd)), dt)
    v = jnp.asarray(r.normal(size=(B, S, Hkv, hd)), dt)
    o1 = flash_attention(q, k, v, window=win, block_q=bq, block_k=bk,
                         interpret=True)
    o2 = ref.flash_attention_ref(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    r = np.random.default_rng(7)
    q = jnp.asarray(r.normal(size=(2, 256, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(r.normal(size=(2, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(r.normal(size=(2, 256, 2, 64)), jnp.bfloat16)
    o1 = flash_attention(q, k, v, interpret=True)
    o2 = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=3e-2, atol=3e-2)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([16, 32, 64]),
       st.sampled_from([8, 16, 64]))
@settings(max_examples=10, deadline=None)
def test_lstm_cell_property(seed, H, B):
    """Fused cell == oracle for random shapes (property sweep)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(B, 4)), jnp.float32)
    h = jnp.asarray(r.normal(size=(B, H)), jnp.float32)
    c = jnp.asarray(r.normal(size=(B, H)), jnp.float32)
    p = {"wx": jnp.asarray(r.normal(size=(4, 4 * H)) * 0.3, jnp.float32),
         "wh": jnp.asarray(r.normal(size=(H, 4 * H)) * 0.3, jnp.float32),
         "b": jnp.asarray(r.normal(size=(4 * H,)) * 0.3, jnp.float32)}
    h1, c1 = ops.lstm_cell_fused(x, h, c, p)
    h2, c2 = ref.lstm_cell_ref(x, h, c, p["wx"], p["wh"], p["b"])
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)


def test_forecaster_pallas_path_matches_jnp():
    """cell_impl='pallas' gives the same forecasts as the jnp path."""
    from repro.configs.base import ForecasterConfig
    from repro.models import forecaster
    r = np.random.default_rng(0)
    for cell in ("lstm", "gru"):
        cfg = ForecasterConfig(cell=cell, hidden_dim=32)
        params = forecaster.init_forecaster(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(r.normal(size=(16, cfg.lookback, 1)), jnp.float32)
        y1 = forecaster.forecast(params, x, cfg, "jnp")
        y2 = forecaster.forecast(params, x, cfg, "pallas")
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_model_flash_path_matches_jnp():
    """USE_FLASH_KERNEL routes full-sequence attention through the Pallas
    kernel (interpret mode) — model outputs must match the jnp path."""
    import numpy as _np
    from repro.configs import get_config
    from repro.models import attention as attn_mod
    from repro.models import transformer as tfm
    cfg = get_config("qwen2-72b").reduced()
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(_np.random.default_rng(0)
                       .integers(0, cfg.vocab_size, (1, 128)), jnp.int32)
    l_ref, _, _ = tfm.forward(params, {"tokens": toks}, cfg,
                              dtype=jnp.float32, remat=False)
    attn_mod.USE_FLASH_KERNEL = True
    try:
        l_flash, _, _ = tfm.forward(params, {"tokens": toks}, cfg,
                                    dtype=jnp.float32, remat=False)
    finally:
        attn_mod.USE_FLASH_KERNEL = False
    np.testing.assert_allclose(np.asarray(l_flash), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
