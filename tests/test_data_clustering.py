"""Synthetic corpus calibration, windowing correctness, K-means invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import clustering
from repro.data import partition, synthetic, windows


def test_series_deterministic():
    a = synthetic.generate_buildings("CA", [5, 7], days=10)
    b = synthetic.generate_buildings("CA", [5, 7], days=10)
    np.testing.assert_array_equal(a, b)
    c = synthetic.generate_buildings("FLO", [5], days=10)
    assert not np.allclose(a[0], c[0])


def test_corpus_calibration_matches_paper_marginals():
    """§4.1 / Fig. 2: min 0.16, Q1 4.7, median 12.7, Q3 28.4 kWh (±tol)."""
    means = synthetic.mean_consumption("CA", list(range(3000)))
    q1, med, q3 = np.percentile(means, [25, 50, 75])
    assert 8.0 < med < 18.0, med                  # paper: 12.7
    assert 3.0 < q1 < 8.0, q1                     # paper: 4.7
    assert 18.0 < q3 < 42.0, q3                   # paper: 28.4
    assert means.min() >= synthetic.MIN_KWH
    assert (means > 63.8).mean() > 0.02           # long tail beyond violin max


def test_series_shape_and_positivity():
    s = synthetic.generate_buildings("RI", [0], days=365)
    assert s.shape == (1, 35040)                  # paper: samples/building
    assert (s > 0).all()


def test_make_windows_alignment():
    series = np.arange(20, dtype=np.float32)
    x, y = windows.make_windows(series, lookback=4, horizon=2)
    assert x.shape == (15, 4, 1) and y.shape == (15, 2)
    np.testing.assert_array_equal(x[0, :, 0], [0, 1, 2, 3])
    np.testing.assert_array_equal(y[0], [4, 5])
    np.testing.assert_array_equal(x[-1, :, 0], [14, 15, 16, 17])
    np.testing.assert_array_equal(y[-1], [18, 19])


def test_minmax_roundtrip():
    r = np.random.default_rng(0)
    s = r.normal(size=(3, 100)).astype(np.float32) * 5 + 10
    n, stats = windows.minmax_normalize(s)
    assert n.min() >= 0 and n.max() <= 1
    np.testing.assert_allclose(windows.denormalize(n, stats), s, rtol=1e-5)


def test_daily_average_vector():
    s = synthetic.generate_buildings("CA", [1], days=30)
    z = windows.daily_average_vector(s, days=20)
    assert z.shape == (1, 20)
    np.testing.assert_allclose(z[0, 0], s[0, :96].mean(), rtol=1e-5)


def test_train_test_split_chronological():
    s = np.arange(100, dtype=np.float32)
    tr, te = windows.train_test_split(s, 0.75)
    assert len(tr) == 75 and len(te) == 25
    assert tr[-1] < te[0]


# ------------------------------------------------------------- K-means
@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_kmeans_assignment_is_nearest_centroid(seed, k):
    r = np.random.default_rng(seed)
    x = r.normal(size=(40, 8))
    cents, assign, inertia = clustering.kmeans(x, k, seed=seed)
    d2 = ((x[:, None, :] - cents[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(assign, d2.argmin(1))
    assert inertia >= 0


def test_kmeans_separated_clusters():
    r = np.random.default_rng(0)
    x = np.concatenate([r.normal(size=(20, 4)) + 10,
                        r.normal(size=(20, 4)) - 10])
    _, assign, _ = clustering.kmeans(x, 2, seed=0)
    assert len(set(assign[:20])) == 1 and len(set(assign[20:])) == 1
    assert assign[0] != assign[-1]
    sil = clustering.silhouette_score(x, assign)
    assert sil > 0.8


def test_elbow_curve_monotone():
    r = np.random.default_rng(1)
    x = r.normal(size=(60, 6))
    inertias = clustering.elbow_curve(x, [1, 2, 4, 8], seed=0)
    assert (np.diff(inertias) <= 1e-6).all()      # inertia non-increasing in k


def test_assign_heldout():
    cents = np.array([[0.0, 0.0], [10.0, 10.0]])
    x = np.array([[1.0, 1.0], [9.0, 9.0]])
    np.testing.assert_array_equal(clustering.assign(x, cents), [0, 1])


# ------------------------------------------------------------- partition
def test_sample_clients_no_replacement():
    r = np.random.default_rng(0)
    s = partition.sample_clients(r, 100, 30)
    assert len(np.unique(s)) == 30


def test_local_steps_matches_epochs():
    assert partition.local_steps(100, 32, 1) == 4     # ceil(100/32)
    assert partition.local_steps(100, 32, 3) == 12
    assert partition.local_steps(1, 64, 2) == 2
