"""Sharding rules, local-SGD/DiLoCo semantics, cost model, SARIMA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import local_sgd, sarima
from repro.data import synthetic
from repro.launch import costmodel
from repro.sharding import ShardingRules, constrain, shard_map, use_rules
from repro.sharding.rules import safe_spec


# ------------------------------------------------------------- rules
def test_safe_spec_drops_indivisible_axes():
    mesh = jax.make_mesh((1,), ("model",))
    # single-device axes (size 1) always pass through
    assert safe_spec((56, 64), P("model", None), mesh) == P("model", None)


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x


def test_param_pspec_rules():
    mesh = jax.make_mesh((1,), ("data",))
    rules = ShardingRules(mesh, tensor_axis="model", fsdp_axis="data")
    assert rules.param_pspec(("blocks", "attn", "wq"), (1024, 2048)) == \
        P("data", "model")
    assert rules.param_pspec(("blocks", "attn", "wo"), (2048, 1024)) == \
        P("model", "data")
    # stacked layer axis is never sharded
    assert rules.param_pspec(("blocks", "moe", "moe_w_in"),
                             (24, 16, 512, 128)) == \
        P(None, "model", "data", None)
    assert rules.param_pspec(("final_norm",), (1024,)) == P(None)


def test_shard_batch_off_disables_batch_axes():
    mesh = jax.make_mesh((1,), ("data",))
    rules = ShardingRules(mesh, shard_batch=False)
    assert rules.logical["batch"] is None


# ------------------------------------------------------------- local SGD
def test_fedavg_outer_is_pmean():
    mesh = jax.make_mesh((1,), ("pod",))

    def f(p):
        return local_sgd.fedavg_outer(p, "pod")

    p = {"w": jnp.arange(4.0)}
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(),
                                out_specs=P()))(p)
    np.testing.assert_allclose(out["w"], p["w"])          # 1 pod: identity


def test_outer_step_plain_fedavg_semantics():
    """outer_lr=1, momentum=0 ⇒ anchor ← mean(local params)."""
    mesh = jax.make_mesh((1,), ("pod",))
    cfg = local_sgd.LocalSGDConfig(outer_lr=1.0, outer_momentum=0.0,
                                   nesterov=False)
    anchor = {"w": jnp.zeros(3)}
    local = {"w": jnp.ones(3) * 2.0}

    def f(local_p):
        st = local_sgd.init_outer_state(anchor)
        new_anchor, _ = local_sgd.outer_step(local_p, st, cfg, "pod")
        return new_anchor

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(),
                                out_specs=P()))(local)
    np.testing.assert_allclose(out["w"], 2.0)             # = mean of locals


def test_outer_momentum_accumulates():
    mesh = jax.make_mesh((1,), ("pod",))
    cfg = local_sgd.LocalSGDConfig(outer_lr=0.5, outer_momentum=0.9,
                                   nesterov=True)
    anchor = {"w": jnp.zeros(2)}

    def f(local_p):
        st = local_sgd.init_outer_state(anchor)
        a1, st = local_sgd.outer_step(local_p, st, cfg, "pod")
        a2, st = local_sgd.outer_step(local_p, st, cfg, "pod")
        return a1, a2

    local = {"w": jnp.ones(2)}
    a1, a2 = jax.jit(shard_map(f, mesh=mesh, in_specs=P(),
                                   out_specs=P()))(local)
    assert abs(float(a2["w"][0])) > abs(float(a1["w"][0]))


def test_make_sharded_outer_single_pod_matches_outer_step():
    """1-pod mesh: the sharded sync == a direct outer_step on that pod."""
    mesh = jax.make_mesh((1,), ("pod",))
    cfg = local_sgd.LocalSGDConfig(outer_lr=1.0, outer_momentum=0.0,
                                   nesterov=False)
    anchor = {"w": jnp.zeros(3)}
    state = local_sgd.init_outer_state(anchor)
    local = {"w": jnp.ones((1, 3)) * 2.0}       # (n_pods=1, ...) stacked
    sync = local_sgd.make_sharded_outer(mesh, cfg)
    new_anchor, _ = sync(local, state)
    np.testing.assert_allclose(new_anchor["w"], 2.0)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs multi-device CPU (run via ./test.sh)")
def test_make_sharded_outer_averages_divergent_pods():
    """2-pod mesh: pods that drifted apart sync to the cross-pod mean."""
    mesh = jax.make_mesh((2,), ("pod",))
    cfg = local_sgd.LocalSGDConfig(outer_lr=1.0, outer_momentum=0.0,
                                   nesterov=False)
    anchor = {"w": jnp.zeros(4)}
    state = local_sgd.init_outer_state(anchor)
    local = {"w": jnp.stack([jnp.full(4, 1.0), jnp.full(4, 3.0)])}
    sync = local_sgd.make_sharded_outer(mesh, cfg)
    new_anchor, _ = sync(local, state)
    np.testing.assert_allclose(new_anchor["w"], 2.0)      # mean of 1 and 3


# ------------------------------------------------------------- cost model
def test_jaxpr_cost_counts_scan_trips():
    W = jnp.ones((32, 32))
    x = jnp.ones((4, 32))

    def f(W):
        def body(c, _):
            return c @ W, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)

    flops = costmodel.jaxpr_cost(jax.make_jaxpr(f)(W))["flops"]
    want = 7 * 2 * 4 * 32 * 32
    assert abs(flops - want) / want < 0.05


def test_jaxpr_cost_grad_triples_dot_flops():
    W = jnp.ones((64, 64))
    x = jnp.ones((8, 64))
    fwd = costmodel.jaxpr_cost(
        jax.make_jaxpr(lambda w: jnp.sum(x @ w))(W))["flops"]
    bwd = costmodel.jaxpr_cost(
        jax.make_jaxpr(jax.grad(lambda w: jnp.sum(x @ w)))(W))["flops"]
    assert 1.5 < bwd / fwd < 2.6                  # fwd+wgrad (dgrad DCE'd)


def test_hlo_collective_parser_trip_counts():
    hlo = """
HloModule test

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %ag = bf16[128,64] all-gather(%x), dimensions={0}
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (a: bf16[8]) -> bf16[8] {
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %ar = f32[256] all-reduce(%y), to_apply=%add
  ROOT %r = bf16[8] copy(%a)
}
"""
    out = costmodel.hlo_collective_bytes(hlo)
    assert out["all-gather"] == 12 * 128 * 64 * 2         # ×12 trips
    assert out["all-reduce"] == 256 * 4


# ------------------------------------------------------------- SARIMA
@pytest.mark.slow
def test_sarima_fits_seasonal_series():
    t = np.arange(96 * 40, dtype=np.float64)
    series = (10 + 5 * np.sin(2 * np.pi * t / 96)
              + np.random.default_rng(0).normal(0, 0.3, len(t)))
    model = sarima.auto_fit(series[:96 * 30])
    fc = sarima.forecast(model, series[:96 * 30], 8)
    actual = series[96 * 30:96 * 30 + 8]
    mape = np.abs((fc - actual) / actual).mean()
    assert mape < 0.15, mape


def test_sarima_rolling_protocol_shapes():
    s = synthetic.generate_buildings("CA", [2], days=33)[0]
    pred, actual = sarima.rolling_forecast(s, lookahead=4, fit_days=30,
                                           horizon_days=1)
    assert pred.shape == actual.shape
    assert pred.shape[1] == 4
    assert np.isfinite(pred).all()


def test_hlo_parser_tuple_allreduce_and_pod_split():
    """Variadic tuple all-reduces sum all elements; pod classification
    catches both replica_groups and source_target_pairs."""
    hlo = """
HloModule t

ENTRY %main (a: bf16[8]) -> bf16[8] {
  %ar = (f32[10,10], f32[4,4]) all-reduce(%x, %y), replica_groups=[256,2]<=[2,256]T(1,0), to_apply=%add
  %cp = bf16[64] collective-permute(%z), source_target_pairs={{0,256},{256,0}}
  %ag = bf16[32,16] all-gather(%w), replica_groups=[32,16]<=[512], dimensions={0}
  ROOT %r = bf16[8] copy(%a)
}
"""
    out = costmodel.hlo_collective_bytes(hlo, pod_size=256)
    assert out["all-reduce"] == (100 + 16) * 4            # tuple summed
    assert out["collective-permute"] == 64 * 2
    # pod-spanning: the [256,2]<=[2,256]T(1,0) groups pair (i, i+256);
    # the permute pairs cross pods; the [32,16]<=[512] groups are intra-pod
    assert out["inter_pod"] == (100 + 16) * 4 + 64 * 2


def test_spans_pod_iota_formats():
    assert costmodel._spans_pod(
        "x replica_groups=[256,2]<=[2,256]T(1,0)", 256)
    assert not costmodel._spans_pod(
        "x replica_groups=[32,16]<=[512]", 256)
    assert costmodel._spans_pod(
        "x replica_groups={{0,300}}", 256)
    assert not costmodel._spans_pod(
        "x source_target_pairs={{0,1},{1,0}}", 256)
