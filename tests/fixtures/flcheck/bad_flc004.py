"""Fixture: nondeterminism in replay-critical code — triggers FLC004 only.

The FLC004 rule is scoped to ``src/repro/core/`` + ``src/repro/data/``;
tests feed this file to the checker under a pretend path in that scope.
"""
import time

import numpy as np


def event_timestamp():
    return time.time()                     # FLC004: wall clock


def jitter_draw():
    return np.random.normal()              # FLC004: global numpy rng


def stable_tag(name):
    return hash(name) % 1000               # FLC004: salted builtin hash


def collect(members):
    out = []
    for m in set(members):                 # FLC004: unordered iteration
        out.append(m)
    return out
