"""Fixture: idiomatic code that must produce ZERO findings under every rule.

Exercises the sanctioned counterparts of each bad fixture: fold_in/split
derivation, SeedSequence mixing, per-iteration key refresh, comprehension
key zips, perf_counter timing, Generator rng, sorted iteration, fp32
contractions with explicit accumulation dtype.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np


def derived_key(cfg_key, cid):
    return jax.random.fold_in(cfg_key, cid)


def split_draws(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k2, (4,))
    return a + b


def rebind_draws(key):
    key, sub = jax.random.split(key)
    a = jax.random.normal(sub, (4,))
    key, sub = jax.random.split(key)       # rebind clears consumption
    b = jax.random.normal(sub, (4,))
    return a + b


def loop_draws(key, n):
    out = []
    for i in range(n):
        key, sub = jax.random.split(key)   # per-iteration refresh
        out.append(jax.random.normal(sub, (2,)))
    return out


def zipped_draws(key, leaves):
    ks = jax.random.split(key, len(leaves))
    return [x + jax.random.normal(k, x.shape) for x, k in zip(leaves, ks)]


def seeded_rng(seed, init):
    return np.random.default_rng(np.random.SeedSequence([seed, init]))


def bench_timing():
    t0 = time.perf_counter()               # perf_counter is fine anywhere
    return time.perf_counter() - t0


def ordered_members(members):
    return [m for m in sorted(set(members))]


def f32_contract(a, b):
    return jnp.einsum("ij,jk->ik", a.astype(b.dtype), b,
                      preferred_element_type=jnp.float32)


def host_metrics(err):
    return np.asarray(err, np.float64)     # host-side fp64 is legitimate
