"""Fixture: Python branch on a traced value — triggers FLC009 only.

The FLC009 rule is scoped to ``src/repro/serving/``; tests feed this file
to the checker under a pretend path in that scope.  Both constructs raise
``TracerBoolConversionError`` under jit, and in eager serving code force a
blocking device->host sync on every request.
"""
import jax.numpy as jnp


def guard_nan(pred):
    if jnp.any(jnp.isnan(pred)):           # FLC009: if on a traced bool
        return jnp.zeros_like(pred)
    return pred


def drain(pred, budget):
    while jnp.sum(pred) > budget:          # FLC009: while on a traced bool
        pred = pred * 0.5
    return pred
