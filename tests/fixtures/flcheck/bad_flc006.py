"""Fixture: unlocked mutation of shared state — triggers FLC006 only.

The FLC006 rule is scoped to ``src/repro/serving/``; tests feed this file
to the checker under a pretend path in that scope.  The class owns a lock
and uses it for the evicting write, but the publish path mutates the same
shared dict WITHOUT it — the race FLC006 exists to catch.  (The locked
``pop`` keeps FLC008 quiet: the mapping has an eviction path.)
"""
import threading


class RacyRegistry:
    def __init__(self):
        self._slots = {}
        self._lock = threading.Lock()

    def publish(self, slot, handle):
        self._slots[slot] = handle         # FLC006: write outside the lock

    def retire(self, slot):
        with self._lock:
            return self._slots.pop(slot, None)
