"""Fixture: raw literal PRNG keys — triggers FLC001 and nothing else."""
import jax


def init_model():
    key = jax.random.PRNGKey(0)            # FLC001
    return jax.random.normal(key, (4,))


def other_stream():
    k = jax.random.key(42)                 # FLC001 (new-style key API)
    return jax.random.uniform(k, (2,))
