"""Fixture: dtype hazards — triggers FLC005 and nothing else.

Scoped like FLC004: tests feed this under a pretend ``src/repro/core/``
path.
"""
import jax.numpy as jnp


def promote(x):
    return x.astype(jnp.float64)           # FLC005: fp64 on device path


def alloc(n):
    return jnp.zeros((n,), dtype="float64")    # FLC005: fp64 alloc


def wrap_prone(x, y):
    return x.astype(jnp.int8) + y          # FLC005: narrow-int arithmetic


def low_precision_contract(a, b):
    return jnp.einsum("ij,jk->ik", a.astype(b.dtype), b)   # FLC005: no
    #                                      # preferred_element_type
