"""Fixture: key reuse — triggers FLC002 and nothing else."""
import jax


def double_draw(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))      # FLC002: key already consumed
    return a + b


def loop_draw(key, n):
    out = []
    for _ in range(n):
        out.append(jax.random.normal(key, (2,)))   # FLC002: same bits/iter
    return out
