"""Fixture: serving-style code that is CLEAN under FLC006-FLC009.

Same shapes as the bad fixtures, written the sanctioned way: every shared
mutation under the class's own lock, one handle snapshot per function,
bounded LRU eviction on the per-key cache, data-plane selection via
``jnp.where`` instead of a Python branch.
"""
import collections
import threading

import jax.numpy as jnp


class LockedRegistry:
    def __init__(self):
        self._slots = {}
        self._lock = threading.Lock()

    def publish(self, slot, handle):
        with self._lock:
            self._slots[slot] = handle

    def retire(self, slot):
        with self._lock:
            return self._slots.pop(slot, None)


class BoundedCache:
    def __init__(self, cap=128):
        self.cap = cap
        self._entries = collections.OrderedDict()

    def record(self, consumer_id, forecast):
        self._entries[consumer_id] = forecast
        while len(self._entries) > self.cap:
            self._entries.popitem(last=False)


def snapshot_fetch(registry, slot):
    handle = registry.handle(slot)         # ONE snapshot, reused
    return handle.cfg, handle.params, handle.generation


def guard_nan(pred):
    return jnp.where(jnp.isnan(pred), jnp.zeros_like(pred), pred)
