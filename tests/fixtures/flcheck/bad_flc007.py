"""Fixture: non-atomic ModelHandle fetches — triggers FLC007 only.

The FLC007 rule is scoped to ``src/repro/serving/``; tests feed this file
to the checker under a pretend path in that scope.  Both functions race a
hot swap: the registry can publish a new generation between the two looks,
so the second look does not see what the first one decided on.
"""


def double_fetch(registry, slot):
    cfg = registry.handle(slot).cfg
    params = registry.handle(slot).params  # FLC007: second fetch, same slot
    return cfg, params


def check_then_fetch(registry, slot, last_gen):
    if registry.generation(slot) == last_gen:
        return None
    return registry.handle(slot)           # FLC007: TOCTOU on the probe
