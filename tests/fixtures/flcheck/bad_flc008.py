"""Fixture: unbounded per-key cache growth — triggers FLC008 only.

The FLC008 rule is scoped to ``src/repro/serving/``; tests feed this file
to the checker under a pretend path in that scope.  Every consumer id ever
seen stays in the dict forever: no eviction, no size check — the leak
pattern real serving traffic turns into an OOM.  (No lock attr in the
class, so FLC006 stays quiet.)
"""


class LeakyResults:
    def __init__(self):
        self._results = {}

    def record(self, consumer_id, forecast):
        self._results[consumer_id] = forecast   # FLC008: grow-only mapping

    def fetch(self, consumer_id):
        return self._results.get(consumer_id)
