"""Fixture: arithmetic seed derivation — triggers FLC003 and nothing else."""
import jax
import numpy as np


def per_client_key(seed, cid):
    return jax.random.PRNGKey(seed + cid)  # FLC003: (s, 1) == (s+1, 0)


def per_init_rng(seed, init):
    return np.random.default_rng(seed * 100 + init)   # FLC003
