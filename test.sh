#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Forces 8 virtual CPU devices BEFORE jax initializes so the multi-device
# shard_map tests (clients sharded over a real >1-device mesh) actually
# exercise cross-shard psum aggregation on a laptop/CI box (olmax idiom).
#
#   ./test.sh                 # fast default suite (slow tests deselected)
#                             # + 1-round streaming-scalability bench smoke
#   ./test.sh -m slow         # only the slow sweeps
#   ./test.sh -m ""           # everything
#   ./test.sh tests/test_server_opt.py -k shard_map
set -euo pipefail
cd "$(dirname "$0")"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# flcheck smoke first: AST lint over src/ + vmap taint proof that no raw
# client delta reaches the aggregation boundary unsanitized — fails fast
# before the (slower) pytest run.  Full topology matrix: tools/flcheck --all
echo "== flcheck smoke (lint + quick taint proof)"
tools/flcheck --quick-taint src/

# level-3 cost-audit smoke: the statically derived wire bytes / stage FLOPs
# must match the committed baseline (the 8-virtual-device geometry above
# covers the flat8/hier2x4 paths) — docs/static_analysis.md
echo "== flcheck cost-audit smoke (wire bytes + stage FLOPs vs baseline)"
tools/flcheck --no-lint --cost --baseline src/repro/analysis/baselines/round_costs.json

python -m pytest -q "$@"

# Default run also smokes the streaming client-window path (1 round over a
# 1000-client population, O(m) per round) so 10k+ scaling can't silently rot,
# then the full pipeline: DP clip + noise + RING-masked int8 deltas (masking
# + quantization compose in the quantizer's integer ring — the secure-agg
# wire stays int8+scale, asserted by the audited byte table the smoke
# prints) aggregated edge->region->cloud over the 2x4 (region, clients) mesh.
if [ "$#" -eq 0 ]; then
  echo "== bench_scalability smoke (streaming provider, 1 round)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_scalability.py \
      --clients 1000 --rounds 1 --clients-per-round 16 --days 30 --smoke
  echo "== bench_scalability smoke (DP + ring-masked int8 + hierarchical, 1 round)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_scalability.py \
      --clients 1000 --rounds 1 --clients-per-round 16 --days 30 --smoke \
      --dp-clip 1.0 --dp-noise 0.5 --quantize 8 --hier --regions 2 \
      --secure-agg
  echo "== bench_scalability smoke (semi-sync buffered rounds, lognormal stragglers)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_scalability.py \
      --clients 200 --rounds 3 --clients-per-round 8 --days 30 --smoke \
      --mode semi_sync --stragglers lognormal --over-select 1.5
  # churn axis: nonzero dropout with secure-agg cohort re-key on the RING
  # wire (--quantize 8 + --dp-clip: the rekey mask correction runs mod 2^b).
  # buffer_k is pinned to m' = ceil(1.5*8) = 12 (wait-for-cohort) because
  # cohort-atomic folds at a k-th-arrival clock need >=4 rounds AND a
  # full-cohort flush threshold to complete any fold in a smoke-sized run.
  echo "== bench_scalability smoke (client churn + dropout, ring-masked re-key)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_scalability.py \
      --clients 200 --rounds 4 --clients-per-round 8 --days 30 --smoke \
      --mode semi_sync --stragglers lognormal --over-select 1.5 \
      --buffer-k 12 --secure-agg --quantize 8 --dp-clip 1.0 \
      --churn 0,0.2 --timeout-rounds 1
  # serving smoke: replay a small Poisson trace through the padded-bucket
  # engine with cluster routing + a mid-replay hot-swap; asserts zero
  # steady-state recompiles (jit-cache probe) on fp32 AND int8 weights.
  echo "== bench_serving smoke (replayed trace, hot-swap + routing)"
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/bench_serving.py --smoke
fi
