#!/usr/bin/env bash
# Tier-1 test entry point.
#
# Forces 8 virtual CPU devices BEFORE jax initializes so the multi-device
# shard_map tests (clients sharded over a real >1-device mesh) actually
# exercise cross-shard psum aggregation on a laptop/CI box (olmax idiom).
#
#   ./test.sh                 # fast default suite (slow tests deselected)
#   ./test.sh -m slow         # only the slow sweeps
#   ./test.sh -m ""           # everything
#   ./test.sh tests/test_server_opt.py -k shard_map
set -euo pipefail
cd "$(dirname "$0")"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q "$@"
