"""End-to-end driver (deliverable b): the paper's full pipeline.

K-means clustering on privacy-coarsened summaries → per-cluster FedAvg LSTM
training with EW-MSE → held-out evaluation vs the single global model —
i.e. Tables 2/3 + the EW-MSE ablation at example scale.

  PYTHONPATH=src python examples/fl_forecasting_e2e.py [--rounds 60]
"""
import argparse

import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, fedavg
from repro.data import synthetic, windows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA")
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--heldout", type=int, default=40)
    ap.add_argument("--days", type=int, default=120)
    args = ap.parse_args()

    series = synthetic.generate_buildings(args.state,
                                          list(range(args.clients)),
                                          days=args.days)
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    base = dict(n_clients=args.clients, clients_per_round=args.clients,
                rounds=args.rounds, lr=0.05, loss="ew_mse", beta=2.0,
                cluster_days=min(273, int(args.days * 0.75)))

    print(f"== clustered FL ({args.clients} clients → 4 clusters)")
    res_c = fedavg.run_federated_training(
        series, fcfg, FLConfig(**base, n_clusters=4),
        log_every=args.rounds // 2)
    print("== global FL (no clustering)")
    res_g = fedavg.run_federated_training(
        series, fcfg, FLConfig(**base, n_clusters=0),
        log_every=args.rounds // 2)

    held = synthetic.generate_buildings(
        args.state, list(range(10_000, 10_000 + args.heldout)),
        days=args.days)
    data = windows.batched_client_windows(held, fcfg.lookback, fcfg.horizon)
    x, y, stats = windows.flatten_test_windows(data)

    g = fedavg.evaluate_global(res_g[-1].params, x, y, fcfg, stats=stats)
    print(f"\nglobal model  F^A : accuracy {g['accuracy']:.2f}%  "
          f"rmse {g['rmse']:.3f}  per-horizon "
          f"{np.round(g['per_horizon_accuracy'], 1)}")

    z = windows.daily_average_vector(held, base["cluster_days"])
    assign = clustering.assign(z, res_c[0].cluster_centroids)
    n_win = data["x_test"].shape[1]
    accs = []
    for cid, res in sorted(res_c.items()):
        m = np.repeat(assign == cid, n_win)
        if not m.any():
            continue
        met = fedavg.evaluate_global(res.params, x[m], y[m], fcfg,
                                     stats=(stats[0][m], stats[1][m]))
        accs.append(met["accuracy"])
        print(f"cluster model F^C{cid}: accuracy {met['accuracy']:.2f}%  "
              f"({int(m.sum() / n_win)} held-out buildings)")
    print(f"\navg of cluster models: {np.mean(accs):.2f}% vs global "
          f"{g['accuracy']:.2f}%  (paper: clustering ≥ global)")


if __name__ == "__main__":
    main()
