"""End-to-end driver (deliverable b): the paper's full pipeline.

K-means clustering on privacy-coarsened summaries → per-cluster federated
LSTM training with EW-MSE → held-out evaluation vs the single global model —
i.e. Tables 2/3 + the EW-MSE ablation at example scale — plus the round
engine's server-optimizer axis and an unseen-CLIENT generalization report:
buildings held out of training entirely (``--holdout-frac``) and fresh
buildings from every state, scored with no client-side retraining (§5.4).

``--ragged`` gives every building a different history length (new deployments
next to year-old ones) — the regime where sample-count-weighted aggregation
and weighted sampling actually differ from uniform; training then runs
through the streaming ``ClientWindowProvider`` with count-masked windows.

  PYTHONPATH=src python examples/fl_forecasting_e2e.py [--rounds 60]
  PYTHONPATH=src python examples/fl_forecasting_e2e.py \
      --server-opt fedadam --server-lr 0.05
  PYTHONPATH=src python examples/fl_forecasting_e2e.py \
      --ragged --server-opt fedavg_weighted --sampling weighted
"""
import argparse

import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, fedavg
from repro.core.sampling import SAMPLING_STRATEGIES
from repro.core.server_opt import SERVER_OPTS
from repro.data import synthetic, windows
from repro.data.windows import ClientWindowProvider


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA")
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--heldout", type=int, default=40)
    ap.add_argument("--days", type=int, default=120)
    ap.add_argument("--server-opt", default="fedavg", choices=SERVER_OPTS,
                    help="server aggregation/optimizer rule")
    ap.add_argument("--server-lr", type=float, default=1.0,
                    help="server step on the pseudo-gradient "
                         "(fedadam/fedyogi want ~0.03-0.1)")
    ap.add_argument("--prox-mu", type=float, default=0.0,
                    help="FedProx proximal strength (with --server-opt fedprox)")
    ap.add_argument("--sampling", default="uniform",
                    choices=SAMPLING_STRATEGIES)
    ap.add_argument("--holdout-frac", type=float, default=0.0,
                    help="fraction of clients excluded from training for the "
                         "unseen-client eval (0 keeps the paper's exact "
                         "training population; fresh-building transfer is "
                         "reported either way)")
    ap.add_argument("--ragged", action="store_true",
                    help="give each building a different history length "
                         "(1/3 .. 1x of --days): sample-count weighting and "
                         "weighted sampling become material, and training "
                         "streams through the ClientWindowProvider")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="per-client delta L2 clip norm C (0 = off)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="Gaussian DP noise multiplier (std = z*C; 0 = off)")
    ap.add_argument("--quantize", type=int, default=0,
                    help="stochastic b-bit delta quantization (0 = off)")
    ap.add_argument("--quantize-ring", action="store_true",
                    help="shared-grid ring quantizer (needs --quantize): the "
                         "clear comparator of the secure-agg wire — masked "
                         "runs use it automatically (docs/privacy.md)")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-masked uploads whose masks cancel in the "
                         "aggregate; with --quantize the masks live in the "
                         "quantizer's integer ring (int-b wire, uniform "
                         "masked uploads) and, under uniform aggregation, "
                         "the accountant switches to central secure-agg "
                         "mode (docs/privacy.md)")
    ap.add_argument("--mask-std", type=float, default=1.0,
                    help="per-pair secure-agg mask scale (float path only: "
                         "ring masks are uniform over the ring)")
    ap.add_argument("--privacy-delta", type=float, default=1e-5,
                    help="target delta for the (eps, delta) accountant "
                         "(reported when --dp-clip AND --dp-noise are set)")
    ap.add_argument("--hier", action="store_true",
                    help="hierarchical edge->region->cloud aggregation (the "
                         "(region, clients) mesh is built automatically)")
    ap.add_argument("--mode", default="sync", choices=("sync", "semi_sync"),
                    help="round pacing: semi_sync buffers stragglers and "
                         "folds them later with staleness-discounted weights")
    ap.add_argument("--over-select", type=float, default=1.5,
                    help="semi_sync dispatch factor: m' = ceil(f * m)")
    ap.add_argument("--buffer-k", type=int, default=0,
                    help="absolute semi_sync flush threshold (0 = use "
                         "--buffer-frac)")
    ap.add_argument("--buffer-frac", type=float, default=0.75,
                    help="flush at ceil(frac * round dispatch size) — "
                         "relative, so it adapts to uneven k-means cluster "
                         "sizes (with full participation there is no over-"
                         "selection headroom, so the demo sheds the slowest "
                         "quarter instead)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="late-update weight discount (1+tau)^-alpha")
    ap.add_argument("--stragglers", default="deterministic",
                    choices=("deterministic", "lognormal", "heavy_tail"),
                    help="simulated client-latency distribution")
    ap.add_argument("--straggler-jitter", type=float, default=1.0,
                    help="straggler spread (0 = deterministic latency)")
    ap.add_argument("--dropout-prob", type=float, default=0.0,
                    help="per-dispatch client dropout probability (semi_sync "
                         "only): dropped uploads never arrive; the engine "
                         "re-dispatches after --timeout-rounds and, under "
                         "--secure-agg, re-keys the surviving cohort")
    ap.add_argument("--absent-prob", type=float, default=0.0,
                    help="per-round client unavailability: absent clients "
                         "are excluded from selection that round")
    ap.add_argument("--timeout-rounds", type=int, default=2,
                    help="rounds a dispatched update may stay unarrived "
                         "before the engine declares it lost")
    ap.add_argument("--checkpoint", default="",
                    help="checkpoint path stem: training state is saved as "
                         "<stem>.clustered.npz / <stem>.global.npz and a "
                         "killed run resumes bit-identically")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="save the checkpoint every N rounds")
    args = ap.parse_args()

    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    if args.ragged:
        dr = np.random.default_rng(7)
        days_i = dr.integers(max(20, args.days // 3), args.days + 1,
                             size=args.clients)
        series = [synthetic.generate_buildings(args.state, [i],
                                               days=int(d))[0]
                  for i, d in enumerate(days_i)]
        # full-participation training revisits every client each round, so
        # cache all of them (the raw series are in memory anyway)
        train_data = ClientWindowProvider.from_series(
            series, fcfg.lookback, fcfg.horizon, cache_size=args.clients)
        c = train_data.train_counts
        print(f"== ragged histories: {args.clients} clients, train windows "
              f"min/median/max = {c.min()}/{int(np.median(c))}/{c.max()} "
              f"(count-masked streaming batches)")
    else:
        series = synthetic.generate_buildings(args.state,
                                              list(range(args.clients)),
                                              days=args.days)
        train_data = series
    base = dict(n_clients=args.clients, clients_per_round=args.clients,
                rounds=args.rounds, lr=0.05, loss="ew_mse", beta=2.0,
                cluster_days=min(273, int(args.days * 0.75)),
                server_opt=args.server_opt, server_lr=args.server_lr,
                prox_mu=args.prox_mu, sampling=args.sampling,
                holdout_frac=args.holdout_frac, dp_clip=args.dp_clip,
                dp_noise=args.dp_noise, quantize_bits=args.quantize,
                quantize_ring=args.quantize_ring,
                secure_agg=args.secure_agg, secure_mask_std=args.mask_std,
                privacy_delta=args.privacy_delta,
                aggregation="hierarchical" if args.hier else "flat",
                mode=args.mode, over_select=args.over_select,
                buffer_k=args.buffer_k,
                # an explicit --buffer-k wins; otherwise the relative
                # threshold flushes at frac of each round's ACTUAL dispatch
                # size, which tracks uneven k-means cluster memberships
                buffer_frac=(0.0 if args.buffer_k or args.mode != "semi_sync"
                             else args.buffer_frac),
                staleness_alpha=args.staleness_alpha,
                stragglers=args.stragglers,
                straggler_jitter=args.straggler_jitter,
                dropout_prob=args.dropout_prob,
                absent_prob=args.absent_prob,
                timeout_rounds=args.timeout_rounds)
    ckpt = dict(checkpoint_every=args.checkpoint_every)

    pipe = ""
    if (args.dp_clip or args.dp_noise or args.quantize or args.hier
            or args.secure_agg):
        ring = bool(args.quantize) and (args.quantize_ring or args.secure_agg)
        pipe = (f", transforms clip={args.dp_clip}/noise={args.dp_noise}"
                f"/quant={args.quantize}b{'-ring' if ring else ''}"
                f"{'/masked' if args.secure_agg else ''}"
                f", agg={base['aggregation']}")
    if args.mode == "semi_sync":
        thresh = (f"buffer_k={args.buffer_k}" if args.buffer_k
                  else f"buffer_frac={args.buffer_frac}")
        pipe += (f", semi_sync(over_select={args.over_select}, {thresh}, "
                 f"alpha={args.staleness_alpha}, "
                 f"stragglers={args.stragglers})")
    if args.dropout_prob or args.absent_prob:
        pipe += (f", churn(dropout={args.dropout_prob}, "
                 f"absent={args.absent_prob}, "
                 f"timeout={args.timeout_rounds}r)")
    print(f"== clustered FL ({args.clients} clients → 4 clusters, "
          f"server_opt={args.server_opt}, sampling={args.sampling}{pipe})")
    res_c = fedavg.run_federated_training(
        train_data, fcfg, FLConfig(**base, n_clusters=4),
        log_every=args.rounds // 2,
        checkpoint_path=(f"{args.checkpoint}.clustered"
                         if args.checkpoint else None), **ckpt)
    print("== global FL (no clustering)")
    res_g = fedavg.run_federated_training(
        train_data, fcfg, FLConfig(**base, n_clusters=0),
        log_every=args.rounds // 2,
        checkpoint_path=(f"{args.checkpoint}.global"
                         if args.checkpoint else None), **ckpt)

    # privacy: the (eps, delta) accountant composes the per-round clipped +
    # noised release across rounds (core/privacy.py; see docs/privacy.md) —
    # reported per trained model since each cluster has its own sampling rate
    if args.dp_clip or args.dp_noise:
        from repro.core import privacy as privacy_mod
        print()
        for cid, res in sorted(res_c.items()):
            print(f"cluster {cid} " + privacy_mod.format_report(res.privacy))
        print("global    " + privacy_mod.format_report(res_g[-1].privacy))

    # round pacing: simulated wall-clock (the edge metric) for the global
    # model; under semi_sync, also train the sync baseline with the SAME
    # straggler model and compare simulated time to the common target loss
    print(f"\nsimulated wall-clock (global model): "
          f"{res_g[-1].sim_times[-1]:.1f}s over {args.rounds} rounds "
          f"({args.stragglers} stragglers)")
    if args.mode == "semi_sync":
        # the sync baseline blocks on every upload, so dropout would stall
        # it forever — compare against the dropout-free sync run instead
        res_sync = fedavg.run_federated_training(
            train_data, fcfg, FLConfig(**{**base, "mode": "sync",
                                          "dropout_prob": 0.0},
                                       n_clusters=0))
        # last FINITE losses: cohort-atomic pacing (--secure-agg) records
        # nan for flushes that complete no cohort
        target = max(fedavg.final_loss(res_g[-1]),
                     fedavg.final_loss(res_sync[-1]))
        tt = {k: fedavg.time_to_target(r, target)
              for k, r in (("semi_sync", res_g[-1]),
                           ("sync", res_sync[-1]))}
        print(f"wall-clock to target loss {target:.5f}: semi_sync "
              f"{tt['semi_sync']:.1f}s vs sync {tt['sync']:.1f}s "
              f"({tt['sync'] / tt['semi_sync']:.2f}x)")

    held = synthetic.generate_buildings(
        args.state, list(range(10_000, 10_000 + args.heldout)),
        days=args.days)
    data = windows.batched_client_windows(held, fcfg.lookback, fcfg.horizon)
    x, y, stats = windows.flatten_test_windows(data)

    g = fedavg.evaluate_global(res_g[-1].params, x, y, fcfg, stats=stats)
    print(f"\nglobal model  F^A : accuracy {g['accuracy']:.2f}%  "
          f"rmse {g['rmse']:.3f}  per-horizon "
          f"{np.round(g['per_horizon_accuracy'], 1)}")

    z = windows.daily_average_vector(held, base["cluster_days"])
    assign = clustering.assign(z, res_c[0].cluster_centroids)
    n_win = data["x_test"].shape[1]
    accs = []
    for cid, res in sorted(res_c.items()):
        m = np.repeat(assign == cid, n_win)
        if not m.any():
            continue
        met = fedavg.evaluate_global(res.params, x[m], y[m], fcfg,
                                     stats=(stats[0][m], stats[1][m]))
        accs.append(met["accuracy"])
        print(f"cluster model F^C{cid}: accuracy {met['accuracy']:.2f}%  "
              f"({int(m.sum() / n_win)} held-out buildings)")
    print(f"\navg of cluster models: {np.mean(accs):.2f}% vs global "
          f"{g['accuracy']:.2f}%  (paper: clustering ≥ global)")

    # ---- unseen-CLIENT generalization (§5.4): clients held out of training
    # entirely, plus fresh buildings from every state — no retraining.
    print("\n== unseen-client generalization (global model, no retraining)")
    held_ids = res_g[-1].heldout_clients
    if held_ids is not None:
        m = fedavg.evaluate_unseen_clients(res_g[-1].params,
                                           [series[i] for i in held_ids],
                                           fcfg)
        print(f"{args.state} held-out clients ({len(held_ids)} never "
              f"trained): accuracy {m['accuracy']:.2f}%  rmse {m['rmse']:.3f}")
    for state in sorted(synthetic.STATES):
        fresh = synthetic.generate_buildings(
            state, list(range(20_000, 20_000 + args.heldout)), days=args.days)
        m = fedavg.evaluate_unseen_clients(res_g[-1].params, fresh, fcfg)
        tag = "in-dist" if state == args.state else "transfer"
        print(f"{state:>4} fresh buildings ({tag}): "
              f"accuracy {m['accuracy']:.2f}%  rmse {m['rmse']:.3f}")


if __name__ == "__main__":
    main()
