"""Quickstart: federated demand forecasting in ~1 minute on CPU.

Trains a global LSTM forecaster with FedAvg + EW-MSE over 12 synthetic
California commercial buildings, then forecasts the next hour for an UNSEEN
building (the paper's deployment story: no client-side retraining).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg
from repro.data import synthetic, windows
from repro.models import forecaster

import jax.numpy as jnp

# 1. a micro-grid of 12 buildings, 60 days of 15-min smart-meter data
series = synthetic.generate_buildings("CA", list(range(12)), days=60)
print(f"corpus: {series.shape[0]} buildings × {series.shape[1]} readings "
      f"(mean {series.mean():.1f} kWh)")

# 2. federated training: every client trains locally, server averages
fcfg = ForecasterConfig(cell="lstm", hidden_dim=32)
flcfg = FLConfig(n_clients=12, clients_per_round=12, rounds=20,
                 loss="ew_mse", beta=2.0, n_clusters=0, lr=0.05)
result = fedavg.run_federated_training(series, fcfg, flcfg, log_every=5)[-1]
print(f"final train loss: {result.loss_history[-1]:.5f}")

# 3. deploy to an unseen building
unseen = synthetic.generate_buildings("CA", [99_999], days=60)[0]
norm, (lo, hi) = windows.minmax_normalize(unseen)
x = jnp.asarray(norm[-fcfg.lookback:][None, :, None])
pred = np.asarray(forecaster.forecast(result.params, x, fcfg))[0]
kwh = pred * max(hi - lo, 1e-9) + lo
actual_recent = unseen[-4:]
print(f"next-hour forecast (kWh/15min): {np.round(kwh, 2)}")
print(f"(building's recent hour was:    {np.round(actual_recent, 2)})")
