"""The paper's FedAvg schedule as a cross-pod LLM training strategy.

Runs a REDUCED qwen-family decoder on a simulated 2-pod mesh (8 fake CPU
devices: pod=2 × data=2 × model=2) with DiLoCo-style local-SGD: H inner
steps per pod with no cross-pod sync, then one FedAvg parameter average
across pods.  Loss decreases and the two pod replicas re-converge at every
sync — FedAvg ≡ local SGD with an H-step communication period (DESIGN.md §2).

  PYTHONPATH=src python examples/llm_local_sgd.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro import optim                                 # noqa: E402
from repro.configs import get_config                    # noqa: E402
from repro.models import transformer as tf              # noqa: E402
from repro.sharding import ShardingRules, use_rules     # noqa: E402

H = 4                # inner steps between cross-pod syncs
ROUNDS = 3
B, S = 8, 64

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = ShardingRules(mesh, fsdp_axis="data", tensor_axis="model",
                      data_axes=("data",), pod_axis=None)

cfg = get_config("qwen1.5-0.5b").reduced()
params = tf.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
opt = optim.adam()
step = tf.make_train_step(cfg, opt, dtype=jnp.float32)

# per-pod replicas: leading pod axis
n_pod = 2
pod = lambda t: jnp.broadcast_to(t, (n_pod,) + t.shape).copy()
params_p = jax.tree.map(pod, params)
opt_p = jax.tree.map(pod, opt.init(params))


def local_sgd_round(params_p, opt_p, batches, lr):
    """H inner steps per pod (vmapped), then FedAvg across pods."""
    def pod_train(p, o, bs):
        def body(carry, b):
            p, o = carry
            with use_rules(rules):
                p, o, m = step(p, o, b, lr)
            return (p, o), m["loss"]
        (p, o), losses = jax.lax.scan(body, (p, o), bs)
        return p, o, losses
    p2, o2, losses = jax.vmap(pod_train, spmd_axis_name="pod")(
        params_p, opt_p, batches)
    drift = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda t: jnp.abs(t[0] - t[1]).sum().astype(jnp.float32),
                     p2))
    synced = jax.tree.map(
        lambda t: jnp.broadcast_to(jnp.mean(t, 0, keepdims=True), t.shape),
        p2)
    return synced, o2, losses, drift


rng = np.random.default_rng(0)
run = jax.jit(local_sgd_round)
with mesh:
    for r in range(ROUNDS):
        toks = rng.integers(0, cfg.vocab_size, (n_pod, H, B, S))
        batches = {"tokens": jnp.asarray(toks, jnp.int32),
                   "labels": jnp.asarray(toks, jnp.int32)}
        params_p, opt_p, losses, drift = run(params_p, opt_p, batches,
                                             jnp.float32(3e-3))
        l = np.asarray(losses)
        print(f"round {r}: pod0 losses {np.round(l[0], 3)}  "
              f"pod1 losses {np.round(l[1], 3)}  "
              f"pre-sync param drift {float(drift):.3f}")
print("pods trained independently for H steps, then FedAvg re-synced them —"
      "\ncross-pod traffic is 1/H of per-step synchronization.")
