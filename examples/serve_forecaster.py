"""Batched-request serving demo (deliverable b, serving kind).

Fits a small FL model, then serves batched next-hour forecast requests for
hundreds of unseen consumers — the micro-grid provider's inference path
(paper §5.4: deploy to clients with no compute for training).

  PYTHONPATH=src python examples/serve_forecaster.py
"""
from repro.launch import serve

if __name__ == "__main__":
    import sys
    sys.argv = [sys.argv[0], "--train-clients", "16", "--rounds", "20",
                "--requests", "256", "--days", "90"]
    serve.main()
