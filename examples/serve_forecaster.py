"""Batched-request serving demo (deliverable b, serving kind).

Fits a small FL model, then serves batched next-hour forecast requests for
hundreds of unseen consumers — the micro-grid provider's inference path
(paper §5.4: deploy to clients with no compute for training).

  PYTHONPATH=src python examples/serve_forecaster.py
  PYTHONPATH=src python examples/serve_forecaster.py --requests 1024
"""
from repro.launch import serve

if __name__ == "__main__":
    import sys
    # demo-sized defaults, overridable from the command line: user flags are
    # appended AFTER the defaults, and argparse lets the last occurrence win
    defaults = ["--train-clients", "16", "--rounds", "20",
                "--requests", "256", "--days", "90"]
    sys.argv = [sys.argv[0]] + defaults + sys.argv[1:]
    serve.main()
