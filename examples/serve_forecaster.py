"""Batched-request serving demo — a thin client of ``repro.serving``.

Fits a small FL model, publishes it into the serving registry, and replays
next-hour forecast requests for hundreds of unseen consumers through the
padded-bucket batching engine (paper §5.4: deploy to clients with no
compute for training).  Raw watt-hours in, kWh out; ``--clusters k`` routes
each unseen consumer to its nearest-centroid cluster model, ``--int8``
serves quantized weights.

  PYTHONPATH=src python examples/serve_forecaster.py
  PYTHONPATH=src python examples/serve_forecaster.py --requests 1024
  PYTHONPATH=src python examples/serve_forecaster.py --clusters 3 --int8
"""
from repro.launch import serve

if __name__ == "__main__":
    import sys
    # demo-sized defaults, overridable from the command line: user flags are
    # appended AFTER the defaults, and argparse lets the last occurrence win
    defaults = ["--train-clients", "16", "--rounds", "20",
                "--requests", "256", "--days", "90"]
    sys.argv = [sys.argv[0]] + defaults + sys.argv[1:]
    serve.main()
