"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    """x: (B, I); h, c: (B, H); wx: (I, 4H) [i|f|g|o]; wh: (H, 4H); b: (4H,)."""
    z = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def gru_cell_ref(x, h, wx, wh, b):
    """x: (B, I); h: (B, H); wx: (I, 3H) [z|r|h̃]; wh: (H, 3H); b: (3H,)."""
    H = h.shape[-1]
    zx = x @ wx + b
    zh = h @ wh
    z = jax.nn.sigmoid(zx[..., :H] + zh[..., :H])
    r = jax.nn.sigmoid(zx[..., H:2 * H] + zh[..., H:2 * H])
    h_tilde = jnp.tanh(zx[..., 2 * H:] + r * zh[..., 2 * H:])
    return z * h + (1.0 - z) * h_tilde


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B, S, H, hd); k, v: (B, S, Hkv, hd). GQA via head grouping.

    Returns (B, S, H, hd). Plain materialized-scores oracle.
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bskgh,btkh->bskgt", qg, k).astype(jnp.float32) * scale
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = j <= i
        if window:
            mask &= j > i - window
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkh->bskgh", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(v.dtype)
    return o.reshape(B, S, Hq, hd)
