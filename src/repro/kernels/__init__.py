"""Pallas TPU kernels for the compute hot-spots: fused LSTM/GRU cells (the
paper's edge training inner loop) and flash attention (the assigned archs'
prefill).  Validated in interpret mode on CPU against ref.py oracles."""
from repro.kernels import ops, ref
