"""Fused LSTM cell — Pallas TPU kernel.

The paper's edge hot-spot is the recurrent cell (Pi clients spend 70–100 s
per round in LSTM training).  On TPU the win is fusing BOTH matmuls and all
four gate nonlinearities into one kernel so the (B, 4H) pre-activation never
round-trips to HBM between the matmul and the gates: HBM traffic drops from
3·(B·4H) intermediate reads/writes to just the final (h', c') writes.

Tiling: grid (B/bt, H/ht).  Weights are laid out (I, 4, H) / (H, 4, H) so a
hidden tile selects a contiguous H-slice of every gate; the gate axis (4) is
resident in full.  The h·Wh matmul needs ALL of h, so the h block is (bt, H)
— for forecaster-scale H (≤1024) this sits comfortably in VMEM, and both
matmuls hit the MXU with K = I resp. H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                 h_out_ref, c_out_ref):
    x = x_ref[...]                                       # (bt, I)
    h = h_ref[...]                                       # (bt, H)
    c = c_ref[...]                                       # (bt, ht)
    wx = wx_ref[...]                                     # (I, 4, ht)
    wh = wh_ref[...]                                     # (H, 4, ht)
    b = b_ref[...]                                       # (4, ht)

    bt = x.shape[0]
    ht = c.shape[-1]
    zx = jax.lax.dot_general(x, wx.reshape(wx.shape[0], 4 * ht),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    zh = jax.lax.dot_general(h, wh.reshape(wh.shape[0], 4 * ht),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    z = (zx + zh).reshape(bt, 4, ht) + b[None].astype(jnp.float32)
    i = jax.nn.sigmoid(z[:, 0])
    f = jax.nn.sigmoid(z[:, 1])
    g = jnp.tanh(z[:, 2])
    o = jax.nn.sigmoid(z[:, 3])
    c_new = f * c.astype(jnp.float32) + i * g
    h_out_ref[...] = (o * jnp.tanh(c_new)).astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_h", "interpret"))
def lstm_cell(x, h, c, wx, wh, b, *, block_b: int = 128, block_h: int = 128,
              interpret: bool = True):
    """Fused LSTM step.  x: (B, I); h, c: (B, H); wx: (I, 4H) [i|f|g|o];
    wh: (H, 4H); b: (4H,).  Returns (h', c')."""
    B, I = x.shape
    H = h.shape[-1]
    bt = min(block_b, B)
    ht = min(block_h, H)
    assert B % bt == 0 and H % ht == 0, (B, H, bt, ht)
    wx3 = wx.reshape(I, 4, H)
    wh3 = wh.reshape(H, 4, H)
    b2 = b.reshape(4, H)

    grid = (B // bt, H // ht)
    return pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, I), lambda bi, hj: (bi, 0)),
            pl.BlockSpec((bt, H), lambda bi, hj: (bi, 0)),
            pl.BlockSpec((bt, ht), lambda bi, hj: (bi, hj)),
            pl.BlockSpec((I, 4, ht), lambda bi, hj: (0, 0, hj)),
            pl.BlockSpec((H, 4, ht), lambda bi, hj: (0, 0, hj)),
            pl.BlockSpec((4, ht), lambda bi, hj: (0, hj)),
        ],
        out_specs=[
            pl.BlockSpec((bt, ht), lambda bi, hj: (bi, hj)),
            pl.BlockSpec((bt, ht), lambda bi, hj: (bi, hj)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h.dtype),
            jax.ShapeDtypeStruct((B, H), c.dtype),
        ],
        interpret=interpret,
    )(x, h, c, wx3, wh3, b2)
