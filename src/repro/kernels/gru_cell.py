"""Fused GRU cell — Pallas TPU kernel (3 gates, reset-gate ordering).

Same fusion rationale as the LSTM cell; the GRU's reset gate makes the
candidate depend on r ⊙ (h·Wh_h̃), so the kernel computes zx = x·Wx + b and
zh = h·Wh in one pass each and combines gates in VREGs.
Weight layout: (I, 3, H) / (H, 3, H), gate order [z | r | h̃].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(x_ref, h_ref, hblk_ref, wx_ref, wh_ref, b_ref, h_out_ref):
    x = x_ref[...]                                       # (bt, I)
    h = h_ref[...]                                       # (bt, H) full
    h_blk = hblk_ref[...]                                # (bt, ht) this tile
    wx = wx_ref[...]                                     # (I, 3, ht)
    wh = wh_ref[...]                                     # (H, 3, ht)
    b = b_ref[...]                                       # (3, ht)

    bt = x.shape[0]
    ht = h_blk.shape[-1]
    zx = jax.lax.dot_general(x, wx.reshape(wx.shape[0], 3 * ht),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    zh = jax.lax.dot_general(h, wh.reshape(wh.shape[0], 3 * ht),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    zx = zx.reshape(bt, 3, ht) + b[None].astype(jnp.float32)
    zh = zh.reshape(bt, 3, ht)
    z = jax.nn.sigmoid(zx[:, 0] + zh[:, 0])
    r = jax.nn.sigmoid(zx[:, 1] + zh[:, 1])
    h_tilde = jnp.tanh(zx[:, 2] + r * zh[:, 2])
    out = z * h_blk.astype(jnp.float32) + (1.0 - z) * h_tilde
    h_out_ref[...] = out.astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_h", "interpret"))
def gru_cell(x, h, wx, wh, b, *, block_b: int = 128, block_h: int = 128,
             interpret: bool = True):
    """Fused GRU step.  x: (B, I); h: (B, H); wx: (I, 3H) [z|r|h̃];
    wh: (H, 3H); b: (3H,).  Returns h'."""
    B, I = x.shape
    H = h.shape[-1]
    bt = min(block_b, B)
    ht = min(block_h, H)
    assert B % bt == 0 and H % ht == 0, (B, H, bt, ht)
    wx3 = wx.reshape(I, 3, H)
    wh3 = wh.reshape(H, 3, H)
    b2 = b.reshape(3, H)

    grid = (B // bt, H // ht)
    return pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, I), lambda bi, hj: (bi, 0)),
            pl.BlockSpec((bt, H), lambda bi, hj: (bi, 0)),
            pl.BlockSpec((bt, ht), lambda bi, hj: (bi, hj)),
            pl.BlockSpec((I, 3, ht), lambda bi, hj: (0, 0, hj)),
            pl.BlockSpec((H, 3, ht), lambda bi, hj: (0, 0, hj)),
            pl.BlockSpec((3, ht), lambda bi, hj: (0, hj)),
        ],
        out_specs=pl.BlockSpec((bt, ht), lambda bi, hj: (bi, hj)),
        out_shape=jax.ShapeDtypeStruct((B, H), h.dtype),
        interpret=interpret,
    )(x, h, h, wx3, wh3, b2)
