"""Jit'd wrappers that route model code through the Pallas kernels.

On CPU the kernels run in interpret mode (Python-level execution of the
kernel body) — correctness only.  On TPU set ``REPRO_PALLAS_COMPILE=1`` (or
call with interpret=False) to lower them for real.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gru_cell as _gru
from repro.kernels import lstm_cell as _lstm

_INTERPRET = (jax.default_backend() == "cpu"
              and not os.environ.get("REPRO_PALLAS_COMPILE"))


def lstm_cell_fused(x_t, h, c, p, *, block_b=None, block_h=None):
    """Drop-in for models.forecaster.lstm_cell: (x_t, h, c, params) -> (h', c').

    Note the forecaster stores gates [i|f|g|o] in wx/wh — same layout the
    kernel expects.  Pads the batch to the block size when needed.
    """
    B, H = h.shape
    bb = block_b or _pick_block(B)
    bh = block_h or _pick_block(H)
    return _lstm.lstm_cell(x_t, h, c, p["wx"], p["wh"], p["b"],
                           block_b=bb, block_h=bh, interpret=_INTERPRET)


def gru_cell_fused(x_t, h, p, *, block_b=None, block_h=None):
    """Drop-in for models.forecaster.gru_cell: (x_t, h, params) -> h'."""
    B, H = h.shape
    bb = block_b or _pick_block(B)
    bh = block_h or _pick_block(H)
    return _gru.gru_cell(x_t, h, p["wx"], p["wh"], p["b"],
                         block_b=bb, block_h=bh, interpret=_INTERPRET)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=_INTERPRET)


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is ≤ target."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1
