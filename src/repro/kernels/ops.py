"""Jit'd wrappers that route model code through the Pallas kernels.

On CPU the kernels run in interpret mode (Python-level execution of the
kernel body) — correctness only.  On TPU set ``REPRO_PALLAS_COMPILE=1`` (or
call with interpret=False) to lower them for real.

The recurrent-cell wrappers are differentiable: ``pallas_call`` has no
autodiff rule, so each cell carries a ``custom_vjp`` whose forward is the
fused kernel and whose backward is the VJP of the pure-jnp oracle
(``kernels/ref.py``) — the same math, so gradients are exact.  That is what
lets the federated ``local_update`` (value_and_grad through the forecaster)
run end-to-end with ``cell_impl="pallas"``.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gru_cell as _gru
from repro.kernels import lstm_cell as _lstm
from repro.kernels import ref as _ref

_INTERPRET = (jax.default_backend() == "cpu"
              and not os.environ.get("REPRO_PALLAS_COMPILE"))


@jax.custom_vjp
def _lstm_cell_ad(x, h, c, wx, wh, b):
    return _lstm.lstm_cell(x, h, c, wx, wh, b,
                           block_b=_pick_block(x.shape[0]),
                           block_h=_pick_block(h.shape[-1]),
                           interpret=_INTERPRET)


def _lstm_cell_ad_fwd(x, h, c, wx, wh, b):
    return _lstm_cell_ad(x, h, c, wx, wh, b), (x, h, c, wx, wh, b)


def _lstm_cell_ad_bwd(res, ct):
    _, vjp = jax.vjp(_ref.lstm_cell_ref, *res)
    return vjp(ct)


_lstm_cell_ad.defvjp(_lstm_cell_ad_fwd, _lstm_cell_ad_bwd)


@jax.custom_vjp
def _gru_cell_ad(x, h, wx, wh, b):
    return _gru.gru_cell(x, h, wx, wh, b,
                         block_b=_pick_block(x.shape[0]),
                         block_h=_pick_block(h.shape[-1]),
                         interpret=_INTERPRET)


def _gru_cell_ad_fwd(x, h, wx, wh, b):
    return _gru_cell_ad(x, h, wx, wh, b), (x, h, wx, wh, b)


def _gru_cell_ad_bwd(res, ct):
    _, vjp = jax.vjp(_ref.gru_cell_ref, *res)
    return vjp(ct)


_gru_cell_ad.defvjp(_gru_cell_ad_fwd, _gru_cell_ad_bwd)


def lstm_cell_fused(x_t, h, c, p, *, block_b=None, block_h=None):
    """Drop-in for models.forecaster.lstm_cell: (x_t, h, c, params) -> (h', c').

    Note the forecaster stores gates [i|f|g|o] in wx/wh — same layout the
    kernel expects.  The default (no explicit blocks) path is differentiable
    via the reference-VJP ``custom_vjp``; explicit block sizes bypass it for
    kernel-tuning benches.
    """
    if block_b or block_h:
        B, H = h.shape
        bb = block_b or _pick_block(B)
        bh = block_h or _pick_block(H)
        return _lstm.lstm_cell(x_t, h, c, p["wx"], p["wh"], p["b"],
                               block_b=bb, block_h=bh, interpret=_INTERPRET)
    return _lstm_cell_ad(x_t, h, c, p["wx"], p["wh"], p["b"])


def gru_cell_fused(x_t, h, p, *, block_b=None, block_h=None):
    """Drop-in for models.forecaster.gru_cell: (x_t, h, params) -> h'."""
    if block_b or block_h:
        B, H = h.shape
        bb = block_b or _pick_block(B)
        bh = block_h or _pick_block(H)
        return _gru.gru_cell(x_t, h, p["wx"], p["wh"], p["b"],
                             block_b=bb, block_h=bh, interpret=_INTERPRET)
    return _gru_cell_ad(x_t, h, p["wx"], p["wh"], p["b"])


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=_INTERPRET)


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is ≤ target."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return 1
