"""Causal flash attention (online softmax) — Pallas TPU kernel.

Grid (B, Hq, nQ, nK); the innermost K dimension streams key/value blocks
through VMEM while fp32 accumulators (running max m, normalizer l, output
acc) persist in VMEM scratch across K iterations — the Flash-2 schedule
mapped onto the TPU grid.  Blocks fully above the causal diagonal (or fully
outside the sliding window) skip their matmuls via ``pl.when``.

GQA is native: the K/V BlockSpec index map folds the query head onto its
KV group (h → h·Hkv/Hq), so no K/V replication is materialized.

VMEM per step: q (bq·hd) + k,v (2·bk·hd) + scores (bq·bk) + scratch
(bq·(hd+2)) — with bq=bk=128, hd=128 ≈ 160 KB fp32, far under the ~16 MB
VMEM budget; bigger bq amortizes the q load when hd is small.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, block_q, block_k, n_k, causal, window):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level skip: fully causal-masked or fully outside the window
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= jk <= iq
        if window:
            mask &= jk > iq - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, S, Hq, hd); k, v: (B, S, Hkv, hd) -> (B, S, Hq, hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_k = S // bq, S // bk

    qt = q.transpose(0, 2, 1, 3)                         # (B, Hq, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kv_map = lambda b, h, i, j: (b, h * Hkv // Hq, j, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=bq,
                               block_k=bk, n_k=n_k, causal=causal,
                               window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
