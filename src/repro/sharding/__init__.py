from repro.sharding.compat import shard_map
from repro.sharding.rules import (ShardingRules, active_rules, constrain,
                                  constrain_heads, use_rules)

__all__ = ["ShardingRules", "active_rules", "constrain", "constrain_heads",
           "shard_map", "use_rules"]
