"""jax version compatibility for ``shard_map``.

``shard_map`` moved from ``jax.experimental.shard_map`` (jax <= 0.4.x, where
its replication-check kwarg is ``check_rep``) to ``jax.shard_map`` (jax >=
0.5, kwarg renamed ``check_vma``).  Everything in this repo goes through
:func:`shard_map` below so core code and tests run unchanged on both: pass
``check_vma=...`` and it is forwarded under whichever name the installed jax
understands.
"""
from __future__ import annotations

from typing import Optional

import jax

try:                                       # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _experimental_shard_map
except ImportError:                        # pragma: no cover - removed in 0.6+
    _experimental_shard_map = None


def shard_map(f, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """Version-portable ``shard_map(f, mesh=..., in_specs=..., out_specs=...)``.

    ``check_vma`` (new-style name; old jax calls it ``check_rep``) is only
    forwarded when explicitly given, so each jax version keeps its default.
    """
    kwargs = {} if check_vma is None else {"check_vma": check_vma}
    if hasattr(jax, "shard_map"):          # jax >= 0.5
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    if check_vma is not None:              # old name for the same knob
        kwargs = {"check_rep": check_vma}
    return _experimental_shard_map(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, **kwargs)
