"""Logical-axis sharding rules.

Model code calls ``constrain(x, "batch", "seq", "embed")`` with *logical* axis
names; the active :class:`ShardingRules` (installed by the launcher via
``use_rules``) maps them to mesh axes.  With no rules installed every call is a
no-op, so the same model code runs on a laptop and on a 512-chip mesh.

Parameter shardings are derived from the param-tree *paths* via
``param_pspec`` — a name/ndim-based rule table in the spirit of MaxText's
logical-to-physical rules, kept in one place so performance iterations can
change the sharding layout without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


class ShardingRules:
    """Maps logical axis names -> mesh axis (or None)."""

    def __init__(self, mesh, logical_to_mesh=None, fsdp_axis="data",
                 tensor_axis="model", data_axes=("data",), pod_axis=None,
                 shard_batch=True, shard_activations=False):
        self.mesh = mesh
        self.fsdp_axis = fsdp_axis
        self.tensor_axis = tensor_axis
        self.pod_axis = pod_axis
        self.shard_activations = shard_activations
        if not shard_batch:                  # e.g. global_batch=1 long-context
            data_axes, pod_axis = (), None
        # data-parallel axes for the *batch* dimension of activations.  On the
        # multi-pod mesh the pod axis is also data-parallel.
        batch_axes = tuple(a for a in ((pod_axis,) if pod_axis else ()) + tuple(data_axes))
        self.logical = {
            "batch": batch_axes if batch_axes else None,
            "seq": None,
            "cache_seq": tensor_axis,      # sequence-sharded KV cache (see DESIGN §5)
            # residual-stream activations optionally shard d_model over the
            # tensor axis ("activation FSDP"): the remat-saved per-layer x is
            # 16× smaller at the cost of one all-gather per layer per pass.
            # Worth it only when activations would not fit (≳30B training);
            # for small models it makes the step collective-bound (§Perf).
            "embed": tensor_axis if shard_activations else None,
            "act_ff": tensor_axis,         # activation hidden/ffn dim under TP
            "act_heads": tensor_axis,
            "act_vocab": tensor_axis,      # sharded logits
            # routing groups shard over the DATA axes only: the (B,S)→(G,gsz)
            # reshape then never resharding across `model`, whose backward
            # fallback replicated a full f32 cotangent per MoE layer (§Perf)
            "moe_group": batch_axes if batch_axes else None,
            "moe_batch": batch_axes if batch_axes else None,
            "act_experts": tensor_axis,
            "clients": batch_axes if batch_axes else None,
        }
        if logical_to_mesh:
            self.logical.update(logical_to_mesh)

    # -------------------------------------------------------- params
    def param_pspec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        """Sharding for one parameter, by its tree path.

        Layout: FSDP over ``fsdp_axis`` on the largest "row" dim, tensor
        parallel over ``tensor_axis`` on head/ffn/expert/vocab dims.  A leading
        layer-stack axis (from scanned segments) is never sharded.
        """
        name = path[-1]
        fsdp, tp = self.fsdp_axis, self.tensor_axis
        ndim = len(shape)

        def spec(*axes):
            # pad to ndim with None on the left for the layer-stack axis
            pad = ndim - len(axes)
            return P(*((None,) * pad + tuple(axes)))

        if name in ("embed_tokens",):            # (vocab, d)
            return spec(tp, fsdp)
        if name == "cb_embed":                   # (K, vocab, d)
            return P(None, tp, fsdp)
        if name == "cb_heads":                   # (d, K, vocab)
            return P(fsdp, None, tp)
        if name in ("lm_head",):                 # (d, vocab)
            return spec(fsdp, tp)
        if name in ("wq", "wk", "wv", "w_in", "w_gate", "wq_up", "wkv_up"):
            return spec(fsdp, tp)                # (d, heads*hd) / (d, ff)
        if name in ("wo", "w_out"):              # (heads*hd, d) / (ff, d)
            return spec(tp, fsdp)
        if name in ("moe_w_in", "moe_w_gate"):   # (E, d, ff_e)
            return spec(tp, fsdp, None)
        if name in ("moe_w_out",):               # (E, ff_e, d)
            return spec(tp, None, fsdp)
        if name == "router":                     # (d, E)
            return spec(fsdp, None)
        if name in ("in_proj", "x_proj", "up_proj"):
            return spec(fsdp, tp)
        if name in ("out_proj", "down_proj"):
            return spec(tp, fsdp)
        if ndim >= 2 and shape[-1] >= 1024 and shape[-2] >= 1024:
            return spec(fsdp, tp)                # generic big matrix
        return spec(*((None,) * ndim))           # small params replicated

    def pspec_tree(self, params):
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        treedef = jax.tree_util.tree_structure(params)
        specs = []
        for kp, leaf in flat:
            path = tuple(_key_name(k) for k in kp)
            specs.append(safe_spec(leaf.shape,
                                   self.param_pspec(path, leaf.shape),
                                   self.mesh))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def sharding_tree(self, params):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.pspec_tree(params),
                            is_leaf=lambda x: isinstance(x, P))


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ------------------------------------------------------------------ context
@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


def _sharding_mesh(mesh):
    """Use the context abstract mesh when one is active (e.g. inside a
    shard_map body, where the pod axis is Manual) so sharding constraints
    carry matching axis types."""
    try:
        from jax.sharding import get_abstract_mesh
        am = get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except ImportError:
        pass
    return mesh


def _axis_size(mesh, m) -> int:
    if m is None:
        return 1
    if isinstance(m, tuple):
        n = 1
        for a in m:
            n *= mesh.shape[a]
        return n
    return mesh.shape[m]


def safe_spec(shape, spec: P, mesh) -> P:
    """Drop mesh axes whose size does not divide the tensor dim (e.g. 56 query
    heads on a 16-way tensor axis) — the constraint silently degrades to
    replicated on that dim instead of failing to lower."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, m in zip(shape, axes):
        sz = _axis_size(mesh, m)
        out.append(m if sz > 1 and dim % sz == 0 else
                   (m if sz == 1 else None))
    return P(*out)


def constrain_heads(x, head_axis: int = 2):
    """Constraint for (B, S, H, hd) attention activations.

    When H divides the tensor axis, shard heads; otherwise fall back to
    sharding hd (head_dim is 64/128/112 — usually divisible) so attention
    activations NEVER go fully replicated (a replicated primal here makes
    GSPMD replicate the f32 cotangent in the backward pass — the dominant
    collective cost for archs whose head count isn't a multiple of 16).
    """
    rules = active_rules()
    if rules is None:
        return x
    tp = rules.tensor_axis
    sz = _axis_size(rules.mesh, tp)
    axes = [None] * x.ndim
    batch = rules.logical.get("batch")
    if batch is not None and x.shape[0] % _axis_size(rules.mesh, batch) == 0:
        axes[0] = batch
    if x.shape[head_axis] % sz == 0:
        axes[head_axis] = tp
    # NOTE: do NOT fall back to sharding hd — it is the contraction dim of
    # the score matmul and sharding it turns every score tensor into an
    # all-reduced partial sum (measured 3.7× collective regression; §Perf)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_sharding_mesh(rules.mesh), P(*axes)))


def constrain(x, *logical_axes: Optional[str]):
    """Apply a sharding constraint by logical axis names (no-op without rules)."""
    rules = active_rules()
    if rules is None:
        return x
    axes = []
    for a in logical_axes:
        m = rules.logical.get(a) if a else None
        if isinstance(m, tuple) and len(m) == 1:
            m = m[0]
        axes.append(m)
    spec = safe_spec(x.shape, P(*axes), rules.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_sharding_mesh(rules.mesh), spec))
