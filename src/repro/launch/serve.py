"""Serving driver: batched demand-forecast requests against a trained global
model (the micro-grid provider's deployment path, §5.4: the FL model is
deployed to 1000s of unseen consumers with NO client-side retraining).

Also exposes ``serve_lm`` used by the decode dry-run shapes: prefill a
context then decode tokens with the KV cache — the LLM-serving analogue.

  PYTHONPATH=src python -m repro.launch.serve --state CA --requests 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg
from repro.data import synthetic, windows
from repro.models import forecaster


def serve_forecaster(params, cfg: ForecasterConfig, requests: np.ndarray,
                     batch: int = 1024):
    """requests: (n, lookback) normalized windows -> (n, horizon) forecasts."""
    outs = []
    for i in range(0, len(requests), batch):
        x = jnp.asarray(requests[i:i + batch][..., None])
        outs.append(np.asarray(forecaster.forecast(params, x, cfg)))
    return np.concatenate(outs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA")
    ap.add_argument("--train-clients", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--requests", type=int, default=256,
                    help="# of held-out consumers sending forecast requests")
    ap.add_argument("--days", type=int, default=120)
    args = ap.parse_args()

    fcfg = ForecasterConfig()
    flcfg = FLConfig(n_clients=args.train_clients,
                     clients_per_round=args.train_clients,
                     rounds=args.rounds, n_clusters=0, lr=0.05)
    print(f"[serve] quick FL fit on {args.train_clients} clients "
          f"({args.rounds} rounds)")
    series = synthetic.generate_buildings(
        args.state, list(range(args.train_clients)), days=args.days)
    res = fedavg.run_federated_training(series, fcfg, flcfg)[-1]

    print(f"[serve] serving {args.requests} unseen consumers")
    held = synthetic.generate_buildings(
        args.state, list(range(50_000, 50_000 + args.requests)),
        days=args.days)
    norm, stats = windows.minmax_normalize(held)
    reqs = norm[:, -fcfg.lookback:]                      # most recent 2 h
    t0 = time.perf_counter()
    fc = serve_forecaster(res.params, fcfg, reqs)
    dt = time.perf_counter() - t0
    lo, hi = stats
    kwh = fc * np.maximum(hi - lo, 1e-9) + lo
    print(f"[serve] {args.requests} forecasts in {dt*1e3:.1f} ms "
          f"({dt/args.requests*1e6:.0f} µs/request)")
    print(f"[serve] sample forecast (kWh, next hour): {np.round(kwh[0], 2)}")


if __name__ == "__main__":
    main()
