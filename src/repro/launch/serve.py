"""Serving driver: a thin client of the ``repro.serving`` tier (the
micro-grid provider's deployment path, §5.4: the FL model is deployed to
1000s of unseen consumers with NO client-side retraining).

Trains a quick global (or per-cluster) model, publishes it into a
:class:`~repro.serving.ModelRegistry`, and replays unseen-consumer requests
through the padded-bucket :class:`~repro.serving.ServingEngine` — raw
watt-hours in, kWh forecasts out.  For throughput/latency numbers under a
Poisson request trace use ``benchmarks/bench_serving.py``.

  PYTHONPATH=src python -m repro.launch.serve --state CA --requests 256
  PYTHONPATH=src python -m repro.launch.serve --clusters 3 --int8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg
from repro.data import synthetic
from repro.models import forecaster
from repro.serving import (ClusterRouter, ModelRegistry, ServingEngine,
                           bucket_for)


def serve_forecaster(params, cfg: ForecasterConfig, requests: np.ndarray,
                     batch: int = 1024):
    """requests: (n, lookback) NORMALIZED windows -> (n, horizon) forecasts.

    Batches are padded UP to the next power-of-two bucket and the pad rows
    sliced off, so the ragged final chunk (and any varying request count)
    reuses one of ≤ log2(batch)+1 compiled shapes instead of triggering a
    fresh XLA compile per distinct tail — regression-pinned via the
    jit-cache probe in ``tests/test_serving.py``.  Callers holding RAW
    watt-hour windows should use :class:`repro.serving.ServingEngine`,
    which also owns normalization and model hot-swap.
    """
    outs = []
    for i in range(0, len(requests), batch):
        chunk = np.asarray(requests[i:i + batch], np.float32)
        n = chunk.shape[0]
        b = bucket_for(n, 1, batch)
        if b > n:
            chunk = np.concatenate(
                [chunk, np.zeros((b - n,) + chunk.shape[1:], chunk.dtype)])
        x = jnp.asarray(chunk[..., None])
        outs.append(np.asarray(forecaster.forecast(params, x, cfg))[:n])
    return np.concatenate(outs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA")
    ap.add_argument("--train-clients", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--requests", type=int, default=256,
                    help="# of held-out consumers sending forecast requests")
    ap.add_argument("--days", type=int, default=120)
    ap.add_argument("--clusters", type=int, default=0,
                    help="k-means clusters (0 = single global model); "
                    "unseen consumers are routed by nearest centroid")
    ap.add_argument("--int8", action="store_true",
                    help="serve int8-quantized weights (4x smaller)")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fcfg = ForecasterConfig()
    flcfg = FLConfig(n_clients=args.train_clients,
                     clients_per_round=args.train_clients,
                     rounds=args.rounds, n_clusters=args.clusters,
                     seed=args.seed, lr=0.05,
                     cluster_days=min(273, int(args.days * 0.75)))
    print(f"[serve] quick FL fit on {args.train_clients} clients "
          f"({args.rounds} rounds, clusters={args.clusters or 'off'})")
    series = synthetic.generate_buildings(
        args.state, list(range(args.train_clients)), days=args.days)
    results = fedavg.run_federated_training(series, fcfg, flcfg)

    # ---- publish the trained globals into the serving registry
    registry = ModelRegistry()
    weights = "int8" if args.int8 else "fp32"
    qroot = jax.random.fold_in(jax.random.PRNGKey(args.seed), args.rounds)
    for cid, res in results.items():
        registry.publish(
            res.params, fcfg, slot=cid, generation=len(res.loss_history),
            weights=weights,
            key=jax.random.fold_in(qroot, cid + 1) if args.int8 else None)
    router = ClusterRouter.from_result(next(iter(results.values())))
    engine = ServingEngine(registry, router, max_batch=args.max_batch,
                           min_bucket=args.min_bucket)
    n_prog = engine.warmup()
    print(f"[serve] registry: slots {registry.slots()} ({weights}); "
          f"warmed {n_prog} bucket programs")

    # ---- replay raw watt-hour requests from unseen consumers
    print(f"[serve] serving {args.requests} unseen consumers")
    held = synthetic.generate_buildings(
        args.state, list(range(50_000, 50_000 + args.requests)),
        days=args.days)
    t0 = time.perf_counter()
    tickets = [engine.submit(50_000 + i, held[i, -fcfg.lookback:],
                             history=held[i])
               for i in range(args.requests)]
    engine.flush()
    dt = time.perf_counter() - t0
    assert all(t.done for t in tickets)
    st = engine.stats
    print(f"[serve] {args.requests} forecasts in {dt*1e3:.1f} ms "
          f"({dt/args.requests*1e6:.0f} µs/request, "
          f"{st.flushes} batches, fill {st.fill():.2f})")
    print(f"[serve] sample forecast (kWh, next hour): "
          f"{np.round(tickets[0].result, 2)}")


if __name__ == "__main__":
    main()
