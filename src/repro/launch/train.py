"""FL training driver — the paper's end-to-end pipeline as a CLI.

Generates the OpenEIA-calibrated corpus for a state, optionally clusters
clients, trains per-cluster federated models (LSTM/GRU × MSE/EW-MSE × any
``--server-opt`` round-engine rule), and evaluates on a large held-out
population, mirroring §4/§5 of the paper.

  PYTHONPATH=src python -m repro.launch.train --state CA --rounds 100 \
      --clusters 4 --loss ew_mse --beta 2 --cell lstm --heldout 500
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, fedavg
from repro.core.sampling import SAMPLING_STRATEGIES
from repro.core.server_opt import SERVER_OPTS
from repro.data import synthetic, windows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA", choices=list(synthetic.STATES))
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="M (0 = all)")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--cell", default="lstm", choices=("lstm", "gru"))
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--loss", default="ew_mse", choices=("mse", "ew_mse"))
    ap.add_argument("--beta", type=float, default=2.0)
    ap.add_argument("--clusters", type=int, default=0,
                    help="K-means k (0 = single global model)")
    ap.add_argument("--server-opt", default="fedavg", choices=SERVER_OPTS,
                    help="round-engine server update rule")
    ap.add_argument("--server-lr", type=float, default=1.0)
    ap.add_argument("--prox-mu", type=float, default=0.0,
                    help="FedProx proximal strength")
    ap.add_argument("--sampling", default="uniform",
                    choices=SAMPLING_STRATEGIES)
    ap.add_argument("--heldout", type=int, default=200,
                    help="# held-out buildings for evaluation")
    ap.add_argument("--days", type=int, default=365)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    fcfg = ForecasterConfig(cell=args.cell, hidden_dim=args.hidden)
    flcfg = FLConfig(
        n_clients=args.clients,
        clients_per_round=args.clients_per_round or args.clients,
        local_epochs=args.local_epochs, batch_size=args.batch_size,
        rounds=args.rounds, lr=args.lr, loss=args.loss, beta=args.beta,
        n_clusters=args.clusters, seed=args.seed,
        cluster_days=min(273, int(args.days * 0.75)),
        server_opt=args.server_opt, server_lr=args.server_lr,
        prox_mu=args.prox_mu, sampling=args.sampling)

    t0 = time.perf_counter()
    print(f"[train] generating {args.clients} train buildings ({args.state})")
    train_series = synthetic.generate_buildings(
        args.state, list(range(args.clients)), days=args.days)
    print(f"[train] FL training: {args.rounds} rounds × "
          f"{flcfg.clients_per_round} clients, loss={args.loss}"
          f"{f' β={args.beta}' if args.loss == 'ew_mse' else ''}, "
          f"clusters={args.clusters or 'off'}")
    results = fedavg.run_federated_training(train_series, fcfg, flcfg,
                                            log_every=max(args.rounds // 5, 1))

    print(f"[train] evaluating on {args.heldout} held-out buildings")
    held_ids = list(range(10_000, 10_000 + args.heldout))
    held = synthetic.generate_buildings(args.state, held_ids, days=args.days)
    data = windows.batched_client_windows(held, fcfg.lookback, fcfg.horizon)
    x, y, stats = windows.flatten_test_windows(data)

    report = {}
    if args.clusters:
        z = windows.daily_average_vector(held, flcfg.cluster_days)
        cents = results[0].cluster_centroids
        assign = clustering.assign(z, cents)
        n_win = data["x_test"].shape[1]
        for cid, res in results.items():
            m = np.repeat(assign == cid, n_win)
            if not m.any():
                continue
            met = fedavg.evaluate_global(res.params, x[m], y[m], fcfg,
                                         stats=(stats[0][m], stats[1][m]))
            report[f"cluster_{cid}"] = met
        accs = [v["accuracy"] for v in report.values()]
        report["avg_of_clusters"] = float(np.mean(accs))
    else:
        report["global"] = fedavg.evaluate_global(results[-1].params, x, y,
                                                  fcfg, stats=stats)
    for k, v in report.items():
        if isinstance(v, dict):
            print(f"  {k}: accuracy={v['accuracy']:.2f}%  rmse={v['rmse']:.3f}"
                  f"  per-horizon={np.round(v['per_horizon_accuracy'], 1)}")
        else:
            print(f"  {k}: {v:.2f}")
    print(f"[train] total {time.perf_counter() - t0:.0f}s")
    if args.out:
        clean = {k: ({kk: (vv.tolist() if hasattr(vv, 'tolist') else vv)
                      for kk, vv in v.items()} if isinstance(v, dict) else v)
                 for k, v in report.items()}
        with open(args.out, "w") as f:
            json.dump(clean, f, indent=1)


if __name__ == "__main__":
    main()
