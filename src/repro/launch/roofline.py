"""Roofline-term derivation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (seconds per step), TPU v5e:
  compute    = FLOPs_global / (chips × 197e12)
  memory     = bytes_global / (chips × 819e9)
  collective = collective_bytes_per_device / 50e9   (per-device ICI traffic)

FLOPs/bytes come from the scan-aware jaxpr cost model (global program);
collective bytes from the while-aware HLO parser (per-device partitioned
program).  MODEL_FLOPS = 6·N·D for train (N = active params for MoE), 2·N·D
for prefill, 2·N·D(1 token) for decode — the ratio MODEL/HLO shows how much
compiled compute is "useful" (remat + routing overhead push it down).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token per request


def terms(rec: Dict) -> Dict:
    chips = rec["n_chips"]
    comp = rec["flops_global"] / (chips * PEAK_FLOPS)
    memt = rec["bytes_global"] / (chips * HBM_BW)
    coll = sum(rec["collective_bytes_per_device"].values()) / ICI_BW
    dom = max(("compute", comp), ("memory", memt), ("collective", coll),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    hbm_gib = (rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
               + rec["memory"]["output_bytes"]) / 2 ** 30
    return {
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": mf / max(rec["flops_global"], 1.0),
        "hbm_gib_per_dev": hbm_gib,
        "fits_16g": hbm_gib <= 16.0,
    }


REQUIRED = ("n_chips", "flops_global", "bytes_global",
            "collective_bytes_per_device", "memory", "arch", "shape")


def load_records(dir_: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for p in sorted(Path(dir_).glob("*.json")):
        r = json.loads(p.read_text())
        if not all(k in r for k in REQUIRED):
            continue                  # side artifacts (local-SGD etc.)
        r["file"] = p.name
        recs.append(r)
    return recs


def table(recs: List[Dict], fmt: str = "md") -> str:
    rows = []
    head = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "useful", "HBM GiB/dev")
    for r in recs:
        t = terms(r)
        rows.append((r["arch"], r["shape"], r["mesh"],
                     f"{t['compute_s']:.3f}", f"{t['memory_s']:.3f}",
                     f"{t['collective_s']:.3f}", t["dominant"],
                     f"{t['useful_ratio']:.2f}",
                     f"{t['hbm_gib_per_dev']:.1f}"
                     + ("" if t["fits_16g"] else " ⚠")))
    if fmt == "md":
        out = ["| " + " | ".join(head) + " |",
               "|" + "|".join("---" for _ in head) + "|"]
        out += ["| " + " | ".join(map(str, r)) + " |" for r in rows]
        return "\n".join(out)
    return "\n".join(",".join(map(str, (head,) + tuple(rows))))


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(table(recs))


if __name__ == "__main__":
    main()
