"""Scan-aware cost extraction for the roofline.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
program built around ``lax.scan`` (layer stacks, microbatch accumulation,
chunked attention) under-reports FLOPs/bytes by the product of its trip
counts.  Two replacements:

* ``jaxpr_cost(closed_jaxpr)`` — walks the GLOBAL (pre-partitioning) jaxpr,
  multiplying through every ``scan`` length.  FLOPs are exact for
  dot_general (2·M·N·K·batch) and conv; elementwise FLOPs are counted 1/elt.
  Bytes are a structural HBM-traffic model: dot operands+result, gather /
  scatter / dynamic-slice results, and elementwise results are charged once
  (fusion-blind: an over-estimate for fused elementwise chains, recorded as
  methodology in EXPERIMENTS.md §Roofline).

* ``hlo_collective_bytes(text)`` — parses the compiled per-device HLO,
  multiplying collective result bytes inside while bodies by the loop trip
  count (recovered from the loop condition's comparison constant).
"""
from __future__ import annotations

import re
from typing import Dict

import jax
import numpy as np

_ELTWISE_SKIP = {"broadcast_in_dim", "reshape", "transpose", "squeeze",
                 "convert_element_type", "slice", "iota", "copy",
                 "stop_gradient", "bitcast_convert_type"}


def _nbytes(aval) -> int:
    return int(np.prod(aval.shape)) * aval.dtype.itemsize if aval.shape \
        else aval.dtype.itemsize


def _size(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def jaxpr_cost(jaxpr) -> Dict[str, float]:
    """Returns {"flops": f, "bytes": b} for a ClosedJaxpr, scan-aware."""
    return _walk(jaxpr.jaxpr)


def _walk(jaxpr) -> Dict[str, float]:
    flops = 0.0
    bytes_ = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            inner = _walk(eqn.params["jaxpr"].jaxpr)
            n = eqn.params["length"]
            flops += inner["flops"] * n
            bytes_ += inner["bytes"] * n
        elif name == "while":
            inner = _walk(eqn.params["body_jaxpr"].jaxpr)
            flops += inner["flops"]              # trip count unknown; rare
            bytes_ += inner["bytes"]
        elif name == "cond":
            branches = [_walk(b.jaxpr) for b in eqn.params["branches"]]
            flops += max(b["flops"] for b in branches)
            bytes_ += max(b["bytes"] for b in branches)
        elif name == "dot_general":
            ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
            a, b = eqn.invars[0].aval, eqn.invars[1].aval
            batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
            k = int(np.prod([a.shape[i] for i in lc])) if lc else 1
            m = _size(a) // max(batch * k, 1)
            n_ = _size(b) // max(batch * k, 1)
            flops += 2.0 * batch * m * n_ * k
            bytes_ += _nbytes(a) + _nbytes(b) + _nbytes(eqn.outvars[0].aval)
        elif name in ("gather", "take", "dynamic_slice",
                      "dynamic_update_slice", "scatter", "scatter-add",
                      "scatter_add", "concatenate", "pad"):
            out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
            bytes_ += out_b
        elif name in _ELTWISE_SKIP:
            pass
        else:
            # generic: recurse into ANY sub-jaxpr param (pjit, remat2,
            # custom_vjp_call, closed_call, ...); else charge elementwise
            subs = _sub_jaxprs(eqn.params)
            if subs:
                for sub in subs:
                    inner = _walk(sub)
                    flops += inner["flops"]
                    bytes_ += inner["bytes"]
            else:
                out_n = sum(_size(v.aval) for v in eqn.outvars)
                out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
                flops += out_n
                bytes_ += out_b
    return {"flops": flops, "bytes": bytes_}


def _sub_jaxprs(params):
    subs = []
    for v in params.values():
        if hasattr(v, "jaxpr"):                          # ClosedJaxpr
            subs.append(v.jaxpr)
        elif hasattr(v, "eqns"):                         # raw Jaxpr
            subs.append(v)
        elif isinstance(v, (list, tuple)):
            for vi in v:
                if hasattr(vi, "jaxpr"):
                    subs.append(vi.jaxpr)
                elif hasattr(vi, "eqns"):
                    subs.append(vi)
    return subs


# ------------------------------------------------------------------ HLO side
# a computation definition: column-0 "%name (args...) -> type {" (args may
# contain nested parens for tuple types)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\n]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_KIND_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _coll_line(line: str):
    """Parse one HLO line; return (kind, result_bytes) for a collective op —
    summing ALL elements of tuple-shaped results (variadic all-reduces carry
    one entry per parameter shard) — or None."""
    m = _KIND_RE.search(line)
    if not m or m.group(2) == "-done":
        return None
    eq = line.find("=")
    if eq < 0 or eq > m.start():
        return None
    b = 0
    for dm in _SHAPE_RE.finditer(line[eq + 1:m.start()]):
        dt, dims = dm.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b += n * _DTYPE_BYTES.get(dt, 4)
    return m.group(1), b
_GROUPS_RE = re.compile(
    r"replica_groups=(?:\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
    r"|\{\{([0-9,]+)\})")


_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]+)\}")


def _spans_pod(line: str, pod_size: int) -> bool:
    """True if the collective's replica groups mix devices from different
    pods (device id // pod_size differs within a group).  collective-permute
    carries source_target_pairs instead of replica_groups."""
    pm = _PAIRS_RE.search(line)
    if pm:
        nums = [int(x) for x in re.findall(r"\d+", pm.group(1))]
        pairs = list(zip(nums[::2], nums[1::2]))
        return any(a // pod_size != b // pod_size for a, b in pairs)
    m = _GROUPS_RE.search(line)
    if not m:
        return False
    if m.group(5) is not None:                           # explicit {{...}}
        ids = [int(x) for x in m.group(5).split(",") if x]
        return len({i // pod_size for i in ids}) > 1
    g, s = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    perm = ([int(x) for x in m.group(4).split(",")]
            if m.group(4) else list(range(len(dims))))
    ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm) \
        .reshape(g, s)
    pods = ids // pod_size
    return bool((pods != pods[:, :1]).any())
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s*constant\((\d+)\)")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _split_computations(text: str) -> Dict[str, str]:
    comps = {}
    cur, buf = None, []
    for line in text.splitlines():
        m = _COMP_RE.match(line) if not line.startswith(" ") else None
        if m:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur = m.group(1)
            buf = [line]
        else:
            buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def hlo_collective_bytes(text: str, pod_size: int = 0) -> Dict[str, float]:
    """Collective result bytes by kind, multiplied through while trip counts.

    With ``pod_size`` > 0 (multi-pod runs), also reports ``inter_pod`` — the
    subtotal of collectives whose replica groups cross a pod boundary (the
    traffic on the slow inter-pod links, the term the paper's FedAvg/local-
    SGD schedule attacks)."""
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return {}

    def trip_count(cond_name: str) -> int:
        body = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST_RE.findall(body)]
        return max(consts) if consts else 1

    def visit(comp_name: str, seen=()) -> Dict[str, float]:
        if comp_name in seen or comp_name not in comps:
            return {}
        out: Dict[str, float] = {}
        body = comps[comp_name]
        for line in body.splitlines():
            parsed = _coll_line(line)
            if parsed is None:
                continue
            kind, b = parsed
            out[kind] = out.get(kind, 0) + b
            if pod_size and _spans_pod(line, pod_size):
                out["inter_pod"] = out.get("inter_pod", 0) + b
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.groups()
            tc = trip_count(cond)
            inner = visit(wbody, seen + (comp_name,))
            for k, v in inner.items():
                out[k] = out.get(k, 0) + v * tc
        # non-while calls (fusion kernels do not contain collectives on TPU,
        # but conditionals / calls may)
        for m in re.finditer(r"(?:calls|to_apply|branch_computations)="
                             r"{?%?([\w.\-]+)", body):
            sub = m.group(1)
            if sub.startswith(("region", "cond", "body", "fused",
                               "add", "max", "min")):
                continue
            inner = visit(sub, seen + (comp_name,))
            for k, v in inner.items():
                out[k] = out.get(k, 0) + v
        return out

    return visit(entry)
