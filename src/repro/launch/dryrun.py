import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, WITHOUT allocating a single model byte.

For each combination this builds ShapeDtypeStruct stand-ins for params,
optimizer state, caches and the input batch, jits the appropriate step
(train_step / prefill_step / decode_step) with explicit in/out shardings
derived from the sharding rules, and runs ``.lower().compile()``.  The
compiled artifact yields:

  * ``memory_analysis()``  — per-device bytes (proves the config fits HBM)
  * ``cost_analysis()``    — per-device HLO FLOPs + bytes for §Roofline
  * collective bytes       — parsed from the optimized HLO text

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``; the
roofline reader (`launch.roofline` / `benchmarks.bench_roofline`) turns them
into the EXPERIMENTS.md table.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import dataclasses
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import costmodel, mesh as mesh_mod
from repro.models import transformer as tf
from repro.sharding import ShardingRules, use_rules
from repro.sharding.rules import safe_spec

# long-context policy (DESIGN.md §long_500k): attention-free archs run native;
# attention archs run the framework's sliding-window variant
LONG_WINDOW = 8192
PARAM_DTYPE = jnp.bfloat16

# optimizer per arch: adafactor where Adam's fp32 m+v would not fit 16 GB/chip
ADAFACTOR_ARCHS = ("deepseek-v3-671b",)

# gradient-accumulation depth for train_4k, by model size (per-device
# activation memory scales with global_batch / microbatches)
MICROBATCHES = {
    "qwen1.5-0.5b": 1, "musicgen-medium": 2, "xlstm-1.3b": 2,
    "codeqwen1.5-7b": 2, "zamba2-7b": 2, "qwen3-14b": 4,
    "llava-next-34b": 8, "qwen2-72b": 8, "dbrx-132b": 8,
    "deepseek-v3-671b": 16,
}


def pick_optimizer(arch: str):
    if arch in ADAFACTOR_ARCHS:
        return optim.adafactor()
    return optim.adam()


# ------------------------------------------------------------------ specs
def input_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input of this step kind."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    if shape.kind == "train":
        if cfg.arch_type == "audio":
            K = cfg.frontend.n_codebooks
            return {"tokens": tok(B, K, S), "labels": tok(B, K, S)}
        if cfg.arch_type == "vlm":
            nm = cfg.frontend.n_media_tokens
            return {"tokens": tok(B, S - nm), "labels": tok(B, S),
                    "media": jax.ShapeDtypeStruct(
                        (B, nm, cfg.frontend.embed_dim), jnp.bfloat16)}
        return {"tokens": tok(B, S), "labels": tok(B, S)}
    if shape.kind == "prefill":
        if cfg.arch_type == "audio":
            K = cfg.frontend.n_codebooks
            return {"tokens": tok(B, K, S)}
        if cfg.arch_type == "vlm":
            nm = cfg.frontend.n_media_tokens
            return {"tokens": tok(B, S - nm),
                    "media": jax.ShapeDtypeStruct(
                        (B, nm, cfg.frontend.embed_dim), jnp.bfloat16)}
        return {"tokens": tok(B, S)}
    # decode: ONE new token against a cache of size seq_len
    if cfg.arch_type == "audio":
        K = cfg.frontend.n_codebooks
        return {"tokens": tok(B, K, 1)}
    return {"tokens": tok(B, 1)}


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.name == "long_500k" and cfg.arch_type in (
            "dense", "vlm", "audio", "moe", "hybrid"):
        return LONG_WINDOW
    return cfg.sliding_window


def cache_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    w = decode_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


# ------------------------------------------------------------ cache pspecs
def cache_pspec_tree(cache_shapes, mesh, rules: ShardingRules):
    """PartitionSpecs for decode caches, by leaf name.

    KV caches are SEQUENCE-sharded over the tensor axis (DESIGN §5) so GQA
    archs with few KV heads still use all 16 model-axis shards; SSM/xLSTM
    states shard their head axis over the tensor axis; everything shards
    batch over the data axes.
    """
    batch = rules.logical["batch"]
    tp = rules.tensor_axis

    def spec_for(path, leaf):
        name = path[-1]
        nd = leaf.ndim
        def pad(*axes):
            return P(*((None,) * (nd - len(axes)) + tuple(axes)))
        if name in ("k", "v"):               # (..., B, W, Hkv, hd)
            s = pad(batch, tp, None, None)
        elif name in ("c_kv", "k_rope"):     # (..., B, W, r)
            s = pad(batch, tp, None)
        elif name == "pos_ids":
            s = P(*((None,) * nd))
        elif name == "ssm":                  # (..., B, nh, hd, ds)
            s = pad(batch, tp, None, None)
        elif name == "conv":                 # (..., B, K-1, Cd)
            s = pad(batch, None, tp)
        elif name == "C":                    # (..., B, nh, hd, hd)
            s = pad(batch, tp, None, None)
        elif name in ("n", "c", "h", "m"):   # (..., B, nh, hd)
            s = pad(batch, tp, None)
        else:
            s = P(*((None,) * nd))
        return safe_spec(leaf.shape, s, mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = [spec_for(tuple(_kname(k) for k in kp), leaf)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _kname(k):
    return str(getattr(k, "key", getattr(k, "idx", k)))


def batch_pspec_tree(specs, mesh, rules: ShardingRules):
    batch = rules.logical["batch"]

    def one(name, leaf):
        s = P(*((batch,) + (None,) * (leaf.ndim - 1)))
        return safe_spec(leaf.shape, s, mesh)

    return {k: one(k, v) for k, v in specs.items()}


def _opt_state_pspecs(arch: str, p_specs, params_shapes):
    """Optimizer-state PartitionSpecs mirroring the parameter shardings.

    adam: (m, v, t) — m/v shard exactly like their params.
    adafactor: ((vr, vc) per param, t) — vr drops the last param axis,
    vc drops the second-to-last (rank-1 factored second moment).
    """
    is_p = lambda x: isinstance(x, P)
    if arch in ADAFACTOR_ARCHS:
        def factor(spec, p):
            s = tuple(spec) + (None,) * (p.ndim - len(tuple(spec)))
            if p.ndim >= 2:
                return (P(*s[:-1]), P(*(s[:-2] + s[-1:])))
            return (P(*s), None)
        fac = jax.tree.map(factor, p_specs, params_shapes, is_leaf=is_p)
        return (fac, P())
    return (p_specs, p_specs, P())


# ------------------------------------------------------------------ steps
def build_lowerable(arch: str, shape_name: str, *, multi_pod: bool = False,
                    beta: float = 1.0, remat: bool = True,
                    window_override=None, rules_override=None,
                    microbatches=None):
    """Returns (jitted_fn, arg_specs) ready for .lower(*arg_specs)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    shard_batch = shape.global_batch % mesh.shape["data"] == 0
    # activation-FSDP where the remat-saved residual stream would blow HBM:
    # saved-x bytes/dev = n_layers · (B_mb/data) · S · d · 2  (bf16)
    mb_n = (MICROBATCHES.get(arch, 1) if microbatches is None
            else microbatches) if shape.kind == "train" else 1
    per_dev_b = max(shape.global_batch // mb_n // mesh.shape["data"], 1)
    saved_x = cfg.n_layers * per_dev_b * shape.seq_len * cfg.d_model * 2
    shard_acts = shape.kind == "train" and saved_x > 3 * 2 ** 30
    rules = rules_override or mesh_mod.make_rules(
        mesh, shard_batch=shard_batch, shard_activations=shard_acts)

    params_shapes = jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, dtype=PARAM_DTYPE))  # flcheck: disable=FLC001 (shape-only eval_shape stand-in; key bits never materialize)
    p_specs = rules.pspec_tree(params_shapes)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    params_in = jax.tree.map(
        lambda sh, sd: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=sd),
        params_shapes, p_shard)
    bspecs = input_specs(cfg, shape)
    b_pspec = batch_pspec_tree(bspecs, mesh, rules)
    b_shard = {k: NamedSharding(mesh, s) for k, s in b_pspec.items()}
    batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                        sharding=b_shard[k])
                for k, v in bspecs.items()}
    window = (decode_window(cfg, shape) if window_override is None
              else window_override)

    if shape.kind == "train":
        optimizer = pick_optimizer(arch)
        mb = MICROBATCHES.get(arch, 1) if microbatches is None else microbatches
        # bf16 gradient accumulation for the 671B fit (DESIGN.md §Assumptions)
        accum = jnp.bfloat16 if arch in ADAFACTOR_ARCHS else jnp.float32
        step = tf.make_train_step(cfg, optimizer, beta=beta, remat=remat,
                                  microbatches=mb, accum_dtype=accum)
        opt_shapes = jax.eval_shape(lambda p: optimizer.init(p), params_shapes)
        o_specs = _opt_state_pspecs(arch, p_specs, params_shapes)
        o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                               is_leaf=lambda x: isinstance(x, P))
        opt_in = jax.tree.map(
            lambda sh, sd: jax.ShapeDtypeStruct(sh.shape, sh.dtype,
                                                sharding=sd),
            opt_shapes, o_shard)

        def fn(params, opt_state, batch, lr):
            with use_rules(rules):
                return step(params, opt_state, batch, lr)

        # donate params + optimizer state: the updated trees alias their
        # inputs — without this memory_analysis double-counts them
        jf = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard, None),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        args = (params_in, opt_in, batch_in,
                jax.ShapeDtypeStruct((), jnp.float32))
        return mesh, jf, args

    if shape.kind == "prefill":
        def fn(params, batch):
            with use_rules(rules):
                caches = tf.init_cache(cfg, shape.global_batch,
                                       cache_capacity(cfg, shape))
                logits, _, (caches, _, _) = tf.forward(
                    params, batch, cfg, dtype=jnp.bfloat16, window=window,
                    caches=caches, remat=False)
                last = (logits[:, :, -1:] if cfg.arch_type == "audio"
                        else logits[:, -1:])
                return last, caches

        jf = jax.jit(fn, in_shardings=(p_shard, b_shard),
                     out_shardings=None)
        return mesh, jf, (params_in, batch_in)

    # decode
    cap = cache_capacity(cfg, shape)
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, shape.global_batch, cap))
    c_pspec = cache_pspec_tree(cache_shapes, mesh, rules)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_pspec,
                           is_leaf=lambda x: isinstance(x, P))
    caches_in = jax.tree.map(
        lambda sh, sd: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=sd),
        cache_shapes, c_shard)

    def fn(params, caches, batch, pos):
        with use_rules(rules):
            return tf.decode_step(params, caches, batch, pos, cfg,
                                  dtype=jnp.bfloat16, window=window)

    # donate the KV/SSM caches — decode updates them in place
    jf = jax.jit(fn, in_shardings=(p_shard, c_shard, b_shard, None),
                 out_shardings=(None, c_shard), donate_argnums=(1,))
    args = (params_in, caches_in, batch_in,
            jax.ShapeDtypeStruct((), jnp.int32))
    return mesh, jf, args


# ------------------------------------------------------- local-SGD (paper)
def build_local_sgd(arch: str, shape_name: str = "train_4k", *,
                    inner_steps: int = 8, microbatches=None):
    """The paper's FedAvg schedule as a cross-pod training strategy (DiLoCo):
    H inner steps per pod with NO cross-pod collectives, then ONE parameter
    pmean across pods — inter-pod traffic drops ~H× vs per-step sync.

    Params/opt-state carry a leading pod axis (per-pod replicas, they drift
    between syncs); ``shard_map`` over the pod axis makes `pod` manual while
    data/model stay auto (GSPMD shards the inner step per pod exactly like
    the single-pod layout).
    """
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=True)
    n_pod = mesh.shape["pod"]
    # inner rules: single-pod style (no pod axis — pod is manual here)
    rules = ShardingRules(mesh, fsdp_axis="data", tensor_axis="model",
                          data_axes=("data",), pod_axis=None,
                          shard_activations=True)
    optimizer = pick_optimizer(arch)
    mb = MICROBATCHES.get(arch, 1) if microbatches is None else microbatches
    step = tf.make_train_step(cfg, optimizer, remat=True, microbatches=mb)

    params_shapes = jax.eval_shape(
        lambda: tf.init_model(jax.random.PRNGKey(0), cfg, dtype=PARAM_DTYPE))  # flcheck: disable=FLC001 (shape-only eval_shape stand-in; key bits never materialize)
    p_specs = rules.pspec_tree(params_shapes)
    pod_spec = lambda s: P(*(("pod",) + tuple(s)))
    p_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, pod_spec(s)), p_specs,
        is_leaf=lambda x: isinstance(x, P))
    podded = lambda sh, sd: jax.ShapeDtypeStruct(
        (n_pod,) + sh.shape, sh.dtype, sharding=sd)
    params_in = jax.tree.map(podded, params_shapes, p_shard)

    opt_shapes = jax.eval_shape(lambda p: optimizer.init(p), params_shapes)
    o_specs = _opt_state_pspecs(arch, p_specs, params_shapes)
    o_shard = jax.tree.map(lambda s: NamedSharding(mesh, pod_spec(s)),
                           o_specs, is_leaf=lambda x: isinstance(x, P))
    opt_in = jax.tree.map(podded, opt_shapes, o_shard)

    bspecs = input_specs(cfg, shape)
    # batch laid out (pod, H, B/pod, ...): pod-major, then inner steps
    b_shard = {k: NamedSharding(
        mesh, P("pod", None, "data", *((None,) * (v.ndim - 1))))
        for k, v in bspecs.items()}
    batch_in = {k: jax.ShapeDtypeStruct(
        (n_pod, inner_steps, v.shape[0] // n_pod) + v.shape[1:], v.dtype,
        sharding=b_shard[k]) for k, v in bspecs.items()}

    def round_fn(params_p, opt_p, batches, lr):
        """One local-SGD round: H inner steps per pod (vmapped over the pod
        dim with spmd_axis_name so constraints pin per-pod shards), then the
        paper's FedAvg aggregation — a single cross-pod parameter mean."""
        def pod_train(params, opt, batches_pod):
            def scan_body(carry, b):
                p, o = carry
                with use_rules(rules):
                    p, o, m = step(p, o, b, lr)
                return (p, o), m["loss"]
            (p, o), losses = jax.lax.scan(scan_body, (params, opt),
                                          batches_pod)
            return p, o, jnp.mean(losses)

        p2, o2, loss = jax.vmap(pod_train, spmd_axis_name="pod")(
            params_p, opt_p, batches)
        # FedAvg across pods (Alg. 1 aggregation, once per H steps)
        synced = jax.tree.map(
            lambda t: jnp.broadcast_to(jnp.mean(t, axis=0, keepdims=True),
                                       t.shape), p2)
        return synced, o2, jnp.mean(loss)

    jf = jax.jit(round_fn,
                 in_shardings=(p_shard, o_shard, b_shard, None),
                 out_shardings=(p_shard, o_shard, None))
    args = (params_in, opt_in, batch_in,
            jax.ShapeDtypeStruct((), jnp.float32))
    return mesh, jf, args


# ------------------------------------------------------------- extraction
_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str):
    """Sum RESULT-shape bytes of every collective op in the optimized HLO.

    (Operand shapes are not printed on the op line in HLO text; result bytes
    equal operand bytes for all-reduce/all-to-all/permute, overcount
    all-gather by the gather factor and undercount reduce-scatter by the
    scatter factor — adequate for a first-order collective-traffic roofline,
    and recorded as the methodology in EXPERIMENTS.md.)
    """
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        _, dt, dims, kind = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + b
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = "experiments/dryrun", quiet: bool = False,
            tag: str = "", **kw):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.perf_counter()
    mesh, jf, args = build_lowerable(arch, shape_name, multi_pod=multi_pod,
                                     **kw)
    with mesh:
        traced = jf.trace(*args)
        gcost = costmodel.jaxpr_cost(traced.jaxpr)       # GLOBAL, scan-aware
        lowered = traced.lower()
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = costmodel.hlo_collective_bytes(hlo)           # per-device, ×trips
    n_chips = 1
    for a in mesh.axis_names:
        n_chips *= mesh.shape[a]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips,
        "kind": SHAPES_BY_NAME[shape_name].kind,
        "flops_global": gcost["flops"],
        "bytes_global": gcost["bytes"],
        "xla_flops_per_device": cost.get("flops", float("nan")),
        "xla_bytes_per_device": cost.get("bytes accessed", float("nan")),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = out / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))
    if not quiet:
        gb = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
              + mem.output_size_in_bytes) / 2 ** 30
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK  "
              f"flops(global)={rec['flops_global']:.3e}  "
              f"bytes(global)={rec['bytes_global']:.3e}  "
              f"mem/dev≈{gb:.1f} GiB  "
              f"coll/dev={sum(coll.values())/2**20:.0f} MiB  "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    combos = ([(a, s) for a in ARCH_IDS for s in SHAPES_BY_NAME]
              if args.all else [(args.arch, args.shape)])
    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, multi_pod=args.multi_pod, out_dir=args.out)
        except Exception as e:                           # noqa: BLE001
            failures.append((arch, shape, repr(e)[:200]))
            print(f"[dryrun] {arch} × {shape}: FAIL {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
