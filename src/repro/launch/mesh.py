"""Production mesh construction + sharding-rule helpers.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before calling it;
tests and benches see the real single device.
"""
from __future__ import annotations

import jax

from repro.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16×16 = 256 chips/pod; 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh, *, shard_batch: bool = True,
               shard_activations: bool = False) -> ShardingRules:
    multi = "pod" in mesh.axis_names
    # on the multi-pod mesh FSDP spans BOTH pod and data axes (32-way):
    # params/grads/optimizer shrink 2× per chip vs single-pod
    return ShardingRules(mesh,
                         fsdp_axis=("pod", "data") if multi else "data",
                         tensor_axis="model",
                         data_axes=("data",),
                         pod_axis="pod" if multi else None,
                         shard_batch=shard_batch,
                         shard_activations=shard_activations)


# TPU v5e hardware model for the roofline (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
