"""Checkpointing: save/restore parameter + optimizer pytrees.

Flat-key .npz format (no pickle — safe to load), with the tree structure
recorded as the key paths.  Used by the FL driver for round snapshots and
full-engine checkpoint/resume (``fedavg.run_federated_training``) and by
the LLM examples.  bfloat16 leaves are stored via a uint16 view (npz has
no native bf16).

Format notes:

* Paths are normalized to carry the ``.npz`` suffix — ``np.savez`` appends
  it silently, so without normalization ``save("ckpt")`` +
  ``restore("ckpt")`` would write ``ckpt.npz`` and then fail to find
  ``ckpt``.
* Key-paths join with ``/``; two DISTINCT tree paths that join to the same
  string (e.g. a dict key containing ``/``), or a leaf keyed by the
  reserved ``__metadata__``, would silently overwrite each other in the
  archive — both raise ``ValueError`` instead of corrupting the checkpoint.
"""
from __future__ import annotations

import glob as _glob
import json
import os
import zipfile
from pathlib import Path
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
_BF16_TAG = "__bf16__"
_META_KEY = "__metadata__"


def _normalize(path) -> Path:
    """Carry the ``.npz`` suffix explicitly (np.savez appends it silently,
    which would make a suffix-less ``save``/``restore`` pair miss)."""
    path = Path(path)
    if path.suffix != ".npz":
        path = path.with_name(path.name + ".npz")
    return path


def _join(kp) -> str:
    return _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _join(kp)
        if key == _META_KEY:
            raise ValueError(
                f"tree leaf keyed {_META_KEY!r} collides with the reserved "
                "metadata entry — rename the leaf")
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            key, arr = key + _BF16_TAG, arr.view(np.uint16)
        if key in out:
            raise ValueError(
                f"distinct tree paths flatten to the same key {key!r} "
                "(a dict key containing '/', or a bf16 leaf shadowing "
                f"an explicit '*{_BF16_TAG}' key) — the checkpoint would "
                "silently drop one of them")
        out[key] = arr
    return out


def save(path, tree, metadata=None):
    """Write a pytree checkpoint to ``path`` (.npz appended if missing).

    The write is ATOMIC (tmp file + ``os.replace``): a concurrent reader —
    e.g. a serving registry polling this path for new generations — sees
    either the previous complete checkpoint or the new one, never a
    half-written archive.
    """
    path = _normalize(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    if metadata is not None:
        flat[_META_KEY] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:           # savez on a handle keeps the name
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_arrays(path):
    """All leaf arrays of a checkpoint keyed by their ``/``-joined tree
    paths (bf16-tagged entries decoded back to bfloat16), plus the metadata
    dict (None when absent) — the structure-free view ``restore`` and the
    engine-state resume path build trees from."""
    data = np.load(_normalize(path), allow_pickle=False)
    out = {}
    for key in data.files:
        if key == _META_KEY:
            continue
        if key.endswith(_BF16_TAG):
            out[key[:-len(_BF16_TAG)]] = (
                jnp.asarray(data[key]).view(jnp.bfloat16))
        else:
            out[key] = data[key]
    meta = (json.loads(bytes(data[_META_KEY]).decode())
            if _META_KEY in data.files else None)
    return out, meta


def unflatten_like(like, flat, prefix: str = ""):
    """Rebuild a tree with ``like``'s structure from a flat key->array dict
    (the ``load_arrays`` view), reading each leaf at ``prefix + keypath``.
    Raises ``KeyError`` on missing leaves and ``ValueError`` on shape
    mismatches."""
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for kp, leaf in flat_like:
        key = prefix + _join(kp)
        if key not in flat:
            raise KeyError(f"checkpoint is missing leaf {key!r}")
        arr = jnp.asarray(flat[key])
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(path, like):
    """Load a checkpoint into the structure of ``like`` (a template tree)."""
    flat, _ = load_arrays(path)
    return unflatten_like(like, flat)


def metadata(path):
    return load_arrays(path)[1]


# ---------------------------------------------------------- publish polling
def generation(path) -> int:
    """Publish generation of a checkpoint, from metadata ALONE — npz members
    load lazily, so this never touches the (potentially large) arrays.

    Priority: an explicit ``metadata["generation"]`` (what the FL driver
    stamps — its global executed-round counter, monotone across clusters),
    falling back to ``rounds_done`` for older snapshots; -1 when the
    checkpoint carries neither (or no metadata at all).
    """
    data = np.load(_normalize(path), allow_pickle=False)
    if _META_KEY not in data.files:
        return -1
    meta = json.loads(bytes(data[_META_KEY]).decode())
    g = meta.get("generation", meta.get("rounds_done"))
    return -1 if g is None else int(g)


def latest(path_glob) -> Optional[Tuple[Path, int]]:
    """``(path, generation)`` of the highest-generation checkpoint matching
    the glob; ``None`` when nothing (readable) matches.

    Metadata-only reads (see :func:`generation`) make this cheap enough to
    poll every few seconds even with multi-GB archives behind the glob.
    Unreadable files are skipped, not fatal — with non-atomic writers a
    half-written archive may transiently match the glob.  Ties break toward
    the lexicographically LAST path so concurrent pollers agree.
    """
    best: Optional[Tuple[Path, int]] = None
    for p in sorted(_glob.glob(str(path_glob))):
        try:
            g = generation(p)
        except (OSError, ValueError, KeyError, zipfile.BadZipFile,
                json.JSONDecodeError):
            continue
        if best is None or g >= best[1]:
            best = (Path(p), g)
    return best
