"""Checkpointing: save/restore parameter + optimizer pytrees.

Flat-key .npz format (no pickle — safe to load), with the tree structure
recorded as the key paths.  Used by the FL driver for round snapshots and
by the LLM examples.  bfloat16 leaves are stored via a uint16 view (npz has
no native bf16).
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
_BF16_TAG = "__bf16__"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[key + _BF16_TAG] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def save(path, tree, metadata=None):
    """Write a pytree checkpoint to ``path`` (.npz)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    if metadata is not None:
        flat["__metadata__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **flat)


def restore(path, like):
    """Load a checkpoint into the structure of ``like`` (a template tree)."""
    data = np.load(Path(path), allow_pickle=False)
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for kp, leaf in flat_like:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if key + _BF16_TAG in data:
            arr = jnp.asarray(data[key + _BF16_TAG]).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(data[key])
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def metadata(path):
    data = np.load(Path(path), allow_pickle=False)
    if "__metadata__" in data:
        return json.loads(bytes(data["__metadata__"]).decode())
    return None
