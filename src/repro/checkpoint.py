"""Checkpointing: save/restore parameter + optimizer pytrees.

Flat-key .npz format (no pickle — safe to load), with the tree structure
recorded as the key paths.  Used by the FL driver for round snapshots and
full-engine checkpoint/resume (``fedavg.run_federated_training``) and by
the LLM examples.  bfloat16 leaves are stored via a uint16 view (npz has
no native bf16).

Format notes:

* Paths are normalized to carry the ``.npz`` suffix — ``np.savez`` appends
  it silently, so without normalization ``save("ckpt")`` +
  ``restore("ckpt")`` would write ``ckpt.npz`` and then fail to find
  ``ckpt``.
* Key-paths join with ``/``; two DISTINCT tree paths that join to the same
  string (e.g. a dict key containing ``/``), or a leaf keyed by the
  reserved ``__metadata__``, would silently overwrite each other in the
  archive — both raise ``ValueError`` instead of corrupting the checkpoint.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"
_BF16_TAG = "__bf16__"
_META_KEY = "__metadata__"


def _normalize(path) -> Path:
    """Carry the ``.npz`` suffix explicitly (np.savez appends it silently,
    which would make a suffix-less ``save``/``restore`` pair miss)."""
    path = Path(path)
    if path.suffix != ".npz":
        path = path.with_name(path.name + ".npz")
    return path


def _join(kp) -> str:
    return _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _join(kp)
        if key == _META_KEY:
            raise ValueError(
                f"tree leaf keyed {_META_KEY!r} collides with the reserved "
                "metadata entry — rename the leaf")
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            key, arr = key + _BF16_TAG, arr.view(np.uint16)
        if key in out:
            raise ValueError(
                f"distinct tree paths flatten to the same key {key!r} "
                "(a dict key containing '/', or a bf16 leaf shadowing "
                f"an explicit '*{_BF16_TAG}' key) — the checkpoint would "
                "silently drop one of them")
        out[key] = arr
    return out


def save(path, tree, metadata=None):
    """Write a pytree checkpoint to ``path`` (.npz appended if missing)."""
    path = _normalize(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    if metadata is not None:
        flat[_META_KEY] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **flat)


def load_arrays(path):
    """All leaf arrays of a checkpoint keyed by their ``/``-joined tree
    paths (bf16-tagged entries decoded back to bfloat16), plus the metadata
    dict (None when absent) — the structure-free view ``restore`` and the
    engine-state resume path build trees from."""
    data = np.load(_normalize(path), allow_pickle=False)
    out = {}
    for key in data.files:
        if key == _META_KEY:
            continue
        if key.endswith(_BF16_TAG):
            out[key[:-len(_BF16_TAG)]] = (
                jnp.asarray(data[key]).view(jnp.bfloat16))
        else:
            out[key] = data[key]
    meta = (json.loads(bytes(data[_META_KEY]).decode())
            if _META_KEY in data.files else None)
    return out, meta


def unflatten_like(like, flat, prefix: str = ""):
    """Rebuild a tree with ``like``'s structure from a flat key->array dict
    (the ``load_arrays`` view), reading each leaf at ``prefix + keypath``.
    Raises ``KeyError`` on missing leaves and ``ValueError`` on shape
    mismatches."""
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for kp, leaf in flat_like:
        key = prefix + _join(kp)
        if key not in flat:
            raise KeyError(f"checkpoint is missing leaf {key!r}")
        arr = jnp.asarray(flat[key])
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(path, like):
    """Load a checkpoint into the structure of ``like`` (a template tree)."""
    flat, _ = load_arrays(path)
    return unflatten_like(like, flat)


def metadata(path):
    return load_arrays(path)[1]
