"""FLC004 — nondeterminism lint for replay-critical code.

Scope (see ``rules.py``): ``src/repro/core/`` and ``src/repro/data/`` only.
Everything the round engine does must be a pure function of
``(FLConfig.seed, round, slot, attempt)`` — the event clock, churn draws,
transform keys and sampler streams all replay bit-identically under a fixed
seed, and checkpoint/resume depends on it (tests/test_churn.py pins a
kill-and-resume run bit-identical).  Wall-clock reads, global rng state,
Python's salted ``hash`` and unordered-set iteration all silently break
that.  ``launch/``/benchmarks legitimately measure wall-clock time, so the
rule simply does not apply there.

Flagged constructs:

* ``time.time()`` / ``time.monotonic()`` / ``datetime.now()`` — simulated
  rounds must use the event clock (``core/latency.py``); host-side timing
  belongs in launch/bench code (and should be ``perf_counter`` there).
* global numpy rng (``np.random.rand`` etc.) and stdlib ``random.*`` —
  hidden shared state; use ``np.random.default_rng(SeedSequence([...]))``.
* builtin ``hash()`` — salted per process (PYTHONHASHSEED).
* ``for ... in set(...)`` / set literals — iteration order is unspecified;
  feeding it into arrays reorders results across runs.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.rules import Finding, Suppressions

__all__ = ["check_source"]

_WALLCLOCK = {"time.time", "time.monotonic", "time.monotonic_ns",
              "time.time_ns", "datetime.now", "datetime.utcnow",
              "datetime.datetime.now", "datetime.datetime.utcnow"}
# numpy legacy global-state samplers (module-level np.random.*); the
# Generator API (default_rng / SeedSequence) is the sanctioned replacement
_NP_GLOBAL = frozenset({
    "seed", "rand", "randn", "random", "randint", "random_sample",
    "ranf", "sample", "normal", "uniform", "choice", "shuffle",
    "permutation", "standard_normal", "poisson", "beta", "gamma",
    "binomial", "exponential", "lognormal", "pareto",
})
_STDLIB_RANDOM = frozenset({
    "random", "randint", "seed", "choice", "choices", "shuffle", "uniform",
    "gauss", "sample", "randrange", "betavariate", "expovariate",
    "normalvariate",
})


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Lint(ast.NodeVisitor):
    def __init__(self, rel: str, sup: Suppressions):
        self.rel, self.sup = rel, sup
        self.findings: List[Finding] = []
        self.has_stdlib_random = False

    def _emit(self, line: int, msg: str) -> None:
        self.findings.append(self.sup.apply("FLC004", self.rel, line, msg))

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name == "random" and (alias.asname or "random") == \
                    "random":
                self.has_stdlib_random = True
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name is not None:
            if name in _WALLCLOCK:
                self._emit(node.lineno,
                           f"wall-clock read {name}() in replay-critical "
                           "code — simulated rounds must use the event "
                           "clock (core/latency.py)")
            else:
                parts = name.split(".")
                if len(parts) >= 2 and parts[-2] == "random" and \
                        parts[0] in ("np", "numpy") and \
                        parts[-1] in _NP_GLOBAL:
                    self._emit(node.lineno,
                               f"global numpy rng {name}() — hidden shared "
                               "state; use np.random.default_rng("
                               "SeedSequence([seed, ...]))")
                elif self.has_stdlib_random and len(parts) == 2 and \
                        parts[0] == "random" and parts[1] in _STDLIB_RANDOM:
                    self._emit(node.lineno,
                               f"stdlib {name}() draws from global state — "
                               "use a seeded np.random.Generator")
            if name == "hash":
                self._emit(node.lineno,
                           "builtin hash() is salted per process "
                           "(PYTHONHASHSEED) — use a stable digest "
                           "(hashlib) or integer tags")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        it = node.iter
        is_set_call = (isinstance(it, ast.Call)
                       and _dotted(it.func) in ("set", "frozenset"))
        if is_set_call or isinstance(it, ast.Set):
            self._emit(node.lineno,
                       "iterating a set — order is unspecified and will "
                       "reorder anything array-shaped; sort first")
        self.generic_visit(node)


def check_source(source: str, rel: str) -> List[Finding]:
    """Run the determinism rule over one module's source."""
    tree = ast.parse(source)
    lint = _Lint(rel, Suppressions(source))
    lint.visit(tree)
    return lint.findings
