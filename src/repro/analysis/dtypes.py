"""FLC005 — dtype-hazard lint for transform and kernel code.

Scope (see ``rules.py``): ``src/repro/core/`` and ``src/repro/kernels/``.
The delta-transform stack and the Pallas cells are the numerically
load-bearing device code: quantization grids, DP noise scales, mask
cancellation and kernel-vs-reference parity are all pinned at float32
tolerance, so silent precision changes break real guarantees.  Host-side
numpy fp64 (metric accumulators, history arrays) is fine and NOT flagged —
the rules below target the jnp/device path only.

Flagged constructs:

* ``jnp.float64`` (attribute, ``astype``, or ``dtype="float64"`` in a jnp
  call) — with jax's default x64-disabled config this silently truncates to
  f32; with x64 enabled it doubles the wire/bench byte counts the latency
  model charges.  Either behavior is a trap; be explicit with f32.
* arithmetic directly on values cast to a narrow int (``astype(jnp.int8)
  + ...``) — int8 wraps at ±127; quantized-delta math must accumulate in
  int32/float and cast at the wire boundary.
* a narrowing ``.astype(...)`` feeding a contraction (``einsum``/``dot``/
  ``matmul``) that has no ``preferred_element_type`` — accumulating in the
  narrowed dtype loses the fp32-accumulation guarantee the Pallas kernels
  make (they all pass ``preferred_element_type=jnp.float32``).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.rules import Finding, Suppressions

__all__ = ["check_source"]

_CONTRACTIONS = frozenset({"einsum", "dot", "matmul", "dot_general",
                           "tensordot"})
_NARROW_INTS = frozenset({"int8", "uint8", "int16", "uint16"})


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _dtype_token(node: ast.AST) -> Optional[str]:
    """'jnp.float64' -> 'float64', "int8" -> 'int8', else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    name = _dotted(node)
    if name and "." in name:
        mod, last = name.rsplit(".", 1)
        if mod in ("jnp", "np", "numpy", "jax.numpy"):
            return last
    return None


def _is_narrow_int_cast(node: ast.AST) -> bool:
    """x.astype(jnp.int8) / jnp.asarray(x, jnp.int8)-style expression."""
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func) or ""
    if name.endswith(".astype") or isinstance(node.func, ast.Attribute) and \
            node.func.attr == "astype":
        args = node.args + [kw.value for kw in node.keywords]
        return any(_dtype_token(a) in _NARROW_INTS for a in args)
    if name.rsplit(".", 1)[-1] in ("asarray", "array", "full", "zeros",
                                   "ones"):
        args = node.args + [kw.value for kw in node.keywords]
        return any(_dtype_token(a) in _NARROW_INTS for a in args)
    return False


def _is_downcast_astype(node: ast.AST) -> bool:
    """x.astype(v.dtype) / x.astype(jnp.bfloat16): a cast to a (possibly)
    narrower dtype — hazardous as a contraction operand."""
    if not (isinstance(node, ast.Call) and isinstance(node.func,
                                                      ast.Attribute)
            and node.func.attr == "astype" and node.args):
        return False
    arg = node.args[0]
    tok = _dtype_token(arg)
    if tok in ("bfloat16", "float16") or tok in _NARROW_INTS:
        return True
    # .astype(other.dtype): target dtype unknown at lint time -> hazard
    return isinstance(arg, ast.Attribute) and arg.attr == "dtype"


class _Lint(ast.NodeVisitor):
    def __init__(self, rel: str, sup: Suppressions):
        self.rel, self.sup = rel, sup
        self.findings: List[Finding] = []

    def _emit(self, line: int, msg: str) -> None:
        self.findings.append(self.sup.apply("FLC005", self.rel, line, msg))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        name = _dotted(node)
        if name in ("jnp.float64", "jax.numpy.float64"):
            self._emit(node.lineno,
                       "jnp.float64 on the device path — silently truncates "
                       "to f32 unless x64 is enabled (and doubles wire "
                       "bytes when it is); use explicit float32")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func) or ""
        last = name.rsplit(".", 1)[-1]
        # dtype="float64" in a jnp call
        if name.startswith(("jnp.", "jax.numpy.")):
            for kw in node.keywords:
                if kw.arg == "dtype" and _dtype_token(kw.value) in \
                        ("float64", "f8"):
                    self._emit(node.lineno,
                               f"dtype=float64 in {name}() — device code "
                               "must stay f32 (x64 silently off by "
                               "default)")
        # narrowing cast feeding a contraction without fp32 accumulation
        if last in _CONTRACTIONS:
            has_pet = any(kw.arg == "preferred_element_type"
                          for kw in node.keywords)
            if not has_pet and any(_is_downcast_astype(a)
                                   for a in node.args):
                self._emit(node.lineno,
                           f"narrowing astype feeding {last}() without "
                           "preferred_element_type — the contraction "
                           "accumulates in the narrowed dtype; pass "
                           "preferred_element_type=jnp.float32")
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub, ast.Mult)) and (
                _is_narrow_int_cast(node.left)
                or _is_narrow_int_cast(node.right)):
            self._emit(node.lineno,
                       "arithmetic on a narrow-int cast — int8/int16 wrap "
                       "silently; accumulate in int32/float and cast at "
                       "the wire boundary")
        self.generic_visit(node)


def check_source(source: str, rel: str) -> List[Finding]:
    """Run the dtype-hazard rule over one module's source."""
    tree = ast.parse(source)
    lint = _Lint(rel, Suppressions(source))
    lint.visit(tree)
    return lint.findings
