"""Dynamic round-hot-path checks: recompile guard + implicit-transfer guard.

Two performance contracts the engine docs promise (ROADMAP "one jitted round
per execution geometry"; the semi-sync engine's event loop):

* **No per-round recompiles.**  ``pipeline_round`` is jitted with the config
  objects static — if a caller threads a value that should be traced (lr,
  round index, weights) through a static argnum instead, every round
  retraces.  :func:`count_recompiles` runs a callable for N steps and
  reports how many NEW jit cache entries each step added after the first.
* **No implicit host<->device transfers.**  The round body must consume
  device-resident arrays; a stray ``np.asarray`` on a traced value or a
  Python float materialized per round forces a sync.
  :func:`check_transfers` warms the function up (compile transfers are
  legitimate) and then re-runs it under ``jax.transfer_guard("disallow")``.

Both are *dynamic* checks (they run the function), packaged here so the CLI
can drive them against the real round bodies next to the static passes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax

__all__ = ["RecompileReport", "count_recompiles", "check_transfers",
           "check_round_hot_path"]


def _live_cache_size() -> int:
    """Total live entries across the process-global pjit caches.

    Per-function caches (``jitted._cache_size()``) are the precise probe —
    pass one to :func:`count_recompiles` when you know the function under
    test.  This aggregate is the fallback for opaque step callables.
    """
    from jax._src import pjit as _pjit
    n = _pjit._cpp_pjit_cache_explicit_attributes.size()
    n += _pjit._cpp_pjit_cache_fun_only.size()
    n += _pjit._infer_params_cached.cache_info().currsize
    return int(n)


@dataclasses.dataclass
class RecompileReport:
    steps: int
    new_entries_per_step: List[int]    # cache growth AFTER the warm-up step

    @property
    def ok(self) -> bool:
        return not any(self.new_entries_per_step)

    def render(self) -> str:
        if self.ok:
            return (f"recompile guard OK: {self.steps} steps after warm-up "
                    "added 0 jit cache entries")
        return ("recompile guard FAILED: post-warm-up steps added cache "
                f"entries {self.new_entries_per_step} — a traced value is "
                "being passed as a static arg (or a new function object is "
                "created per step)")


def count_recompiles(step: Callable[[int], Any], steps: int = 3,
                     cache_size: Optional[Callable[[], int]] = None
                     ) -> RecompileReport:
    """Run ``step(i)`` for ``i in range(steps + 1)``; the first call is
    warm-up (compiles are expected), the rest must add zero cache entries.

    ``cache_size`` is the probe — pass the jitted function's own
    ``._cache_size`` for a per-function count, default is the global
    aggregate."""
    probe = cache_size or _live_cache_size
    step(0)
    growth: List[int] = []
    before = probe()
    for i in range(1, steps + 1):
        step(i)
        now = probe()
        growth.append(max(0, now - before))
        before = now
    return RecompileReport(steps, growth)


def check_transfers(step: Callable[[int], Any]) -> Optional[str]:
    """Warm ``step`` up, then re-run it with implicit transfers disallowed.
    Returns None when clean, else the transfer-guard error message."""
    step(0)
    try:
        with jax.transfer_guard("disallow"):
            out = step(1)
            jax.block_until_ready(out)
    except Exception as e:  # transfer guard raises jaxlib-level errors
        return str(e)
    return None


def check_round_hot_path(steps: int = 3):
    """Drive the REAL vmap pipeline round for a few rounds and apply both
    guards.  Returns (RecompileReport, transfer_error_or_None)."""
    import jax.numpy as jnp

    from repro.configs.base import (ForecasterConfig, SecureAggConfig,
                                    TransformConfig)
    from repro.core import fedavg, losses
    from repro.models.forecaster import init_forecaster

    fcfg = ForecasterConfig(hidden_dim=8)
    tcfg = TransformConfig(clip_norm=1.0, noise_multiplier=0.5,
                           quantize_bits=4)
    scfg = SecureAggConfig(enabled=False)
    loss = losses.make_loss("mse")
    m, n_win, steps_l, batch = 4, 4, 2, 2

    root = jax.random.PRNGKey(1234)  # flcheck: disable=FLC001 (self-contained guard harness; no config seed exists here)
    params = init_forecaster(jax.random.fold_in(root, 0), fcfg)
    x = jnp.zeros((m, n_win, fcfg.lookback, 1), jnp.float32)
    y = jnp.zeros((m, n_win, fcfg.horizon), jnp.float32)
    bidx = jnp.zeros((m, steps_l, batch), jnp.int32)
    w = jnp.ones((m,), jnp.float32)
    lr = jnp.float32(0.01)
    mu = jnp.float32(0.0)
    # per-round keys precomputed ON DEVICE: the harness itself must not
    # trip the transfer guard it is applying to the round body
    all_keys = jax.vmap(lambda i: jax.random.fold_in(root, i))(
        jnp.arange((steps + 1) * m)).astype(jnp.uint32)
    round_keys = [jax.block_until_ready(all_keys[i * m:(i + 1) * m])
                  for i in range(steps + 1)]

    def step(i: int):
        # per-round key refresh + traced lr: exactly what the engine does
        out = fedavg.pipeline_round(params, x, y, bidx, w, round_keys[i],
                                    lr, mu, fcfg, loss, tcfg, "jnp", scfg,
                                    None)
        return out[0]

    report = count_recompiles(step, steps=steps,
                              cache_size=fedavg.pipeline_round._cache_size)
    transfer_err = check_transfers(step)
    return report, transfer_err
