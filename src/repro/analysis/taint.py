"""Level-1 flcheck: jaxpr dataflow taint for the federated round bodies.

**The contract being proved** (paper privacy pitch; docs/privacy.md): a
per-client update delta may only cross a shard boundary — any cross-client
collective or the vmap path's cross-client reduction — after flowing through
EVERY transform stage the config enables (clip -> noise -> quantize ->
mask).  Numeric tests pin that the configured pipeline currently behaves;
this pass proves the dataflow *structurally*, per config, on the actual
round body jaxpr — so a refactor that silently moves the masking after the
psum (or drops a stage on one topology) fails CI even if no numeric pin
happens to cover that path.

**How**: the production pipeline carries three zero-cost markers —

* :func:`tag_private` at the delta's birth (``fedavg._pipeline_body``),
* :func:`declassify` at each transform stage's output
  (``core/transforms.py``, ``core/secure_agg.py``), labeled ``clip`` /
  ``noise`` / ``quantize`` / ``mask``,
* :func:`boundary` on every aggregator's reduction input
  (``core/aggregation.py``) — the semantic "this value leaves the client
  shard" point, which also covers the vmap path where no collective
  primitive exists.

In production the markers are plain identity returns (no primitive is
bound; zero trace or runtime cost).  Under :func:`analysis_mode` they bind
identity primitives that appear in the jaxpr, and :func:`analyze_closed`
interprets the jaxpr abstractly: a value is *tainted* when it descends from
a ``tag_private`` source; passing a ``declassify`` adds its label; reaching
a ``boundary`` or a raw collective (``psum`` & friends, defense-in-depth)
with any required label missing is a violation.  Taint joins as you expect
(labels = intersection over tainted operands: mixing a masked and an
unmasked delta is only as sanitized as the weaker one), and the interpreter
descends into pjit / shard_map / scan / while / cond / custom-vjp
sub-jaxprs (scan/while to a fixpoint).

**What this does and does not prove** — see ``docs/static_analysis.md``:
it proves marker placement relative to boundaries on the traced dataflow,
for the traced config and topology; it does not prove the transforms'
numerics (the tests pin those) nor cover values never tagged (e.g. the
weighted scalar LOSS reduction, an accepted disclosure documented in
docs/privacy.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, FrozenSet, List, Optional, Sequence

import jax

PyTree = Any

# --------------------------------------------------------------- markers
_ANALYSIS_MODE = False

try:  # jax >= 0.4.33 keeps Primitive in jax.extend.core
    from jax.extend.core import Primitive as _Primitive
except ImportError:  # pragma: no cover - older jax
    from jax.core import Primitive as _Primitive  # type: ignore

from jax.interpreters import batching as _batching
from jax.interpreters import mlir as _mlir


def _identity_prim(name: str) -> _Primitive:
    p = _Primitive(name)
    p.def_impl(lambda x, **kw: x)
    p.def_abstract_eval(lambda aval, **kw: aval)
    _batching.defvectorized(p)           # vmap: rebind on the batched value
    try:  # identity lowering so a leaked marker can never break a compile
        _mlir.register_lowering(p, lambda ctx, x, **kw: [x])
    except Exception:  # pragma: no cover - lowering registry moved
        pass
    return p


source_p = _identity_prim("flcheck_source")
declassify_p = _identity_prim("flcheck_declassify")
boundary_p = _identity_prim("flcheck_boundary")


class analysis_mode:
    """Context manager: make the pipeline's taint markers bind real (still
    identity) primitives so they appear in traced jaxprs.  Production code
    never enters this, so the markers cost nothing there."""

    def __enter__(self):
        global _ANALYSIS_MODE
        self._prev = _ANALYSIS_MODE
        _ANALYSIS_MODE = True
        return self

    def __exit__(self, *exc):
        global _ANALYSIS_MODE
        _ANALYSIS_MODE = self._prev
        return False


def tag_private(tree: PyTree) -> PyTree:
    """Mark a per-client value tree as the private taint source."""
    if not _ANALYSIS_MODE:
        return tree
    return jax.tree.map(lambda x: source_p.bind(x), tree)


def declassify(tree: PyTree, label: str,
               wire: Optional[str] = None) -> PyTree:
    """Record that ``tree`` passed the transform stage ``label``.

    ``wire`` optionally declares the WIRE ENCODING the stage leaves the
    upload in (``"int8+scale"`` for the quantizer's int grid + per-leaf
    fp32 scale, ``"float32"`` for a stage that re-widens, e.g. the float
    pairwise masks).  The level-3 cost auditor (``analysis/costs.py``)
    reads the declaration off the boundary crossings; stages that do not
    change the encoding pass ``wire=None`` and the value keeps whatever
    encoding it already carried (``None`` = raw fp32)."""
    if not _ANALYSIS_MODE:
        return tree
    return jax.tree.map(
        lambda x: declassify_p.bind(x, label=label, wire=wire), tree)


def boundary(tree: PyTree) -> PyTree:
    """Mark a shard-boundary crossing point (aggregator reductions, or the
    semi-sync path's per-client uploads leaving the round body)."""
    if not _ANALYSIS_MODE:
        return tree
    return jax.tree.map(lambda x: boundary_p.bind(x), tree)


# ------------------------------------------------------------ interpreter
# cross-shard collectives checked in addition to the boundary markers
COLLECTIVES = frozenset({
    "psum", "psum2", "pmean", "pmax", "pmin", "all_gather",
    "all_gather_invariant", "all_to_all", "reduce_scatter", "ppermute",
    "pbroadcast",
})


@dataclasses.dataclass(frozen=True)
class Taint:
    """Labels of the sanitizer stages this value has passed through, plus
    the declared wire encoding (``None`` = undeclared, i.e. raw fp32)."""
    labels: FrozenSet[str]
    wire: Optional[str] = None


TaintVal = Optional[Taint]  # None = clean (no private ancestry)


def _wire_rank(wire: Optional[str]) -> int:
    """Width order for joining wire declarations: an ``int<k>+scale`` grid
    is narrower than an undeclared/float32 payload; mixing always widens to
    the widest ancestor (conservative: a sum of an int8 grid with anything
    wider no longer fits the grid)."""
    if wire and wire.startswith("int") and wire.endswith("+scale"):
        try:
            return int(wire[3:-len("+scale")])
        except ValueError:  # pragma: no cover - malformed declaration
            return 1 << 10
    return 1 << 10                       # None / "float32" / unknown: widest


def _join_wire(a: Optional[str], b: Optional[str]) -> Optional[str]:
    return a if _wire_rank(a) >= _wire_rank(b) else b


@dataclasses.dataclass(frozen=True)
class Crossing:
    """One boundary/collective equation observed by the interpreter — the
    raw material of the level-3 cost audit (``analysis/costs.py``):
    primitive name, operand shape/dtype, and (for tainted operands) the
    joined sanitizer labels + declared wire encoding."""
    primitive: str
    shape: tuple
    dtype: str
    tainted: bool
    labels: Optional[FrozenSet[str]] = None
    wire: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class TaintViolation:
    primitive: str
    missing: FrozenSet[str]
    applied: FrozenSet[str]

    def render(self) -> str:
        return (f"tainted value reaches {self.primitive} with stages "
                f"{sorted(self.applied)} applied but "
                f"{sorted(self.missing)} missing")


@dataclasses.dataclass
class TaintReport:
    required: FrozenSet[str]
    violations: List[TaintViolation]
    checked: int       # boundary/collective eqns that saw a tainted operand
    sources: int       # tag_private markers found in the jaxpr
    # every boundary/collective crossing observed (tainted or not), in eqn
    # order — consumed by the level-3 cost auditor (analysis/costs.py)
    crossings: List[Crossing] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def proved(self) -> bool:
        """True when the pass actually proved something: the private source
        was present, at least one tainted value crossed a checked boundary,
        and every crossing carried every required stage label."""
        return self.ok and self.sources > 0 and self.checked > 0

    def render(self) -> str:
        state = ("PROVED" if self.proved
                 else ("VACUOUS" if self.ok else "VIOLATED"))
        head = (f"taint {state}: required={sorted(self.required)} "
                f"sources={self.sources} tainted-crossings={self.checked}")
        return "\n".join([head] + ["  " + v.render()
                                   for v in self.violations])


def _join(taints: Sequence[TaintVal]) -> TaintVal:
    """Combine operand taints: tainted if ANY is; labels = intersection over
    the tainted ones (mixing weakens to the least-sanitized ancestor); the
    wire encoding widens to the widest tainted ancestor."""
    labels: Optional[FrozenSet[str]] = None
    wire: Optional[str] = None
    first = True
    for t in taints:
        if t is not None:
            if labels is None:
                labels = t.labels
            else:
                labels = labels & t.labels
            wire = t.wire if first else _join_wire(wire, t.wire)
            first = False
    return None if labels is None else Taint(labels, wire)


def _taint_eq(a: TaintVal, b: TaintVal) -> bool:
    return (a is None) == (b is None) and \
        (a is None or (a.labels == b.labels and a.wire == b.wire))


def _merge(old: TaintVal, new: TaintVal) -> TaintVal:
    """Fixpoint accumulator: taint only grows, labels only shrink."""
    return _join([old, new]) if (old is not None or new is not None) else None


def _sub_jaxprs(params: Dict[str, Any]):
    """Every (Closed)Jaxpr reachable in an eqn's params, with its key."""
    from jax._src import core as jcore
    found = []
    for k, v in params.items():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if isinstance(item, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                found.append((k, item))
    return found


def _as_open(j):
    """(jaxpr, const_taints) view of a Jaxpr or ClosedJaxpr."""
    if hasattr(j, "jaxpr"):
        return j.jaxpr, [None] * len(j.consts)
    return j, []


class _Interp:
    def __init__(self, required: FrozenSet[str]):
        self.required = required
        self.violations: List[TaintViolation] = []
        self.checked = 0
        self.sources = 0
        self.crossings: List[Crossing] = []

    def _check(self, eqn, taints: Sequence[TaintVal]) -> None:
        prim = eqn.primitive.name
        joined = _join(taints)
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            self.crossings.append(Crossing(
                prim, tuple(aval.shape), str(aval.dtype),
                joined is not None,
                None if joined is None else joined.labels,
                None if joined is None else joined.wire))
        if joined is None:
            return
        self.checked += 1
        missing = self.required - joined.labels
        if missing:
            self.violations.append(
                TaintViolation(prim, frozenset(missing), joined.labels))

    def run(self, jaxpr, in_taints: Sequence[TaintVal],
            const_taints: Sequence[TaintVal] = ()) -> List[TaintVal]:
        env: Dict[Any, TaintVal] = {}

        def read(v) -> TaintVal:
            return None if type(v).__name__ == "Literal" else env.get(v)

        for var, t in list(zip(jaxpr.constvars, const_taints)) + \
                list(zip(jaxpr.invars, in_taints)):
            env[var] = t
        for eqn in jaxpr.eqns:
            in_t = [read(v) for v in eqn.invars]
            out_t = self._eqn(eqn, in_t)
            for var, t in zip(eqn.outvars, out_t):
                env[var] = t
        return [read(v) for v in jaxpr.outvars]

    # ------------------------------------------------------------- eqns
    def _eqn(self, eqn, in_t: List[TaintVal]) -> List[TaintVal]:
        name = eqn.primitive.name
        n_out = len(eqn.outvars)
        if name == "flcheck_source":
            self.sources += 1
            return [Taint(frozenset())]
        if name == "flcheck_declassify":
            t = in_t[0]
            label = eqn.params["label"]
            wire = eqn.params.get("wire")
            if t is None:
                return [None]
            return [Taint(t.labels | {label},
                          t.wire if wire is None else wire)]
        if name == "flcheck_boundary":
            self._check(eqn, in_t)
            return [_join(in_t)]
        if name in COLLECTIVES:
            self._check(eqn, in_t)
            return [_join(in_t)] * n_out
        if name == "scan":
            return self._scan(eqn, in_t)
        if name == "while":
            return self._while(eqn, in_t)
        if name == "cond":
            return self._cond(eqn, in_t)
        subs = _sub_jaxprs(eqn.params)
        if subs:
            return self._call_like(eqn, in_t, subs)
        return [_join(in_t)] * n_out

    def _positional(self, sub, in_t: List[TaintVal],
                    n_out: int) -> List[TaintVal]:
        jx, const_t = _as_open(sub)
        if len(jx.invars) == len(in_t):
            sub_in = in_t
        else:  # unknown calling convention: weakest taint everywhere
            sub_in = [_join(in_t)] * len(jx.invars)
        out = self.run(jx, sub_in, const_t)
        if len(out) == n_out:
            return out
        return [_join(out + in_t)] * n_out

    def _call_like(self, eqn, in_t, subs) -> List[TaintVal]:
        n_out = len(eqn.outvars)
        outs = [self._positional(sub, in_t, n_out) for _, sub in subs]
        if len(outs) == 1:
            return outs[0]
        return [_join([o[i] for o in outs]) for i in range(n_out)]

    def _scan(self, eqn, in_t) -> List[TaintVal]:
        jx, const_t = _as_open(eqn.params["jaxpr"])
        nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
        consts, carry, xs = in_t[:nc], in_t[nc:nc + ncar], in_t[nc + ncar:]
        for _ in range(32):  # taint lattice is tiny: converges fast
            out = self.run(jx, consts + carry + xs, const_t)
            new_carry = [_merge(c, o) for c, o in zip(carry, out[:ncar])]
            if all(_taint_eq(a, b) for a, b in zip(carry, new_carry)):
                break
            carry = new_carry
        out = self.run(jx, consts + carry + xs, const_t)
        return out

    def _while(self, eqn, in_t) -> List[TaintVal]:
        cj, cj_const = _as_open(eqn.params["cond_jaxpr"])
        bj, bj_const = _as_open(eqn.params["body_jaxpr"])
        cn, bn = eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
        cond_c, body_c, carry = in_t[:cn], in_t[cn:cn + bn], in_t[cn + bn:]
        for _ in range(32):
            out = self.run(bj, body_c + carry, bj_const)
            new_carry = [_merge(c, o) for c, o in zip(carry, out)]
            if all(_taint_eq(a, b) for a, b in zip(carry, new_carry)):
                break
            carry = new_carry
        self.run(cj, cond_c + carry, cj_const)   # cond may contain checks
        return carry

    def _cond(self, eqn, in_t) -> List[TaintVal]:
        n_out = len(eqn.outvars)
        outs = [self._positional(br, in_t[1:], n_out)
                for br in eqn.params["branches"]]
        return [_join([o[i] for o in outs]) for i in range(n_out)]


def analyze_closed(closed_jaxpr, required: FrozenSet[str],
                   in_taints: Optional[Sequence[TaintVal]] = None
                   ) -> TaintReport:
    """Interpret a ClosedJaxpr and check the sanitize-before-boundary
    contract for the given required stage labels."""
    interp = _Interp(frozenset(required))
    jx, const_t = _as_open(closed_jaxpr)
    if in_taints is None:
        in_taints = [None] * len(jx.invars)
    interp.run(jx, list(in_taints), const_t)
    return TaintReport(frozenset(required), interp.violations,
                       interp.checked, interp.sources, interp.crossings)


# -------------------------------------------------------- pipeline proofs
def required_labels(tcfg, scfg=None) -> FrozenSet[str]:
    """The stage labels a config demands on every boundary crossing."""
    req = set()
    if tcfg.clip_norm > 0.0:
        req.add("clip")
    if tcfg.noise_multiplier > 0.0:
        req.add("noise")
    if tcfg.quantize_bits:
        req.add("quantize")
    if scfg is not None and scfg.enabled:
        req.add("mask")
    return frozenset(req)


def _round_shapes(fcfg, m: int, n_win: int = 4, steps: int = 2,
                  batch: int = 2):
    import jax.numpy as jnp

    from repro.models.forecaster import init_forecaster

    sds = jax.ShapeDtypeStruct
    params = jax.eval_shape(lambda: init_forecaster(
        jax.random.PRNGKey(0), fcfg))  # flcheck: disable=FLC001 (shape-only eval_shape stand-in; bits never materialize)
    x = sds((m, n_win, fcfg.lookback, 1), jnp.float32)
    y = sds((m, n_win, fcfg.horizon), jnp.float32)
    bidx = sds((m, steps, batch), jnp.int32)
    w = sds((m,), jnp.float32)
    keys = sds((m, 2), jnp.uint32)
    slots = sds((m,), jnp.int32)
    rk = sds((2,), jnp.uint32)
    lr = sds((), jnp.float32)
    mu = sds((), jnp.float32)
    return params, x, y, bidx, w, keys, slots, rk, lr, mu


def _maybe_analysis(analysis: bool):
    import contextlib
    return analysis_mode() if analysis else contextlib.nullcontext()


def trace_pipeline_round(fcfg, tcfg, scfg=None, acfg=None, mesh=None,
                         m: Optional[int] = None, cell_impl: str = "jnp",
                         analysis: bool = True):
    """Trace the REAL round body (vmap or mesh path) to a ClosedJaxpr with
    the taint markers active.

    Deliberately bypasses both jit caches (``pipeline_round.__wrapped__``,
    ``make_pipeline_round.__wrapped__``): a cached trace from a production
    (marker-free) run must never satisfy — or pollute — the analysis.

    ``analysis=False`` traces the PRODUCTION jaxpr (markers are no-ops, so
    they contribute zero equations) — what the level-3 FLOP/byte cost model
    walks, so marker bookkeeping can never pollute the cost numbers.
    """
    from repro.core import fedavg, losses
    from repro.configs.base import AggregationConfig

    from repro.core import transforms as transforms_mod

    loss = losses.make_loss("mse")
    # the extended (slots, w_full, round_key) call shape mirrors
    # fedavg.make_pipeline_round's own needs_cohort branch — the clear ring
    # quantizer (quantize_ring, no masker) is cohort-aware too
    needs_ctx = transforms_mod.make_stack(tcfg, scfg).needs_cohort
    if mesh is None:
        m = m or 4
        params, x, y, bidx, w, keys, slots, rk, lr, mu = _round_shapes(
            fcfg, m)
        body = getattr(fedavg.pipeline_round, "__wrapped__",
                       fedavg.pipeline_round)

        def entry(params, x, y, bidx, w, keys, rk, lr, mu):
            return body(params, x, y, bidx, w, keys, lr, mu, fcfg, loss,
                        tcfg, cell_impl, scfg, rk if needs_ctx else None)

        with _maybe_analysis(analysis):
            return jax.make_jaxpr(entry)(params, x, y, bidx, w, keys, rk,
                                         lr, mu)

    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    m = m or n_dev
    acfg = acfg or AggregationConfig()
    params, x, y, bidx, w, keys, slots, rk, lr, mu = _round_shapes(fcfg, m)
    with _maybe_analysis(analysis):
        # fresh (uncached) jitted round: lru_cache bypassed on purpose
        fn = fedavg.make_pipeline_round.__wrapped__(
            mesh, fcfg, loss, tcfg, acfg, cell_impl, scfg)
        if needs_ctx:
            return jax.make_jaxpr(fn)(params, x, y, bidx, w, keys, slots,
                                      w, rk, lr, mu)
        return jax.make_jaxpr(fn)(params, x, y, bidx, w, keys, lr, mu)


def trace_client_deltas(fcfg, tcfg, scfg=None, m: int = 4,
                        cell_impl: str = "jnp", analysis: bool = True):
    """Trace the semi-sync dispatch stage (``async_engine.client_deltas``)
    — the boundary there is the function's RETURN (the buffered uploads)."""
    from repro.core import async_engine, losses
    from repro.core import transforms as transforms_mod

    loss = losses.make_loss("mse")
    needs_ctx = transforms_mod.make_stack(tcfg, scfg).needs_cohort
    params, x, y, bidx, w, keys, slots, rk, lr, mu = _round_shapes(fcfg, m)
    body = getattr(async_engine.client_deltas, "__wrapped__",
                   async_engine.client_deltas)

    def entry(params, x, y, bidx, w, keys, rk, lr, mu):
        return body(params, x, y, bidx, keys, lr, mu, fcfg, loss, tcfg,
                    cell_impl, scfg, rk if needs_ctx else None,
                    w if needs_ctx else None, None)

    with _maybe_analysis(analysis):
        return jax.make_jaxpr(entry)(params, x, y, bidx, w, keys, rk, lr,
                                     mu)


def verify_pipeline(topology: str, tcfg, scfg=None, fcfg=None,
                    cell_impl: str = "jnp") -> TaintReport:
    """Prove sanitize-before-boundary for one topology x config.

    ``topology``: ``"vmap"`` (LocalAggregator — the boundary marker is the
    cross-client reduction), ``"flat"`` (1-D clients mesh over all
    devices), ``"hier"`` (2-D (region, clients) mesh, 2 regions), or
    ``"semi_sync"`` (the dispatch stage whose returned uploads feed the
    server's straggler buffer).
    """
    from repro.configs.base import AggregationConfig, ForecasterConfig

    fcfg = fcfg or ForecasterConfig(hidden_dim=8)
    req = required_labels(tcfg, scfg)
    if topology == "semi_sync":
        jx = trace_client_deltas(fcfg, tcfg, scfg, cell_impl=cell_impl)
    elif topology == "vmap":
        jx = trace_pipeline_round(fcfg, tcfg, scfg, cell_impl=cell_impl)
    elif topology == "flat":
        mesh = jax.make_mesh((len(jax.devices()),), ("clients",))
        jx = trace_pipeline_round(fcfg, tcfg, scfg, mesh=mesh,
                                  cell_impl=cell_impl)
    elif topology == "hier":
        n_dev = len(jax.devices())
        if n_dev % 2:
            raise ValueError(f"hier topology needs an even device count, "
                             f"got {n_dev}")
        mesh = jax.make_mesh((2, n_dev // 2), ("region", "clients"))
        jx = trace_pipeline_round(
            fcfg, tcfg, scfg, mesh=mesh,
            acfg=AggregationConfig(kind="hierarchical", n_regions=2),
            cell_impl=cell_impl)
    else:
        raise ValueError(f"unknown topology {topology!r} "
                         "(vmap | flat | hier | semi_sync)")
    return analyze_closed(jx, req)
