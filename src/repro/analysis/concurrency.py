"""FLC006-FLC009 — serving-tier concurrency lint.

Scope (see ``rules.py``): ``src/repro/serving/`` only.  The serving tier is
the one part of the repo written for a MULTI-THREADED front (the ROADMAP's
serving item): a registry that hot-swaps model handles under readers, an
engine that batches concurrent forecast requests, consumer caches that grow
with traffic.  The rest of the repo is single-threaded simulation, so these
rules do not fire there.

The rules are lexical heuristics, deliberately conservative:

``FLC006`` (locked-class unlocked mutation)
    In a class that OWNS a lock (``self.x = threading.Lock()/RLock()/
    Condition()``), any mutation of shared container state initialized in
    ``__init__`` (keyed assign, mutating method call, rebinding) outside a
    ``with self.<lock>:`` block.  A class that takes a lock for SOME writes
    has declared its state shared; the unlocked write is the bug.
``FLC007`` (non-atomic handle fetch / TOCTOU)
    Two ``.handle(<same slot>)`` fetches on the same receiver in one
    function, or a ``.generation(...)`` probe followed by ``.handle(...)``
    — the registry can hot-swap between the two calls, so decisions made on
    the first fetch do not hold for the second.  Fetch ONE snapshot and
    read everything off it.
``FLC008`` (unbounded cache growth)
    A mapping attribute with keyed inserts (``self.m[k] = v`` /
    ``.setdefault`` / ``.update``) but no eviction (``.pop/.popitem/
    .clear`` / ``del``) and no size check (``len(...)`` over the attr)
    anywhere in the class: per-key state that only ever grows leaks under
    real traffic.  Bounded caches evict; if growth is intentionally
    unbounded (e.g. a fixed slot universe), suppress with the rationale.
``FLC009`` (Python branch on a traced value)
    ``if``/``while`` whose test calls ``jnp.*`` — under jit a traced
    boolean raises ``TracerBoolConversionError``, and in eager serving code
    it forces a device sync per request; use ``jnp.where``/``lax.cond`` or
    hoist the check behind an explicit ``float()``/``block_until_ready``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.rules import Finding, Suppressions

__all__ = ["check_source"]

_LOCK_CTORS = {"threading.Lock", "threading.RLock", "threading.Condition"}
_CONTAINER_CTORS = {"dict", "list", "set", "collections.OrderedDict",
                    "OrderedDict", "collections.defaultdict", "defaultdict",
                    "collections.deque", "deque"}
_MUTATORS = {"setdefault", "update", "pop", "popitem", "clear", "append",
             "extend", "add", "remove", "discard", "insert", "appendleft"}
_INSERTERS = {"setdefault", "update"}          # keyed growth (FLC008)
_EVICTORS = {"pop", "popitem", "clear"}        # keyed shrink (FLC008)


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.<name>`` -> ``name`` (else None)."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _is_mapping_ctor(node: ast.AST) -> bool:
    if isinstance(node, ast.Dict):
        return True
    if isinstance(node, ast.Call):
        return _dotted(node.func) in ("dict", "collections.OrderedDict",
                                      "OrderedDict", "collections.defaultdict",
                                      "defaultdict")
    return False


def _is_container_ctor(node: ast.AST) -> bool:
    if isinstance(node, (ast.Dict, ast.List, ast.Set)):
        return True
    if isinstance(node, ast.Call):
        return _dotted(node.func) in _CONTAINER_CTORS
    return False


class _ClassInfo:
    """First-pass facts about one class body."""

    def __init__(self) -> None:
        self.locks: Set[str] = set()          # lock-valued self attrs
        self.containers: Set[str] = set()     # container attrs set in init
        self.mappings: Set[str] = set()       # dict-valued subset
        # FLC008 bookkeeping (whole-class):
        self.inserts: Dict[str, int] = {}     # attr -> first insert line
        self.evicts: Set[str] = set()         # attrs with any evict/len/del


def _scan_class(cls: ast.ClassDef) -> _ClassInfo:
    info = _ClassInfo()
    for node in ast.walk(cls):
        # both plain and annotated attribute assignments declare state
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            attr, value = _self_attr(node.targets[0]), node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            attr, value = _self_attr(node.target), node.value
        else:
            continue
        if attr:
            if isinstance(value, ast.Call) and \
                    _dotted(value.func) in _LOCK_CTORS:
                info.locks.add(attr)
            elif _is_container_ctor(value):
                info.containers.add(attr)
                if _is_mapping_ctor(value):
                    info.mappings.add(attr)
    for node in ast.walk(cls):
        # keyed insert: self.m[k] = v  |  self.m.setdefault/update(...)
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Subscript):
                    attr = _self_attr(tgt.value)
                    if attr in info.mappings:
                        info.inserts.setdefault(attr, node.lineno)
        elif isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute):
                attr = _self_attr(node.func.value)
                if attr in info.mappings:
                    if node.func.attr in _INSERTERS:
                        info.inserts.setdefault(attr, node.lineno)
                    elif node.func.attr in _EVICTORS:
                        info.evicts.add(attr)
            elif isinstance(node.func, ast.Name) and node.func.id == "len":
                # any len() whose argument mentions the attr counts as a
                # size check (len(self.m) or len(self.m[k]) alike)
                for sub in ast.walk(node):
                    attr = _self_attr(sub)
                    if attr in info.mappings:
                        info.evicts.add(attr)
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
                attr = _self_attr(base)
                if attr in info.mappings:
                    info.evicts.add(attr)
    return info


class _FuncLint(ast.NodeVisitor):
    """Per-function pass: FLC006 (lock discipline), FLC007 (TOCTOU),
    FLC009 (traced branch).  Tracks ``with self.<lock>:`` nesting."""

    def __init__(self, rel: str, sup: Suppressions, info: _ClassInfo,
                 in_init: bool, findings: List[Finding]):
        self.rel, self.sup, self.info = rel, sup, info
        self.in_init = in_init
        self.findings = findings
        self.lock_depth = 0
        # FLC007: (receiver, arg-src) -> first .handle line; receivers with
        # a .generation probe
        self.handle_seen: Dict[Tuple[str, str], int] = {}
        self.gen_probed: Dict[str, int] = {}

    def _emit(self, code: str, line: int, msg: str) -> None:
        self.findings.append(self.sup.apply(code, self.rel, line, msg))

    # --- lock tracking -------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        locked = any(_self_attr(item.context_expr) in self.info.locks
                     for item in node.items)
        if locked:
            self.lock_depth += 1
        self.generic_visit(node)
        if locked:
            self.lock_depth -= 1

    def _unlocked_mutation(self, attr: Optional[str], line: int,
                           what: str) -> None:
        if (attr in self.info.containers and self.info.locks
                and self.lock_depth == 0 and not self.in_init):
            self._emit("FLC006", line,
                       f"unlocked {what} of shared 'self.{attr}' in a class "
                       f"that guards state with "
                       f"'self.{sorted(self.info.locks)[0]}' — wrap the "
                       "mutation in 'with self."
                       f"{sorted(self.info.locks)[0]}:' or document why "
                       "this write races safely")

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                self._unlocked_mutation(_self_attr(tgt.value), node.lineno,
                                        "keyed assignment")
            else:
                attr = _self_attr(tgt)
                if attr in self.info.containers:
                    self._unlocked_mutation(attr, node.lineno, "rebinding")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        tgt = node.target
        base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
        self._unlocked_mutation(_self_attr(base), node.lineno,
                                "augmented assignment")
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
            self._unlocked_mutation(_self_attr(base), node.lineno, "delete")
        self.generic_visit(node)

    # --- calls: FLC006 mutators + FLC007 handle fetches ----------------
    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute):
            recv = _dotted(node.func.value)
            if node.func.attr in _MUTATORS:
                self._unlocked_mutation(_self_attr(node.func.value),
                                        node.lineno,
                                        f".{node.func.attr}() mutation")
            if recv is not None and node.func.attr == "handle":
                arg = ast.unparse(node.args[0]) if node.args else "()"
                key = (recv, arg)
                if key in self.handle_seen:
                    self._emit(
                        "FLC007", node.lineno,
                        f"second {recv}.handle({arg}) fetch in one function "
                        f"(first at line {self.handle_seen[key]}) — the "
                        "registry can hot-swap between fetches; take ONE "
                        "handle snapshot and reuse it")
                else:
                    self.handle_seen[key] = node.lineno
                    if recv in self.gen_probed:
                        self._emit(
                            "FLC007", node.lineno,
                            f"{recv}.handle({arg}) after a "
                            f"{recv}.generation(...) probe (line "
                            f"{self.gen_probed[recv]}) — check-then-fetch "
                            "races a hot swap; fetch the handle and read "
                            ".generation off the snapshot")
            if recv is not None and node.func.attr == "generation":
                self.gen_probed.setdefault(recv, node.lineno)
        self.generic_visit(node)

    # --- FLC009: Python branch on a traced value -----------------------
    def _traced_test(self, test: ast.AST) -> Optional[str]:
        for sub in ast.walk(test):
            if isinstance(sub, ast.Call):
                name = _dotted(sub.func)
                if name and (name.startswith("jnp.")
                             or name.startswith("jax.numpy.")):
                    return name
        return None

    def visit_If(self, node: ast.If) -> None:
        name = self._traced_test(node.test)
        if name:
            self._emit("FLC009", node.lineno,
                       f"Python 'if' on a traced value ({name}(...)) — "
                       "raises under jit and forces a device sync per "
                       "request in eager serving code; use jnp.where/"
                       "lax.cond or hoist behind an explicit host read")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        name = self._traced_test(node.test)
        if name:
            self._emit("FLC009", node.lineno,
                       f"Python 'while' on a traced value ({name}(...)) — "
                       "raises under jit; use lax.while_loop or an explicit "
                       "host read")
        self.generic_visit(node)


def check_source(source: str, rel: str) -> List[Finding]:
    """Run the serving-concurrency rules over one module's source."""
    tree = ast.parse(source)
    sup = Suppressions(source)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = _scan_class(node)
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                lint = _FuncLint(rel, sup, info,
                                 in_init=item.name == "__init__",
                                 findings=findings)
                lint.visit(item)
        # FLC008: grow-only mappings (whole-class view)
        for attr, line in sorted(info.inserts.items()):
            if attr not in info.evicts:
                findings.append(sup.apply(
                    "FLC008", rel, line,
                    f"'self.{attr}' grows per key with no eviction or size "
                    "check anywhere in the class — per-key serving state "
                    "leaks under real traffic; bound it (evict/len) or "
                    "suppress with the rationale for unbounded growth"))
    # module-level FLC009 (functions outside classes)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            lint = _FuncLint(rel, sup, _ClassInfo(), in_init=False,
                             findings=findings)
            lint.visit(node)
    return findings
