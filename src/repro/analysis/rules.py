"""flcheck rule registry, findings, and inline suppression parsing.

Every lint rule has a stable ``FLCxxx`` code, a one-line summary, and a
*scope* — the repo-relative path prefixes it applies to (``()`` = everywhere
the linter is pointed).  Scoping is part of the rule, not the caller: the
determinism rule FLC004 is load-bearing in ``core/``/``data/`` (replayable
rounds, resumable checkpoints) but wall-clock timing in ``launch/`` and the
benchmarks is legitimate, so the rule simply does not fire there.

Suppression syntax (inline, same line or the line directly above)::

    t0 = time.time()  # flcheck: disable=FLC004 (bench timing, not round math)

The parenthesized rationale is REQUIRED: a ``disable`` without one does not
suppress — the finding stays fatal and carries a note asking for the reason.
Multiple codes: ``disable=FLC001,FLC003 (reason)``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

__all__ = ["Rule", "Finding", "RULES", "Suppressions", "relpath"]


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str
    # repo-relative path prefixes the rule fires under; () = everywhere
    scope: Tuple[str, ...] = ()

    def in_scope(self, rel: str) -> bool:
        rel = rel.replace("\\", "/")
        return not self.scope or any(rel.startswith(p) for p in self.scope)


RULES: Dict[str, Rule] = {r.code: r for r in (
    Rule("FLC001", "raw-prng-key",
         "raw jax.random.PRNGKey(<literal>) outside whitelisted init/test "
         "code — derive keys from the config seed (fold_in) so streams are "
         "replayable and never collide"),
    Rule("FLC002", "key-reuse",
         "the same PRNG key fed to two random draws without an intervening "
         "fold_in/split — the draws are perfectly correlated"),
    Rule("FLC003", "arithmetic-seed",
         "arithmetic seed derivation (seed + i style): (seed, 1) and "
         "(seed+1, 0) collide — use fold_in or SeedSequence([seed, i])"),
    Rule("FLC004", "nondeterminism",
         "nondeterministic construct in replay-critical code (wall clock, "
         "global numpy/stdlib rng state, builtin hash, unordered-set "
         "iteration) — rounds must be pure functions of "
         "(seed, round, slot, attempt)",
         scope=("src/repro/core/", "src/repro/data/",
                "src/repro/serving/")),
    Rule("FLC005", "dtype-hazard",
         "dtype hazard (fp64 promotion on the device path, arithmetic in a "
         "narrow int type, accumulation-precision downcast) in transform/"
         "kernel code",
         scope=("src/repro/core/", "src/repro/kernels/",
                "src/repro/serving/")),
    Rule("FLC006", "unlocked-shared-mutation",
         "mutation of shared container state outside the class's own lock "
         "— a class that guards SOME writes with a threading lock has "
         "declared its state shared; the unlocked write is a race",
         scope=("src/repro/serving/",)),
    Rule("FLC007", "non-atomic-handle-fetch",
         "two registry .handle() fetches (or a .generation() probe then a "
         ".handle() fetch) in one function — a hot swap between them "
         "invalidates the first look; take one handle snapshot (TOCTOU)",
         scope=("src/repro/serving/",)),
    Rule("FLC008", "unbounded-cache-growth",
         "per-key mapping state that only ever grows (keyed inserts, no "
         "eviction or size check anywhere in the class) — leaks under real "
         "serving traffic; bound it or suppress with the rationale",
         scope=("src/repro/serving/",)),
    Rule("FLC009", "python-branch-on-traced",
         "Python if/while on a jnp.* result — raises TracerBoolConversion"
         "Error under jit and forces a per-request device sync in eager "
         "serving code; use jnp.where/lax.cond or an explicit host read",
         scope=("src/repro/serving/",)),
)}


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    path: str                 # repo-relative
    line: int
    message: str
    suppressed: bool = False
    suppress_reason: str = ""

    def render(self) -> str:
        tag = " [suppressed: %s]" % self.suppress_reason if self.suppressed \
            else ""
        return f"{self.path}:{self.line}: {self.code} {self.message}{tag}"


_SUPPRESS_RE = re.compile(
    r"#\s*flcheck:\s*disable=([A-Z0-9,\s]+?)\s*(?:\(([^)]*)\))?\s*$")


class Suppressions:
    """Per-file map of line -> (codes, rationale) from inline comments.

    A finding at line L is suppressed when line L or line L-1 carries a
    matching ``# flcheck: disable=CODE (reason)`` comment WITH a non-empty
    rationale.  ``disable`` comments without a rationale are collected in
    ``missing_reason`` so the CLI can complain precisely.
    """

    def __init__(self, source: str):
        self.by_line: Dict[int, Tuple[List[str], str]] = {}
        self.missing_reason: List[int] = []
        for i, text in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            codes = [c.strip() for c in m.group(1).split(",") if c.strip()]
            reason = (m.group(2) or "").strip()
            if not reason:
                self.missing_reason.append(i)
            self.by_line[i] = (codes, reason)

    def lookup(self, code: str, line: int) -> Tuple[bool, str]:
        for ln in (line, line - 1):
            entry = self.by_line.get(ln)
            if entry and code in entry[0] and entry[1]:
                return True, entry[1]
        return False, ""

    def apply(self, code: str, path: str, line: int, message: str) -> Finding:
        hit, reason = self.lookup(code, line)
        return Finding(code, path, line, message, suppressed=hit,
                       suppress_reason=reason)


def relpath(path: str, root: str) -> str:
    """Repo-relative, forward-slash path (rule scopes key off this)."""
    import os
    rel = os.path.relpath(os.path.abspath(path), os.path.abspath(root))
    return rel.replace("\\", "/")
