"""Level-3 flcheck: static wire-format & cost auditor for the round.

The paper's scalability pitch is a COST claim — int8-quantized uplinks,
edge->region->cloud fan-in, Pi-class compute budgets — but the repo's
simulated costs live in a hand-maintained formula
(``core/latency.py::payload_bytes``).  This module makes the cost model
*proved* instead of asserted: it re-runs the level-1 taint interpreter over
the REAL traced round bodies (``taint.verify_pipeline``, all four execution
paths) and reads, off every boundary crossing, the payload dtype and the
declared wire encoding (``declassify(..., wire=...)`` markers planted by
``core/transforms.py`` / ``core/secure_agg.py``), then derives exact
per-client upload bytes:

* ``int<k>+scale`` — the quantizer's integer grid: ``ceil(size*k/8)`` bytes
  per leaf plus one fp32 scale (4 bytes) per leaf;
* anything else — raw fp32, 4 bytes per coordinate.  With quantize AND
  masking on, the pairwise masker operates in the quantizer's integer ring
  mod 2^k (``core/secure_agg.py``), so masked uploads keep the
  ``int<k>+scale`` declaration — the audit proves end-to-end that masking
  no longer re-widens the wire, and ``check_report`` treats any re-widened
  masked upload as the FATAL ``masked_fp32_regression`` (the divergence the
  audit used to merely track).

Alongside the wire audit, :func:`stage_costs` walks the marker-free
production jaxprs with the scan-aware cost model
(``launch/costmodel.jaxpr_cost``) and positions the per-stage FLOP/HBM-byte
totals against the ``launch/roofline.py`` constants (single-chip seconds;
the same PEAK_FLOPS / HBM_BW the dry-run roofline uses).

**What is and is not proved.**  The audit proves the DECLARED wire format
reaching each boundary on the traced dataflow — the quantizer's simulated
dequantize floats *stand for* the int grid the real uplink ships, and the
audit proves no later stage silently re-widened them (the masker visibly
does).  It does not measure a real network, does not model headers or
framing, and the FLOP counts inherit ``jaxpr_cost``'s fusion-blind byte
methodology.  Everything the audit emits is deterministic for a fixed jax
version, which is what makes the baseline diff a gate:
``tools/flcheck --cost --baseline src/repro/analysis/baselines/round_costs.json``
fails when wire bytes, boundary dtypes, or stage FLOPs drift without a
deliberate ``--update-baseline``.

Import-light contract (see ``analysis/__init__``): ``repro.core`` /
``repro.launch`` are imported lazily inside functions only.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Tuple

PyTree = Any

VERSION = 1
# repo-relative committed baseline (the CI gate target)
DEFAULT_BASELINE = "src/repro/analysis/baselines/round_costs.json"

# the audited execution paths; flat8/hier2x4 pin the 8-virtual-device CI
# geometry so the traced jaxpr (and its cost) is identical everywhere
PATHS = ("vmap", "semi_sync", "flat8", "hier2x4")


def _audit_matrix():
    """(name, tcfg, scfg) triples the canonical report covers: raw-fp32,
    quantize-on (the int8 proof target), and quantize+secure (the ring-
    masked path, which must hold the int8 wire UNDER masking)."""
    from repro.configs.base import SecureAggConfig, TransformConfig
    return (
        ("fp32", TransformConfig(clip_norm=1.0), None),
        ("quantize8", TransformConfig(clip_norm=1.0, quantize_bits=8), None),
        ("quantize8_secure",
         TransformConfig(clip_norm=1.0, quantize_bits=8),
         SecureAggConfig(enabled=True)),
    )


# ------------------------------------------------------------ wire formats
def wire_bits(wire: Optional[str]) -> int:
    """Payload bits per coordinate for a declared wire encoding."""
    if wire and wire.startswith("int") and wire.endswith("+scale"):
        return int(wire[3:-len("+scale")])
    return 32


def leaf_wire_bytes(size: int, wire: Optional[str]) -> int:
    """Exact uplink bytes of ONE leaf of ``size`` coordinates: the integer
    grid packed to ``ceil(size*k/8)`` plus its fp32 scale, or raw fp32."""
    if wire and wire.startswith("int") and wire.endswith("+scale"):
        k = wire_bits(wire)
        return math.ceil(size * k / 8) + 4          # +4: per-leaf fp32 scale
    return size * 4


def model_leaf_sizes(fcfg) -> List[int]:
    """Coordinate counts of the model's param leaves (shape-only trace)."""
    import jax
    import numpy as np

    from repro.models.forecaster import init_forecaster

    tmpl = jax.eval_shape(lambda: init_forecaster(
        jax.random.PRNGKey(0), fcfg))  # flcheck: disable=FLC001 (shape-only eval_shape stand-in; bits never materialize)
    return [int(np.prod(l.shape)) if l.shape else 1
            for l in jax.tree.leaves(tmpl)]


def payload_bytes_for_wire(fcfg, wire: Optional[str]) -> int:
    """Audited per-client upload bytes: the declared wire encoding applied
    leaf-by-leaf to the model's parameter tree."""
    return sum(leaf_wire_bytes(s, wire) for s in model_leaf_sizes(fcfg))


# ------------------------------------------------------------- path audits
def _verify(topology: str, tcfg, scfg, fcfg):
    from repro.analysis import taint
    topo = {"flat8": "flat", "hier2x4": "hier"}.get(topology, topology)
    return taint.verify_pipeline(topo, tcfg, scfg, fcfg=fcfg)


def audit_round(topology: str, tcfg, scfg=None, fcfg=None) -> Dict[str, Any]:
    """Audit one (execution path, config): taint-proof the boundary, read
    the declared wire encoding off the tainted crossings, and derive the
    exact per-client upload bytes plus the tracked divergences against the
    ``latency.payload_bytes`` formula."""
    from repro.configs.base import ForecasterConfig
    from repro.core import latency

    fcfg = fcfg or ForecasterConfig(hidden_dim=8)
    report = _verify(topology, tcfg, scfg, fcfg)
    bnd = [c for c in report.crossings
           if c.primitive == "flcheck_boundary"]
    tainted = [c for c in bnd if c.tainted]
    # all upload leaves must agree on the encoding; a mix joins to widest
    wires = {c.wire for c in tainted}
    wire = None
    for w in wires:
        wire = w if wire is None and w is not None else wire
    if None in wires or not wires:
        wire = "float32"                 # undeclared leaves ship raw fp32
    labels = sorted(set.intersection(*[set(c.labels) for c in tainted])) \
        if tainted else []

    sizes = model_leaf_sizes(fcfg)
    n_params = sum(sizes)
    audited = sum(leaf_wire_bytes(s, wire) for s in sizes)
    # what RoundEngine charges the latency model (formula, not audit): the
    # quantized wire survives masking (ring masks live in the int grid),
    # so masked and clear uploads are charged identically
    modeled = latency.payload_bytes(n_params, tcfg.quantize_bits)

    divergences: List[Dict[str, Any]] = []
    if tcfg.quantize_bits:
        # formula ignores the per-leaf fp32 scale the real wire carries
        delta = audited - latency.payload_bytes(n_params, tcfg.quantize_bits)
        if delta:
            divergences.append(dict(
                kind="scale_overhead", bytes=int(delta), fatal=False,
                note=f"{len(sizes)} per-leaf fp32 scales the "
                     "payload_bytes formula documents as ignored"))

    return {
        "proved": bool(report.proved),
        "wire": wire,
        "labels": labels,
        "upload_bytes_per_client": int(audited),
        "modeled_bytes_per_client": int(modeled),
        "divergences": divergences,
        "crossings": [
            {"primitive": c.primitive, "shape": list(c.shape),
             "dtype": c.dtype, "tainted": bool(c.tainted),
             "wire": (c.wire or "float32") if c.tainted else c.dtype}
            for c in bnd],
    }


def audit_upload(fcfg, tcfg, scfg=None, topology: str = "vmap"
                 ) -> Dict[str, Any]:
    """Bench-facing wrapper: audited vs modeled upload bytes for one
    config on one path (default: the vmap trace — wire format is
    path-invariant, proved by the full matrix in the cost report)."""
    a = audit_round(topology, tcfg, scfg, fcfg)
    return {"wire": a["wire"],
            "audited_bytes": a["upload_bytes_per_client"],
            "modeled_bytes": a["modeled_bytes_per_client"],
            "divergences": a["divergences"],
            "proved": a["proved"]}


# ------------------------------------------------------------- stage costs
def _roofline_position(flops: int, hbm_bytes: int) -> Dict[str, Any]:
    from repro.launch import mesh as mesh_mod
    compute_s = flops / mesh_mod.PEAK_FLOPS
    hbm_s = hbm_bytes / mesh_mod.HBM_BW
    return {"compute_s": float(f"{compute_s:.3e}"),
            "hbm_s": float(f"{hbm_s:.3e}"),
            "bound": "memory" if hbm_s > compute_s else "compute"}


def stage_costs(fcfg, tcfg, scfg=None, m: int = 4) -> Dict[str, Any]:
    """Per-stage FLOP / HBM-byte totals of the production (marker-free)
    round jaxprs, positioned against the ``launch/roofline.py`` constants.

    ``client_dispatch`` is the select->local-update->transform prefix (the
    semi-sync dispatch body); ``round_total`` the full vmap round; the
    aggregate+server remainder is reported as their difference (derived —
    both traces share shapes, so the subtraction is exact up to common
    subexpressions XLA would fuse anyway)."""
    from repro.analysis import taint
    from repro.launch import costmodel

    jx_round = taint.trace_pipeline_round(fcfg, tcfg, scfg, m=m,
                                          analysis=False)
    jx_disp = taint.trace_client_deltas(fcfg, tcfg, scfg, m=m,
                                        analysis=False)
    rc = costmodel.jaxpr_cost(jx_round)
    dc = costmodel.jaxpr_cost(jx_disp)
    agg_f = max(int(rc["flops"]) - int(dc["flops"]), 0)
    agg_b = max(int(rc["bytes"]) - int(dc["bytes"]), 0)
    out = {
        "client_dispatch": {"flops": int(dc["flops"]),
                            "hbm_bytes": int(dc["bytes"])},
        "round_total": {"flops": int(rc["flops"]),
                        "hbm_bytes": int(rc["bytes"])},
        "aggregate_server": {"flops": agg_f, "hbm_bytes": agg_b,
                             "derived": True},
    }
    for stage in out.values():
        stage["roofline"] = _roofline_position(stage["flops"],
                                               stage["hbm_bytes"])
    return out


# ---------------------------------------------------------- report + gate
def cost_report(fcfg=None) -> Dict[str, Any]:
    """The canonical cost report the baseline gate diffs.

    Deterministic for a fixed jax version: fixed tiny model, fixed client
    count, fixed config matrix.  ``flat8``/``hier2x4`` need the 8-virtual-
    device CI geometry and are listed under ``skipped`` elsewhere (the diff
    treats a skip as a warning, not a drift)."""
    import jax

    from repro.configs.base import ForecasterConfig

    fcfg = fcfg or ForecasterConfig(hidden_dim=8)
    n_dev = len(jax.devices())
    sizes = model_leaf_sizes(fcfg)
    audits: Dict[str, Any] = {}
    skipped: Dict[str, str] = {}
    for path in PATHS:
        if path in ("flat8", "hier2x4") and n_dev != 8:
            skipped[path] = (f"needs 8 virtual devices, have {n_dev} "
                             "(run under test.sh / CI XLA_FLAGS)")
            continue
        for cname, tcfg, scfg in _audit_matrix():
            audits[f"{path}/{cname}"] = audit_round(path, tcfg, scfg, fcfg)
    q8 = _audit_matrix()[1]
    return {
        "version": VERSION,
        "model": {"cell": fcfg.cell, "hidden_dim": fcfg.hidden_dim,
                  "lookback": fcfg.lookback, "horizon": fcfg.horizon,
                  "n_params": int(sum(sizes)), "n_leaves": len(sizes)},
        "audits": audits,
        "skipped": skipped,
        "stages": stage_costs(fcfg, q8[1], q8[2]),
        "stage_trace": "quantize8 config, vmap, m=4 clients",
    }


def check_report(report: Dict[str, Any]) -> List[str]:
    """The int8 wire PROOF: fatal messages when any audited path breaks the
    declared-format contract (independent of any baseline)."""
    fatal: List[str] = []
    for key, a in sorted(report["audits"].items()):
        path, cname = key.split("/", 1)
        if not a["proved"]:
            fatal.append(f"{key}: taint proof is not non-vacuous — the "
                         "boundary markers are disconnected")
        if cname == "quantize8" and a["wire"] != "int8+scale":
            fatal.append(
                f"{key}: quantize-on upload is {a['wire']!r}, expected "
                "'int8+scale' — a stage after the quantizer re-widened "
                "the wire (or the quantizer lost its declaration)")
        if cname == "quantize8_secure" and a["wire"] != "int8+scale":
            fatal.append(
                f"{key}: masked_fp32_regression — the quantize+mask upload "
                f"is {a['wire']!r}, expected 'int8+scale': the masker "
                "re-widened the ring wire (masks must stay in the "
                "quantizer's integer ring mod 2^b)")
        if cname == "fp32" and wire_bits(a["wire"]) != 32:
            fatal.append(f"{key}: raw config declares {a['wire']!r} — an "
                         "int grid without a quantize stage cannot be real")
    return fatal


def canonical_json(report: Dict[str, Any]) -> str:
    return json.dumps(report, sort_keys=True, indent=2) + "\n"


def diff_reports(baseline: Dict[str, Any], current: Dict[str, Any]
                 ) -> Tuple[List[str], List[str]]:
    """(errors, warnings) between the committed baseline and a fresh
    report.  Errors gate CI: wire bytes, boundary dtypes/shapes, declared
    encodings, and stage FLOP/byte totals must match the baseline exactly;
    a path the current environment cannot trace (device count) is a
    warning, never silent."""
    errors: List[str] = []
    warnings: List[str] = []
    if baseline.get("version") != current.get("version"):
        errors.append(f"report version {current.get('version')} != baseline "
                      f"{baseline.get('version')}")
        return errors, warnings
    if baseline.get("model") != current.get("model"):
        errors.append(f"audited model changed: {current.get('model')} != "
                      f"baseline {baseline.get('model')}")
    b_aud, c_aud = baseline.get("audits", {}), current.get("audits", {})
    for key in sorted(set(b_aud) | set(c_aud)):
        if key not in c_aud:
            path = key.split("/", 1)[0]
            if path in current.get("skipped", {}):
                warnings.append(f"{key}: not audited here "
                                f"({current['skipped'][path]}) — baseline "
                                "entry kept, compared in CI")
            else:
                errors.append(f"{key}: in baseline but not audited — "
                              "removed path needs --update-baseline")
            continue
        if key not in b_aud:
            errors.append(f"{key}: audited but absent from baseline — new "
                          "path needs --update-baseline")
            continue
        b, c = b_aud[key], c_aud[key]
        for field in ("wire", "upload_bytes_per_client",
                      "modeled_bytes_per_client", "labels", "proved"):
            if b.get(field) != c.get(field):
                errors.append(f"{key}: {field} {c.get(field)!r} != baseline "
                              f"{b.get(field)!r}")
        if b.get("crossings") != c.get("crossings"):
            errors.append(f"{key}: boundary crossings changed "
                          f"({len(c.get('crossings', []))} vs baseline "
                          f"{len(b.get('crossings', []))} records, or "
                          "shape/dtype/wire drift)")
        bdiv = {d["kind"]: d["bytes"] for d in b.get("divergences", [])}
        cdiv = {d["kind"]: d["bytes"] for d in c.get("divergences", [])}
        if bdiv != cdiv:
            errors.append(f"{key}: tracked divergences {cdiv} != baseline "
                          f"{bdiv}")
    b_st, c_st = baseline.get("stages", {}), current.get("stages", {})
    for name in sorted(set(b_st) | set(c_st)):
        b, c = b_st.get(name, {}), c_st.get(name, {})
        for field in ("flops", "hbm_bytes"):
            if b.get(field) != c.get(field):
                errors.append(f"stage {name}: {field} {c.get(field)} != "
                              f"baseline {b.get(field)}")
    return errors, warnings


def render_summary(report: Dict[str, Any]) -> str:
    """Human-readable audit summary (what ``flcheck --cost`` prints)."""
    lines = []
    m = report["model"]
    lines.append(f"cost audit: {m['cell']} h={m['hidden_dim']} "
                 f"({m['n_params']} params, {m['n_leaves']} leaves)")
    for key, a in sorted(report["audits"].items()):
        lines.append(
            f"  {key}: wire={a['wire']} "
            f"upload={a['upload_bytes_per_client']}B "
            f"modeled={a['modeled_bytes_per_client']}B "
            f"proved={a['proved']}")
        for d in a["divergences"]:
            lines.append(f"    tracked divergence [{d['kind']}] "
                         f"{d['bytes']:+d}B: {d['note']}")
    for path, why in sorted(report.get("skipped", {}).items()):
        lines.append(f"  {path}: SKIPPED ({why})")
    for name, st in sorted(report.get("stages", {}).items()):
        r = st["roofline"]
        lines.append(f"  stage {name}: {st['flops']:.3e} flops, "
                     f"{st['hbm_bytes']:.3e} HBM B -> {r['bound']}-bound "
                     f"on one v5e chip (compute {r['compute_s']:.2e}s, "
                     f"hbm {r['hbm_s']:.2e}s)")
    return "\n".join(lines)
