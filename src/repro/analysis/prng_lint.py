"""PRNG-hygiene AST lint: FLC001 (raw literal keys), FLC002 (key reuse),
FLC003 (arithmetic seed derivation).

Why these three are load-bearing here: every random draw in the engine —
client selection, transform noise, stochastic rounding, pairwise masks,
straggler/churn schedules — must be a pure function of
``(FLConfig.seed, round, slot, attempt)`` so runs replay and checkpoints
resume bit-identically (pinned by tests/test_churn.py).  The failure modes
this catches:

* **FLC001** ``PRNGKey(0)``-style literals fork an unrelated root stream
  that ignores the config seed: two runs with different seeds share the
  literal stream, and the draw can collide with any other literal-keyed
  stream in the process.
* **FLC002** feeding one key object to two random ops yields perfectly
  correlated draws (the classic jax.random misuse — keys are consumed, not
  reused; ``fold_in``/``split`` first).
* **FLC003** ``PRNGKey(seed + cid)`` collides across configs:
  ``(seed=0, cid=1)`` and ``(seed=1, cid=0)`` are the SAME stream, so two
  "independent" runs can share every draw.  ``fold_in(PRNGKey(seed), cid)``
  and ``SeedSequence([seed, cid])`` mix injectively.

All checks are flow-light heuristics over the AST — findings carry inline
``# flcheck: disable=CODE (reason)`` suppressions (see ``rules.py``).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.rules import Finding, Suppressions

__all__ = ["check_source"]

# jax.random samplers that CONSUME a key (fold_in/split derive, not consume)
_CONSUMERS = frozenset({
    "normal", "uniform", "bernoulli", "randint", "permutation",
    "categorical", "truncated_normal", "gumbel", "laplace", "exponential",
    "gamma", "beta", "poisson", "choice", "bits", "rademacher", "cauchy",
    "dirichlet", "loggamma", "maxwell", "multivariate_normal", "orthogonal",
    "pareto", "rayleigh", "t", "ball",
})
_KEY_MAKERS = frozenset({"PRNGKey", "key"})
_SEEDED_CTORS = frozenset({"default_rng", "SeedSequence"})


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.normal' for an Attribute chain, 'hash' for a Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_key_maker(call: ast.Call) -> bool:
    name = _dotted(call.func)
    if name is None:
        return False
    last = name.rsplit(".", 1)[-1]
    if last not in _KEY_MAKERS:
        return False
    # 'key' only counts as jax.random.key (plain .key() methods abound)
    return last != "key" or name.endswith("random.key")


def _is_consumer(call: ast.Call) -> bool:
    name = _dotted(call.func)
    if name is None or "." not in name:
        return False
    mod, last = name.rsplit(".", 1)
    return last in _CONSUMERS and (mod == "random" or mod.endswith(".random"))


def _is_arith(node: ast.AST) -> bool:
    return isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.Add, ast.Sub, ast.Mult, ast.FloorDiv, ast.Mod,
                  ast.BitXor, ast.BitOr, ast.LShift))


class _Lint(ast.NodeVisitor):
    def __init__(self, rel: str, sup: Suppressions):
        self.rel, self.sup = rel, sup
        self.findings: List[Finding] = []

    def _emit(self, code: str, line: int, msg: str) -> None:
        self.findings.append(self.sup.apply(code, self.rel, line, msg))

    # ---------------------------------------------- FLC001 / FLC003 (calls)
    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func) or ""
        last = name.rsplit(".", 1)[-1]
        arg0 = node.args[0] if node.args else None
        if _is_key_maker(node) and arg0 is not None:
            if isinstance(arg0, ast.Constant):
                self._emit(
                    "FLC001", node.lineno,
                    f"raw {last}({arg0.value!r}) — derive from the config "
                    "seed (jax.random.fold_in) or suppress with a rationale")
            elif _is_arith(arg0):
                self._emit(
                    "FLC003", node.lineno,
                    f"arithmetic seed fed to {last}(...) — (seed, i) pairs "
                    "collide under +/-; use fold_in(PRNGKey(seed), i)")
        elif last in _SEEDED_CTORS and arg0 is not None and _is_arith(arg0):
            self._emit(
                "FLC003", node.lineno,
                f"arithmetic seed fed to {last}(...) — use "
                "SeedSequence([seed, i]) (injective mixing) instead")
        self.generic_visit(node)

    # ------------------------------------------------------ FLC002 (reuse)
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._scan_block(node.body, {})
        # nested defs get their own fresh scan via generic_visit recursion
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _assigned_names(self, node: ast.AST) -> List[str]:
        return [n.id for n in ast.walk(node)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)]

    def _consumes_in(self, node: ast.AST):
        """(line, key_name) for every key-consuming jax.random call inside
        ``node``, skipping nested function bodies (they have their own
        scopes) but descending into comprehensions with their targets
        treated as local rebinds."""
        out = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and _is_consumer(sub) and sub.args \
                    and isinstance(sub.args[0], ast.Name):
                out.append((sub.lineno, sub.args[0].id))
        return out

    def _scan_block(self, stmts: List[ast.stmt], consumed: Dict[str, int]):
        """Straight-line key-reuse scan: ``consumed`` maps key name -> line
        of its (only allowed) consumption; any rebind clears it.  Compound
        statements are scanned with a copy of the state (branches cannot
        alias each other) and forget it afterwards — except loops, which get
        the cross-iteration check below."""
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # separate scope; visited independently
            if isinstance(st, (ast.For, ast.While)):
                self._check_loop_reuse(st)
                for body in (st.body, st.orelse):
                    self._scan_block(body, dict(consumed))
                for name in self._assigned_names(st):
                    consumed.pop(name, None)
                continue
            if isinstance(st, (ast.If, ast.Try, ast.With)):
                for body in [getattr(st, "body", [])] + \
                        [h.body for h in getattr(st, "handlers", [])] + \
                        [getattr(st, "orelse", []),
                         getattr(st, "finalbody", [])]:
                    self._scan_block(body, dict(consumed))
                for name in self._assigned_names(st):
                    consumed.pop(name, None)
                continue
            comp_targets = {
                n for sub in ast.walk(st)
                if isinstance(sub, (ast.ListComp, ast.SetComp, ast.DictComp,
                                    ast.GeneratorExp))
                for gen in sub.generators
                for n in self._assigned_names(gen.target)}
            for line, key in self._consumes_in(st):
                if key in comp_targets:
                    continue
                if key in consumed:
                    self._emit(
                        "FLC002", line,
                        f"key {key!r} already consumed at line "
                        f"{consumed[key]} — fold_in/split before drawing "
                        "again (reused keys give identical bits)")
                else:
                    consumed[key] = line
            for name in self._assigned_names(st):
                consumed.pop(name, None)

    def _check_loop_reuse(self, loop: ast.stmt) -> None:
        """A key consumed inside a loop body without being (re)assigned in
        that body is the same bits every iteration."""
        assigned = set(self._assigned_names(loop))
        for line, key in self._consumes_in(loop):
            # comprehension targets inside the body count as assignments too
            if key not in assigned:
                self._emit(
                    "FLC002", line,
                    f"key {key!r} consumed inside a loop without a "
                    "per-iteration fold_in/split — every iteration draws "
                    "the same bits")


def check_source(source: str, rel: str) -> List[Finding]:
    """Run the PRNG-hygiene rules over one module's source."""
    tree = ast.parse(source)
    lint = _Lint(rel, Suppressions(source))
    lint.visit(tree)
    return lint.findings
