"""``flcheck`` — static analysis for the federated pipeline's load-bearing
invariants (see ``docs/static_analysis.md``).

Two levels:

* **Level 1 — jaxpr dataflow taint** (``analysis/taint.py``): trace the real
  round bodies to jaxprs, taint the per-client delta values at their source,
  and prove that no tainted value reaches a shard-boundary collective without
  first flowing through every configured transform stage (clip -> noise ->
  quantize -> mask).  Plus a jit recompile guard and an implicit host<->device
  transfer check for the round hot path (``analysis/recompile.py``).
* **Level 2 — AST lint** (``analysis/prng_lint.py``, ``determinism.py``,
  ``dtypes.py``, ``concurrency.py``): PRNG hygiene (raw literal keys, key
  reuse, arithmetic seed derivation), nondeterminism in ``core/``/``data/``,
  dtype hazards in ``core/``/``kernels/``, and serving-tier concurrency
  hazards (unlocked shared mutation, TOCTOU handle fetches, unbounded cache
  growth, Python branches on traced values) in ``serving/``.  Rule catalog +
  inline suppression syntax live in ``analysis/rules.py``.
* **Level 3 — wire-format & cost audit** (``analysis/costs.py``): read the
  declared wire encoding off every boundary crossing of the traced round,
  derive exact per-client upload bytes + per-stage FLOP/HBM totals, and gate
  them against the committed ``analysis/baselines/round_costs.json``.

CLI: ``python -m repro.analysis src/`` or ``tools/flcheck src/``.

This package is import-light on purpose: ``repro.core`` modules import
``repro.analysis.taint`` for the (production no-op) taint markers, so nothing
here may import ``repro.core`` at module level.
"""
