"""``flcheck`` command line: lint + taint + hot-path guards.

Usage (from the repo root)::

    tools/flcheck src/                      # level-2 AST lint (fast, no jax)
    tools/flcheck --taint                   # level-1 jaxpr taint proofs
    tools/flcheck --hot-path                # recompile + transfer guards
    tools/flcheck --all src/                # everything CI runs
    tools/flcheck --list-rules

Exit status: 0 when every selected pass is clean (suppressed findings with a
rationale are clean; ``disable`` comments WITHOUT a rationale are fatal),
1 on any finding/violation, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Iterable, List, Tuple

from repro.analysis import determinism, dtypes, prng_lint
from repro.analysis.rules import RULES, Finding, Suppressions, relpath

_CHECKERS = (prng_lint.check_source, determinism.check_source,
             dtypes.check_source)


def _iter_py(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__", ".git"))
                for f in sorted(filenames):
                    if f.endswith(".py"):
                        yield os.path.join(dirpath, f)


def find_repo_root(start: str) -> str:
    """Nearest ancestor holding a ``.git`` or ``pyproject.toml`` — rule
    scopes are keyed on repo-relative paths like ``src/repro/core/``."""
    d = os.path.abspath(start)
    while True:
        if any(os.path.exists(os.path.join(d, m))
               for m in (".git", "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start)
        d = parent


def lint_file(path: str, root: str) -> Tuple[List[Finding], List[str]]:
    """All level-2 findings for one file, plus fatal suppression-syntax
    errors (``disable`` without a rationale)."""
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    rel = relpath(path, root)
    try:
        findings = [f for check in _CHECKERS for f in check(source, rel)]
    except SyntaxError as e:
        return [Finding("FLC000", rel, e.lineno or 0,
                        f"syntax error: {e.msg}")], []
    findings = [f for f in findings if RULES[f.code].in_scope(rel)]
    errors = [f"{rel}:{ln}: flcheck disable without a (rationale) — "
              "suppressions must say why"
              for ln in Suppressions(source).missing_reason]
    return findings, errors


def run_lint(paths: List[str], root: str, show_suppressed: bool = False
             ) -> int:
    n_files = 0
    fatal: List[str] = []
    suppressed: List[Finding] = []
    for path in _iter_py(paths):
        n_files += 1
        findings, errors = lint_file(path, root)
        fatal.extend(errors)
        for f in findings:
            if f.suppressed:
                suppressed.append(f)
            else:
                fatal.append(f.render())
    for line in fatal:
        print(line)
    if show_suppressed:
        for f in suppressed:
            print(f.render())
    print(f"flcheck lint: {n_files} files, {len(fatal)} findings, "
          f"{len(suppressed)} suppressed")
    return 1 if fatal else 0


def run_taint(quick: bool = False) -> int:
    """Prove sanitize-before-boundary on the real round bodies.

    Configs x topologies: the full transform+secure stack must carry all
    four labels to every boundary; a clip-only config must carry ``clip``.
    ``quick`` limits to the vmap topology (no mesh setup) for the test.sh
    smoke.
    """
    from repro.analysis import taint
    from repro.configs.base import SecureAggConfig, TransformConfig

    full_t = TransformConfig(clip_norm=1.0, noise_multiplier=0.5,
                             quantize_bits=4)
    cases = [("vmap", full_t, SecureAggConfig(enabled=True)),
             ("vmap", TransformConfig(clip_norm=1.0), None),
             ("semi_sync", full_t, SecureAggConfig(enabled=True))]
    if not quick:
        import jax
        n_dev = len(jax.devices())
        cases += [("flat", full_t, SecureAggConfig(enabled=True)),
                  ("flat", TransformConfig(clip_norm=1.0), None)]
        if n_dev >= 2 and n_dev % 2 == 0:
            cases.append(("hier", full_t, SecureAggConfig(enabled=True)))
        else:
            print(f"flcheck taint: skipping hier topology "
                  f"({n_dev} devices; need an even count >= 2)")
    rc = 0
    for topo, tcfg, scfg in cases:
        report = taint.verify_pipeline(topo, tcfg, scfg)
        label = f"[{topo}] required={sorted(report.required)}"
        if report.proved:
            print(f"flcheck taint OK {label}: sources={report.sources} "
                  f"tainted-crossings={report.checked}")
        else:
            rc = 1
            print(f"flcheck taint FAILED {label}:")
            print("  " + report.render().replace("\n", "\n  "))
    return rc


def run_hot_path() -> int:
    from repro.analysis import recompile

    report, transfer_err = recompile.check_round_hot_path()
    print("flcheck hot-path: " + report.render())
    rc = 0 if report.ok else 1
    if transfer_err is None:
        print("flcheck hot-path: transfer guard OK (no implicit "
              "host<->device transfers after warm-up)")
    else:
        rc = 1
        print("flcheck hot-path: transfer guard FAILED: " + transfer_err)
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="flcheck",
        description="Static + dataflow analysis for the federated pipeline "
                    "(see docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", help="files/dirs to lint "
                    "(default: src/ under the repo root)")
    ap.add_argument("--taint", action="store_true",
                    help="run the jaxpr taint proofs on the round bodies")
    ap.add_argument("--quick-taint", action="store_true",
                    help="vmap-only taint proof (fast smoke)")
    ap.add_argument("--hot-path", action="store_true",
                    help="run the recompile + transfer guards (slow)")
    ap.add_argument("--all", action="store_true",
                    help="lint + taint + hot-path")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint (with --taint/--hot-path)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings with rationales")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            scope = ", ".join(rule.scope) if rule.scope else "everywhere"
            print(f"{rule.code} {rule.name} [{scope}]\n    {rule.summary}")
        return 0

    root = find_repo_root(args.paths[0] if args.paths else os.getcwd())
    paths = args.paths or [os.path.join(root, "src")]
    do_taint = args.taint or args.quick_taint or args.all
    do_hot = args.hot_path or args.all
    do_lint = not args.no_lint or not (do_taint or do_hot)

    rc = 0
    if do_lint:
        rc |= run_lint(paths, root, show_suppressed=args.show_suppressed)
    if do_taint:
        rc |= run_taint(quick=args.quick_taint and not (args.taint
                                                        or args.all))
    if do_hot:
        rc |= run_hot_path()
    return rc


if __name__ == "__main__":
    sys.exit(main())
