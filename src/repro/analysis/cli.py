"""``flcheck`` command line: lint + taint + hot-path guards.

Usage (from the repo root)::

    tools/flcheck src/                      # level-2 AST lint (fast, no jax)
    tools/flcheck --taint                   # level-1 jaxpr taint proofs
    tools/flcheck --hot-path                # recompile + transfer guards
    tools/flcheck --cost --baseline \
        src/repro/analysis/baselines/round_costs.json   # level-3 cost gate
    tools/flcheck --cost --update-baseline  # rewrite the committed baseline
    tools/flcheck --all src/                # everything CI runs
    tools/flcheck --list-rules

Exit status: 0 when every selected pass is clean (suppressed findings with a
rationale are clean; ``disable`` comments WITHOUT a rationale are fatal),
1 on any finding/violation, 2 on usage errors — including a missing or
Python-free lint target ('nothing to lint' is an error, not a pass).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Iterable, List, Tuple

from repro.analysis import concurrency, determinism, dtypes, prng_lint
from repro.analysis.rules import RULES, Finding, Suppressions, relpath

_CHECKERS = (prng_lint.check_source, determinism.check_source,
             dtypes.check_source, concurrency.check_source)


def _iter_py(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__", ".git"))
                for f in sorted(filenames):
                    if f.endswith(".py"):
                        yield os.path.join(dirpath, f)


def check_paths(paths: List[str]) -> List[str]:
    """Fatal path errors: a missing target, or a directory with no Python
    under it.  'nothing to lint' must never silently pass as 'clean' —
    a typo'd path would otherwise green-light CI."""
    errors = []
    for p in paths:
        if not os.path.exists(p):
            errors.append(f"flcheck: path does not exist: {p}")
        elif os.path.isfile(p) and not p.endswith(".py"):
            errors.append(f"flcheck: not a Python file: {p}")
        elif os.path.isdir(p) and not any(_iter_py([p])):
            errors.append(f"flcheck: no Python files under: {p}")
    return errors


def find_repo_root(start: str) -> str:
    """Nearest ancestor holding a ``.git`` or ``pyproject.toml`` — rule
    scopes are keyed on repo-relative paths like ``src/repro/core/``."""
    d = os.path.abspath(start)
    while True:
        if any(os.path.exists(os.path.join(d, m))
               for m in (".git", "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start)
        d = parent


def lint_file(path: str, root: str) -> Tuple[List[Finding], List[str]]:
    """All level-2 findings for one file, plus fatal suppression-syntax
    errors (``disable`` without a rationale)."""
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    rel = relpath(path, root)
    try:
        findings = [f for check in _CHECKERS for f in check(source, rel)]
    except SyntaxError as e:
        return [Finding("FLC000", rel, e.lineno or 0,
                        f"syntax error: {e.msg}")], []
    findings = [f for f in findings if RULES[f.code].in_scope(rel)]
    errors = [f"{rel}:{ln}: flcheck disable without a (rationale) — "
              "suppressions must say why"
              for ln in Suppressions(source).missing_reason]
    return findings, errors


def run_lint(paths: List[str], root: str, show_suppressed: bool = False
             ) -> int:
    n_files = 0
    fatal: List[str] = []
    suppressed: List[Finding] = []
    for path in _iter_py(paths):
        n_files += 1
        findings, errors = lint_file(path, root)
        fatal.extend(errors)
        for f in findings:
            if f.suppressed:
                suppressed.append(f)
            else:
                fatal.append(f.render())
    for line in fatal:
        print(line)
    if show_suppressed:
        for f in suppressed:
            print(f.render())
    print(f"flcheck lint: {n_files} files, {len(fatal)} findings, "
          f"{len(suppressed)} suppressed")
    return 1 if fatal else 0


def run_taint(quick: bool = False) -> int:
    """Prove sanitize-before-boundary on the real round bodies.

    Configs x topologies: the full transform+secure stack must carry all
    four labels to every boundary; a clip-only config must carry ``clip``.
    ``quick`` limits to the vmap topology (no mesh setup) for the test.sh
    smoke.
    """
    from repro.analysis import taint
    from repro.configs.base import SecureAggConfig, TransformConfig

    full_t = TransformConfig(clip_norm=1.0, noise_multiplier=0.5,
                             quantize_bits=4)
    cases = [("vmap", full_t, SecureAggConfig(enabled=True)),
             ("vmap", TransformConfig(clip_norm=1.0), None),
             ("semi_sync", full_t, SecureAggConfig(enabled=True))]
    if not quick:
        import jax
        n_dev = len(jax.devices())
        cases += [("flat", full_t, SecureAggConfig(enabled=True)),
                  ("flat", TransformConfig(clip_norm=1.0), None)]
        if n_dev >= 2 and n_dev % 2 == 0:
            cases.append(("hier", full_t, SecureAggConfig(enabled=True)))
        else:
            print(f"flcheck taint: skipping hier topology "
                  f"({n_dev} devices; need an even count >= 2)")
    rc = 0
    for topo, tcfg, scfg in cases:
        report = taint.verify_pipeline(topo, tcfg, scfg)
        label = f"[{topo}] required={sorted(report.required)}"
        if report.proved:
            print(f"flcheck taint OK {label}: sources={report.sources} "
                  f"tainted-crossings={report.checked}")
        else:
            rc = 1
            print(f"flcheck taint FAILED {label}:")
            print("  " + report.render().replace("\n", "\n  "))
    return rc


def resolve_baseline(arg: str, root: str) -> str:
    """Baseline path resolution: as given if it exists or is absolute,
    else relative to the repo root (CI passes the repo-relative path from
    any working directory)."""
    if os.path.isabs(arg) or os.path.exists(arg):
        return arg
    return os.path.join(root, arg)


def run_cost(root: str, baseline: str = None, update: bool = False) -> int:
    """Level-3 cost audit + baseline gate (see ``analysis/costs.py``).

    Always runs the fatal wire proof (quantize-on uploads must reach every
    boundary as int8-grid + fp32-scale on every traced path).  With
    ``--baseline``, diffs the fresh report against the committed JSON and
    fails on any wire-byte / boundary-dtype / stage-FLOP drift; with
    ``--update-baseline``, rewrites the JSON instead (do this ONLY in the
    same change that intentionally moved the cost — see
    docs/static_analysis.md).
    """
    import json

    from repro.analysis import costs

    report = costs.cost_report()
    print(costs.render_summary(report))
    rc = 0
    for msg in costs.check_report(report):
        rc = 1
        print(f"flcheck cost FATAL: {msg}")
    path = resolve_baseline(baseline or costs.DEFAULT_BASELINE, root)
    if update:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            f.write(costs.canonical_json(report))
        print(f"flcheck cost: baseline written to {path}")
        return rc
    if baseline is not None:
        if not os.path.exists(path):
            print(f"flcheck cost FATAL: baseline not found: {path} "
                  "(generate it with --cost --update-baseline)")
            return 1
        with open(path, "r", encoding="utf-8") as f:
            base = json.load(f)
        errors, warnings = costs.diff_reports(base, report)
        for w in warnings:
            print(f"flcheck cost note: {w}")
        for e in errors:
            rc = 1
            print(f"flcheck cost DRIFT: {e}")
        if not errors:
            print(f"flcheck cost: report matches baseline {path}")
        else:
            print("flcheck cost: wire/FLOP drift against the committed "
                  "baseline — if the change is intentional, rerun with "
                  "--cost --update-baseline and commit the JSON")
    return rc


def run_hot_path() -> int:
    from repro.analysis import recompile

    report, transfer_err = recompile.check_round_hot_path()
    print("flcheck hot-path: " + report.render())
    rc = 0 if report.ok else 1
    if transfer_err is None:
        print("flcheck hot-path: transfer guard OK (no implicit "
              "host<->device transfers after warm-up)")
    else:
        rc = 1
        print("flcheck hot-path: transfer guard FAILED: " + transfer_err)
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="flcheck",
        description="Static + dataflow analysis for the federated pipeline "
                    "(see docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", help="files/dirs to lint "
                    "(default: src/ under the repo root)")
    ap.add_argument("--taint", action="store_true",
                    help="run the jaxpr taint proofs on the round bodies")
    ap.add_argument("--quick-taint", action="store_true",
                    help="vmap-only taint proof (fast smoke)")
    ap.add_argument("--hot-path", action="store_true",
                    help="run the recompile + transfer guards (slow)")
    ap.add_argument("--cost", action="store_true",
                    help="run the level-3 wire-format & cost audit")
    ap.add_argument("--baseline", metavar="JSON",
                    help="with --cost: diff the report against this "
                         "committed baseline and fail on drift")
    ap.add_argument("--update-baseline", action="store_true",
                    help="with --cost: rewrite the baseline JSON instead "
                         "of diffing")
    ap.add_argument("--all", action="store_true",
                    help="lint + taint + hot-path + cost audit")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint (with --taint/--hot-path)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings with rationales")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            scope = ", ".join(rule.scope) if rule.scope else "everywhere"
            print(f"{rule.code} {rule.name} [{scope}]\n    {rule.summary}")
        return 0

    if (args.baseline or args.update_baseline) and not args.cost:
        print("flcheck: --baseline/--update-baseline require --cost",
              file=sys.stderr)
        return 2

    root = find_repo_root(args.paths[0] if args.paths else os.getcwd())
    paths = args.paths or [os.path.join(root, "src")]
    do_taint = args.taint or args.quick_taint or args.all
    do_hot = args.hot_path or args.all
    do_cost = args.cost or args.all
    do_lint = not args.no_lint or not (do_taint or do_hot or do_cost)

    if do_lint:
        path_errors = check_paths(paths)
        if path_errors:
            for e in path_errors:
                print(e, file=sys.stderr)
            return 2

    rc = 0
    if do_lint:
        rc |= run_lint(paths, root, show_suppressed=args.show_suppressed)
    if do_taint:
        rc |= run_taint(quick=args.quick_taint and not (args.taint
                                                        or args.all))
    if do_cost:
        rc |= run_cost(root, baseline=args.baseline,
                       update=args.update_baseline)
    if do_hot:
        rc |= run_hot_path()
    return rc


if __name__ == "__main__":
    sys.exit(main())
