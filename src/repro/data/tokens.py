"""LM token pipeline for the assigned architectures.

Offline container ⇒ synthetic-but-structured token streams (a Zipf-mixture
"language" with local n-gram structure, so losses decrease meaningfully in
smoke training), plus the modality-specific batch layouts:

  * dense/moe/ssm/hybrid: {"tokens", "labels"} (B, S)
  * vlm (llava anyres):   tokens (B, S − n_media) + media patch embeddings
  * audio (musicgen):     (B, K, S) EnCodec-style codes with the DELAY
                          pattern — codebook k is shifted k steps so step t
                          decodes code k of frame t−k (arXiv:2306.05284)
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


def zipf_tokens(rng: np.random.Generator, shape, vocab: int,
                alpha: float = 1.2) -> np.ndarray:
    """Zipf-distributed ids with first-order Markov structure."""
    n = int(np.prod(shape))
    ranks = rng.zipf(alpha, size=n).astype(np.int64)
    base = (ranks - 1) % vocab
    # bigram structure: with p=0.3, next token = prev + 1 (mod vocab)
    flat = base.copy()
    follow = rng.random(n) < 0.3
    flat[1:][follow[1:]] = (flat[:-1][follow[1:]] + 1) % vocab
    return flat.reshape(shape).astype(np.int32)


def apply_delay_pattern(codes: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """MusicGen delay interleave: codes (B, K, S) -> delayed (B, K, S)."""
    B, K, S = codes.shape
    out = np.full_like(codes, pad_id)
    for k in range(K):
        out[:, k, k:] = codes[:, k, :S - k]
    return out


def undelay_pattern(delayed: np.ndarray) -> np.ndarray:
    B, K, S = delayed.shape
    out = np.zeros_like(delayed)
    for k in range(K):
        out[:, k, :S - k] = delayed[:, k, k:]
    return out


def make_lm_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """A training batch matching the arch's input layout (numpy)."""
    rng = np.random.default_rng(seed)
    if cfg.arch_type == "audio":
        K = cfg.frontend.n_codebooks
        raw = zipf_tokens(rng, (batch, K, seq), cfg.vocab_size)
        toks = apply_delay_pattern(raw)
        return {"tokens": toks, "labels": toks}
    if cfg.arch_type == "vlm":
        nm = cfg.frontend.n_media_tokens
        toks = zipf_tokens(rng, (batch, seq - nm), cfg.vocab_size)
        media = rng.normal(size=(batch, nm, cfg.frontend.embed_dim)) \
            .astype(np.float32)
        labels = zipf_tokens(rng, (batch, seq), cfg.vocab_size)
        labels[:, nm:] = np.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels, "media": media}
    toks = zipf_tokens(rng, (batch, seq), cfg.vocab_size)
    labels = np.roll(toks, -1, axis=1)
    return {"tokens": toks, "labels": labels}
