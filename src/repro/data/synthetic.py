"""OpenEIA-calibrated synthetic commercial-building demand corpus.

The real OpenEIA comstock release is not available offline, so this module
generates a corpus whose *marginal statistics match what the paper reports*
(§4.1, Fig. 2): 15-min kWh readings, 35,040 samples/building-year, and a
long-tailed mean-consumption distribution with min 0.16, Q1 4.7, median 12.7,
Q3 28.4 kWh and a tail beyond 63.8 kWh.

Mean consumption is drawn log-normally: median 12.7 ⇒ μ = ln 12.7; the paper's
Q3/median ratio 28.4/12.7 = 2.236 ⇒ σ = ln(2.236)/0.6745 ≈ 1.19.  Per-building
series mix commercial archetypes (office / retail / industrial / school /
restaurant) with daily + weekly + annual seasonality and AR(1) noise — the
heterogeneity the paper's clustering exploits.

Everything is deterministic in (state, building_id): building i of a state is
always the same series, so train/held-out splits are reproducible and the
39k-building evaluations stream without holding the corpus in memory.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

STEPS_PER_DAY = 96            # 15-min sampling
DAYS_PER_YEAR = 365

# state -> (seed offset, scale, annual-seasonality amplitude, summer-peak phase)
STATES = {
    "CA": dict(seed=1_000_003, scale=1.00, annual_amp=0.15, phase=0.55),
    "FLO": dict(seed=2_000_003, scale=1.05, annual_amp=0.30, phase=0.52),
    "RI": dict(seed=3_000_003, scale=0.90, annual_amp=0.22, phase=0.02),
}

# archetype -> (daytime window, weekday factor, weekend factor, base load frac)
_ARCHETYPES = (
    # name        open  close  wkday wkend  base  evening_bump
    ("office",     8.0, 18.0,  1.00, 0.25, 0.25, 0.0),
    ("retail",    10.0, 21.0,  1.00, 0.95, 0.30, 0.0),
    ("industrial", 0.0, 24.0,  1.00, 0.80, 0.85, 0.0),
    ("school",     7.0, 16.0,  1.00, 0.10, 0.20, 0.0),
    ("restaurant", 11.0, 23.0, 1.00, 1.10, 0.25, 0.6),
)

LOGNORM_MU = float(np.log(12.7))
LOGNORM_SIGMA = float(np.log(28.4 / 12.7) / 0.6745)
MIN_KWH = 0.16


def _rng(state: str, building_id: int) -> np.random.Generator:
    cfg = STATES[state]
    return np.random.default_rng(np.random.SeedSequence([cfg["seed"], building_id]))


def mean_consumption(state: str, building_ids: Sequence[int]) -> np.ndarray:
    """Target mean kWh per building (the Fig. 2 marginal), deterministic."""
    out = np.empty(len(building_ids), np.float64)
    for j, b in enumerate(building_ids):
        g = _rng(state, b)
        out[j] = max(MIN_KWH, np.exp(LOGNORM_MU + LOGNORM_SIGMA * g.standard_normal())
                     * STATES[state]["scale"])
    return out


def _daily_shape(arch_row, hours: np.ndarray) -> np.ndarray:
    """Smooth occupancy curve over one day (96 steps), peak 1.0."""
    _, op, cl, _, _, base, evening = arch_row
    occ = 1.0 / (1.0 + np.exp(-(hours - op) * 1.5)) * \
          1.0 / (1.0 + np.exp((hours - cl) * 1.5))
    if evening:
        occ = occ + evening * np.exp(-0.5 * ((hours - 19.5) / 1.5) ** 2)
    shape = base + (1.0 - base) * occ / max(occ.max(), 1e-9)
    return shape


def generate_buildings(state: str, building_ids: Sequence[int],
                       days: int = DAYS_PER_YEAR) -> np.ndarray:
    """Generate (n_buildings, days*96) float32 kWh series, deterministic."""
    n_steps = days * STEPS_PER_DAY
    hours = (np.arange(STEPS_PER_DAY) + 0.5) * 24.0 / STEPS_PER_DAY
    day_idx = np.arange(days)
    scfg = STATES[state]
    means = mean_consumption(state, building_ids)
    out = np.empty((len(building_ids), n_steps), np.float32)
    for j, b in enumerate(building_ids):
        g = _rng(state, b)
        g.standard_normal()                              # consumed by mean draw
        arch = _ARCHETYPES[int(g.integers(len(_ARCHETYPES)))]
        arch = (arch[0],) + tuple(
            v * (1.0 + 0.15 * g.standard_normal()) if isinstance(v, float) and v
            else v for v in arch[1:])
        daily = _daily_shape(arch, hours)                # (96,)
        wk = np.where((day_idx % 7) < 5, arch[3], arch[4])   # (days,)
        annual = 1.0 + scfg["annual_amp"] * np.cos(
            2 * np.pi * (day_idx / 365.0 - scfg["phase"]))
        grid = (daily[None, :] * wk[:, None] * annual[:, None]).reshape(-1)
        # AR(1) multiplicative noise — exact via truncated impulse response
        # (ρ=0.9 ⇒ ρ^128 ≈ 1e-6, negligible), vectorized as a convolution.
        rho = 0.9
        eps = g.standard_normal(n_steps) * 0.08
        kern = rho ** np.arange(128)
        noise = np.convolve(eps, kern)[:n_steps]
        series = grid * np.exp(noise)
        series *= means[j] / max(series.mean(), 1e-9)     # hit the target mean
        out[j] = np.maximum(series, 0.01).astype(np.float32)
    return out


def state_population(state: str) -> int:
    """Paper Table 1 building counts."""
    return {"CA": 39391, "FLO": 24444, "RI": 1376}[state]
