"""Client partitioning + per-round sampling (paper Alg. 1)."""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def sample_clients(rng: np.random.Generator, n_clients: int, m: int) -> np.ndarray:
    """Server randomly selects M clients out of N (without replacement)."""
    m = min(m, n_clients)
    return rng.choice(n_clients, size=m, replace=False)


def holdout_clients(rng: np.random.Generator, n_clients: int,
                    holdout_frac: float):
    """Client-level train/holdout split for unseen-client generalization.

    Returns (train_ids, held_ids), both sorted.  held_ids clients never
    participate in training; evaluating on their windows measures transfer to
    buildings the model has NEVER seen (paper §5.4), a strictly harder test
    than held-out windows of training clients.
    """
    n_held = int(round(n_clients * holdout_frac))
    if n_held <= 0:
        return np.arange(n_clients), np.empty(0, np.int64)
    perm = rng.permutation(n_clients)
    return np.sort(perm[n_held:]), np.sort(perm[:n_held])


def cluster_partition(assignments: np.ndarray) -> Dict[int, np.ndarray]:
    """cluster id -> client indices."""
    return {int(c): np.flatnonzero(assignments == c)
            for c in np.unique(assignments)}


def sample_minibatch_indices(rng: np.random.Generator, n_windows: int,
                             batch: int, steps: int) -> np.ndarray:
    """(steps, batch) window indices for a client's local SGD schedule.

    Emulates E epochs of shuffled minibatches with a fixed step count so the
    local update is a fixed-shape ``lax.scan`` (vmap-able across clients).
    """
    return rng.integers(0, n_windows, size=(steps, batch))


def ragged_minibatch_indices(rng: np.random.Generator, counts: np.ndarray,
                             steps: int, batch: int) -> np.ndarray:
    """(m, steps, batch) window indices with per-client count-masking.

    Client i's indices are drawn in ``[0, counts[i])`` so zero-padded window
    rows (ragged histories, ``ClientWindowProvider``) are never sampled.  The
    equal-count fast path issues ONE ``rng.integers`` call with the same
    bounds/shape as the historical materialized pipeline, keeping its rng
    stream — and therefore trained params — bit-identical.
    """
    counts = np.asarray(counts, np.int64)
    c0 = int(counts[0])
    if (counts == c0).all():
        return rng.integers(0, c0, size=(len(counts), steps, batch))
    return np.stack([rng.integers(0, int(c), size=(steps, batch))
                     for c in counts])


def local_steps(n_windows: int, batch: int, epochs: int) -> int:
    """Number of SGD steps for E epochs of minibatch size B (Alg. 1 inner loop)."""
    return max(1, (n_windows + batch - 1) // batch) * epochs
