from repro.data.synthetic import STATES, generate_buildings, mean_consumption
from repro.data.windows import (client_dataset, daily_average_vector,
                                make_windows, minmax_normalize, train_test_split)
from repro.data.partition import sample_clients

__all__ = ["STATES", "generate_buildings", "mean_consumption", "client_dataset",
           "daily_average_vector", "make_windows", "minmax_normalize",
           "train_test_split", "sample_clients"]
