from repro.data.synthetic import STATES, generate_buildings, mean_consumption
from repro.data.windows import (ClientWindowProvider, batched_client_windows,
                                client_dataset, daily_average_vector,
                                make_windows, minmax_normalize, train_test_split)
from repro.data.partition import ragged_minibatch_indices, sample_clients

__all__ = ["STATES", "generate_buildings", "mean_consumption",
           "ClientWindowProvider", "batched_client_windows", "client_dataset",
           "daily_average_vector", "make_windows", "minmax_normalize",
           "train_test_split", "sample_clients", "ragged_minibatch_indices"]
