"""Windowing + normalization (paper §4.2).

Per building: Min–Max scale to [0,1] over the entire year, frame into
look-back-8 / horizon-4 windows, split 75:25 chronologically (≈9 months train,
3 months test).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.data.synthetic import STEPS_PER_DAY


def minmax_normalize(series: np.ndarray) -> Tuple[np.ndarray, Tuple]:
    """series: (..., T). Returns normalized series + (min, max) for inversion."""
    lo = series.min(axis=-1, keepdims=True)
    hi = series.max(axis=-1, keepdims=True)
    scale = np.maximum(hi - lo, 1e-9)
    return (series - lo) / scale, (lo, hi)


def denormalize(x: np.ndarray, stats: Tuple) -> np.ndarray:
    lo, hi = stats
    return x * np.maximum(hi - lo, 1e-9) + lo


def make_windows(series: np.ndarray, lookback: int, horizon: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """series: (T,) -> x: (n, lookback, 1), y: (n, horizon)."""
    T = series.shape[-1]
    n = T - lookback - horizon + 1
    idx = np.arange(lookback)[None, :] + np.arange(n)[:, None]
    x = series[idx][..., None].astype(np.float32)
    yidx = lookback + np.arange(horizon)[None, :] + np.arange(n)[:, None]
    y = series[yidx].astype(np.float32)
    return x, y


def train_test_split(series: np.ndarray, frac: float = 0.75):
    """Chronological split of a (T,) series."""
    cut = int(series.shape[-1] * frac)
    return series[..., :cut], series[..., cut:]


def daily_average_vector(series: np.ndarray, days: int = 273) -> np.ndarray:
    """Privacy-coarsened consumption summary z_k (Alg. 1): daily means of the
    *training* period.  series: (..., T) -> (..., days)."""
    t = days * STEPS_PER_DAY
    s = series[..., :t]
    return s.reshape(*s.shape[:-1], days, STEPS_PER_DAY).mean(axis=-1)


def client_dataset(series: np.ndarray, lookback: int, horizon: int,
                   train_frac: float = 0.75) -> Dict[str, np.ndarray]:
    """Full per-client pipeline: normalize -> split -> window.

    series: (T,) raw kWh. Returns dict with train/test windows (normalized)
    plus the min/max stats for de-normalization.
    """
    norm, stats = minmax_normalize(series)
    tr, te = train_test_split(norm, train_frac)
    x_tr, y_tr = make_windows(tr, lookback, horizon)
    x_te, y_te = make_windows(te, lookback, horizon)
    return {"x_train": x_tr, "y_train": y_tr, "x_test": x_te, "y_test": y_te,
            "stats": stats}


def batched_client_windows(all_series: np.ndarray, lookback: int, horizon: int,
                           train_frac: float = 0.75):
    """Vectorized pipeline over clients: (N, T) -> stacked train/test windows
    of shape (N, n_windows, ...), suitable for vmap/shard_map over axis 0."""
    norm, stats = minmax_normalize(all_series)
    cut = int(all_series.shape[-1] * train_frac)
    tr, te = norm[:, :cut], norm[:, cut:]

    def win(block):
        xs, ys = [], []
        for row in block:
            x, y = make_windows(row, lookback, horizon)
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.stack(ys)

    x_tr, y_tr = win(tr)
    x_te, y_te = win(te)
    return {"x_train": x_tr, "y_train": y_tr, "x_test": x_te, "y_test": y_te,
            "stats": stats}


def flatten_test_windows(data):
    """(N, n_win, ...) stacked test windows -> flat (N*n_win, ...) plus the
    per-row (lo, hi) stats for kWh-space metric computation."""
    x = data["x_test"]
    n, n_win = x.shape[:2]
    lo, hi = data["stats"]
    rep = lambda a: np.repeat(a, n_win, axis=0)
    return (x.reshape(n * n_win, *x.shape[2:]),
            data["y_test"].reshape(n * n_win, -1),
            (rep(lo), rep(hi)))
