"""Windowing + normalization (paper §4.2) + the streaming client provider.

Per building: Min–Max scale to [0,1] over the entire year, frame into
look-back-8 / horizon-4 windows, split 75:25 chronologically (≈9 months train,
3 months test).

Two data paths share this math:

* :func:`batched_client_windows` materializes the full ``(N, n_win, L, 1)``
  train/test tensors — fine for dozens of clients, quadratic pain at 10k+.
* :class:`ClientWindowProvider` is the streaming replacement: per-client
  series are fetched (or generated) lazily and normalized/windowed on demand,
  so a federated round only ever touches the ``m`` clients selected that
  round.  Ragged histories are supported via count-masking: every batch is
  zero-padded to a fixed ``(m, n_win_max, L, 1)`` shape and carries per-client
  valid-window counts; training draws minibatch indices in ``[0, count_i)``
  so the padding is never read.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, Iterator, Sequence, Tuple, Union

import numpy as np

from repro.data.synthetic import STEPS_PER_DAY
from repro.data import synthetic as _synthetic


def minmax_normalize(series: np.ndarray) -> Tuple[np.ndarray, Tuple]:
    """series: (..., T). Returns normalized series + (min, max) for inversion."""
    lo = series.min(axis=-1, keepdims=True)
    hi = series.max(axis=-1, keepdims=True)
    scale = np.maximum(hi - lo, 1e-9)
    return (series - lo) / scale, (lo, hi)


def denormalize(x: np.ndarray, stats: Tuple) -> np.ndarray:
    lo, hi = stats
    return x * np.maximum(hi - lo, 1e-9) + lo


def make_windows(series: np.ndarray, lookback: int, horizon: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """series: (T,) -> x: (n, lookback, 1), y: (n, horizon)."""
    T = series.shape[-1]
    n = T - lookback - horizon + 1
    idx = np.arange(lookback)[None, :] + np.arange(n)[:, None]
    x = series[idx][..., None].astype(np.float32)
    yidx = lookback + np.arange(horizon)[None, :] + np.arange(n)[:, None]
    y = series[yidx].astype(np.float32)
    return x, y


def train_test_split(series: np.ndarray, frac: float = 0.75):
    """Chronological split of a (T,) series."""
    cut = int(series.shape[-1] * frac)
    return series[..., :cut], series[..., cut:]


def daily_average_vector(series: np.ndarray, days: int = 273) -> np.ndarray:
    """Privacy-coarsened consumption summary z_k (Alg. 1): daily means of the
    *training* period.  series: (..., T) -> (..., days)."""
    t = days * STEPS_PER_DAY
    s = series[..., :t]
    return s.reshape(*s.shape[:-1], days, STEPS_PER_DAY).mean(axis=-1)


def client_dataset(series: np.ndarray, lookback: int, horizon: int,
                   train_frac: float = 0.75) -> Dict[str, np.ndarray]:
    """Full per-client pipeline: normalize -> split -> window.

    series: (T,) raw kWh. Returns dict with train/test windows (normalized)
    plus the min/max stats for de-normalization.
    """
    norm, stats = minmax_normalize(series)
    tr, te = train_test_split(norm, train_frac)
    x_tr, y_tr = make_windows(tr, lookback, horizon)
    x_te, y_te = make_windows(te, lookback, horizon)
    return {"x_train": x_tr, "y_train": y_tr, "x_test": x_te, "y_test": y_te,
            "stats": stats}


def batched_client_windows(all_series: np.ndarray, lookback: int, horizon: int,
                           train_frac: float = 0.75):
    """Vectorized pipeline over clients: (N, T) -> stacked train/test windows
    of shape (N, n_windows, ...), suitable for vmap/shard_map over axis 0."""
    norm, stats = minmax_normalize(all_series)
    cut = int(all_series.shape[-1] * train_frac)
    tr, te = norm[:, :cut], norm[:, cut:]

    def win(block):
        xs, ys = [], []
        for row in block:
            x, y = make_windows(row, lookback, horizon)
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.stack(ys)

    x_tr, y_tr = win(tr)
    x_te, y_te = win(te)
    return {"x_train": x_tr, "y_train": y_tr, "x_test": x_te, "y_test": y_te,
            "stats": stats}


# --------------------------------------------------- streaming provider
class ClientWindowProvider:
    """Lazy per-client normalization + windowing for O(m)-per-round training.

    ``series_fn(i)`` returns client ``i``'s raw (T_i,) kWh series; only the
    clients selected in a round are ever fetched, so a 10k+-client federation
    never materializes the full (N, n_win, L, 1) tensor.  ``lengths`` must be
    known up front (cheap metadata) so per-client window *counts* — the
    aggregation/sampling weights and the ragged count-masks — are available
    without touching any series.

    All batches share one fixed shape ``(m, n_win_max, L, 1)``: clients with
    fewer than ``n_win_max`` train windows are zero-padded and report their
    true count, and callers draw minibatch indices in ``[0, count_i)`` (see
    ``partition.ragged_minibatch_indices``), so padding is never read.  On
    equal-length histories every batch is bit-identical to the corresponding
    rows of :func:`batched_client_windows`.
    """

    def __init__(self, series_fn: Callable[[int], np.ndarray],
                 lengths: Sequence[int], lookback: int, horizon: int,
                 train_frac: float = 0.75, cache_size: int = 32):
        self._fn = series_fn
        self.lengths = np.asarray(lengths, np.int64)
        self.lookback, self.horizon = int(lookback), int(horizon)
        self.train_frac = float(train_frac)
        self._cuts = np.array([int(t * train_frac) for t in self.lengths],
                              np.int64)
        win = lookback + horizon - 1
        self.train_counts = (self._cuts - win).astype(np.int64)
        self.test_counts = (self.lengths - self._cuts - win).astype(np.int64)
        bad = np.flatnonzero((self.train_counts < 1) | (self.test_counts < 1))
        if len(bad):
            raise ValueError(
                f"clients {bad[:8].tolist()} have too little history for "
                f"lookback={lookback}, horizon={horizon}, "
                f"train_frac={train_frac} (min length "
                f"{int(self.lengths[bad].min())})")
        self.n_win_max = int(self.train_counts.max())
        self.test_win_max = int(self.test_counts.max())
        self._cache: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self._raw: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._cache_size = int(cache_size)

    # ------------------------------------------------------- constructors
    @classmethod
    def from_series(cls, series: Union[np.ndarray, Sequence[np.ndarray]],
                    lookback: int, horizon: int, train_frac: float = 0.75,
                    cache_size: int = 32) -> "ClientWindowProvider":
        """Wrap an in-memory (N, T) array or a ragged list of (T_i,) series."""
        if isinstance(series, np.ndarray) and series.ndim == 2:
            lengths = [series.shape[1]] * series.shape[0]
            fn = lambda i: series[i]
        else:
            rows = [np.asarray(s).reshape(-1) for s in series]
            lengths = [len(s) for s in rows]
            fn = lambda i: rows[i]
        return cls(fn, lengths, lookback, horizon, train_frac, cache_size)

    @classmethod
    def from_synthetic(cls, state: str, building_ids: Sequence[int],
                       lookback: int, horizon: int,
                       days: Union[int, Sequence[int]] = 365,
                       train_frac: float = 0.75, cache_size: int = 32
                       ) -> "ClientWindowProvider":
        """On-demand generator variant: client i's year is synthesized only
        when selected (deterministic in (state, building_id)), so population
        size N costs metadata only.  ``days`` may be per-client for ragged
        histories."""
        ids = list(building_ids)
        days_arr = np.broadcast_to(np.asarray(days, np.int64), (len(ids),))
        fn = lambda i: _synthetic.generate_buildings(
            state, [ids[i]], days=int(days_arr[i]))[0]
        return cls(fn, days_arr * STEPS_PER_DAY, lookback, horizon,
                   train_frac, cache_size)

    @property
    def n_clients(self) -> int:
        return len(self.lengths)

    # ------------------------------------------------------- per-client core
    def _series(self, i: int) -> np.ndarray:
        """Fetch client i's raw series — the ONE fetch point (`_client` and
        `daily_summary` share it), with its own small LRU so clustering
        summaries and the rounds that follow don't regenerate series
        back-to-back.  Kept in the source dtype: normalizing in the series'
        own precision keeps provider batches bit-identical to
        batched_client_windows rows."""
        hit = self._raw.get(i)
        if hit is not None:
            self._raw.move_to_end(i)
            return hit
        series = np.asarray(self._fn(i)).reshape(-1)
        if series.shape[0] != self.lengths[i]:
            raise ValueError(f"client {i}: series_fn returned length "
                             f"{series.shape[0]}, expected {self.lengths[i]}")
        if self._cache_size > 0:
            self._raw[i] = series
            while len(self._raw) > self._cache_size:
                self._raw.popitem(last=False)
        return series

    def _client(self, i: int) -> Dict[str, np.ndarray]:
        """Normalize + split + window ONE client (LRU-cached, unpadded)."""
        hit = self._cache.get(i)
        if hit is not None:
            self._cache.move_to_end(i)
            return hit
        series = self._series(i)
        norm, (lo, hi) = minmax_normalize(series)
        cut = self._cuts[i]
        x_tr, y_tr = make_windows(norm[:cut], self.lookback, self.horizon)
        x_te, y_te = make_windows(norm[cut:], self.lookback, self.horizon)
        out = {"x_train": x_tr, "y_train": y_tr, "x_test": x_te,
               "y_test": y_te, "lo": np.float32(lo[0]),
               "hi": np.float32(hi[0])}
        if self._cache_size > 0:
            self._cache[i] = out
            while len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return out

    def _stack(self, ids, xk: str, yk: str, counts: np.ndarray, n_max: int):
        ids = np.asarray(ids)
        x0 = self._client(int(ids[0]))[xk]
        x = np.zeros((len(ids), n_max) + x0.shape[1:], np.float32)
        y = np.zeros((len(ids), n_max, self.horizon), np.float32)
        for j, i in enumerate(ids):
            c = self._client(int(i))
            x[j, :counts[j]] = c[xk]
            y[j, :counts[j]] = c[yk]
        return x, y

    # ----------------------------------------------------------- round API
    def round_batch(self, ids) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Train windows for the clients selected THIS round.

        Returns ``(x, y, counts)`` with x: (m, n_win_max, L, 1),
        y: (m, n_win_max, H), counts: (m,) float32 valid-window counts.
        """
        counts = self.train_counts[np.asarray(ids)]
        x, y = self._stack(ids, "x_train", "y_train", counts, self.n_win_max)
        return x, y, counts.astype(np.float32)

    def test_batch(self, ids):
        """Test windows + per-client (lo, hi) stats, same padding scheme."""
        ids = np.asarray(ids)
        counts = self.test_counts[ids]
        x, y = self._stack(ids, "x_test", "y_test", counts, self.test_win_max)
        lo = np.array([[self._client(int(i))["lo"]] for i in ids], np.float32)
        hi = np.array([[self._client(int(i))["hi"]] for i in ids], np.float32)
        return x, y, counts.astype(np.float32), (lo, hi)

    def iter_test_flat(self, ids=None, clients_per_chunk: int = 64
                       ) -> Iterator[Tuple[np.ndarray, np.ndarray, Tuple]]:
        """Stream flat test windows in client chunks for O(chunk) eval memory.

        Yields ``(x, y, (lo, hi))`` with only VALID windows (no padding), the
        row-repeated stats matching :func:`flatten_test_windows` layout.
        """
        ids = np.arange(self.n_clients) if ids is None else np.asarray(ids)
        for s in range(0, len(ids), clients_per_chunk):
            chunk = ids[s:s + clients_per_chunk]
            xs, ys, los, his = [], [], [], []
            for i in chunk:
                c = self._client(int(i))
                xs.append(c["x_test"])
                ys.append(c["y_test"])
                n = len(c["x_test"])
                los.append(np.full((n, 1), c["lo"], np.float32))
                his.append(np.full((n, 1), c["hi"], np.float32))
            yield (np.concatenate(xs), np.concatenate(ys),
                   (np.concatenate(los), np.concatenate(his)))

    # ------------------------------------------------------------ summaries
    def daily_summary(self, ids, days: int) -> np.ndarray:
        """Privacy-coarsened per-client daily means (Alg. 1's z_k), streamed.

        Matches :func:`daily_average_vector` on clients with ≥ ``days`` days
        of training history; shorter (ragged) clients contribute only their
        TRAIN-period days (never the chronological test split, which must not
        inform cluster assignment) and are right-padded with their own mean
        so k-means sees a fixed-width summary.
        """
        ids = np.asarray(ids)
        out = np.empty((len(ids), days), np.float64)
        for j, i in enumerate(ids):
            series = self._series(int(i))
            cut = int(self._cuts[i])
            d = min(days, cut // STEPS_PER_DAY)
            if d == 0:      # train period shorter than one day: flat summary
                out[j, :] = series[:cut].mean()
                continue
            z = series[:d * STEPS_PER_DAY].reshape(d, STEPS_PER_DAY).mean(-1)
            out[j, :d] = z
            out[j, d:] = z.mean()
        return out


def flatten_test_windows(data):
    """(N, n_win, ...) stacked test windows -> flat (N*n_win, ...) plus the
    per-row (lo, hi) stats for kWh-space metric computation."""
    x = data["x_test"]
    n, n_win = x.shape[:2]
    lo, hi = data["stats"]
    rep = lambda a: np.repeat(a, n_win, axis=0)
    return (x.reshape(n * n_win, *x.shape[2:]),
            data["y_test"].reshape(n * n_win, -1),
            (rep(lo), rep(hi)))
