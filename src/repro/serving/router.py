"""Serve-time cluster routing for unseen consumers (paper §5.4 + §3.1).

Training clusters clients by k-means on their privacy-coarsened daily-mean
consumption vectors (``core/clustering.py``, Briggs et al. — clustering
BEFORE federation handles non-IID load).  At serve time an unseen consumer
has no cluster label, so the router assigns one by **nearest centroid on the
same coarsened summary** — the consumer's raw history is reduced to daily
means (never the raw 15-min trace) before any comparison, matching the
privacy posture of training-side clustering.

With clustering off (no centroids) the router is disabled and everything
maps to ``GLOBAL_SLOT`` — the single-global deployment of the base paper.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import clustering
from repro.data.synthetic import STEPS_PER_DAY
from repro.serving.registry import GLOBAL_SLOT

__all__ = ["ClusterRouter", "daily_summary_of"]


def daily_summary_of(series: np.ndarray, days: int) -> np.ndarray:
    """One consumer's raw history -> fixed-width (days,) daily-mean summary.

    Mirrors ``ClientWindowProvider.daily_summary`` padding semantics:
    shorter histories contribute the days they have and are right-padded
    with their own mean; a sub-day history degenerates to a flat summary.
    At serve time the WHOLE provided history is observation (there is no
    train/test split to protect), so no cut is applied.
    """
    s = np.asarray(series, np.float64).reshape(-1)
    out = np.empty(days, np.float64)
    d = min(days, len(s) // STEPS_PER_DAY)
    if d == 0:
        out[:] = s.mean() if len(s) else 0.0
        return out
    z = s[:d * STEPS_PER_DAY].reshape(d, STEPS_PER_DAY).mean(-1)
    out[:d] = z
    out[d:] = z.mean()
    return out


class ClusterRouter:
    """Nearest-centroid slot assignment on coarsened daily summaries.

    ``centroids``: the (k, days) k-means centroids a clustered FL run
    reports on every ``FLResult.cluster_centroids``; ``None`` disables
    routing (every consumer -> ``GLOBAL_SLOT``).
    """

    def __init__(self, centroids: Optional[np.ndarray] = None):
        self.centroids = (None if centroids is None
                          else np.asarray(centroids, np.float64))
        if self.centroids is not None and self.centroids.ndim != 2:
            raise ValueError(
                f"centroids must be (k, days), got {self.centroids.shape}")

    @classmethod
    def from_result(cls, result) -> "ClusterRouter":
        """Router for an ``FLResult`` (clustered or not)."""
        return cls(getattr(result, "cluster_centroids", None))

    @property
    def enabled(self) -> bool:
        return self.centroids is not None

    @property
    def days(self) -> int:
        return 0 if self.centroids is None else self.centroids.shape[1]

    def route(self, history: np.ndarray) -> int:
        """One consumer's raw watt-hour history -> model slot."""
        if not self.enabled:
            return GLOBAL_SLOT
        z = daily_summary_of(history, self.days)
        return int(clustering.assign(z[None, :], self.centroids)[0])

    def route_summaries(self, z: np.ndarray) -> np.ndarray:
        """Batch assignment for already-coarsened (n, days) summaries."""
        if not self.enabled:
            return np.full(len(z), GLOBAL_SLOT, np.int64)
        return clustering.assign(np.asarray(z, np.float64), self.centroids)
