"""Padded-bucket batching engine — the serving tier's hot path.

Forecast requests arrive one consumer at a time as RAW watt-hour windows;
the engine owns everything between that and the jit-compiled forward:

* **Coalescing**: requests queue per model slot (the router's cluster id)
  and are served in batches of at most ``max_batch``.
* **Power-of-two shape buckets**: each batch is zero-padded UP to the next
  power-of-two bucket in ``[min_bucket, max_batch]``, so a steady stream of
  ragged request counts presents XLA with a BOUNDED set of shapes
  (≤ log2(max_batch/min_bucket)+1 per weights kind) instead of one fresh
  compile per distinct count.  :meth:`ServingEngine.warmup` pre-compiles
  every bucket; after it, the steady state adds ZERO new jit-cache entries
  — enforced with the :func:`repro.analysis.recompile.count_recompiles`
  probe against :meth:`ServingEngine.jit_cache_size` (tests + bench).
* **Per-request normalization inside the engine**: callers send raw
  watt-hours plus (once per consumer) a raw history; the engine derives the
  consumer's min-max stats, normalizes INSIDE the jitted forward, and
  de-normalizes the forecast back to kWh — the jit boundary sees only
  fixed-shape f32 buffers, and callers never touch model space.
* **Buffer donation**: on accelerator backends the padded input buffers are
  donated to XLA (they are dead after the call), saving one device copy per
  batch.  CPU does not implement donation, so it is off there by default.
* **Hot-swap safety**: a flush snapshots its :class:`ModelHandle` ONCE and
  serves the whole batch from it; a registry publish lands at the next
  flush boundary, never mid-batch.  Model parameters are TRACED jit
  arguments, so a swap never recompiles.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forecaster
from repro.serving.registry import (GLOBAL_SLOT, ModelHandle, ModelRegistry,
                                    dequantize_params)

__all__ = ["ForecastRequest", "FlushStats", "EngineStats", "ServingEngine",
           "bucket_for", "bucket_ladder"]


def bucket_for(n: int, min_bucket: int, max_batch: int) -> int:
    """Power-of-two bucket for ``n`` requests, clamped to
    ``[min_bucket, max_batch]``.  ``n`` must fit one batch."""
    if n < 1 or n > max_batch:
        raise ValueError(f"n={n} outside [1, max_batch={max_batch}]")
    b = 1 << max(n - 1, 0).bit_length()
    return min(max(b, min_bucket), max_batch)


def bucket_ladder(min_bucket: int, max_batch: int) -> List[int]:
    """All bucket sizes the engine can emit: min_bucket, 2·min_bucket, …,
    max_batch."""
    out, b = [], min_bucket
    while b < max_batch:
        out.append(b)
        b *= 2
    return out + [max_batch]


@dataclasses.dataclass
class ForecastRequest:
    """One pending forecast; doubles as the caller's result ticket.

    ``window`` is the consumer's most recent ``lookback`` RAW watt-hour
    readings; ``result`` is the (horizon,) kWh forecast once flushed.
    """
    consumer_id: Any
    window: np.ndarray
    lo: float
    hi: float
    slot: Any
    result: Optional[np.ndarray] = None

    @property
    def done(self) -> bool:
        return self.result is not None


@dataclasses.dataclass(frozen=True)
class FlushStats:
    """One executed batch: who ran, how padded, and how long it took."""
    slot: Any
    n_requests: int                       # real rows
    bucket: int                           # padded shape actually executed
    wall_s: float                         # measured device time (blocked)
    generation: int                       # handle generation that served it
    weights: str                          # "fp32" | "int8"
    requests: Tuple[ForecastRequest, ...] = ()


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    flushes: int = 0
    busy_s: float = 0.0
    swaps_seen: int = 0                   # generation changes across flushes
    by_bucket: Dict[int, int] = dataclasses.field(default_factory=dict)

    def fill(self) -> float:
        """Mean batch occupancy: real rows / padded rows across flushes."""
        padded = sum(b * n for b, n in self.by_bucket.items())
        return self.requests / padded if padded else 0.0


# jit bodies are module-level so every engine shares one trace per
# (shape-bucket, cfg, weights) — engines only differ in donation policy
def _forecast_kwh(params, x, lo, hi, cfg):
    """(B, L) raw watt-hours + per-row (lo, hi) stats -> (B, H) kWh."""
    scale = jnp.maximum(hi - lo, 1e-9)
    xn = (x - lo) / scale
    pred = forecaster.forecast(params, xn[..., None], cfg)
    return pred * scale + lo


def _forecast_kwh_int8(qparams, x, lo, hi, cfg):
    # dequantize INSIDE the jit: the fp32 copy is an XLA temporary
    return _forecast_kwh(dequantize_params(qparams), x, lo, hi, cfg)


class ServingEngine:
    """Queue + bucketed-batch executor over a :class:`ModelRegistry`.

    ``router`` (a :class:`repro.serving.router.ClusterRouter`) maps a
    consumer's raw history to a model slot at first contact; without one
    (or without a history) everything runs on the global slot.  Consumer
    stats/slot assignments live in a bounded LRU (``consumer_cache``).

    ``auto_flush`` flushes a slot the moment its queue reaches
    ``max_batch``; replay harnesses that account queueing time themselves
    (``benchmarks/bench_serving.py``) turn it off and drive
    :meth:`flush` explicitly.
    """

    def __init__(self, registry: ModelRegistry, router=None, *,
                 max_batch: int = 256, min_bucket: int = 8,
                 auto_flush: bool = True, donate: Optional[bool] = None,
                 consumer_cache: int = 100_000):
        for name, v in (("max_batch", max_batch), ("min_bucket", min_bucket)):
            if v < 1 or v & (v - 1):
                raise ValueError(f"{name}={v} must be a power of two")
        if min_bucket > max_batch:
            raise ValueError(f"min_bucket={min_bucket} > max_batch={max_batch}")
        self.registry = registry
        self.router = router
        self.max_batch, self.min_bucket = int(max_batch), int(min_bucket)
        self.auto_flush = bool(auto_flush)
        self.stats = EngineStats()
        self._queues: Dict[Any, List[ForecastRequest]] = {}
        self._consumers: "OrderedDict[Any, Tuple[Any, float, float]]" = \
            OrderedDict()
        self._consumer_cache = int(consumer_cache)
        self._last_gen: Dict[Any, int] = {}
        if donate is None:                  # CPU has no donation support
            donate = jax.default_backend() != "cpu"
        kw: dict = dict(static_argnames=("cfg",))
        if donate:
            kw["donate_argnums"] = (1, 2, 3)      # x, lo, hi die with the call
        self._fp32 = jax.jit(_forecast_kwh, **kw)
        self._int8 = jax.jit(_forecast_kwh_int8, **kw)

    # -------------------------------------------------------------- probes
    def jit_cache_size(self) -> int:
        """Live jit-cache entries across both weight paths — the probe
        ``analysis.recompile.count_recompiles`` pins the zero-new-entries
        steady-state contract against."""
        return int(self._fp32._cache_size() + self._int8._cache_size())

    def pending(self, slot: Any = None) -> int:
        if slot is not None:
            return len(self._queues.get(slot, ()))
        return sum(len(q) for q in self._queues.values())

    def queued_slots(self) -> List[Any]:
        """Slots with at least one pending request (replay-harness hook)."""
        return [s for s, q in self._queues.items() if q]

    def oldest(self, slot: Any) -> Optional[ForecastRequest]:
        """Head of a slot's queue (None when empty) — what a deadline-based
        flush policy ages against."""
        q = self._queues.get(slot)
        return q[0] if q else None

    # -------------------------------------------------------------- intake
    def _resolve(self, consumer_id, window: np.ndarray,
                 history) -> Tuple[Any, float, float]:
        """(slot, lo, hi) for one consumer: cached after first contact.

        With a raw ``history`` the min-max stats come from the full history
        (matching training-side per-building normalization) and the router
        assigns the cluster slot from its privacy-coarsened daily summary.
        Without either, the request window's own min-max is the documented
        fallback — fine for flat consumers, coarse for peaky ones.
        """
        if consumer_id is not None and history is None:
            hit = self._consumers.get(consumer_id)
            if hit is not None:
                self._consumers.move_to_end(consumer_id)
                return hit
        if history is not None:
            h = np.asarray(history, np.float32).reshape(-1)
            lo, hi = float(h.min()), float(h.max())
            slot = (self.router.route(h)
                    if self.router is not None and self.router.enabled
                    else GLOBAL_SLOT)
        else:
            lo, hi = float(window.min()), float(window.max())
            slot = GLOBAL_SLOT
        entry = (slot, lo, hi)
        if consumer_id is not None and history is not None \
                and self._consumer_cache > 0:
            self._consumers[consumer_id] = entry
            while len(self._consumers) > self._consumer_cache:
                self._consumers.popitem(last=False)
        return entry

    def submit(self, consumer_id, window, history=None) -> ForecastRequest:
        """Enqueue one forecast request (raw watt-hours) and return its
        ticket.  Pass ``history`` on a consumer's first contact so routing
        and normalization use their real range; later requests hit the
        consumer cache."""
        w = np.asarray(window, np.float32).reshape(-1)
        slot, lo, hi = self._resolve(consumer_id, w, history)
        handle = self.registry.handle(slot)
        if w.shape[0] != handle.cfg.lookback:
            raise ValueError(
                f"window has {w.shape[0]} readings; slot {handle.slot!r} "
                f"model wants lookback={handle.cfg.lookback}")
        req = ForecastRequest(consumer_id, w, lo, hi, handle.slot)
        self._queues.setdefault(handle.slot, []).append(req)
        self.stats.requests += 1
        if self.auto_flush and len(self._queues[handle.slot]) >= self.max_batch:
            self.flush(handle.slot)
        return req

    # --------------------------------------------------------------- flush
    def flush(self, slot: Any = None) -> List[FlushStats]:
        """Serve queued requests — one slot, or every non-empty queue."""
        slots = ([slot] if slot is not None
                 else [s for s, q in self._queues.items() if q])
        out: List[FlushStats] = []
        for s in slots:
            out.extend(self._flush_slot(s))
        return out

    def _flush_slot(self, slot) -> List[FlushStats]:
        q = self._queues.get(slot)
        if not q:
            return []
        # ONE handle snapshot for everything this flush executes: a publish
        # that lands mid-flush is observed at the next flush boundary, so a
        # batch can never mix generations (hot-swap atomicity, pinned)
        handle = self.registry.handle(slot)
        last = self._last_gen.get(slot)
        if last is not None and handle.generation != last:
            self.stats.swaps_seen += 1
        # flcheck: disable=FLC008 (one int per routed slot; slots come from the registry's fixed cluster universe, not from request traffic)
        self._last_gen[slot] = handle.generation
        out = []
        while q:
            chunk, self._queues[slot] = q[:self.max_batch], q[self.max_batch:]
            q = self._queues[slot]
            out.append(self._run_batch(handle, chunk))
        return out

    def _run_batch(self, handle: ModelHandle,
                   chunk: List[ForecastRequest]) -> FlushStats:
        n = len(chunk)
        b = bucket_for(n, self.min_bucket, self.max_batch)
        L = handle.cfg.lookback
        x = np.zeros((b, L), np.float32)
        lo = np.zeros((b, 1), np.float32)
        hi = np.ones((b, 1), np.float32)      # pad rows: scale 1, masked off
        for j, r in enumerate(chunk):
            x[j] = r.window
            lo[j, 0] = r.lo
            hi[j, 0] = r.hi
        fn = self._int8 if handle.weights == "int8" else self._fp32
        t0 = time.perf_counter()
        pred = np.asarray(fn(handle.params, jnp.asarray(x), jnp.asarray(lo),
                             jnp.asarray(hi), handle.cfg))   # blocks
        dt = time.perf_counter() - t0
        for j, r in enumerate(chunk):
            r.result = pred[j]
        self.stats.flushes += 1
        self.stats.busy_s += dt
        self.stats.by_bucket[b] = self.stats.by_bucket.get(b, 0) + 1
        return FlushStats(handle.slot, n, b, dt, handle.generation,
                          handle.weights, tuple(chunk))

    # -------------------------------------------------------------- warmup
    def warmup(self, slots=None) -> int:
        """Compile every (bucket, cfg, weights) shape the registry can
        serve; afterwards the steady state adds zero jit-cache entries
        (hot-swaps included — parameters are traced arguments).  Returns
        the number of distinct programs compiled."""
        n = 0
        seen = set()
        for s in (self.registry.slots() if slots is None else slots):
            handle = self.registry.handle(s)
            sig = (handle.cfg, handle.weights)
            if sig in seen:
                continue
            seen.add(sig)
            fn = self._int8 if handle.weights == "int8" else self._fp32
            L = handle.cfg.lookback
            for b in bucket_ladder(self.min_bucket, self.max_batch):
                fn(handle.params, jnp.asarray(np.zeros((b, L), np.float32)),
                   jnp.asarray(np.zeros((b, 1), np.float32)),
                   jnp.asarray(np.ones((b, 1), np.float32)), handle.cfg)
                n += 1
        return n
