"""High-throughput forecast-serving tier (paper §5.4: the FL-trained global
model serves thousands of UNSEEN consumers with no client-side retraining).

Three pieces, composed by the drivers and ``benchmarks/bench_serving.py``:

* :class:`~repro.serving.engine.ServingEngine` — request coalescing into
  jit-compiled padded power-of-two shape buckets (zero steady-state
  recompiles, per-request normalization/denormalization inside the engine).
* :class:`~repro.serving.registry.ModelRegistry` — per-slot model handles
  with atomic hot-swap, int8 serving weights, and checkpoint polling so FL
  training runs publish new globals live.
* :class:`~repro.serving.router.ClusterRouter` — nearest-centroid cluster
  assignment for unseen consumers on privacy-coarsened daily summaries.

See ``docs/serving.md`` for the architecture and knob guide.
"""
from repro.serving.engine import (EngineStats, FlushStats, ForecastRequest,
                                  ServingEngine, bucket_for, bucket_ladder)
from repro.serving.registry import (GLOBAL_SLOT, ModelHandle, ModelRegistry,
                                    dequantize_params, quantize_params)
from repro.serving.router import ClusterRouter, daily_summary_of

__all__ = [
    "ServingEngine", "ForecastRequest", "FlushStats", "EngineStats",
    "bucket_for", "bucket_ladder",
    "ModelRegistry", "ModelHandle", "GLOBAL_SLOT",
    "quantize_params", "dequantize_params",
    "ClusterRouter", "daily_summary_of",
]
