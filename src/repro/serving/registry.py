"""Serving model registry: per-slot model handles with atomic hot-swap.

The serving tier's source of truth for "which parameters answer this
request".  A **slot** is any hashable routing key — the cluster ids the
:mod:`repro.serving.router` produces (``GLOBAL_SLOT = -1`` is the single
global model, matching the FL driver's cluster id for unclustered runs), or
richer keys like ``("CA", 2)`` for per-state deployments.  Each slot holds an
immutable :class:`ModelHandle`; :meth:`ModelRegistry.publish` builds the
replacement handle COMPLETELY (device transfer, int8 quantization) before the
swap, and the swap itself is one dict assignment under a lock — so a reader
either sees the old generation or the new one, never a half-built mix, and an
in-flight batch that snapshotted its handle finishes on the old parameters.

Generations are strictly monotone per slot: a stale publish (generation ≤
the live one) raises, or is skipped with ``if_newer=True`` — the polling
path, where several pollers may race on the same checkpoint glob.

**int8 serving weights** (``weights="int8"``) store each leaf as an int8
integer grid plus one fp32 scale — a 4× parameter-memory cut — using
EXACTLY the stochastic-rounding grid of the training-side uplink quantizer
(:class:`repro.core.transforms.StochasticQuantize`): per-leaf max-abs
scaling, ``floor(x/s + u)`` rounding.  ``dequantize_params(quantize_params
(p, key))`` is bit-identical to ``StochasticQuantize(8)(p, key)``, pinned by
``tests/test_serving.py``, and the fp32-vs-int8 serving MAPE delta is pinned
there too.

**FL rounds as publishers**: a training run with ``checkpoint_path`` becomes
a publisher — :meth:`ModelRegistry.poll_checkpoint` watches a checkpoint
glob via :func:`repro.checkpoint.latest` (metadata-only reads, no array
traffic) and republishes every per-cluster slot whose generation advanced.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs.base import ForecasterConfig
from repro.models import forecaster

__all__ = ["GLOBAL_SLOT", "ModelHandle", "ModelRegistry",
           "quantize_params", "dequantize_params"]

# the FL driver reports the unclustered run as cluster id -1; the serving
# tier reuses it as the fallback slot, so checkpoint polling needs no remap
GLOBAL_SLOT = -1

_WEIGHT_KINDS = ("fp32", "int8")


def _is_qleaf(node: Any) -> bool:
    return isinstance(node, dict) and set(node.keys()) == {"q", "scale"}


def quantize_params(params, key: jax.Array, bits: int = 8):
    """fp32 param pytree -> tree of ``{"q": int8, "scale": fp32}`` leaves.

    Same grid + stochastic rounding as the uplink quantizer
    (``transforms.StochasticQuantize``): per-leaf max-abs scale to the
    signed ``2^(bits-1)-1`` grid, unbiased ``floor(x/s + u)`` rounding,
    per-leaf keys split exactly as the transform stack splits them — so
    ``dequantize_params(quantize_params(p, key))`` reproduces
    ``StochasticQuantize(bits)(p, key)`` bit-for-bit (regression-pinned).
    Unlike the transform (which simulates the wire and returns floats),
    the integer grid is MATERIALIZED here: serving holds 1 byte/param.
    """
    levels = float(2 ** (bits - 1) - 1)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    out = []
    for x, k in zip(leaves, keys):
        x = jnp.asarray(x, jnp.float32)
        scale = jnp.max(jnp.abs(x)) / levels
        safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        u = jax.random.uniform(k, x.shape)
        q = jnp.clip(jnp.floor(x / safe + u), -levels, levels)
        out.append({"q": q.astype(jnp.int8), "scale": safe})
    return jax.tree.unflatten(treedef, out)


def dequantize_params(qparams):
    """int8 q-leaf tree -> fp32 param pytree (``q * scale`` per leaf).

    jit-safe: the serving engine calls this INSIDE its jitted forward, so
    the dequantized fp32 copy is an XLA temporary, never host memory.
    """
    return jax.tree.map(
        lambda n: n["q"].astype(jnp.float32) * n["scale"],
        qparams, is_leaf=_is_qleaf)


@dataclasses.dataclass(frozen=True)
class ModelHandle:
    """One immutable serving model: parameters + config + generation.

    Handles are what the batching engine snapshots at flush time — frozen,
    so a hot-swap can never mutate parameters under an in-flight batch.
    ``params`` is an fp32 pytree (``weights="fp32"``) or a q-leaf tree
    (``weights="int8"``, see :func:`quantize_params`).
    """
    slot: Any
    cfg: ForecasterConfig
    params: Any
    weights: str
    generation: int


class ModelRegistry:
    """Slot -> :class:`ModelHandle` map with atomic, monotone hot-swap."""

    def __init__(self):
        self._slots: Dict[Any, ModelHandle] = {}
        self._lock = threading.Lock()
        # per-glob watermark: poll_checkpoint re-reads arrays only when the
        # (metadata-only) generation probe says something advanced
        self._poll_gen: Dict[str, int] = {}

    # ------------------------------------------------------------ publish
    def publish(self, params, cfg: ForecasterConfig, *, slot: Any = GLOBAL_SLOT,
                generation: int = 0, weights: str = "fp32",
                key: Optional[jax.Array] = None,
                if_newer: bool = False) -> Optional[ModelHandle]:
        """Build a fresh handle and atomically swap it into ``slot``.

        The handle is built COMPLETELY before the swap (device transfer,
        int8 quantization), so readers never observe intermediate state;
        in-flight batches keep the handle they snapshotted.  Generations
        are strictly monotone per slot: a stale ``generation`` raises
        ``ValueError``, or returns ``None`` with ``if_newer=True`` (the
        poller idiom).  ``weights="int8"`` requires ``key`` (stochastic
        rounding; fold it from a config seed, never a literal).
        """
        if weights not in _WEIGHT_KINDS:
            raise ValueError(f"weights={weights!r}; pick from {_WEIGHT_KINDS}")
        if weights == "int8":
            if key is None:
                raise ValueError("int8 publish needs a PRNG key for "
                                 "stochastic rounding (derive from the "
                                 "config seed)")
            stored = quantize_params(params, key)
        else:
            stored = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                                  params)
        handle = ModelHandle(slot=slot, cfg=cfg, params=stored,
                             weights=weights, generation=int(generation))
        with self._lock:
            cur = self._slots.get(slot)
            if cur is not None and handle.generation <= cur.generation:
                if if_newer:
                    return None
                raise ValueError(
                    f"stale publish for slot {slot!r}: generation "
                    f"{handle.generation} <= live {cur.generation}")
            # flcheck: disable=FLC008 (slot universe = cluster ids from the FL config, fixed per deployment; hot-swap REPLACES handles, never adds keys past the cluster count)
            self._slots[slot] = handle
        return handle

    # ------------------------------------------------------------- lookup
    def handle(self, slot: Any = GLOBAL_SLOT) -> ModelHandle:
        """The live handle for ``slot``, falling back to ``GLOBAL_SLOT``
        when the slot has no model (e.g. clustering is on but this cluster
        was never published) — the router's documented fallback."""
        with self._lock:
            h = self._slots.get(slot)
            if h is None:
                h = self._slots.get(GLOBAL_SLOT)
        if h is None:
            raise KeyError(
                f"no model for slot {slot!r} and no {GLOBAL_SLOT} global "
                "fallback — publish one first")
        return h

    def slots(self) -> List[Any]:
        with self._lock:
            return sorted(self._slots, key=repr)

    def generation(self, slot: Any = GLOBAL_SLOT) -> int:
        """Live generation of ``slot`` (no fallback), -1 when empty."""
        with self._lock:
            h = self._slots.get(slot)
        return -1 if h is None else h.generation

    # ------------------------------------------------- checkpoint polling
    def poll_checkpoint(self, path_glob, cfg: ForecasterConfig, *,
                        weights: str = "fp32",
                        key: Optional[jax.Array] = None) -> List[ModelHandle]:
        """Publish new globals from the freshest checkpoint under a glob.

        ``repro.checkpoint.latest`` finds the highest-generation match with
        metadata-only reads; arrays are loaded only when that generation
        beats this registry's per-glob watermark.  FL-driver checkpoints
        publish every finished cluster (``done/<cid>/params``) plus the
        in-progress one (``cur/params`` under ``metadata["cluster"]``);
        a bare param-tree checkpoint publishes ``GLOBAL_SLOT``.  Returns
        the handles actually swapped in (stale slots are skipped).
        """
        found = checkpoint.latest(path_glob)
        if found is None:
            return []
        path, gen = found
        # watermark read under the lock: two concurrent pollers must not
        # both see a stale watermark and double-load the same arrays
        with self._lock:
            if gen <= self._poll_gen.get(str(path_glob), -1):
                return []
        flat, meta = checkpoint.load_arrays(path)
        meta = meta or {}
        template = forecaster.param_template(cfg)
        entries = [(int(cid), f"done/{cid}/params/")
                   for cid in meta.get("done", [])]
        if "cluster" in meta:
            entries.append((int(meta["cluster"]), "cur/params/"))
        if not entries:                     # plain params-tree checkpoint
            entries.append((GLOBAL_SLOT, ""))
        updated = []
        for slot, prefix in entries:
            try:
                params = checkpoint.unflatten_like(template, flat,
                                                   prefix=prefix)
            except KeyError:
                continue                    # slot absent from this snapshot
            # +1 keeps GLOBAL_SLOT=-1 and slot 0 on distinct key streams
            k = None if key is None else jax.random.fold_in(key, slot + 1)
            h = self.publish(params, cfg, slot=slot, generation=gen,
                             weights=weights, key=k, if_newer=True)
            if h is not None:
                updated.append(h)
        # watermark write back under the lock (NOT held across publish():
        # publish takes the same non-reentrant lock).  Worst case two racing
        # pollers both pass the read above and both publish — if_newer makes
        # the second a no-op, and max() keeps the watermark monotone.
        with self._lock:
            prev = self._poll_gen.get(str(path_glob), -1)
            # flcheck: disable=FLC008 (one watermark per polled glob pattern; the glob set is static config, not per-request traffic)
            self._poll_gen[str(path_glob)] = max(gen, prev)
        return updated
