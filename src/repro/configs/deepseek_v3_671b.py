"""DeepSeek-V3-671B — MLA + fine-grained MoE (1 shared + 256 routed, top-8) + MTP.

Source: [arXiv:2412.19437] (DeepSeek-V3 technical report). 61 layers, first 3
dense (d_ff=18432 per report; the assigned card's d_ff=2048 is the EXPERT width,
used for all routed/shared experts). MLA: q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128. MTP = one extra depth of multi-token prediction.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                  # the 3 dense layers
    vocab_size=129280,
    dense_layers=3,
    mtp=True,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, capacity_factor=1.25, group_size=512),
    source="arXiv:2412.19437",
)
