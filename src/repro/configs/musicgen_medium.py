"""MusicGen-medium — decoder-only LM over EnCodec tokens (4 codebooks).

Source: [arXiv:2306.05284] (MusicGen). 48L, d=1536, 24H MHA, vocab=2048 per
codebook, 4 codebooks with the delay interleaving pattern (handled in the data
pipeline stub). The EnCodec codec itself is a STUB; per-codebook embeddings are
summed at input and 4 per-codebook LM heads produce logits.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend=FrontendConfig(kind="audio", n_codebooks=4),
    source="arXiv:2306.05284",
)
