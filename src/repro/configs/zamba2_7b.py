"""Zamba2-7B — hybrid: Mamba2 backbone + shared attention block every 6 layers.

Source: [arXiv:2411.15242] (Zamba2). 81 Mamba2 layers, d=3584, ssm_state=64;
a single SHARED full attention+MLP block (32H MHA) is invoked periodically
(every 6 Mamba2 layers) — parameters are shared across invocations, as in the
paper. We fold the paper's per-invocation LoRA deltas into the shared block
(simplification recorded in DESIGN.md).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,                 # shared block MLP width
    vocab_size=32000,
    attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=2),
    source="arXiv:2411.15242",
)
