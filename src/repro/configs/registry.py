"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
