"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's own
forecasting models use ``ForecasterConfig``.  Configs are frozen dataclasses so
they can be used as static args to jit.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (GShard-style capacity routing)."""
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0          # DeepSeek-style always-on shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    group_size: int = 2048             # GShard dispatch group size (perf knob)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1                  # B/C projection groups


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block mix (arXiv:2405.04517)."""
    slstm_every: int = 8               # 7 mLSTM : 1 sLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3334
    mlstm_head_dim: int = 512          # qk head dim for matrix memory
    chunk_size: int = 256


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend (the one sanctioned carve-out).

    For VLM: ``input_specs`` provides pre-projector patch embeddings of shape
    (batch, n_media_tokens, embed_dim); the projector itself IS implemented.
    For audio: tokens come as (batch, n_codebooks, seq) EnCodec codes.
    """
    kind: str                          # "vlm" | "audio"
    embed_dim: int = 1024              # ViT/SigLIP output width (vlm)
    n_media_tokens: int = 1152         # anyres tiles x 576 patches (vlm, train_4k)
    n_codebooks: int = 4               # EnCodec codebooks (audio)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0            # 0 = full causal attention
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: Optional[FrontendConfig] = None
    dense_layers: int = 0              # DeepSeek: first-k layers are dense FFN
    attn_every: int = 0                # zamba2: shared attention block period
    mtp: bool = False                  # DeepSeek multi-token-prediction head
    source: str = ""                   # citation for the config numbers

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def uses_attention(self) -> bool:
        return self.arch_type not in ("ssm",) or self.attn_every > 0

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        if self.frontend is not None and self.frontend.kind == "audio":
            emb *= self.frontend.n_codebooks  # per-codebook embeddings + heads
        n = emb
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_attn = 0
        if self.mla is not None:
            m = self.mla
            per_attn = (d * m.q_lora_rank
                        + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_dim)
                        + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
        elif self.uses_attention:
            per_attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                        + self.n_heads * hd * d)
        per_dense_ff = 3 * d * self.d_ff if self.d_ff else 0
        per_moe_ff = 0
        if self.moe is not None:
            e = self.moe
            per_moe_ff = ((e.n_experts + e.n_shared_experts) * 3 * d * e.d_ff_expert
                          + d * e.n_experts)
        per_ssm = 0
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per_ssm = (d * (2 * d_in + 2 * s.n_groups * s.state_dim + nh)
                       + d_in * d + s.conv_width * (d_in + 2 * s.n_groups * s.state_dim))
        per_xlstm = 0
        if self.xlstm is not None:
            x = self.xlstm
            d_in_m = int(x.mlstm_proj_factor * d)
            per_xlstm = d * d_in_m * 2 + 3 * d_in_m * d_in_m // 4 + d_in_m * d  # approx
        # assemble per-layer
        n_layers = self.n_layers
        if self.arch_type == "moe":
            dense_l = self.dense_layers
            n += dense_l * (per_attn + per_dense_ff)
            n += (n_layers - dense_l) * (per_attn + per_moe_ff)
        elif self.arch_type == "ssm" and self.xlstm is not None:
            n_s = n_layers // self.xlstm.slstm_every
            n += (n_layers - n_s) * per_xlstm + n_s * per_xlstm  # same order
        elif self.arch_type in ("hybrid",):
            n += n_layers * per_ssm
            if self.attn_every:
                n += per_attn + per_dense_ff  # one shared block
        else:
            n += n_layers * (per_attn + per_dense_ff)
        return int(n)

    def active_params(self) -> int:
        """Active (per-token) parameters — MoE uses top_k + shared experts."""
        if self.moe is None:
            return self.num_params()
        e = self.moe
        full_moe = (e.n_experts + e.n_shared_experts) * 3 * self.d_model * e.d_ff_expert
        act_moe = (e.top_k + e.n_shared_experts) * 3 * self.d_model * e.d_ff_expert
        n_moe_layers = self.n_layers - self.dense_layers
        return int(self.num_params() - n_moe_layers * (full_moe - act_moe))

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        hd = max(32, d // n_heads)
        kv = max(1, min(self.n_kv_heads, n_heads,
                        max(1, n_heads * self.n_kv_heads // self.n_heads)))
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            dense_layers=min(self.dense_layers, 1),
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=128, group_size=64,
                n_shared_experts=min(self.moe.n_shared_experts, 1))
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=32,
                                            chunk_size=32)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2,
                                              mlstm_head_dim=64, chunk_size=32)
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, embed_dim=64,
                n_media_tokens=min(self.frontend.n_media_tokens, 16))
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ForecasterConfig:
    """The paper's RNN demand-forecasting model (§3.2)."""
    cell: str = "lstm"                 # "lstm" | "gru"
    input_dim: int = 1
    hidden_dim: int = 64
    n_layers: int = 1
    lookback: int = 8                  # 2 h of 15-min steps (§4.2)
    horizon: int = 4                   # 1 h ahead (§4.2)

    def num_params(self) -> int:
        h, i = self.hidden_dim, self.input_dim
        gates = 4 if self.cell == "lstm" else 3
        n = 0
        for l in range(self.n_layers):
            inp = i if l == 0 else h
            n += gates * h * (inp + h + 1)
        n += h * self.horizon + self.horizon
        return n


# ---------------------------------------------------------------------------
# Federated pipeline stage configs.
#
# One federated round is an explicit pipeline of five typed stages
#
#     select -> local-update -> transform(deltas) -> aggregate -> server-update
#
# and each stage is configured by its own frozen dataclass below.  The valid
# names for every pluggable stage live HERE (not in the implementing core
# module) so the ``FLConfig`` facade can validate eagerly at construction
# without importing ``repro.core`` (which imports this module); the core
# modules re-export them (``core/server_opt.py::SERVER_OPTS`` etc.).
# ---------------------------------------------------------------------------
SERVER_OPTS = ("fedavg", "fedavg_weighted", "fedprox", "fedadam", "fedyogi")
SAMPLING_STRATEGIES = ("uniform", "weighted", "round_robin")
AGGREGATORS = ("flat", "hierarchical")
LOSSES = ("mse", "ew_mse")
ASYNC_MODES = ("sync", "semi_sync")
STRAGGLER_DISTRIBUTIONS = ("deterministic", "lognormal", "heavy_tail")


def _check_choice(kind: str, value: str, valid: Tuple[str, ...]) -> None:
    if value not in valid:
        raise ValueError(f"unknown {kind} {value!r}; valid choices: "
                         f"{list(valid)}")


@dataclass(frozen=True)
class SamplingConfig:
    """Select stage: per-round client-selection scheme (``core/sampling.py``).

    ``seed`` parameterizes schedule-type samplers (round_robin's fixed
    ordering); rng-driven samplers draw from the per-call rng instead.
    """
    strategy: str = "uniform"          # uniform | weighted | round_robin
    seed: int = 0

    def __post_init__(self):
        _check_choice("sampling strategy", self.strategy, SAMPLING_STRATEGIES)


@dataclass(frozen=True)
class ClientOptConfig:
    """Local-update stage: E epochs of minibatch SGD (``core/client.py``)."""
    lr: float = 1e-2
    local_epochs: int = 1              # E
    batch_size: int = 64               # B
    loss: str = "ew_mse"               # "mse" | "ew_mse"
    beta: float = 2.0                  # EW-MSE beta (>1)
    prox_mu: float = 0.0               # FedProx proximal strength

    def __post_init__(self):
        _check_choice("loss", self.loss, LOSSES)


@dataclass(frozen=True)
class TransformConfig:
    """Transform stage: per-client delta transforms (``core/transforms.py``).

    Applied to each client's update ``w_i - w_global`` INSIDE the round body,
    before the aggregation collective, in the fixed order
    clip -> noise -> quantize.  All knobs default to off; the identity stack
    keeps the round bit-identical to the pre-transform engine.
    """
    clip_norm: float = 0.0             # C: per-client delta L2 bound (0 = off)
    noise_multiplier: float = 0.0      # Gaussian DP noise sigma/C (0 = off)
    quantize_bits: int = 0             # stochastic int quantize (0 = off)
    quantize_ring: bool = False        # shared-grid ring quantizer (the
    #                                  # secure-agg wire; forced on by masking)

    def __post_init__(self):
        if self.clip_norm < 0:
            raise ValueError(f"clip_norm must be >= 0, got {self.clip_norm}")
        if self.noise_multiplier < 0:
            raise ValueError("noise_multiplier must be >= 0, got "
                             f"{self.noise_multiplier}")
        if self.quantize_bits and not 2 <= self.quantize_bits <= 8:
            raise ValueError("quantize_bits must be 0 (off) or in [2, 8], "
                             f"got {self.quantize_bits}")
        if self.quantize_ring and not self.quantize_bits:
            raise ValueError("quantize_ring needs quantize_bits > 0 (the "
                             "ring IS the quantizer's integer grid)")

    @property
    def is_identity(self) -> bool:
        return (self.clip_norm == 0.0 and self.noise_multiplier == 0.0
                and self.quantize_bits == 0)


@dataclass(frozen=True)
class SecureAggConfig:
    """Secure-aggregation stage: pairwise masking (``core/secure_agg.py``).

    When ``enabled``, every client adds antisymmetric pairwise masks
    (``mask_ij = -mask_ji``, derived from the dispatch cohort's shared round
    key) to its WEIGHTED contribution before it leaves the device, so the
    honest-but-curious server sees per-client uploads whose masks cancel
    exactly in the aggregator sum.  The masks are full-strength on the
    uploaded quantity itself (never scaled by ``1/w_i``), so upload secrecy
    does not depend on the client's aggregation weight.  With the quantize
    stage on, masking runs in the quantizer's integer ring mod ``2^b``
    (uniform ring masks, exact wraparound cancellation, int``b``+scale
    wire); without it, ``mask_std`` is the Gaussian mask scale on the
    weighted float upload (see ``core/secure_agg.py`` and docs/privacy.md —
    ``mask_std`` is ignored in ring mode, where masks are uniform over the
    whole ring).  In semi-sync mode, enabling secure aggregation forces
    cohort-atomic folds (see :class:`AsyncConfig`).
    """
    enabled: bool = False
    mask_std: float = 1.0

    def __post_init__(self):
        if self.mask_std <= 0:
            raise ValueError(f"mask_std must be > 0, got {self.mask_std}")


@dataclass(frozen=True)
class PrivacyConfig:
    """(epsilon, delta) accounting for the DP transform stage
    (``core/privacy.py``).

    The accountant composes the per-round subsampled Gaussian mechanism
    (clip ``C`` + noise ``z*C`` from :class:`TransformConfig`, sampling rate
    ``m/N``) across rounds via RDP at integer orders and reports a running
    ``(epsilon, delta)``.  ``delta`` is the target failure probability;
    ``orders`` overrides the default integer RDP order grid (empty = the
    default ``core/privacy.py::DEFAULT_ORDERS``) — a library-level knob for
    direct ``privacy.make_accountant(tcfg, PrivacyConfig(...), q)`` users;
    the flat ``FLConfig`` facade surfaces only ``privacy_delta``.
    Accounting is only meaningful with BOTH clip and noise on — otherwise
    the accountant reports ``epsilon = inf`` (disabled).
    """
    delta: float = 1e-5
    orders: Tuple[int, ...] = ()

    def __post_init__(self):
        if not 0.0 < self.delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if any(o < 2 for o in self.orders):
            raise ValueError("RDP orders must be >= 2, got "
                             f"{self.orders}")


@dataclass(frozen=True)
class AggregationConfig:
    """Aggregate stage: cross-client reduction topology (``core/aggregation.py``).

    ``flat`` is the one-psum cloud aggregation; ``hierarchical`` is the
    two-level edge->region->cloud reduction over a 2-D (region, clients)
    mesh.  ``n_regions=0`` lets the mesh builder pick (see
    ``aggregation.make_hierarchical_mesh``).
    """
    kind: str = "flat"                 # flat | hierarchical
    n_regions: int = 0                 # hierarchical: # of region groups

    def __post_init__(self):
        _check_choice("aggregation", self.kind, AGGREGATORS)
        if self.n_regions < 0:
            raise ValueError(f"n_regions must be >= 0, got {self.n_regions}")


@dataclass(frozen=True)
class LatencyConfig:
    """Simulated per-client round-trip time model (``core/latency.py``).

    A selected client's time-to-server is

        mult * (compute_s_per_window_epoch * n_windows * E
                + payload_bytes / uplink_bytes_per_s)

    — compute proportional to its local work (windows x epochs, the paper's
    Pi-4B regime where training dominates), uplink proportional to the
    post-quantize payload size.  ``mult`` is the pluggable straggler draw:
    ``deterministic`` is always 1 (zero jitter), ``lognormal`` is
    ``exp(jitter * N(0, 1))``, ``heavy_tail`` is ``1 + jitter * Pareto(1.5)``
    (rare but extreme stalls).  ``jitter=0`` makes every distribution
    deterministic.  Draws are a pure function of (seed, round, slot), so a
    simulated schedule replays exactly.

    The default constants are calibrated against the paper's measured
    70-100 s Pi-4B rounds (§5.5); the term-by-term derivation lives in the
    ``core/latency.py`` module docstring (and README): one year of 15-min
    readings => ~26.3k train windows per client, so 3.2 ms/(window*epoch)
    puts a jitter-free E=1 round at ~84 s compute + ~0.6 s uplink — mid-band
    of the measurement.
    """
    distribution: str = "deterministic"  # deterministic | lognormal | heavy_tail
    compute_s_per_window_epoch: float = 3.2e-3  # Pi-4B local SGD cost per
    #                                  # window*epoch (see core/latency.py)
    uplink_bytes_per_s: float = 1e6            # edge uplink bandwidth
    jitter: float = 0.5                        # straggler spread (0 = none)

    def __post_init__(self):
        _check_choice("straggler distribution", self.distribution,
                      STRAGGLER_DISTRIBUTIONS)
        if self.compute_s_per_window_epoch <= 0:
            raise ValueError("compute_s_per_window_epoch must be > 0, got "
                             f"{self.compute_s_per_window_epoch}")
        if self.uplink_bytes_per_s <= 0:
            raise ValueError("uplink_bytes_per_s must be > 0, got "
                             f"{self.uplink_bytes_per_s}")
        if self.jitter < 0:
            raise ValueError(f"jitter must be >= 0, got {self.jitter}")


@dataclass(frozen=True)
class ChurnConfig:
    """Client-churn / failure injection for the simulated event clock
    (``core/latency.py`` draws, ``core/async_engine.py`` recovery).

    Real edge fleets lose clients mid-round (the paper's Pi cluster, §5.5;
    arXiv:2201.11248, arXiv:2404.03320) — this stage makes dispatched work
    able to *never arrive* and membership able to change across rounds,
    with every draw a pure function of ``(seed, round, slot)`` so a faulty
    schedule replays bit-exactly.

    ``dropout_prob``
        Per-dispatch probability a client fails MID-UPLOAD: its update gets
        an infinite finish time and the server only learns about it via the
        dispatch timeout.  Requires ``mode="semi_sync"`` — a synchronous
        round that waits for a vanished client would simply never end.
    ``absent_prob``
        Per-round probability a member is unavailable for selection (device
        off / left the fleet / rejoined later) — join/leave membership
        churn, applied before the select stage.  Valid in every mode.
    ``timeout_rounds``
        Dispatch timeout, in rounds: work still unarrived
        ``timeout_rounds`` rounds after its (re)dispatch is declared
        abandoned.  The server cannot distinguish a crashed client from an
        extreme straggler, so timeouts abandon both.
    ``max_retries``
        Re-dispatch attempts for abandoned non-cohort work (the client
        re-uploads its retained transformed delta, charged a fresh uplink
        latency draw; the retry can itself drop out).  Under cohort-atomic
        folds (secure aggregation) abandoned members are not retried —
        the surviving cohort re-keys instead (``core/secure_agg.py``).
    """
    dropout_prob: float = 0.0          # P(dispatched upload never arrives)
    absent_prob: float = 0.0           # P(member unavailable in a round)
    timeout_rounds: int = 2            # rounds before unarrived work is
    #                                  # declared abandoned
    max_retries: int = 1               # re-dispatches per abandoned update

    def __post_init__(self):
        if not 0.0 <= self.dropout_prob < 1.0:
            raise ValueError("dropout_prob must be in [0, 1), got "
                             f"{self.dropout_prob}")
        if not 0.0 <= self.absent_prob < 1.0:
            raise ValueError("absent_prob must be in [0, 1), got "
                             f"{self.absent_prob}")
        if self.timeout_rounds < 1:
            raise ValueError("timeout_rounds must be >= 1, got "
                             f"{self.timeout_rounds}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0, got "
                             f"{self.max_retries}")

    @property
    def faulty(self) -> bool:
        """True when dispatched work can fail to arrive (dropouts on) —
        the engine only runs timeout/recovery bookkeeping then, so
        churn-off runs stay bit-identical to the fault-free engine."""
        return self.dropout_prob > 0.0


@dataclass(frozen=True)
class AsyncConfig:
    """Round-pacing stage: synchronous vs semi-synchronous buffered rounds
    (``core/async_engine.py``).

    ``sync`` is the paper's Alg. 1 — the server waits for every selected
    client, so the slowest straggler gates the round.  ``semi_sync``
    over-selects ``m' = ceil(over_select * m)`` clients, flushes the
    aggregate as soon as the first ``buffer_k`` pending updates arrive
    (simulated event clock, :class:`LatencyConfig`), and folds late arrivals
    into later rounds with staleness-discounted weights
    ``w_i * (1 + tau_i)^(-staleness_alpha)`` (tau = rounds late).

    The flush threshold is either ABSOLUTE (``buffer_k``) or RELATIVE
    (``buffer_frac``: ``ceil(frac * this round's dispatch size)``, resolved
    per round).  Prefer the fraction when round sizes vary — per-cluster
    memberships or holdouts shrink the in-flight set, and an absolute
    ``buffer_k`` at or above it silently waits for every straggler.  With
    both at 0 the server waits for all dispatched (bit-identical to sync
    under zero-jitter latency); setting both raises.

    ``cohort_atomic`` makes folds atomic per DISPATCH cohort: a round's
    updates enter the fold only once EVERY member of that dispatch set has
    arrived, so a whole cohort folds late together (all with the same
    staleness tau) instead of trickling in per arrival.  This is the fold
    granularity secure aggregation requires — pairwise masks cancel only
    over a complete cohort — and is forced on automatically when
    :class:`SecureAggConfig` is enabled.
    """
    mode: str = "sync"                 # sync | semi_sync
    over_select: float = 1.0           # m' = ceil(over_select * m) >= m
    buffer_k: int = 0                  # absolute flush threshold (0 = off)
    buffer_frac: float = 0.0           # relative threshold (0 = off)
    staleness_alpha: float = 0.5       # weight discount exponent (0 = none)
    cohort_atomic: bool = False        # fold whole dispatch cohorts only
    latency: LatencyConfig = field(default_factory=LatencyConfig)

    def __post_init__(self):
        _check_choice("async mode", self.mode, ASYNC_MODES)
        if self.over_select < 1.0:
            raise ValueError("over_select must be >= 1 (m' >= m), got "
                             f"{self.over_select}")
        if self.buffer_k < 0:
            raise ValueError(f"buffer_k must be >= 0, got {self.buffer_k}")
        if not 0.0 <= self.buffer_frac <= 1.0:
            raise ValueError("buffer_frac must be in [0, 1], got "
                             f"{self.buffer_frac}")
        if self.buffer_k and self.buffer_frac:
            raise ValueError("set buffer_k OR buffer_frac, not both "
                             f"(got {self.buffer_k} and {self.buffer_frac})")
        if self.staleness_alpha < 0:
            raise ValueError("staleness_alpha must be >= 0, got "
                             f"{self.staleness_alpha}")


@dataclass(frozen=True)
class ServerOptConfig:
    """Server-update stage: optimizer on the pseudo-gradient
    ``w_global - w_agg`` (``core/server_opt.py``)."""
    name: str = "fedavg"               # fedavg | fedavg_weighted | fedprox
    #                                  # | fedadam | fedyogi
    lr: float = 1.0
    momentum: float = 0.0              # >0 turns fedavg* into FedAvgM
    beta1: float = 0.9                 # fedadam / fedyogi first moment
    beta2: float = 0.99                # fedadam / fedyogi second moment
    eps: float = 1e-3                  # fedadam / fedyogi adaptivity floor

    def __post_init__(self):
        _check_choice("server_opt", self.name, SERVER_OPTS)


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning schedule (paper Alg. 1 + §4): flat facade over the
    typed pipeline-stage configs.

    Construction is unchanged from the original flat config (every existing
    call site and default is preserved), but the engine consumes it through
    the typed views — ``.sampling_config``, ``.client_opt``, ``.transform``,
    ``.aggregation_config``, ``.server`` — one per pipeline stage
    (select -> local-update -> transform -> aggregate -> server-update).
    Validation is EAGER: a typo'd ``server_opt`` / ``sampling`` /
    ``aggregation`` or out-of-range transform knob raises ``ValueError`` at
    construction with the valid choices, instead of surfacing rounds-deep in
    training.  Defaults reproduce the paper exactly (uniform FedAvg, uniform
    sampling, identity transform, flat aggregation).
    """
    n_clients: int = 100               # N
    clients_per_round: int = 100       # M
    local_epochs: int = 1              # E
    batch_size: int = 64               # B
    rounds: int = 500                  # T
    lr: float = 1e-2
    loss: str = "ew_mse"               # "mse" | "ew_mse"
    beta: float = 2.0                  # EW-MSE beta (>1)
    n_clusters: int = 4                # K-means k (0 = no clustering)
    cluster_days: int = 273            # t_p: daily-average summary length
    seed: int = 0
    # ------------------------------------------------- round-engine knobs
    server_opt: str = "fedavg"         # fedavg | fedavg_weighted | fedprox
    #                                  # | fedadam | fedyogi
    server_lr: float = 1.0             # server step on the pseudo-gradient
    server_momentum: float = 0.0       # >0 turns fedavg* into FedAvgM
    server_beta1: float = 0.9          # fedadam / fedyogi first moment
    server_beta2: float = 0.99         # fedadam / fedyogi second moment
    server_eps: float = 1e-3           # fedadam / fedyogi adaptivity floor
    prox_mu: float = 0.0               # FedProx proximal strength (client side)
    sampling: str = "uniform"          # uniform | weighted | round_robin
    holdout_frac: float = 0.0          # fraction of clients held out of
    #                                  # training for unseen-client eval
    # --------------------------------------------- delta-transform stage
    dp_clip: float = 0.0               # per-client delta L2 clip C (0 = off)
    dp_noise: float = 0.0              # Gaussian noise multiplier (0 = off)
    quantize_bits: int = 0             # stochastic int quantize (0 = off)
    quantize_ring: bool = False        # shared-grid ring quantizer even
    #                                  # without masking (the clear
    #                                  # comparator of the secure-agg wire)
    # ------------------------------------------- secure-agg / DP accounting
    secure_agg: bool = False           # pairwise-masked uploads (masks cancel
    #                                  # in the aggregator sum)
    secure_mask_std: float = 1.0       # per-pair mask scale
    privacy_delta: float = 1e-5        # target delta for the (eps, delta)
    #                                  # accountant (needs dp_clip + dp_noise)
    # ------------------------------------------------- aggregation stage
    aggregation: str = "flat"          # flat | hierarchical
    n_regions: int = 0                 # hierarchical: # of regions (0 = auto)
    # ------------------------------------------------- round-pacing stage
    mode: str = "sync"                 # sync | semi_sync
    over_select: float = 1.0           # semi_sync: m' = ceil(over_select * m)
    buffer_k: int = 0                  # absolute flush threshold (0 = off)
    buffer_frac: float = 0.0           # relative flush threshold (0 = off;
    #                                  # both 0 = wait for all dispatched)
    staleness_alpha: float = 0.5       # late-update weight discount exponent
    cohort_atomic: bool = False        # fold whole dispatch cohorts only
    #                                  # (forced on by secure_agg)
    stragglers: str = "deterministic"  # latency distribution (see LatencyConfig)
    straggler_jitter: float = 0.5      # straggler spread (ignored when
    #                                  # stragglers="deterministic")
    # ------------------------------------------------- client-churn stage
    dropout_prob: float = 0.0          # P(dispatched upload never arrives);
    #                                  # semi_sync only (see ChurnConfig)
    absent_prob: float = 0.0           # P(member unavailable in a round)
    timeout_rounds: int = 2            # dispatch timeout (rounds) before
    #                                  # unarrived work is abandoned
    max_retries: int = 1               # re-dispatches per abandoned update

    def __post_init__(self):
        # materializing every typed stage view runs that stage's own
        # validation -> bad names/knobs fail here, at construction
        _ = (self.sampling_config, self.client_opt, self.transform,
             self.aggregation_config, self.server, self.async_config,
             self.secure, self.privacy, self.churn)
        if self.dropout_prob > 0.0 and self.mode != "semi_sync":
            raise ValueError(
                "dropout_prob > 0 requires mode='semi_sync': a synchronous "
                "round waits for every client, so a vanished upload would "
                "gate it forever (absent_prob works in any mode)")

    # ------------------------------------------------- typed stage views
    @property
    def sampling_config(self) -> SamplingConfig:
        return SamplingConfig(strategy=self.sampling, seed=self.seed)

    @property
    def client_opt(self) -> ClientOptConfig:
        return ClientOptConfig(lr=self.lr, local_epochs=self.local_epochs,
                               batch_size=self.batch_size, loss=self.loss,
                               beta=self.beta, prox_mu=self.prox_mu)

    @property
    def transform(self) -> TransformConfig:
        return TransformConfig(clip_norm=self.dp_clip,
                               noise_multiplier=self.dp_noise,
                               quantize_bits=self.quantize_bits,
                               quantize_ring=self.quantize_ring)

    @property
    def aggregation_config(self) -> AggregationConfig:
        return AggregationConfig(kind=self.aggregation,
                                 n_regions=self.n_regions)

    @property
    def async_config(self) -> AsyncConfig:
        # secure aggregation forces cohort-atomic folds: pairwise masks
        # cancel only over a complete dispatch cohort
        return AsyncConfig(mode=self.mode, over_select=self.over_select,
                           buffer_k=self.buffer_k,
                           buffer_frac=self.buffer_frac,
                           staleness_alpha=self.staleness_alpha,
                           cohort_atomic=self.cohort_atomic or
                           self.secure_agg,
                           latency=LatencyConfig(
                               distribution=self.stragglers,
                               jitter=self.straggler_jitter))

    @property
    def churn(self) -> ChurnConfig:
        return ChurnConfig(dropout_prob=self.dropout_prob,
                           absent_prob=self.absent_prob,
                           timeout_rounds=self.timeout_rounds,
                           max_retries=self.max_retries)

    @property
    def secure(self) -> SecureAggConfig:
        return SecureAggConfig(enabled=self.secure_agg,
                               mask_std=self.secure_mask_std)

    @property
    def privacy(self) -> PrivacyConfig:
        return PrivacyConfig(delta=self.privacy_delta)

    @property
    def server(self) -> ServerOptConfig:
        return ServerOptConfig(name=self.server_opt, lr=self.server_lr,
                               momentum=self.server_momentum,
                               beta1=self.server_beta1,
                               beta2=self.server_beta2, eps=self.server_eps)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
