"""xLSTM-1.3B — sLSTM + mLSTM blocks (xLSTM[7:1] mix).

Source: [arXiv:2405.04517] (xLSTM). 48 blocks, d=2048, 4 heads. d_ff=0: the
blocks carry their own up/down projections (proj_factor). The mLSTM uses the
parallel/chunkwise matrix-memory form; the sLSTM is a true recurrent scan —
the same cell family as the reproduced paper's forecaster, and it shares the
fused-cell Pallas kernel lineage.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, mlstm_proj_factor=2.0,
                      slstm_proj_factor=1.3334, mlstm_head_dim=512,
                      chunk_size=256),
    source="arXiv:2405.04517",
)
