"""DBRX-132B — fine-grained MoE: 16 experts, top-4, GQA kv=8.

Source: [hf:databricks/dbrx-base] config.json.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=0,                      # every FFN is MoE
    vocab_size=100352,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752,
                  n_shared_experts=0, capacity_factor=1.25, group_size=512),
    source="hf:databricks/dbrx-base",
)
