"""LLaVA-NeXT-34B — VLM: dense LM backbone + anyres-tiled vision frontend stub.

Source: [hf:llava-hf/llava-v1.6-mistral-7b-hf] (anyres tiling scheme); backbone
dims per the assigned 34B card (Yi-34B-like: 60L, d=7168, 56H GQA kv=8).
The ViT/SigLIP encoder is a STUB — ``input_specs`` supplies pre-projector patch
embeddings (embed_dim=1024); the multimodal projector (1024 -> d_model) is real.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    frontend=FrontendConfig(kind="vlm", embed_dim=1024, n_media_tokens=1152),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
