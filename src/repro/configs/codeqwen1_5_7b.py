"""CodeQwen1.5-7B — dense decoder, Qwen1.5 architecture (QKV bias, full MHA).

Source: [hf:Qwen/CodeQwen1.5-7B] model card / config.json.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B",
)
