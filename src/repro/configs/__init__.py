from repro.configs.base import (AggregationConfig, ClientOptConfig, FLConfig,
                                ForecasterConfig, FrontendConfig, InputShape,
                                INPUT_SHAPES, MLAConfig, ModelConfig,
                                MoEConfig, SamplingConfig, ServerOptConfig,
                                SHAPES_BY_NAME, SSMConfig, TransformConfig,
                                XLSTMConfig)
from repro.configs.registry import ARCH_IDS, all_configs, get_config

__all__ = ["AggregationConfig", "ClientOptConfig", "FLConfig",
           "ForecasterConfig", "FrontendConfig", "InputShape", "INPUT_SHAPES",
           "MLAConfig", "ModelConfig", "MoEConfig", "SamplingConfig",
           "ServerOptConfig", "SHAPES_BY_NAME", "SSMConfig",
           "TransformConfig", "XLSTMConfig", "ARCH_IDS", "all_configs",
           "get_config"]
