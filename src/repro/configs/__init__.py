from repro.configs.base import (FLConfig, ForecasterConfig, FrontendConfig,
                                InputShape, INPUT_SHAPES, MLAConfig,
                                ModelConfig, MoEConfig, SHAPES_BY_NAME,
                                SSMConfig, XLSTMConfig)
from repro.configs.registry import ARCH_IDS, all_configs, get_config

__all__ = ["FLConfig", "ForecasterConfig", "FrontendConfig", "InputShape",
           "INPUT_SHAPES", "MLAConfig", "ModelConfig", "MoEConfig",
           "SHAPES_BY_NAME", "SSMConfig", "XLSTMConfig", "ARCH_IDS",
           "all_configs", "get_config"]
