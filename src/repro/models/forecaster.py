"""The paper's demand forecasters (§3.2): stacked LSTM / GRU + linear head.

Univariate input: a look-back window of L normalized kWh readings, shape
(B, L, input_dim); output: (B, horizon) — multi-step direct forecast, matching
the paper's 8-step look-back / 4-step (1 h) horizon.

The recurrent cells are written so the per-step compute is one fused function
of ``(x_t, state, params)``; ``cell_impl="jnp"`` uses the pure-jnp path (the
oracle), ``cell_impl="pallas"`` routes through the fused Pallas TPU cell in
``repro.kernels`` (interpret mode on CPU).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ForecasterConfig
from repro.models.layers import dense_init


# ------------------------------------------------------------------ init
def init_forecaster(key, cfg: ForecasterConfig, dtype=jnp.float32) -> Dict:
    gates = 4 if cfg.cell == "lstm" else 3
    layers = []
    for l in range(cfg.n_layers):
        inp = cfg.input_dim if l == 0 else cfg.hidden_dim
        key, k1, k2 = jax.random.split(key, 3)
        layers.append({
            "wx": dense_init(k1, inp, gates * cfg.hidden_dim, dtype=dtype),
            "wh": dense_init(k2, cfg.hidden_dim, gates * cfg.hidden_dim,
                             scale=cfg.hidden_dim ** -0.5, dtype=dtype),
            "b": jnp.zeros((gates * cfg.hidden_dim,), dtype),
        })
    key, kh = jax.random.split(key)
    head = {"w": dense_init(kh, cfg.hidden_dim, cfg.horizon, dtype=dtype),
            "b": jnp.zeros((cfg.horizon,), dtype)}
    return {"layers": layers, "head": head}


def param_template(cfg: ForecasterConfig, dtype=jnp.float32) -> Dict:
    """Zero-valued tree with :func:`init_forecaster`'s exact structure.

    The shape/treedef oracle for structure-driven loads (e.g.
    ``checkpoint.unflatten_like`` in the serving registry) — no PRNG key
    needed, since only the skeleton matters.
    """
    gates = 4 if cfg.cell == "lstm" else 3
    layers = []
    for l in range(cfg.n_layers):
        inp = cfg.input_dim if l == 0 else cfg.hidden_dim
        layers.append({
            "wx": jnp.zeros((inp, gates * cfg.hidden_dim), dtype),
            "wh": jnp.zeros((cfg.hidden_dim, gates * cfg.hidden_dim), dtype),
            "b": jnp.zeros((gates * cfg.hidden_dim,), dtype),
        })
    head = {"w": jnp.zeros((cfg.hidden_dim, cfg.horizon), dtype),
            "b": jnp.zeros((cfg.horizon,), dtype)}
    return {"layers": layers, "head": head}


# ------------------------------------------------------------------ cells
def lstm_cell(x_t, h, c, p):
    """One LSTM step (paper §3.2.1). x_t: (B, in); h, c: (B, H)."""
    z = x_t @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c = f * c + i * g
    h = o * jnp.tanh(c)
    return h, c


def gru_cell(x_t, h, p):
    """One GRU step (paper §3.2.2). Gate layout: [z | r | h̃]."""
    H = h.shape[-1]
    zx = x_t @ p["wx"] + p["b"]
    zh = h @ p["wh"]
    z = jax.nn.sigmoid(zx[..., :H] + zh[..., :H])
    r = jax.nn.sigmoid(zx[..., H:2 * H] + zh[..., H:2 * H])
    h_tilde = jnp.tanh(zx[..., 2 * H:] + r * zh[..., 2 * H:])
    return z * h + (1.0 - z) * h_tilde


def _pallas_cells():
    from repro.kernels import ops as kops
    return kops.lstm_cell_fused, kops.gru_cell_fused


# ------------------------------------------------------------------ forward
@functools.partial(jax.jit, static_argnames=("cfg", "cell_impl"))
def forecast(params, x, cfg: ForecasterConfig, cell_impl: str = "jnp"):
    """x: (B, L, input_dim) -> (B, horizon)."""
    B = x.shape[0]
    H = cfg.hidden_dim
    if cell_impl == "pallas":
        lstm_step, gru_step = _pallas_cells()
    else:
        lstm_step, gru_step = lstm_cell, gru_cell

    h_seq = x
    for p in params["layers"]:
        if cfg.cell == "lstm":
            def step(carry, x_t, p=p):
                h, c = carry
                h, c = lstm_step(x_t, h, c, p)
                return (h, c), h
            init = (jnp.zeros((B, H), x.dtype), jnp.zeros((B, H), x.dtype))
        else:
            def step(carry, x_t, p=p):
                h = gru_step(x_t, carry[0], p)
                return (h, carry[1]), h
            init = (jnp.zeros((B, H), x.dtype), jnp.zeros((B, 0), x.dtype))
        (_, _), hs = jax.lax.scan(step, init, h_seq.swapaxes(0, 1))
        h_seq = hs.swapaxes(0, 1)                       # (B, L, H)
    h_last = h_seq[:, -1]
    return h_last @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, batch, cfg: ForecasterConfig, loss, cell_impl="jnp"):
    """batch: {"x": (B,L,1), "y": (B,horizon)} -> scalar loss."""
    pred = forecast(params, batch["x"], cfg, cell_impl)
    return loss(pred, batch["y"])
