"""Modality frontends — the sanctioned stub carve-out.

VLM (llava-next): the ViT/SigLIP encoder is a STUB; ``input_specs`` supplies
pre-encoder patch embeddings (B, n_media_tokens, embed_dim) as if produced by
the anyres tiling pipeline.  The multimodal PROJECTOR (2-layer MLP,
embed_dim → d_model) IS implemented — it is trained with the LM.

Audio (musicgen): the EnCodec codec is a STUB; tokens arrive as
(B, n_codebooks, S) code indices (delay pattern applied by the data pipeline).
Per-codebook embeddings (summed at input) and per-codebook LM heads ARE
implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding import constrain


# ------------------------------------------------------------------ VLM
def init_projector(key, cfg: ModelConfig, dtype=jnp.float32):
    f = cfg.frontend
    k1, k2 = jax.random.split(key)
    return {
        "proj_in": dense_init(k1, f.embed_dim, cfg.d_model, dtype=dtype),
        "proj_out": dense_init(k2, cfg.d_model, cfg.d_model,
                               scale=cfg.d_model ** -0.5, dtype=dtype),
    }


def project_media(params, media, dtype):
    """media: (B, n_media, embed_dim) -> (B, n_media, d_model)."""
    h = jnp.einsum("bme,ed->bmd", media.astype(dtype),
                   params["proj_in"].astype(dtype))
    h = jax.nn.gelu(h)
    return jnp.einsum("bmd,de->bme", h, params["proj_out"].astype(dtype))


# ------------------------------------------------------------------ audio
def init_codebook_embeddings(key, cfg: ModelConfig, dtype=jnp.float32):
    f = cfg.frontend
    k1, k2 = jax.random.split(key)
    emb = (jax.random.normal(
        k1, (f.n_codebooks, cfg.vocab_size, cfg.d_model), jnp.float32)
        * 0.02).astype(dtype)
    heads = dense_init(k2, cfg.d_model,
                       f.n_codebooks * cfg.vocab_size, dtype=dtype)
    return {"cb_embed": emb,
            "cb_heads": heads.reshape(cfg.d_model, f.n_codebooks,
                                      cfg.vocab_size)}


def embed_codes(params, codes, dtype):
    """codes: (B, K, S) -> summed embeddings (B, S, d)."""
    K = codes.shape[1]
    outs = [jnp.take(params["cb_embed"][k].astype(dtype), codes[:, k], axis=0)
            for k in range(K)]
    return sum(outs)


def codebook_logits(params, h):
    """h: (B, S, d) -> (B, K, S, V)."""
    logits = jnp.einsum("bsd,dkv->bksv", h, params["cb_heads"].astype(h.dtype))
    return constrain(logits, "batch", None, None, "act_vocab")
