"""Mixture-of-Experts FFN — GShard-style capacity-based dispatch/combine.

TPU-native design: tokens are flattened to (G, S_g, d) groups, each group
routes its tokens to E experts with per-expert capacity
C = ceil(cf · S_g · k / E).  Dispatch and combine are one-hot einsums — the
canonical GShard/Mesh-TF formulation that GSPMD turns into all-to-alls when
the expert axis is mesh-sharded.  Top-k routing with renormalized gates,
auxiliary load-balance loss (Switch/GShard style), optional DeepSeek-style
always-on shared experts.

Sharding: expert weights (E, d, ff) carry the expert axis on the ``model``
mesh axis (see sharding.rules); dispatched activations (G, E, C, d) are
constrained so E is on ``model`` — the G→E reshard is the all-to-all.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import dense_init
from repro.sharding import constrain


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d, e.n_experts, scale=d ** -0.5,
                             dtype=jnp.float32),         # router in fp32
        "moe_w_in": _expert_init(ks[1], e.n_experts, d, e.d_ff_expert, dtype),
        "moe_w_gate": _expert_init(ks[2], e.n_experts, d, e.d_ff_expert, dtype),
        "moe_w_out": _expert_init(ks[3], e.n_experts, e.d_ff_expert, d, dtype,
                                  scale=e.d_ff_expert ** -0.5),
    }
    if e.n_shared_experts:
        ff_sh = e.n_shared_experts * e.d_ff_expert
        p["shared_w_in"] = dense_init(ks[4], d, ff_sh, dtype=dtype)
        p["shared_w_gate"] = dense_init(ks[5], d, ff_sh, dtype=dtype)
        p["shared_w_out"] = dense_init(ks[6], ff_sh, d,
                                       scale=ff_sh ** -0.5, dtype=dtype)
    return p


def _expert_init(key, E, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (E, d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def _choose_group(tokens: int, target: int) -> int:
    """Largest divisor of ``tokens`` that is ≤ target (routing group size)."""
    for g in range(target, 0, -1):
        if tokens % g == 0:
            return g
    return 1


def capacity(cfg: MoEConfig, group_size: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * group_size * cfg.top_k
                      / cfg.n_experts))
    return max(c, cfg.top_k)


def _route(router_w, x32, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                   jnp.ndarray]:
    """x32: (G, S, d) fp32 -> (gates (G,S,k), experts (G,S,k), aux loss)."""
    logits = jnp.einsum("gsd,de->gse", x32, router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)     # (G,S,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # GShard aux loss: E * Σ_e (frac tokens to e) · (mean router prob e)
    E = cfg.n_experts
    top1 = jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return gates, experts, aux


def moe_ffn(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Capacity-dropped tokens contribute zero from the routed experts (the
    residual stream and shared experts still carry them) — GShard semantics.
    """
    e = cfg.moe
    B, S, d = x.shape
    tokens = B * S
    gsz = _choose_group(tokens, min(e.group_size, tokens))
    G = tokens // gsz
    xg = x.reshape(G, gsz, d)
    xg = constrain(xg, "moe_group", None, None)

    gates, experts, aux = _route(params["router"], xg.astype(jnp.float32), e)
    C = capacity(e, gsz)
    E = e.n_experts

    # position of each (token, k) in its expert's buffer
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.int32)         # (G,S,k,E)
    flat = onehot.reshape(G, gsz * e.top_k, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - 1                       # (G,S*k,E)
    pos = jnp.sum(flat * pos_in_e, axis=-1).reshape(G, gsz, e.top_k)
    keep = pos < C
    gates_k = gates * keep.astype(gates.dtype)

    # dispatch/combine masks, built per k-slot (Mesh-TF style) so the largest
    # intermediate is (G, S, E, C), never (G, S, k, E, C)
    disp = jnp.zeros((G, gsz, E, C), x.dtype)
    weights = jnp.zeros((G, gsz, E, C), x.dtype)
    for kk in range(e.top_k):
        oh = (jax.nn.one_hot(experts[..., kk], E, dtype=x.dtype)[..., None]
              * jax.nn.one_hot(pos[..., kk], C, dtype=x.dtype)[..., None, :]
              * keep[..., kk, None, None].astype(x.dtype))       # (G,S,E,C)
        disp = disp + oh
        weights = weights + oh * gates_k[..., kk, None, None].astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)                   # (G,E,C,d)
    xe = constrain(xe, "moe_batch", "act_experts", None, None)

    # expert FFN (SwiGLU), expert-parallel over the model axis
    w_in = params["moe_w_in"].astype(x.dtype)
    w_gate = params["moe_w_gate"].astype(x.dtype)
    w_out = params["moe_w_out"].astype(x.dtype)
    h = jnp.einsum("gecd,edf->gecf", xe, w_in)
    g = jnp.einsum("gecd,edf->gecf", xe, w_gate)
    h = h * jax.nn.silu(g)
    ye = jnp.einsum("gecf,efd->gecd", h, w_out)                   # (G,E,C,d)
    ye = constrain(ye, "moe_batch", "act_experts", None, None)

    # combine: gate-weighted scatter back to token order
    out = jnp.einsum("gsec,gecd->gsd", weights, ye)
    out = out.reshape(B, S, d)

    if e.n_shared_experts:
        h = jnp.einsum("bsd,df->bsf", x, params["shared_w_in"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, params["shared_w_gate"].astype(x.dtype))
        h = h * jax.nn.silu(g)
        h = constrain(h, "batch", None, "act_ff")
        out = out + jnp.einsum("bsf,fd->bsd", h,
                               params["shared_w_out"].astype(x.dtype))
    return out, aux * e.router_aux_weight
