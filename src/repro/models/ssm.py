"""Mamba2 (SSD — state-space duality) block, chunked-scan formulation.

Training/prefill uses the chunkwise algorithm (Dao & Gu 2024): within a chunk
of Q tokens the output is a masked quadratic form (MXU-friendly); across
chunks a single ``lax.scan`` carries the (nh, hd, ds) state.  Decode is the
plain single-step recurrence against a conv ring buffer + SSM state.

Layout: x (B, S, d) → in_proj → [z | xBC | dt]; depthwise causal conv over
xBC; heads nh = d_inner / head_dim; per-head scalar decay a_t = exp(-softplus
(A) · dt_t) (Mamba2's scalar-identity A).  Gated RMSNorm before out_proj.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import dense_init, rms_norm
from repro.sharding import constrain


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return s, d_in, nh


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    s, d_in, nh = _dims(cfg)
    d = cfg.d_model
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * s.n_groups * s.state_dim
                              + nh, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),          # A = -softplus? see below
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),   # softplus^-1(~0.12)
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[3], d_in, d, scale=d_in ** -0.5, dtype=dtype),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    s, d_in, nh = _dims(cfg)
    gdim = s.n_groups * s.state_dim
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * gdim]
    dt = zxbcdt[..., 2 * d_in + 2 * gdim:]
    return z, xBC, dt


def _conv(xBC, w, b):
    """Depthwise causal conv over sequence. xBC: (B,S,Cd); w: (K,Cd)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _conv_step(x_t, conv_state, w, b):
    """x_t: (B,Cd); conv_state: (B,K-1,Cd) most-recent-last."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)   # (B,K,Cd)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return jax.nn.silu(out), window[:, 1:]


def _heads(xBC, dt, params, cfg: ModelConfig):
    s, d_in, nh = _dims(cfg)
    gdim = s.n_groups * s.state_dim
    x = xBC[..., :d_in]
    Bm = xBC[..., d_in:d_in + gdim]
    Cm = xBC[..., d_in + gdim:]
    shp = x.shape[:-1]
    x = x.reshape(*shp, nh, s.head_dim)
    Bm = Bm.reshape(*shp, s.n_groups, s.state_dim)
    Cm = Cm.reshape(*shp, s.n_groups, s.state_dim)
    # broadcast groups over heads
    rep = nh // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=-2)
    Cm = jnp.repeat(Cm, rep, axis=-2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (...,nh)
    a = -jnp.exp(params["a_log"])                        # (nh,) negative decay
    decay = jnp.exp(a * dt)                              # (...,nh) in (0,1)
    return x, Bm, Cm, dt, decay


def ssm_forward(params, x, cfg: ModelConfig, *, state=None
                ) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence chunked SSD. x: (B, S, d) -> (B, S, d).

    Returns (out, final_state) — state = {"ssm": (B,nh,hd,ds), "conv": (B,K-1,Cd)}.
    """
    s, d_in, nh = _dims(cfg)
    B, S, _ = x.shape
    Q = min(s.chunk_size, S)
    pad = (-S) % Q
    nc = (S + pad) // Q

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"].astype(x.dtype))
    z, xBC_raw, dt_raw = _split_proj(zxbcdt, cfg)
    xBC = _conv(xBC_raw, params["conv_w"].astype(x.dtype), params["conv_b"]
                .astype(x.dtype))
    xh, Bm, Cm, dt, decay = _heads(xBC, dt_raw, params, cfg)
    xh = constrain(xh, "batch", None, "act_heads", None)
    if pad:
        # pad to a chunk multiple with IDENTITY steps: decay=1, contribution=0
        pz = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xh, Bm, Cm, dt = map(pz, (xh, Bm, Cm, dt))
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1.0)

    # chunk to (nc, B, Q, ...) and scan over chunks — bounds the quadratic
    # intra-chunk intermediate at one (B, Q, Q, nh) block at a time
    ch = lambda t: t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)
    xh_c, Bm_c, Cm_c, dt_c, decay_c = map(ch, (xh, Bm, Cm, dt, decay))
    xdt_c = xh_c * dt_c[..., None].astype(xh_c.dtype)    # fold dt into x

    init = (jnp.zeros((B, nh, s.head_dim, s.state_dim), jnp.float32)
            if state is None else state["ssm"])
    iq = jnp.arange(Q)
    causal = iq[:, None] >= iq[None, :]

    def scan_body(st, inp):
        xdt, Bc, Cc, dec = inp                           # (B,Q,...) one chunk
        logdec = jnp.log(jnp.maximum(dec, 1e-20))        # (B,Q,nh) fp32
        cum = jnp.cumsum(logdec, axis=1)                 # inclusive
        seg = cum[:, :, None, :] - cum[:, None, :, :]    # (B,Qi,Qj,nh)
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bqhn,bkhn->bqkh", Cc, Bc)       # (B,Qi,Qj,nh)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", cb * L.astype(cb.dtype), xdt)
        # inter-chunk: C_t · decay_from_chunk_start · st
        dfs = jnp.exp(cum)                               # (B,Q,nh)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp",
                             Cc * dfs[..., None].astype(Cc.dtype),
                             st.astype(Cc.dtype))
        # state update: st' = decay_whole · st + Σ_j decay_to_end_j · B_j x_j
        dte = jnp.exp(cum[:, -1:, :] - cum)              # (B,Q,nh)
        contrib = jnp.einsum("bqhn,bqhp->bhpn",
                             (Bc * dte[..., None].astype(Bc.dtype))
                             .astype(jnp.float32), xdt.astype(jnp.float32))
        st = st * jnp.exp(cum[:, -1, :])[..., None, None] + contrib
        return st, y_intra + y_inter

    final_state, y_c = jax.lax.scan(scan_body, init,
                                    (xdt_c, Bm_c, Cm_c, decay_c))
    y = y_c.swapaxes(0, 1).reshape(B, S + pad, nh, s.head_dim)[:, :S]
    y = y + xh[:, :S] * params["d_skip"][:, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(x.dtype))

    new_conv = jnp.swapaxes(xBC_raw[:, S - (s.conv_width - 1):], 0, 0)
    return out, {"ssm": final_state, "conv": new_conv}


def ssm_decode(params, x, state, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Single-token recurrence. x: (B, 1, d); state from ``init_ssm_state``."""
    s, d_in, nh = _dims(cfg)
    B = x.shape[0]
    zxbcdt = jnp.einsum("bd,dk->bk", x[:, 0], params["in_proj"].astype(x.dtype))
    z, xBC_raw, dt_raw = _split_proj(zxbcdt, cfg)
    xBC, new_conv = _conv_step(xBC_raw, state["conv"],
                               params["conv_w"].astype(x.dtype),
                               params["conv_b"].astype(x.dtype))
    xh, Bm, Cm, dt, decay = _heads(xBC, dt_raw, params, cfg)   # (B,nh,hd) etc.

    st = state["ssm"]                                    # (B,nh,hd,ds) fp32
    contrib = jnp.einsum("bhn,bhp->bhpn", Bm.astype(jnp.float32),
                         (xh * dt[..., None].astype(xh.dtype))
                         .astype(jnp.float32))
    st = st * decay[..., None, None] + contrib
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), st)
    y = y.astype(x.dtype) + xh * params["d_skip"][:, None].astype(x.dtype)
    y = y.reshape(B, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, params["out_proj"].astype(x.dtype))
    return out[:, None], {"ssm": st, "conv": new_conv}


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    s, d_in, nh = _dims(cfg)
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return {
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }
