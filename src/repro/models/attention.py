"""Attention: GQA (+QKV bias, qk-norm, RoPE, sliding window) and MLA.

Three entry points per variant:
  * ``init_*``            parameter init
  * ``*_forward``         full-sequence (train / prefill); optionally fills a cache
  * ``*_decode``          one-token step against a cache

Cache layout (GQA): ``{"k": (B, W, Hkv, hd), "v": ..., "pos_ids": (W,)}`` where
``W`` is the cache capacity (seq_len, or the sliding window).  ``pos_ids``
stores absolute positions (-1 = empty) so sliding-window decode masks correctly
after wraparound.  The cache's second axis is *sequence*-sharded on the mesh
(logical axis "cache_seq") so GQA archs with few KV heads still shard 16-way.

MLA (DeepSeek-V3): caches the compressed latent ``c_kv`` (+ shared ``k_rope``)
and uses the *absorbed* formulation for decode (q absorbed through W_uk, output
absorbed through W_uv), which is what makes 128-head MLA decode tractable.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding import constrain, constrain_heads

NEG_INF = -1e9


# ===================================================================== GQA
def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype=dtype),
        "wk": dense_init(ks[1], d, Hkv * hd, dtype=dtype),
        "wv": dense_init(ks[2], d, Hkv * hd, dtype=dtype),
        "wo": dense_init(ks[3], H * hd, d, scale=(H * hd) ** -0.5, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = constrain(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)),
                  "batch", None, "act_ff")
    k = constrain(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype)),
                  "batch", None, "act_ff")
    v = constrain(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype)),
                  "batch", None, "act_ff")
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,H,hd), k: (B,W,Hkv,hd) -> (B,S,H,W) with KV-head grouping."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bskgh,bwkh->bskgw", qg, k)
    return s.reshape(B, S, H, k.shape[1])


def _gqa_out(w, v):
    """w: (B,S,H,W), v: (B,W,Hkv,hd) -> (B,S,H,hd)."""
    B, S, H, W = w.shape
    Hkv = v.shape[2]
    G = H // Hkv
    wg = w.reshape(B, S, Hkv, G, W)
    o = jnp.einsum("bskgw,bwkh->bskgh", wg, v)
    return o.reshape(B, S, H, v.shape[-1])


Q_CHUNK = 512          # q-block size for the chunked (memory-bounded) path
CHUNK_THRESHOLD = 4096  # use chunked attention for sequences >= this

# route full-sequence attention through the Pallas flash kernel
# (repro.kernels.flash_attention).  On TPU this is the production path; on
# CPU it runs in interpret mode (slow -- tests only), so it defaults off.
USE_FLASH_KERNEL = bool(os.environ.get("REPRO_FLASH"))


def _causal_attend(q, k, v, scale, window: int, dtype):
    """Causal attention, q-chunked above CHUNK_THRESHOLD to bound the score
    materialization at (B, Q_CHUNK, H, S) instead of (B, S, H, S)."""
    B, S = q.shape[:2]
    if USE_FLASH_KERNEL and S % 128 == 0 and v.shape[-1] == q.shape[-1]:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, window=window,
                                    scale=scale)

    @jax.checkpoint
    def block(args):
        # checkpointed: the (B, qc, H, S) score/weight tensors are transient
        # in BOTH passes — backward recomputes them chunk by chunk instead of
        # stacking one copy per chunk in the lax.map residuals
        qb, off = args                                  # qb: (B, qc, H, hd)
        qc = qb.shape[1]
        s = _gqa_scores(qb, k) * scale                  # (B,qc,H,S)
        i = off + jnp.arange(qc)[:, None]
        j = jnp.arange(S)[None, :]
        mask = j <= i
        if window:
            mask &= j > i - window
        s = jnp.where(mask[:, None, :][None], s.astype(jnp.float32), NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(dtype)
        return constrain_heads(_gqa_out(w, v))          # (B,qc,H,hd)

    if S < CHUNK_THRESHOLD or S % Q_CHUNK:
        return block((q, 0))
    n = S // Q_CHUNK
    qb = q.reshape(B, n, Q_CHUNK, *q.shape[2:]).swapaxes(0, 1)
    offs = jnp.arange(n, dtype=jnp.int32) * Q_CHUNK
    ob = jax.lax.map(block, (qb, offs))                 # (n,B,qc,H,hd_v)
    return ob.swapaxes(0, 1).reshape(B, S, ob.shape[-2], ob.shape[-1])


def attention_forward(params, x, cfg: ModelConfig, *, cache=None,
                      window: int = 0):
    """Full-sequence causal attention. Fills ``cache`` in place-of (returns new)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = constrain_heads(q)
    k = constrain_heads(k)
    v = constrain_heads(v)

    o = _causal_attend(q, k, v, hd ** -0.5, window, x.dtype)
    o = constrain_heads(o)
    o = o.reshape(B, S, cfg.n_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(x.dtype))

    new_cache = None
    if cache is not None:
        W = cache["k"].shape[1]
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0))
        pos_ids = cache["pos_ids"]
        pos_ids = jax.lax.dynamic_update_slice(
            pos_ids, jnp.arange(S, dtype=pos_ids.dtype), (0,))
        new_cache = {"k": kc, "v": vc, "pos_ids": pos_ids}
    return out, new_cache


def attention_decode(params, x, cache, pos, cfg: ModelConfig, *, window: int = 0):
    """One-token decode. x: (B,1,d); pos: scalar int32 (tokens already cached)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)   # q:(B,1,H,hd) k:(B,1,Hkv,hd)

    W = cache["k"].shape[1]
    slot = (pos % W) if window else jnp.minimum(pos, W - 1)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    pos_ids = jax.lax.dynamic_update_slice(
        cache["pos_ids"], jnp.array([pos], cache["pos_ids"].dtype), (slot,))
    kc = constrain(kc, "batch", "cache_seq", None, None)
    vc = constrain(vc, "batch", "cache_seq", None, None)

    scores = _gqa_scores(q, kc) * (hd ** -0.5)          # (B,1,H,W)
    valid = (pos_ids >= 0) & (pos_ids <= pos)
    if window:
        valid &= pos_ids > pos - window
    scores = jnp.where(valid[None, None, None, :], scores.astype(jnp.float32),
                       NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = _gqa_out(w, vc).reshape(B, 1, cfg.n_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(x.dtype))
    return out, {"k": kc, "v": vc, "pos_ids": pos_ids}


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, hd), dtype),
        "pos_ids": jnp.full((capacity,), -1, jnp.int32),
    }


# ===================================================================== MLA
def init_mla(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_down": dense_init(ks[0], d, m.q_lora_rank, dtype=dtype),
        "wq_up": dense_init(ks[1], m.q_lora_rank,
                            H * (m.qk_nope_dim + m.qk_rope_dim), dtype=dtype),
        "wkv_down": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype=dtype),
        "wk_up": dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_dim, dtype=dtype),
        "wv_up": dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype=dtype),
        "wo": dense_init(ks[5], H * m.v_head_dim, d,
                         scale=(H * m.v_head_dim) ** -0.5, dtype=dtype),
        "q_ln": jnp.ones((m.q_lora_rank,), dtype),
        "kv_ln": jnp.ones((m.kv_lora_rank,), dtype),
    }


def _mla_q(params, x, m: MLAConfig, H, positions, eps):
    B, S, _ = x.shape
    cq = jnp.einsum("bsd,dr->bsr", x, params["wq_down"].astype(x.dtype))
    cq = rms_norm(cq, params["q_ln"], eps)
    q = jnp.einsum("bsr,rh->bsh", cq, params["wq_up"].astype(x.dtype))
    q = q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, 10000.0)
    return q_nope, q_rope


def _mla_ckv(params, x, m: MLAConfig, positions, eps):
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_down"].astype(x.dtype))
    c_kv, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c_kv = rms_norm(c_kv, params["kv_ln"], eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, 10000.0)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(params, x, cfg: ModelConfig, *, cache=None, window: int = 0):
    """Full-sequence MLA (non-absorbed: expand k/v, standard attention)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(params, x, m, H, positions, cfg.norm_eps)
    c_kv, k_rope = _mla_ckv(params, x, m, positions, cfg.norm_eps)

    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, params["wk_up"].astype(x.dtype))
    k_nope = k_nope.reshape(B, S, H, m.qk_nope_dim)
    v = jnp.einsum("bsr,rh->bsh", c_kv, params["wv_up"].astype(x.dtype))
    v = v.reshape(B, S, H, m.v_head_dim)
    q_nope = constrain(q_nope, "batch", None, "act_heads", None)

    # fold q_rope/k_rope into the head dim so the chunked GQA path applies
    q_all = jnp.concatenate(
        [q_nope, q_rope], axis=-1)                      # (B,S,H,nope+rope)
    k_all = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_dim))], axis=-1)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    o = _causal_attend(q_all, k_all, v, scale, window, x.dtype)
    o = o.reshape(B, S, H * m.v_head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(x.dtype))

    new_cache = None
    if cache is not None:
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0))
        pos_ids = jax.lax.dynamic_update_slice(
            cache["pos_ids"], jnp.arange(S, dtype=jnp.int32), (0,))
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c, "pos_ids": pos_ids}
    return out, new_cache


def mla_decode(params, x, cache, pos, cfg: ModelConfig, *, window: int = 0):
    """Absorbed one-token MLA decode against the latent cache."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(params, x, m, H, positions, cfg.norm_eps)  # (B,1,H,*)
    c_kv_new, k_rope_new = _mla_ckv(params, x, m, positions, cfg.norm_eps)

    W = cache["c_kv"].shape[1]
    slot = (pos % W) if window else jnp.minimum(pos, W - 1)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, slot, 0))
    pos_ids = jax.lax.dynamic_update_slice(
        cache["pos_ids"], jnp.array([pos], jnp.int32), (slot,))
    c_kv = constrain(c_kv, "batch", "cache_seq", None)
    k_rope = constrain(k_rope, "batch", "cache_seq", None)

    # absorb q through W_uk:  q_abs[b,h,r] = sum_c q_nope[b,h,c] * Wk_up[r, h, c]
    wk_up = params["wk_up"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_abs = jnp.einsum("bhc,rhc->bhr", q_nope[:, 0], wk_up)            # (B,H,r)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bhr,bwr->bhw", q_abs, c_kv)
              + jnp.einsum("bhc,bwc->bhw", q_rope[:, 0], k_rope)) * scale
    valid = (pos_ids >= 0) & (pos_ids <= pos)
    if window:
        valid &= pos_ids > pos - window
    scores = jnp.where(valid[None, None, :], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhw,bwr->bhr", w, c_kv)                        # (B,H,r)
    # absorb output through W_uv
    wv_up = params["wv_up"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv_up).reshape(B, 1, H * m.v_head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(x.dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope, "pos_ids": pos_ids}


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_dim), dtype),
        "pos_ids": jnp.full((capacity,), -1, jnp.int32),
    }
