"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, true recurrence) — the 7:1 mix of xlstm-1.3b.

mLSTM cell:   C_t = f_t C_{t-1} + i_t v_t k_tᵀ ;  n_t = f_t n_{t-1} + i_t k_t
              h_t = o_t ⊙ (q_tᵀC_t) / max(|q_t·n_t|, 1)
with f = σ(f̃) and i = exp(ĩ) (clamped; the full max-stabilizer of the paper
is used in the sLSTM and in mLSTM decode; the chunkwise-parallel train path
uses the clamped-exponent form — recorded in DESIGN.md).  Training/prefill
runs the chunkwise algorithm (same algebra as SSD + a normalizer row), decode
the plain recurrence.  This is the same cell family as the reproduced paper's
forecaster — the fused Pallas LSTM cell in ``repro.kernels`` is the TPU
realization of the recurrent path.

sLSTM cell (per head, block-diagonal recurrence):
  m_t = max(f̃ + m_{t-1}, ĩ);  c_t = e^{f̃+m_{t-1}-m_t} c + e^{ĩ-m_t} tanh(z̃)
  n_t likewise;  h_t = σ(õ) · c_t / n_t
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.sharding import constrain

ICLAMP = 8.0       # clamp on the exponential input gate pre-activation


def _mdims(cfg: ModelConfig):
    x = cfg.xlstm
    d_m = int(x.mlstm_proj_factor * cfg.d_model)
    nh = max(1, d_m // x.mlstm_head_dim)
    hd = d_m // nh
    return x, d_m, nh, hd


# ===================================================================== mLSTM
def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    x, d_m, nh, hd = _mdims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d, 2 * d_m, dtype=dtype),
        "wq": dense_init(ks[1], d_m, d_m, dtype=dtype),
        "wk": dense_init(ks[2], d_m, d_m, dtype=dtype),
        "wv": dense_init(ks[3], d_m, d_m, dtype=dtype),
        "w_gates": dense_init(ks[4], d_m, 2 * nh, dtype=jnp.float32),
        "b_gates": jnp.concatenate([jnp.zeros((nh,)),                 # ĩ
                                    jnp.full((nh,), 3.0)]).astype(jnp.float32),
        "ogate": dense_init(ks[5], d_m, d_m, dtype=dtype),
        "norm_w": jnp.ones((d_m,), dtype),
        "down_proj": dense_init(ks[6], d_m, d, scale=d_m ** -0.5, dtype=dtype),
    }


def _mlstm_qkvg(params, a, cfg):
    x, d_m, nh, hd = _mdims(cfg)
    shp = a.shape[:-1]
    q = (a @ params["wq"].astype(a.dtype)).reshape(*shp, nh, hd)
    k = (a @ params["wk"].astype(a.dtype)).reshape(*shp, nh, hd) * hd ** -0.5
    v = (a @ params["wv"].astype(a.dtype)).reshape(*shp, nh, hd)
    gates = a.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    i_raw = jnp.minimum(gates[..., :nh], ICLAMP)
    logf = -jax.nn.softplus(-gates[..., nh:])            # log σ(f̃)
    o = jax.nn.sigmoid(a @ params["ogate"].astype(a.dtype))
    return q, k, v, i_raw, logf, o


def mlstm_forward(params, xin, cfg: ModelConfig, *, state=None
                  ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (B, S, d). Chunkwise-parallel mLSTM."""
    x, d_m, nh, hd = _mdims(cfg)
    B, S, _ = xin.shape
    Q = min(x.chunk_size, S)
    pad = (-S) % Q
    nc = (S + pad) // Q

    u = jnp.einsum("bsd,dk->bsk", xin, params["up_proj"].astype(xin.dtype))
    a, b = u[..., :d_m], u[..., d_m:]
    q, k, v, i_raw, logf, o = _mlstm_qkvg(params, a, cfg)
    q = constrain(q, "batch", None, "act_heads", None)
    if pad:
        # identity padding: f=1 (logf=0), i=exp(-inf)=0 contribution
        pz = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = map(pz, (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e9)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))

    ch = lambda t: t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)
    q_c, k_c, v_c, i_c, lf_c = map(ch, (q, k, v, i_raw, logf))

    if state is None:
        C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
    else:
        C0, n0 = state["C"], state["n"]
    iq = jnp.arange(Q)
    causal = iq[:, None] >= iq[None, :]

    def body(carry, inp):
        C, n = carry
        qc, kc, vc, ic, lfc = inp                        # (B,Q,...)
        cum = jnp.cumsum(lfc, axis=1)                    # (B,Q,nh)
        seg = cum[:, :, None, :] - cum[:, None, :, :]
        w = jnp.where(causal[None, :, :, None],
                      jnp.exp(seg + ic[:, None, :, :]), 0.0)   # (B,Qi,Qj,nh)
        qk = jnp.einsum("bqhe,bjhe->bqjh", qc, kc)
        aw = (qk.astype(jnp.float32) * w)
        num_intra = jnp.einsum("bqjh,bjhe->bqhe", aw.astype(vc.dtype), vc)
        den_intra = jnp.sum(aw, axis=2)                  # Σ_j w_qj (q·k_j)
        dfs = jnp.exp(cum)                               # decay from chunk start
        qd = qc * dfs[..., None].astype(qc.dtype)
        num_inter = jnp.einsum("bqhe,bhef->bqhf", qd, C.astype(qc.dtype))
        den_inter = jnp.einsum("bqhe,bhe->bqh", qd, n.astype(qc.dtype))
        num = num_intra + num_inter
        den = den_intra.astype(jnp.float32) + den_inter
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None].astype(num.dtype)
        # state update
        dte = jnp.exp(cum[:, -1:, :] - cum + ic)         # (B,Q,nh)
        kw = kc * dte[..., None].astype(kc.dtype)
        C = C * jnp.exp(cum[:, -1])[..., None, None] + \
            jnp.einsum("bqhe,bqhf->bhef", kw, vc).astype(jnp.float32)
        n = n * jnp.exp(cum[:, -1])[..., None] + \
            jnp.sum(kw, axis=1).astype(jnp.float32)
        return (C, n), h

    (Cf, nf), h_c = jax.lax.scan(body, (C0, n0), (q_c, k_c, v_c, i_c, lf_c))
    h = h_c.swapaxes(0, 1).reshape(B, S + pad, d_m)[:, :S] * o
    h = rms_norm(h, params["norm_w"], cfg.norm_eps)
    h = h * jax.nn.silu(b)
    out = jnp.einsum("bsk,kd->bsd", h, params["down_proj"].astype(xin.dtype))
    return out, {"C": Cf, "n": nf}


def mlstm_decode(params, xin, state, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent mLSTM. xin: (B, 1, d)."""
    x, d_m, nh, hd = _mdims(cfg)
    B = xin.shape[0]
    u = jnp.einsum("bd,dk->bk", xin[:, 0], params["up_proj"].astype(xin.dtype))
    a, b = u[..., :d_m], u[..., d_m:]
    q, k, v, i_raw, logf, o = _mlstm_qkvg(params, a, cfg)  # (B,nh,hd) etc.
    i_w = jnp.exp(i_raw)                                 # (B,nh)
    f_w = jnp.exp(logf)
    C = state["C"] * f_w[..., None, None] + \
        jnp.einsum("bhe,bhf->bhef", (k * i_w[..., None].astype(k.dtype))
                   .astype(jnp.float32), v.astype(jnp.float32))
    n = state["n"] * f_w[..., None] + \
        (k * i_w[..., None].astype(k.dtype)).astype(jnp.float32)
    num = jnp.einsum("bhe,bhef->bhf", q.astype(jnp.float32), C)
    den = jnp.einsum("bhe,bhe->bh", q.astype(jnp.float32), n)
    h = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).astype(xin.dtype)
    h = h.reshape(B, d_m) * o
    h = rms_norm(h, params["norm_w"], cfg.norm_eps)
    h = h * jax.nn.silu(b)
    out = jnp.einsum("bk,kd->bd", h, params["down_proj"].astype(xin.dtype))
    return out[:, None], {"C": C, "n": n}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict:
    x, d_m, nh, hd = _mdims(cfg)
    return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32)}


# ===================================================================== sLSTM
def _sdims(cfg: ModelConfig):
    x = cfg.xlstm
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    d_ff = int(x.slstm_proj_factor * cfg.d_model)
    return x, nh, hd, d_ff


def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    x, nh, hd, d_ff = _sdims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "wx": dense_init(ks[0], d, 4 * d, dtype=dtype),
        # block-diagonal recurrence: per-head (hd, 4*hd)
        "r": (jax.random.normal(ks[1], (nh, hd, 4 * hd), jnp.float32)
              * hd ** -0.5).astype(dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm_w": jnp.ones((d,), dtype),
        "up_proj": dense_init(ks[2], d, 2 * d_ff, dtype=dtype),
        "down_proj": dense_init(ks[3], d_ff, d, scale=d_ff ** -0.5, dtype=dtype),
    }


def _slstm_step(params, x_t, state, cfg: ModelConfig):
    """x_t: (B, d) pre-computed Wx·x_t; state: dict of (B, nh, hd)."""
    x, nh, hd, _ = _sdims(cfg)
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhe,hek->bhk", h.astype(x_t.dtype),
                     params["r"].astype(x_t.dtype))      # (B,nh,4*hd)
    # wx output layout: [ĩ(d) | f̃(d) | z̃(d) | õ(d)]; regroup to per-head
    # (B, nh, 4*hd) matching the recurrent block-diagonal layout
    z = x_t.reshape(-1, 4, nh, hd).transpose(0, 2, 1, 3).reshape(-1, nh, 4 * hd)
    bias = params["b"].reshape(4, nh, hd).transpose(1, 0, 2).reshape(nh, 4 * hd)
    pre = (z + rec).astype(jnp.float32) + bias
    i_t = pre[..., :hd]
    f_t = pre[..., hd:2 * hd]
    z_t = jnp.tanh(pre[..., 2 * hd:3 * hd])
    o_t = jax.nn.sigmoid(pre[..., 3 * hd:])
    logf = -jax.nn.softplus(-f_t)                        # log σ(f̃)
    m_new = jnp.maximum(logf + m, i_t)
    i_w = jnp.exp(i_t - m_new)
    f_w = jnp.exp(logf + m - m_new)
    c = f_w * c + i_w * z_t
    n = f_w * n + i_w
    h_new = o_t * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h_new.astype(h.dtype), "m": m_new}


def slstm_forward(params, xin, cfg: ModelConfig, *, state=None
                  ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (B, S, d). True recurrent scan (not parallelizable)."""
    x, nh, hd, d_ff = _sdims(cfg)
    B, S, d = xin.shape
    if state is None:
        state = init_slstm_state(cfg, B)
    xw = jnp.einsum("bsd,dk->bsk", xin, params["wx"].astype(xin.dtype))

    def step(st, x_t):
        st = _slstm_step(params, x_t, st, cfg)
        return st, st["h"]

    state, hs = jax.lax.scan(step, state, xw.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(xin.dtype)
    h = rms_norm(h, params["norm_w"], cfg.norm_eps)
    u = jnp.einsum("bsd,dk->bsk", h, params["up_proj"].astype(xin.dtype))
    a, g = jnp.split(u, 2, axis=-1)
    out = jnp.einsum("bsk,kd->bsd", a * jax.nn.gelu(g),
                     params["down_proj"].astype(xin.dtype))
    return out, state


def slstm_decode(params, xin, state, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, Dict]:
    x, nh, hd, d_ff = _sdims(cfg)
    B = xin.shape[0]
    xw = jnp.einsum("bd,dk->bk", xin[:, 0], params["wx"].astype(xin.dtype))
    state = _slstm_step(params, xw, state, cfg)
    h = state["h"].reshape(B, -1).astype(xin.dtype)
    h = rms_norm(h, params["norm_w"], cfg.norm_eps)
    u = jnp.einsum("bd,dk->bk", h, params["up_proj"].astype(xin.dtype))
    a, g = jnp.split(u, 2, axis=-1)
    out = jnp.einsum("bk,kd->bd", a * jax.nn.gelu(g),
                     params["down_proj"].astype(xin.dtype))
    return out[:, None], state


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict:
    x, nh, hd, _ = _sdims(cfg)
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, nh, hd), -1e9, jnp.float32)}
