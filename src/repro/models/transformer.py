"""Unified decoder model over all assigned architecture families.

One parameter tree + three entry points per architecture:

  * ``forward``      — full-sequence (train / prefill), scanned over layers
  * ``decode_step``  — one token against per-layer caches/states
  * ``init_cache``   — decode-state construction (KV cache / SSM / xLSTM)

Homogeneous layer stacks are STACKED (leading layer axis) and iterated with
``lax.scan`` + per-layer ``jax.checkpoint`` (remat) — compile time stays flat
in depth and activation memory is O(1) layers.  Heterogeneous stacks are
decomposed into scannable groups:

  dense/vlm/audio : one stack of [attn + MLP] blocks
  moe             : dense-FFN stack (first ``dense_layers``) + MoE stack
  hybrid (zamba2) : (groups × attn_every) Mamba2 stack scanned per group with
                    ONE shared attention+MLP block applied between groups +
                    a tail stack for the remainder
  ssm (xlstm)     : groups of (slstm_every−1) mLSTM + 1 sLSTM
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.losses import chunked_weighted_ce, weighted_ce
from repro.models import attention as attn
from repro.models import frontends, moe as moe_mod, ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (dense_init, embed, init_embedding, init_mlp,
                                 mlp, rms_norm)
from repro.sharding import constrain


# ===================================================================== init
def _stack_init(fn, key, n, *args, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args, **kw))(keys)


def _init_dense_block(key, cfg: ModelConfig, dtype, d_ff=None):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.ones((cfg.d_model,), dtype)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = attn.init_attention(k1, cfg, dtype)
    p["mlp"] = init_mlp(k2, cfg.d_model, d_ff or cfg.d_ff, dtype)
    return p


def _init_moe_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.ones((cfg.d_model,), dtype)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = attn.init_attention(k1, cfg, dtype)
    p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
    return p


def _init_mamba_block(key, cfg: ModelConfig, dtype):
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "ssm": ssm_mod.init_ssm(key, cfg, dtype)}


def _init_mlstm_block(key, cfg: ModelConfig, dtype):
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "inner": xlstm_mod.init_mlstm(key, cfg, dtype)}


def _init_slstm_block(key, cfg: ModelConfig, dtype):
    return {"ln1": jnp.ones((cfg.d_model,), dtype),
            "inner": xlstm_mod.init_slstm(key, cfg, dtype)}


def _zamba_split(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, tail) so n_layers = n_groups·attn_every + tail."""
    g = cfg.n_layers // cfg.attn_every
    return g, cfg.n_layers - g * cfg.attn_every


def _xlstm_groups(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, mlstm_per_group)."""
    per = cfg.xlstm.slstm_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1


def init_model(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if cfg.arch_type == "audio":
        p.update(frontends.init_codebook_embeddings(ks[0], cfg, dtype))
    else:
        p["embed_tokens"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                           dtype)
    if cfg.arch_type == "vlm":
        p["projector"] = frontends.init_projector(ks[1], cfg, dtype)

    if cfg.arch_type in ("dense", "vlm", "audio"):
        p["blocks"] = _stack_init(_init_dense_block, ks[2], cfg.n_layers,
                                  cfg, dtype)
    elif cfg.arch_type == "moe":
        if cfg.dense_layers:
            p["dense_blocks"] = _stack_init(_init_dense_block, ks[2],
                                            cfg.dense_layers, cfg, dtype)
        p["moe_blocks"] = _stack_init(_init_moe_block, ks[3],
                                      cfg.n_layers - cfg.dense_layers,
                                      cfg, dtype)
        if cfg.mtp:
            k_mtp1, k_mtp2 = jax.random.split(ks[6])
            p["mtp_proj"] = dense_init(k_mtp1, 2 * cfg.d_model, cfg.d_model,
                                       dtype=dtype)
            p["mtp_block"] = _init_dense_block(k_mtp2, cfg, dtype,
                                               d_ff=cfg.d_ff)
            p["mtp_ln"] = jnp.ones((cfg.d_model,), dtype)
    elif cfg.arch_type == "hybrid":
        g, tail = _zamba_split(cfg)
        blocks = _stack_init(_init_mamba_block, ks[2], cfg.n_layers, cfg, dtype)
        p["mamba_groups"] = jax.tree.map(
            lambda t: t[:g * cfg.attn_every].reshape(g, cfg.attn_every,
                                                     *t.shape[1:]), blocks)
        if tail:
            p["mamba_tail"] = jax.tree.map(lambda t: t[-tail:], blocks)
        p["shared_attn"] = _init_dense_block(ks[3], cfg, dtype)
    elif cfg.arch_type == "ssm":                          # xlstm
        g, per = _xlstm_groups(cfg)
        p["mlstm_groups"] = jax.tree.map(
            lambda t: t.reshape(g, per, *t.shape[1:]),
            _stack_init(_init_mlstm_block, ks[2], g * per, cfg, dtype))
        p["slstm_blocks"] = _stack_init(_init_slstm_block, ks[3], g,
                                        cfg, dtype)
    else:
        raise ValueError(cfg.arch_type)

    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.arch_type != "audio" and not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[4], cfg.d_model, cfg.vocab_size,
                                  scale=cfg.d_model ** -0.5, dtype=dtype)
    return p


# ===================================================================== blocks
def _dense_block_fwd(p, x, cfg: ModelConfig, *, cache=None, window=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_forward(p["attn"], h, cfg, cache=cache,
                                    window=window)
    else:
        a, cache = attn.attention_forward(p["attn"], h, cfg, cache=cache,
                                          window=window)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp(p["mlp"], h)
    x = constrain(x, "batch", None, "embed")
    return x, cache


def _dense_block_dec(p, x, cache, pos, cfg: ModelConfig, *, window=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_decode(p["attn"], h, cache, pos, cfg,
                                   window=window)
    else:
        a, cache = attn.attention_decode(p["attn"], h, cache, pos, cfg,
                                         window=window)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["mlp"], h), cache


def _moe_block_fwd(p, x, cfg: ModelConfig, *, cache=None, window=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_forward(p["attn"], h, cfg, cache=cache,
                                    window=window)
    else:
        a, cache = attn.attention_forward(p["attn"], h, cfg, cache=cache,
                                          window=window)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    ff, aux = moe_mod.moe_ffn(p["moe"], h, cfg)
    x = x + ff
    x = constrain(x, "batch", None, "embed")
    return x, cache, aux


def _moe_block_dec(p, x, cache, pos, cfg: ModelConfig, *, window=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_decode(p["attn"], h, cache, pos, cfg,
                                   window=window)
    else:
        a, cache = attn.attention_decode(p["attn"], h, cache, pos, cfg,
                                         window=window)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    ff, _ = moe_mod.moe_ffn(p["moe"], h, cfg)
    return x + ff, cache


# ===================================================================== embed
def _embed_input(params, batch, cfg: ModelConfig, dtype):
    """Returns (x (B,S,d), label_mask or None)."""
    if cfg.arch_type == "audio":
        x = frontends.embed_codes(params, batch["tokens"], dtype)
        return x, None
    x = embed(params["embed_tokens"], batch["tokens"], dtype)
    if cfg.arch_type == "vlm" and "media" in batch:
        # media patch embeddings are PREPENDED: seq = n_media + n_text.
        # The data pipeline supplies tokens of length (seq_len - n_media).
        m = frontends.project_media(params["projector"], batch["media"], dtype)
        n_media, n_text = m.shape[1], x.shape[1]
        x = jnp.concatenate([m, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((x.shape[0], n_media), bool),
             jnp.ones((x.shape[0], n_text), bool)], axis=1)
        return x, mask
    return x, None


def _lm_logits(params, h, cfg: ModelConfig):
    if cfg.arch_type == "audio":
        return frontends.codebook_logits(params, h)      # (B,K,S,V)
    w = (params["embed_tokens"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(h.dtype)
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    return constrain(logits, "batch", None, "act_vocab")


# ===================================================================== forward
def forward(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16,
            window: Optional[int] = None, caches=None, remat: bool = True):
    """Full-sequence pass. Returns (logits, aux_loss, new_caches).

    ``caches`` (optional) are per-layer decode caches to fill (prefill mode);
    pass ``init_cache(...)`` trees.  ``window`` overrides cfg.sliding_window.
    """
    window = cfg.sliding_window if window is None else window
    x, media_mask = _embed_input(params, batch, cfg, dtype)
    x = constrain(x, "batch", None, "embed")
    aux_total = jnp.zeros((), jnp.float32)
    fill = caches is not None

    def maybe_remat(f):
        return jax.checkpoint(f) if remat else f

    if cfg.arch_type in ("dense", "vlm", "audio"):
        def body(carry, layer):
            xc, _ = carry
            p, c = layer

            def blk(xc, p, c):
                return _dense_block_fwd(p, xc, cfg,
                                        cache=c if fill else None,
                                        window=window)
            xc, c = maybe_remat(blk)(xc, p, c)
            return (xc, jnp.zeros((), jnp.float32)), c
        cs = caches if fill else _dummy_caches(cfg, params["blocks"])
        (x, _), new_caches = jax.lax.scan(body, (x, aux_total),
                                          (params["blocks"], cs))
        new_caches = new_caches if fill else None

    elif cfg.arch_type == "moe":
        new_caches = {"dense": None, "moe": None}
        if cfg.dense_layers:
            def body_d(carry, layer):
                xc = carry
                p, c = layer

                def blk(xc, p, c):
                    return _dense_block_fwd(p, xc, cfg,
                                            cache=c if fill else None,
                                            window=window)
                xc, c = maybe_remat(blk)(xc, p, c)
                return xc, c
            cs = caches["dense"] if fill else _dummy_caches(
                cfg, params["dense_blocks"])
            x, nc = jax.lax.scan(body_d, x, (params["dense_blocks"], cs))
            new_caches["dense"] = nc if fill else None

        def body_m(carry, layer):
            xc, aux = carry
            p, c = layer

            def blk(xc, p, c):
                return _moe_block_fwd(p, xc, cfg, cache=c if fill else None,
                                      window=window)
            xc, c, a = maybe_remat(blk)(xc, p, c)
            return (xc, aux + a), c
        cs = caches["moe"] if fill else _dummy_caches(cfg, params["moe_blocks"])
        (x, aux_total), nc = jax.lax.scan(body_m, (x, aux_total),
                                          (params["moe_blocks"], cs))
        new_caches["moe"] = nc if fill else None
        if not fill:
            new_caches = None

    elif cfg.arch_type == "hybrid":
        g, tail = _zamba_split(cfg)

        def mamba_one(xc, p):
            def blk(xc, p):
                h = rms_norm(xc, p["ln1"], cfg.norm_eps)
                o, st = ssm_mod.ssm_forward(p["ssm"], h, cfg, state=None)
                return constrain(xc + o, "batch", None, "embed"), st
            return maybe_remat(blk)(xc, p)

        def group_body(carry, layer):
            xc = carry
            pg, sc = layer                               # (attn_every,) + cache
            xc, st = jax.lax.scan(mamba_one, xc, pg)

            def shared(xc, sc):
                return _dense_block_fwd(params["shared_attn"], xc, cfg,
                                        cache=sc if fill else None,
                                        window=window)
            xc, sc = maybe_remat(shared)(xc, sc)
            return xc, (st, sc)

        sc0 = (caches["shared"] if fill
               else _dummy_caches(cfg, params["mamba_groups"]))
        x, (group_states, shared_caches) = jax.lax.scan(
            group_body, x, (params["mamba_groups"], sc0))
        tail_states = None
        if tail:
            x, tail_states = jax.lax.scan(mamba_one, x, params["mamba_tail"])
        new_caches = ({"groups": group_states, "tail": tail_states,
                       "shared": shared_caches} if fill else None)

    elif cfg.arch_type == "ssm":                          # xlstm
        g, per = _xlstm_groups(cfg)
        B = x.shape[0]

        def mlstm_one(carry, p):
            xc = carry

            def blk(xc, p):
                h = rms_norm(xc, p["ln1"], cfg.norm_eps)
                o, st = xlstm_mod.mlstm_forward(p["inner"], h, cfg)
                return constrain(xc + o, "batch", None, "embed"), st
            xc, st = maybe_remat(blk)(xc, p)
            return xc, st

        def group_body(carry, layer):
            xc = carry
            pm, ps = layer
            xc, m_st = jax.lax.scan(mlstm_one, xc, pm)

            def sblk(xc, ps):
                h = rms_norm(xc, ps["ln1"], cfg.norm_eps)
                o, st = xlstm_mod.slstm_forward(ps["inner"], h, cfg)
                return constrain(xc + o, "batch", None, "embed"), st
            xc, s_st = maybe_remat(sblk)(xc, ps)
            return xc, (m_st, s_st)

        x, states = jax.lax.scan(group_body, x,
                                 (params["mlstm_groups"],
                                  params["slstm_blocks"]))
        new_caches = states if fill else None
    else:
        raise ValueError(cfg.arch_type)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    h = constrain(h, "batch", None, "embed")
    logits = _lm_logits(params, h, cfg)
    return logits, aux_total, (new_caches, h, media_mask)


def _dummy_caches(cfg, stacked_blocks):
    """Zero-size scan companion when no cache is being filled."""
    n = jax.tree.leaves(stacked_blocks)[0].shape[0]
    return jnp.zeros((n, 0), jnp.int32)


# ===================================================================== train
def make_train_step(cfg: ModelConfig, optimizer, *, beta: float = 1.0,
                    dtype=jnp.bfloat16, remat: bool = True,
                    microbatches: int = 1, accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch, lr) -> (params, opt_state,
    metrics).  ``beta`` is the EW loss exponent (paper's EW-MSE transferred to
    position-weighted CE; beta=1 == plain CE).

    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split on its leading axis and scanned — peak activation memory scales
    with the microbatch, not the global batch.  ``accum_dtype`` controls the
    gradient-accumulator precision: fp32 by default; bf16 halves optimizer-
    path memory for the 671B fit (precision trade recorded in DESIGN.md).
    """
    def loss_fn(params, batch):
        # the full-logits output of forward() is unused here (the chunked CE
        # recomputes per-chunk logits from h) — XLA dead-code-eliminates it
        _, aux, (_, h, media_mask) = forward(params, batch, cfg,
                                             dtype=dtype, remat=remat)
        if cfg.arch_type == "audio":
            K = cfg.frontend.n_codebooks
            lbl = batch["labels"]                        # (B,K,S)
            ce = sum(chunked_weighted_ce(h, params["cb_heads"][:, k, :],
                                         lbl[:, k], beta)
                     for k in range(K)) / K
        else:
            w_head = (params["embed_tokens"].T if cfg.tie_embeddings
                      else params["lm_head"])
            ce = chunked_weighted_ce(h, w_head, batch["labels"], beta,
                                     media_mask)
        loss = ce + aux
        if cfg.mtp:
            loss = loss + 0.3 * _mtp_loss(params, h, batch, cfg, beta)
        return loss, {"ce": ce, "aux": aux}

    def train_step(params, opt_state, batch, lr):
        if microbatches > 1:
            def split(t):
                m = t.reshape(microbatches, t.shape[0] // microbatches,
                              *t.shape[1:])
                # keep each microbatch batch-sharded (the raw reshape of a
                # data-sharded leading axis would force SPMD to replicate)
                return constrain(m, None, "batch", *((None,) * (t.ndim - 1)))
            mb = jax.tree.map(split, batch)

            def acc_body(gsum, mbatch):
                (l, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g)
                return gsum, (l, parts)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            gsum, (losses, partss) = jax.lax.scan(acc_body, g0, mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = jnp.mean(losses)
            parts = jax.tree.map(jnp.mean, partss)
        else:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              params, updates)
        return params, opt_state, {"loss": loss, **parts}

    return train_step


def _mtp_loss(params, h, batch, cfg: ModelConfig, beta: float):
    """DeepSeek-V3 multi-token prediction: one extra block predicts t+2.

    h'_t = W_proj [RMSNorm(h_t); RMSNorm(Emb(label_t))] → block → head.
    """
    lbl = batch["labels"]
    emb = embed(params["embed_tokens"], lbl, h.dtype)     # token t+1 stream
    emb = constrain(emb, "batch", None, "embed")
    cat = jnp.concatenate([rms_norm(h, params["mtp_ln"], cfg.norm_eps),
                           rms_norm(emb, params["mtp_ln"], cfg.norm_eps)], -1)
    x = jnp.einsum("bsk,kd->bsd", cat, params["mtp_proj"].astype(h.dtype))
    x = constrain(x, "batch", None, "embed")
    x, _ = _dense_block_fwd(params["mtp_block"], x, cfg)
    h2 = rms_norm(x, params["final_norm"], cfg.norm_eps)
    h2 = constrain(h2, "batch", None, "embed")
    # labels for t+2: shift labels left by one; mask the last position
    lbl2 = jnp.concatenate([lbl[:, 1:], lbl[:, -1:]], axis=1)
    mask = jnp.concatenate([jnp.ones_like(lbl[:, 1:], dtype=bool),
                            jnp.zeros_like(lbl[:, -1:], dtype=bool)], axis=1)
    w_head = (params["embed_tokens"].T if cfg.tie_embeddings
              else params["lm_head"])
    return chunked_weighted_ce(h2, w_head, lbl2, beta, mask)


# ===================================================================== decode
def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16):
    """Per-layer decode caches, stacked to match the layer-scan layout."""
    if cfg.arch_type in ("dense", "vlm", "audio"):
        one = (attn.init_mla_cache if cfg.mla is not None
               else attn.init_kv_cache)(cfg, batch, capacity, dtype)
        return _stack_tree(one, cfg.n_layers)
    if cfg.arch_type == "moe":
        one = (attn.init_mla_cache if cfg.mla is not None
               else attn.init_kv_cache)(cfg, batch, capacity, dtype)
        out = {"moe": _stack_tree(one, cfg.n_layers - cfg.dense_layers)}
        out["dense"] = (_stack_tree(one, cfg.dense_layers)
                        if cfg.dense_layers else None)
        return out
    if cfg.arch_type == "hybrid":
        g, tail = _zamba_split(cfg)
        st = ssm_mod.init_ssm_state(cfg, batch, dtype)
        # the SHARED attention block runs g times per token with different
        # inputs, so it needs one KV cache per invocation
        return {"groups": _stack_tree(_stack_tree(st, cfg.attn_every), g),
                "tail": _stack_tree(st, tail) if tail else None,
                "shared": _stack_tree(
                    attn.init_kv_cache(cfg, batch, capacity, dtype), g),
                }
    if cfg.arch_type == "ssm":
        g, per = _xlstm_groups(cfg)
        m = xlstm_mod.init_mlstm_state(cfg, batch)
        s = xlstm_mod.init_slstm_state(cfg, batch)
        return (_stack_tree(_stack_tree(m, per), g), _stack_tree(s, g))
    raise ValueError(cfg.arch_type)


def _stack_tree(tree, n):
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t, (n,) + t.shape).copy()
        if n else None, tree)


def decode_step(params, caches, batch, pos, cfg: ModelConfig, *,
                dtype=jnp.bfloat16, window: Optional[int] = None):
    """One-token decode. batch["tokens"]: (B,1) (audio: (B,K,1)).

    ``pos`` — number of tokens already in the cache (scalar int32).
    Returns (logits for the new token, new caches).
    """
    window = cfg.sliding_window if window is None else window
    x, _ = _embed_input(params, batch, cfg, dtype)

    if cfg.arch_type in ("dense", "vlm", "audio"):
        def body(xc, layer):
            p, c = layer
            xc, c = _dense_block_dec(p, xc, c, pos, cfg, window=window)
            return xc, c
        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))

    elif cfg.arch_type == "moe":
        new_caches = {"dense": None, "moe": None}
        if cfg.dense_layers:
            def body_d(xc, layer):
                p, c = layer
                xc, c = _dense_block_dec(p, xc, c, pos, cfg, window=window)
                return xc, c
            x, nc = jax.lax.scan(body_d, x,
                                 (params["dense_blocks"], caches["dense"]))
            new_caches["dense"] = nc

        def body_m(xc, layer):
            p, c = layer
            xc, c = _moe_block_dec(p, xc, c, pos, cfg, window=window)
            return xc, c
        x, nc = jax.lax.scan(body_m, x, (params["moe_blocks"], caches["moe"]))
        new_caches["moe"] = nc

    elif cfg.arch_type == "hybrid":
        def mamba_one(xc, layer):
            p, st = layer
            h = rms_norm(xc, p["ln1"], cfg.norm_eps)
            o, st = ssm_mod.ssm_decode(p["ssm"], h, st, cfg)
            return xc + o, st

        def group_body(xc, layer):
            pg, stg, sc = layer
            xc, st = jax.lax.scan(mamba_one, xc, (pg, stg))
            xc, sc = _dense_block_dec(params["shared_attn"], xc, sc, pos,
                                      cfg, window=window)
            return xc, (st, sc)

        x, (g_states, shared_caches) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], caches["groups"], caches["shared"]))
        t_states = None
        if caches["tail"] is not None:
            x, t_states = jax.lax.scan(mamba_one, x,
                                       (params["mamba_tail"], caches["tail"]))
        new_caches = {"groups": g_states, "tail": t_states,
                      "shared": shared_caches}

    elif cfg.arch_type == "ssm":
        m_caches, s_caches = caches

        def mlstm_one(xc, layer):
            p, st = layer
            h = rms_norm(xc, p["ln1"], cfg.norm_eps)
            o, st = xlstm_mod.mlstm_decode(p["inner"], h, st, cfg)
            return xc + o, st

        def group_body(xc, layer):
            (pm, ps), (ms, ss) = layer
            xc, mst = jax.lax.scan(mlstm_one, xc, (pm, ms))
            h = rms_norm(xc, ps["ln1"], cfg.norm_eps)
            o, sst = xlstm_mod.slstm_decode(ps["inner"], h, ss, cfg)
            return xc + o, (mst, sst)

        x, states = jax.lax.scan(
            group_body, x,
            ((params["mlstm_groups"], params["slstm_blocks"]),
             (m_caches, s_caches)))
        new_caches = states
    else:
        raise ValueError(cfg.arch_type)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, h, cfg)
    return logits, new_caches
