"""Shared building blocks: norms, RoPE, dense MLP, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def dense_init(key, in_dim, out_dim, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), dtype) * scale).astype(dtype)


def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP
def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype=dtype),
        "w_gate": dense_init(k2, d_model, d_ff, dtype=dtype),
        "w_out": dense_init(k3, d_ff, d_model, scale=d_ff ** -0.5, dtype=dtype),
    }


def mlp(params, x):
    """SwiGLU MLP. x: (..., d)."""
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
    h = h * jax.nn.silu(g)
    h = constrain(h, "batch", None, "act_ff")
    return jnp.einsum("...f,fd->...d", h, params["w_out"].astype(x.dtype))


# ------------------------------------------------------------------ embeddings
def init_embedding(key, vocab, d_model, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model), dtype) * 0.02).astype(dtype)


def embed(embed_tokens, tokens, dtype):
    return jnp.take(embed_tokens.astype(dtype), tokens, axis=0)
