"""Adam / AdamW with fp32 state regardless of param dtype."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer


def adam(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return (jax.tree.map(zeros, params), jax.tree.map(zeros, params),
                jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        m, v, t = state
        t = t + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, m, g32)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, g32)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(mm, vv, p):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        return jax.tree.map(upd, m, v, params), (m, v, t)

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return adam(b1, b2, eps, weight_decay)
