"""Adafactor-style factored second moment.

For the 671B fit on a 16 GB/chip v5e pod the optimizer state must be sub-
linear in parameters per matrix: the second moment of an (n, m) matrix is
stored as row/col factors (n,) + (m,) instead of (n, m), and there is no fp32
master copy (updates are applied in the param dtype).  Vectors fall back to a
full second moment.  First moment is optional (off by default, as Adafactor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.99) -> Optimizer:
    def init(params):
        def factor(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], jnp.float32),      # row factor
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return (jnp.zeros(p.shape, jnp.float32), None)
        return (jax.tree.map(factor, params,
                             is_leaf=lambda x: isinstance(x, jax.Array)),
                jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        factors, t = state
        t = t + 1

        def upd(g, f, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if p.ndim >= 2:
                vr, vc = f
                vr = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)
                                       [..., None], eps))
                u = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nf = (vr, vc)
            else:
                v, _ = f
                v = decay * v + (1 - decay) * g2
                u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                nf = (v, None)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), nf

        flat_g, tdef = jax.tree.flatten(grads)
        flat_f = tdef.flatten_up_to(factors)
        flat_p = tdef.flatten_up_to(params)
        outs = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
        updates = tdef.unflatten([o[0] for o in outs])
        new_f = tdef.unflatten([o[1] for o in outs])
        return updates, (new_f, t)

    return Optimizer(init, update)
