from repro.optim.sgd import sgd
from repro.optim.adam import adam, adamw
from repro.optim.factored import adafactor
from repro.optim.schedules import constant, warmup_cosine

__all__ = ["sgd", "adam", "adamw", "adafactor", "constant", "warmup_cosine"]
