"""SGD (+momentum) — the paper's client-side optimizer (Alg. 1)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable
    update: callable           # (grads, state, params, lr) -> (updates, state)


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return (jax.tree.map(jnp.zeros_like, params),)

    def update(grads, state, params, lr):
        del params
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), ()
        (m,) = state
        m = jax.tree.map(lambda mm, g: momentum * mm + g, m, grads)
        if nesterov:
            upd = jax.tree.map(lambda mm, g: momentum * mm + g, m, grads)
        else:
            upd = m
        return jax.tree.map(lambda u: -lr * u, upd), (m,)

    return Optimizer(init, update)
