"""(epsilon, delta) accounting for the federated DP pipeline
(``PrivacyConfig``; see docs/privacy.md).

Each federated round with the DP transform stack on releases, for every
selected client, a clipped delta (L2 sensitivity ``C = clip_norm``) plus
per-coordinate Gaussian noise ``N(0, (z*C)^2)`` (``z = noise_multiplier``).
From the honest-but-curious server's point of view this is one invocation
of the **subsampled Gaussian mechanism**: a client participates in a round
with probability ``q ~= m/N`` (the dispatch fraction) and, when selected,
its contribution is released through a Gaussian mechanism with noise
multiplier ``z``.  Composing ``T`` rounds is done in Renyi-DP space
(Mironov 2017; Mironov/Talwar/Zhang 2019 for the sampled Gaussian):

* per-round RDP at integer order ``a``:

      q = 1:  RDP(a) = a / (2 z^2)
      q < 1:  RDP(a) = (1/(a-1)) * log( sum_{k=0}^{a}
                  C(a,k) (1-q)^(a-k) q^k exp(k(k-1) / (2 z^2)) )

  (the exact binomial expansion for integer orders, evaluated in log space
  with ``lgamma`` so large orders cannot overflow);
* RDP composes ADDITIVELY across rounds — ``T`` rounds cost ``T * RDP(a)``;
* conversion to ``(epsilon, delta)`` uses the improved bound
  (Canonne-Kamath-Steinke 2020, as in Opacus/TF-Privacy):

      eps(a) = T*RDP(a) + log1p(-1/a) - (log(delta) + log(a)) / (a - 1)

  minimized over the order grid.

Honesty notes (also in docs/privacy.md):

* Accounting needs a bounded sensitivity AND noise: with ``clip_norm == 0``
  or ``noise_multiplier == 0`` the accountant is *disabled* and reports
  ``epsilon = inf`` rather than a vacuous number.
* Two accounting MODES.  ``per-client`` (the default) accounts the
  server's per-client view with multiplier ``z`` — each client's delta is
  individually noised, so the release of the whole round is a Gaussian
  mechanism of multiplier ``z`` per contribution.  ``central:secure-agg``
  (``secure_agg_accountant``; selected by the engine when pairwise masking
  is on) accounts the only value the masked protocol reveals — the SUM —
  on which the ``m`` independent per-client noises add in variance to an
  aggregate Gaussian of std ``z*C*sqrt(m)`` on sensitivity ``C``, i.e. an
  effective multiplier ``z_eff = z*sqrt(m)``: a strictly tighter epsilon at
  the same per-client noise.  The central mode is only sound when masking
  actually hides the individual uploads, so it is DISABLED (with the
  reason) when secure aggregation is off.
* Selection is fixed-size sampling without replacement; the bound assumes
  Poisson sampling at the same expected rate, the standard approximation in
  DP-FedAvg implementations.

The accountant is stepped once per FLUSH by the round engine (one dispatch
= one mechanism invocation; in semi-sync pacing each ``RoundEngine.step``
call dispatches one cohort and flushes once, so the composition count is
the number of dispatched rounds either way) and surfaced as
``FLResult.eps_history`` / ``FLResult.privacy``.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import PrivacyConfig, TransformConfig

# Integer RDP orders: dense where the subsampled-Gaussian optimum usually
# lands, sparse tail for tiny q / huge T.
DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 65)) + (96, 128, 256, 512)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def rdp_sampled_gaussian(q: float, noise_multiplier: float,
                         order: int) -> float:
    """Renyi DP of ONE subsampled Gaussian release at an integer order.

    ``q``: sampling rate in (0, 1]; ``noise_multiplier``: z = sigma / C;
    ``order``: integer Renyi order >= 2.  Evaluated with the exact integer-
    order binomial expansion in log space.
    """
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sampling rate q must be in (0, 1], got {q}")
    if noise_multiplier <= 0.0:
        return math.inf
    if order < 2 or order != int(order):
        raise ValueError(f"order must be an integer >= 2, got {order}")
    a, z = int(order), float(noise_multiplier)
    if q == 1.0:
        return a / (2.0 * z * z)
    log_terms = [
        _log_comb(a, k)
        + (a - k) * math.log1p(-q)
        + (k * math.log(q) if k else 0.0)
        + k * (k - 1) / (2.0 * z * z)
        for k in range(a + 1)
    ]
    m = max(log_terms)
    log_sum = m + math.log(sum(math.exp(t - m) for t in log_terms))
    return log_sum / (a - 1)


def eps_from_rdp(rdp: Sequence[float], orders: Sequence[int],
                 delta: float) -> float:
    """Best (smallest) epsilon over the order grid at target ``delta``,
    via the improved RDP -> (eps, delta) conversion (CKS 2020)."""
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    best = math.inf
    for r, a in zip(rdp, orders):
        if not math.isfinite(r):
            continue
        eps = (r + math.log1p(-1.0 / a)
               - (math.log(delta) + math.log(a)) / (a - 1))
        best = min(best, eps)
    return max(best, 0.0)


class PrivacyAccountant:
    """Running (epsilon, delta) over composed rounds of the subsampled
    Gaussian mechanism.

    Per-order per-round RDP is precomputed once; ``step`` is O(1) and
    ``epsilon`` is O(|orders|), so per-round surfacing costs nothing.
    ``active`` is False when the mechanism certifies nothing (no noise, or
    unbounded sensitivity) — then ``epsilon`` is ``inf``, ``step`` still
    counts rounds, and ``report`` says why.
    """

    def __init__(self, noise_multiplier: float, sample_rate: float,
                 delta: float = 1e-5,
                 orders: Sequence[int] = DEFAULT_ORDERS,
                 disabled_reason: Optional[str] = None,
                 mode: str = "per-client"):
        self.noise_multiplier = float(noise_multiplier)
        self.sample_rate = float(sample_rate)
        self.mode = mode
        self.delta = float(delta)
        self.orders = tuple(int(o) for o in orders)
        self.rounds = 0
        self.active = (disabled_reason is None and noise_multiplier > 0.0)
        self.disabled_reason = disabled_reason if not self.active else None
        if self.active:
            self._rdp_per_round = np.asarray(
                [rdp_sampled_gaussian(self.sample_rate,
                                      self.noise_multiplier, a)
                 for a in self.orders])
        else:
            if self.disabled_reason is None:
                self.disabled_reason = "noise_multiplier is 0"
            self._rdp_per_round = np.full(len(self.orders), math.inf)

    def step(self, n: int = 1) -> None:
        """Compose ``n`` further rounds (one per dispatch/flush)."""
        self.rounds += int(n)

    def state_dict(self) -> Dict[str, int]:
        """The accountant's only mutable state (JSON-serializable) — the
        composition count; everything else is rebuilt from the configs on
        resume (``checkpoint``/``fedavg.run_federated_training``)."""
        return {"rounds": int(self.rounds)}

    def load_state(self, state: Dict[str, int]) -> None:
        self.rounds = int(state["rounds"])

    @property
    def total_rdp(self) -> np.ndarray:
        """Composed RDP per order after ``rounds`` rounds."""
        return self.rounds * self._rdp_per_round

    def epsilon(self) -> float:
        """Current epsilon at the target delta: 0 before any round has
        composed, ``inf`` when the accountant is disabled."""
        if not self.active:
            return math.inf
        if self.rounds == 0:
            return 0.0
        return eps_from_rdp(self.total_rdp, self.orders, self.delta)

    def report(self) -> Dict[str, float]:
        """One-line-able summary for drivers / FLResult.privacy."""
        return {
            "enabled": self.active,
            "epsilon": self.epsilon(),
            "delta": self.delta,
            "rounds": self.rounds,
            "noise_multiplier": self.noise_multiplier,
            "sample_rate": self.sample_rate,
            "mode": self.mode,
            **({"disabled_reason": self.disabled_reason}
               if not self.active else {}),
        }


def make_accountant(tcfg: TransformConfig, pcfg: PrivacyConfig,
                    sample_rate: float) -> PrivacyAccountant:
    """Accountant for one training run: the PR 3 clip + noise knobs define
    the per-round mechanism, ``sample_rate ~= dispatch_m / n_members`` its
    subsampling.  Noise without a clip bound (or no noise at all) yields a
    DISABLED accountant that reports ``epsilon = inf`` with the reason,
    instead of certifying something the mechanism does not provide.
    """
    q = min(max(float(sample_rate), 0.0), 1.0)
    orders = pcfg.orders or DEFAULT_ORDERS
    if tcfg.noise_multiplier <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders,
                                 disabled_reason="dp_noise is 0 (no "
                                                 "Gaussian mechanism)")
    if tcfg.clip_norm <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders,
                                 disabled_reason="dp_clip is 0 (unbounded "
                                                 "sensitivity)")
    if q <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders,
                                 disabled_reason="sampling rate is 0")
    return PrivacyAccountant(tcfg.noise_multiplier, q, pcfg.delta, orders)


def secure_agg_accountant(tcfg: TransformConfig, pcfg: PrivacyConfig,
                          sample_rate: float, secure_enabled: bool,
                          cohort: int) -> PrivacyAccountant:
    """Central-DP accountant for the MASKED SUM (mode ``central:secure-agg``).

    With pairwise masking on, the server never observes an individual
    upload — only the aggregate, carrying the sum of ``cohort`` independent
    per-client Gaussian draws: noise std ``z*C*sqrt(cohort)`` against the
    one-client sensitivity ``C``, so the composed mechanism is a subsampled
    Gaussian with the effective multiplier ``z_eff = z*sqrt(cohort)`` —
    strictly tighter than the per-client ``z`` for any cohort > 1.  When
    masking is OFF the central view does not exist (the server sees every
    upload individually), so this returns a DISABLED accountant with the
    reason instead of a guarantee the protocol does not provide.
    """
    q = min(max(float(sample_rate), 0.0), 1.0)
    orders = pcfg.orders or DEFAULT_ORDERS
    mode = "central:secure-agg"
    if not secure_enabled:
        return PrivacyAccountant(
            0.0, q, pcfg.delta, orders, mode=mode,
            disabled_reason="secure aggregation is off (no masked sum to "
                            "account centrally; per-client accounting "
                            "applies instead)")
    if tcfg.noise_multiplier <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="dp_noise is 0 (no "
                                                 "Gaussian mechanism)")
    if tcfg.clip_norm <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="dp_clip is 0 (unbounded "
                                                 "sensitivity)")
    if q <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="sampling rate is 0")
    if cohort < 1:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="empty dispatch cohort")
    z_eff = tcfg.noise_multiplier * math.sqrt(cohort)
    return PrivacyAccountant(z_eff, q, pcfg.delta, orders, mode=mode)


def format_report(report: Dict[str, float]) -> str:
    """Human-readable accountant line for the drivers/bench."""
    mode = report.get("mode", "per-client")
    if not report["enabled"]:
        return (f"privacy [{mode}]: accounting disabled — "
                f"{report['disabled_reason']}"
                " (set --dp-clip and --dp-noise to certify a guarantee)")
    return (f"privacy [{mode}]: (eps={report['epsilon']:.2f}, "
            f"delta={report['delta']:.0e}) after {report['rounds']} rounds "
            f"(z_eff={report['noise_multiplier']:.3g}, "
            f"q={report['sample_rate']:.3g})")
