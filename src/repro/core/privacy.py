"""(epsilon, delta) accounting for the federated DP pipeline
(``PrivacyConfig``; see docs/privacy.md).

Each federated round with the DP transform stack on releases, for every
selected client, a clipped delta (L2 sensitivity ``C = clip_norm``) plus
per-coordinate Gaussian noise ``N(0, (z*C)^2)`` (``z = noise_multiplier``).
From the honest-but-curious server's point of view this is one invocation
of the **subsampled Gaussian mechanism**: a client participates in a round
with probability ``q ~= m/N`` (the dispatch fraction) and, when selected,
its contribution is released through a Gaussian mechanism with noise
multiplier ``z``.  Composing ``T`` rounds is done in Renyi-DP space
(Mironov 2017; Mironov/Talwar/Zhang 2019 for the sampled Gaussian):

* per-round RDP at integer order ``a``:

      q = 1:  RDP(a) = a / (2 z^2)
      q < 1:  RDP(a) = (1/(a-1)) * log( sum_{k=0}^{a}
                  C(a,k) (1-q)^(a-k) q^k exp(k(k-1) / (2 z^2)) )

  (the exact binomial expansion for integer orders, evaluated in log space
  with ``lgamma`` so large orders cannot overflow);
* RDP composes ADDITIVELY across rounds — ``T`` rounds cost ``T * RDP(a)``;
* conversion to ``(epsilon, delta)`` uses the improved bound
  (Canonne-Kamath-Steinke 2020, as in Opacus/TF-Privacy):

      eps(a) = T*RDP(a) + log1p(-1/a) - (log(delta) + log(a)) / (a - 1)

  minimized over the order grid.

Honesty notes (also in docs/privacy.md):

* Accounting needs a bounded sensitivity AND noise: with ``clip_norm == 0``
  or ``noise_multiplier == 0`` the accountant is *disabled* and reports
  ``epsilon = inf`` rather than a vacuous number.
* Two accounting MODES.  ``per-client`` (the default) accounts the
  server's per-client view with multiplier ``z`` — each client's delta is
  individually noised, so the release of the whole round is a Gaussian
  mechanism of multiplier ``z`` per contribution.  ``central:secure-agg``
  (``secure_agg_accountant``) accounts the only value the masked protocol
  reveals — the SUM — on which the ``m`` independent per-client noises add
  in variance to an aggregate Gaussian of std ``z*C*sqrt(m)`` on
  sensitivity ``C``, i.e. an effective multiplier ``z_eff = z*sqrt(m)``: a
  strictly tighter epsilon at the same per-client noise.
* The central mode is only sound when the protocol really reduces the
  server's view to the UNIFORM cohort sum (``central_gate_reason``):
  (a) RING masking — uniform integer masks over the full ring are
  information-theoretically hiding; float Gaussian masks of finite
  ``mask_std`` are not, so the float path keeps per-client accounting;
  (b) UNIFORM aggregation — under weighted aggregation client ``i``'s
  sensitivity scales with its weight share ``frac_i`` while the aggregate
  noise std is ``z*C*sqrt(sum frac^2)``, so a heavy client's effective
  multiplier approaches ``z``, not ``z*sqrt(m)`` (the exact weighted
  formula ``z_eff = z*sqrt(sum frac^2)/max frac`` is available via
  ``secure_agg_accountant(..., weights=...)`` for a FIXED weight vector);
  (c) the released sum must carry ALL ``m`` noise draws — under churn a
  Bonawitz re-key folds a survivor-only sum, so the engine reports every
  fold's surviving cohort (``observe_cohort``) and the accountant
  retroactively re-prices the whole run at the MINIMUM cohort observed
  (conservative: every released sum carried at least that much noise).
  When a gate fails the engine falls back to per-client accounting — a
  sound certificate, surfaced with ``central_fallback_reason`` — and the
  central accountant itself is DISABLED (with the reason) when secure
  aggregation is off.
* Selection is fixed-size sampling without replacement; the bound assumes
  Poisson sampling at the same expected rate, the standard approximation in
  DP-FedAvg implementations.

The accountant is stepped once per FLUSH by the round engine (one dispatch
= one mechanism invocation; in semi-sync pacing each ``RoundEngine.step``
call dispatches one cohort and flushes once, so the composition count is
the number of dispatched rounds either way) and surfaced as
``FLResult.eps_history`` / ``FLResult.privacy``.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import PrivacyConfig, TransformConfig

# Integer RDP orders: dense where the subsampled-Gaussian optimum usually
# lands, sparse tail for tiny q / huge T.
DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 65)) + (96, 128, 256, 512)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def rdp_sampled_gaussian(q: float, noise_multiplier: float,
                         order: int) -> float:
    """Renyi DP of ONE subsampled Gaussian release at an integer order.

    ``q``: sampling rate in (0, 1]; ``noise_multiplier``: z = sigma / C;
    ``order``: integer Renyi order >= 2.  Evaluated with the exact integer-
    order binomial expansion in log space.
    """
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sampling rate q must be in (0, 1], got {q}")
    if noise_multiplier <= 0.0:
        return math.inf
    if order < 2 or order != int(order):
        raise ValueError(f"order must be an integer >= 2, got {order}")
    a, z = int(order), float(noise_multiplier)
    if q == 1.0:
        return a / (2.0 * z * z)
    log_terms = [
        _log_comb(a, k)
        + (a - k) * math.log1p(-q)
        + (k * math.log(q) if k else 0.0)
        + k * (k - 1) / (2.0 * z * z)
        for k in range(a + 1)
    ]
    m = max(log_terms)
    log_sum = m + math.log(sum(math.exp(t - m) for t in log_terms))
    return log_sum / (a - 1)


def eps_from_rdp(rdp: Sequence[float], orders: Sequence[int],
                 delta: float) -> float:
    """Best (smallest) epsilon over the order grid at target ``delta``,
    via the improved RDP -> (eps, delta) conversion (CKS 2020)."""
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    best = math.inf
    for r, a in zip(rdp, orders):
        if not math.isfinite(r):
            continue
        eps = (r + math.log1p(-1.0 / a)
               - (math.log(delta) + math.log(a)) / (a - 1))
        best = min(best, eps)
    return max(best, 0.0)


class PrivacyAccountant:
    """Running (epsilon, delta) over composed rounds of the subsampled
    Gaussian mechanism.

    Per-order per-round RDP is precomputed once; ``step`` is O(1) and
    ``epsilon`` is O(|orders|), so per-round surfacing costs nothing.
    ``active`` is False when the mechanism certifies nothing (no noise, or
    unbounded sensitivity) — then ``epsilon`` is ``inf``, ``step`` still
    counts rounds, and ``report`` says why.
    """

    def __init__(self, noise_multiplier: float, sample_rate: float,
                 delta: float = 1e-5,
                 orders: Sequence[int] = DEFAULT_ORDERS,
                 disabled_reason: Optional[str] = None,
                 mode: str = "per-client",
                 base_noise_multiplier: Optional[float] = None,
                 cohort: Optional[int] = None):
        self.noise_multiplier = float(noise_multiplier)
        self.sample_rate = float(sample_rate)
        self.mode = mode
        self.delta = float(delta)
        self.orders = tuple(int(o) for o in orders)
        self.rounds = 0
        # central-mode cohort tracking: z_eff = base * sqrt(cohort), shrunk
        # by observe_cohort to the smallest cohort whose noise a released
        # sum actually carried (churn re-keys fold survivor-only sums)
        self.base_noise_multiplier = (None if base_noise_multiplier is None
                                      else float(base_noise_multiplier))
        self.cohort = None if cohort is None else int(cohort)
        self.central_fallback_reason: Optional[str] = None
        self.active = (disabled_reason is None and noise_multiplier > 0.0)
        self.disabled_reason = disabled_reason if not self.active else None
        if self.active:
            self._recompute()
        else:
            if self.disabled_reason is None:
                self.disabled_reason = "noise_multiplier is 0"
            self._rdp_per_round = np.full(len(self.orders), math.inf)

    def _recompute(self) -> None:
        self._rdp_per_round = np.asarray(
            [rdp_sampled_gaussian(self.sample_rate, self.noise_multiplier, a)
             for a in self.orders])

    def step(self, n: int = 1) -> None:
        """Compose ``n`` further rounds (one per dispatch/flush)."""
        self.rounds += int(n)

    def observe_cohort(self, survivors: int) -> None:
        """Central mode only: a released (or about-to-fold) sum carries the
        noise draws of only ``survivors`` cohort members — a short dispatch,
        or a churn re-key that subtracted dropped uploads.  The accountant
        keeps the MINIMUM cohort observed and re-prices EVERY composed
        round at ``z_eff = z * sqrt(min cohort)``: retroactively
        conservative, since each released sum carried at least that many
        draws.  No-op for per-client accountants (their multiplier never
        depended on the cohort) and for non-shrinking observations."""
        if self.base_noise_multiplier is None or self.cohort is None:
            return
        c = max(1, int(survivors))
        if c >= self.cohort or not self.active:
            return
        self.cohort = c
        self.noise_multiplier = self.base_noise_multiplier * math.sqrt(c)
        self._recompute()

    def state_dict(self) -> Dict[str, int]:
        """The accountant's mutable state (JSON-serializable) — the
        composition count, plus the min observed cohort in central mode;
        everything else is rebuilt from the configs on resume
        (``checkpoint``/``fedavg.run_federated_training``)."""
        state = {"rounds": int(self.rounds)}
        if self.cohort is not None:
            state["cohort"] = int(self.cohort)
        return state

    def load_state(self, state: Dict[str, int]) -> None:
        self.rounds = int(state["rounds"])
        if self.cohort is not None and "cohort" in state:
            c = int(state["cohort"])
            if c != self.cohort and self.active:
                self.cohort = c
                self.noise_multiplier = (self.base_noise_multiplier
                                         * math.sqrt(c))
                self._recompute()
            else:
                self.cohort = c

    @property
    def total_rdp(self) -> np.ndarray:
        """Composed RDP per order after ``rounds`` rounds."""
        return self.rounds * self._rdp_per_round

    def epsilon(self) -> float:
        """Current epsilon at the target delta: 0 before any round has
        composed, ``inf`` when the accountant is disabled."""
        if not self.active:
            return math.inf
        if self.rounds == 0:
            return 0.0
        return eps_from_rdp(self.total_rdp, self.orders, self.delta)

    def report(self) -> Dict[str, float]:
        """One-line-able summary for drivers / FLResult.privacy."""
        return {
            "enabled": self.active,
            "epsilon": self.epsilon(),
            "delta": self.delta,
            "rounds": self.rounds,
            "noise_multiplier": self.noise_multiplier,
            "sample_rate": self.sample_rate,
            "mode": self.mode,
            **({"cohort": self.cohort} if self.cohort is not None else {}),
            **({"central_fallback_reason": self.central_fallback_reason}
               if self.central_fallback_reason else {}),
            **({"disabled_reason": self.disabled_reason}
               if not self.active else {}),
        }


def make_accountant(tcfg: TransformConfig, pcfg: PrivacyConfig,
                    sample_rate: float) -> PrivacyAccountant:
    """Accountant for one training run: the PR 3 clip + noise knobs define
    the per-round mechanism, ``sample_rate ~= dispatch_m / n_members`` its
    subsampling.  Noise without a clip bound (or no noise at all) yields a
    DISABLED accountant that reports ``epsilon = inf`` with the reason,
    instead of certifying something the mechanism does not provide.
    """
    q = min(max(float(sample_rate), 0.0), 1.0)
    orders = pcfg.orders or DEFAULT_ORDERS
    if tcfg.noise_multiplier <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders,
                                 disabled_reason="dp_noise is 0 (no "
                                                 "Gaussian mechanism)")
    if tcfg.clip_norm <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders,
                                 disabled_reason="dp_clip is 0 (unbounded "
                                                 "sensitivity)")
    if q <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders,
                                 disabled_reason="sampling rate is 0")
    return PrivacyAccountant(tcfg.noise_multiplier, q, pcfg.delta, orders)


def central_gate_reason(ring: bool, weighted: bool) -> Optional[str]:
    """Why ``central:secure-agg`` accounting may NOT price the masked sum.

    The aggregate-Gaussian argument (``z_eff = z*sqrt(m)``) needs BOTH:
    (a) the server's view to be ONLY the cohort sum — true for RING
    masking (uniform integer masks over the full ring are information-
    theoretically hiding), NOT for float masking, whose finite-sigma
    Gaussian masks leak beyond the sum; and (b) UNIFORM aggregation — a
    weighted sum scales client ``i``'s sensitivity by ``frac_i`` while the
    aggregate noise std is ``z*C*sqrt(sum frac^2)``, so a heavy client's
    effective multiplier approaches the per-client ``z``, not
    ``z*sqrt(m)``.  Returns the blocking reason (the engine then falls
    back to sound per-client accounting), or None when central mode
    applies.
    """
    if not ring:
        return ("float masking (finite mask_std) is not information-"
                "theoretically hiding, so the server's view is more than "
                "the cohort sum; per-client accounting applies instead")
    if weighted:
        return ("weighted aggregation: a heavy client's effective noise "
                "multiplier approaches z, not z*sqrt(m); per-client "
                "accounting applies instead")
    return None


def secure_agg_accountant(tcfg: TransformConfig, pcfg: PrivacyConfig,
                          sample_rate: float, secure_enabled: bool,
                          cohort: int, *, ring: bool = True,
                          weighted: bool = False,
                          weights=None) -> PrivacyAccountant:
    """Central-DP accountant for the MASKED SUM (mode ``central:secure-agg``).

    With RING masking on and UNIFORM aggregation, the server never observes
    an individual upload — only the aggregate, carrying the sum of
    ``cohort`` independent per-client Gaussian draws: noise std
    ``z*C*sqrt(cohort)`` against the one-client sensitivity ``C``, so the
    composed mechanism is a subsampled Gaussian with the effective
    multiplier ``z_eff = z*sqrt(cohort)`` — strictly tighter than the
    per-client ``z`` for any cohort > 1.  The returned accountant tracks
    the cohort (``observe_cohort``): under churn the engine shrinks it to
    the smallest surviving fold, retroactively re-pricing the run.

    When the premise fails the accountant is DISABLED with the reason
    instead of certifying a guarantee the protocol does not provide:
    masking off (no masked sum exists), ``ring=False`` (float Gaussian
    masks are not information-theoretically hiding), or ``weighted=True``
    without a concrete weight vector.  For a FIXED, known weight vector
    pass ``weights``: the exact weighted-sum multiplier
    ``z_eff = z * sqrt(sum frac_i^2) / max_i frac_i`` applies (equal to
    ``z*sqrt(m)`` for uniform weights, approaching ``z`` as one client
    dominates) — with no cohort shrink tracking, since the formula is tied
    to that exact vector.
    """
    q = min(max(float(sample_rate), 0.0), 1.0)
    orders = pcfg.orders or DEFAULT_ORDERS
    mode = "central:secure-agg"
    if not secure_enabled:
        return PrivacyAccountant(
            0.0, q, pcfg.delta, orders, mode=mode,
            disabled_reason="secure aggregation is off (no masked sum to "
                            "account centrally; per-client accounting "
                            "applies instead)")
    gate = central_gate_reason(ring, weighted and weights is None)
    if gate is not None:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason=gate)
    if tcfg.noise_multiplier <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="dp_noise is 0 (no "
                                                 "Gaussian mechanism)")
    if tcfg.clip_norm <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="dp_clip is 0 (unbounded "
                                                 "sensitivity)")
    if q <= 0.0:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="sampling rate is 0")
    if weights is not None:
        w = np.asarray(weights, np.float64)
        w = w[w > 0]
        if w.size == 0:
            return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                     disabled_reason="empty dispatch cohort")
        frac = w / w.sum()
        z_eff = (tcfg.noise_multiplier
                 * math.sqrt(float(np.sum(frac * frac)))
                 / float(frac.max()))
        return PrivacyAccountant(z_eff, q, pcfg.delta, orders, mode=mode)
    if cohort < 1:
        return PrivacyAccountant(0.0, q, pcfg.delta, orders, mode=mode,
                                 disabled_reason="empty dispatch cohort")
    z_eff = tcfg.noise_multiplier * math.sqrt(cohort)
    return PrivacyAccountant(z_eff, q, pcfg.delta, orders, mode=mode,
                             base_noise_multiplier=tcfg.noise_multiplier,
                             cohort=cohort)


def format_report(report: Dict[str, float]) -> str:
    """Human-readable accountant line for the drivers/bench."""
    mode = report.get("mode", "per-client")
    if not report["enabled"]:
        return (f"privacy [{mode}]: accounting disabled — "
                f"{report['disabled_reason']}"
                " (set --dp-clip and --dp-noise to certify a guarantee)")
    cohort = (f", cohort={report['cohort']}" if "cohort" in report else "")
    return (f"privacy [{mode}]: (eps={report['epsilon']:.2f}, "
            f"delta={report['delta']:.0e}) after {report['rounds']} rounds "
            f"(z_eff={report['noise_multiplier']:.3g}, "
            f"q={report['sample_rate']:.3g}{cohort})")
