"""The paper's contribution: FedAvg with clustering + EW-MSE, and its
generalization to cross-pod local-SGD training."""
from repro.core import clustering, fedavg, local_sgd, losses, sarima
from repro.core.fedavg import (FLResult, evaluate_global, fedavg_aggregate,
                               fedavg_round, make_sharded_round,
                               run_federated_training)
from repro.core.local_sgd import (LocalSGDConfig, OuterState, fedavg_outer,
                                  init_outer_state, outer_step)
from repro.core.losses import (accuracy, ew_mse, make_loss, mape, mse,
                               per_horizon_accuracy, rmse, weighted_ce)

__all__ = ["clustering", "fedavg", "local_sgd", "losses", "sarima",
           "FLResult", "evaluate_global", "fedavg_aggregate", "fedavg_round",
           "make_sharded_round", "run_federated_training", "LocalSGDConfig",
           "OuterState", "fedavg_outer", "init_outer_state", "outer_step",
           "accuracy", "ew_mse", "make_loss", "mape", "mse",
           "per_horizon_accuracy", "rmse", "weighted_ce"]
