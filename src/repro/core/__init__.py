"""The paper's contribution: FedAvg with clustering + EW-MSE, generalized
into a composable federated pipeline (select -> local-update ->
transform(deltas) -> aggregate -> server-update) with pluggable samplers,
delta transforms (clip / DP noise / quantize), aggregation topologies
(flat / hierarchical edge->region->cloud) and server optimizers, plus its
extension to cross-pod local-SGD training."""
from repro.core import (aggregation, clustering, fedavg, local_sgd, losses,
                        sampling, sarima, server_opt, transforms)
from repro.core.aggregation import (Aggregator, FlatAggregator,
                                    HierarchicalAggregator, LocalAggregator,
                                    make_aggregator)
from repro.core.fedavg import (FLResult, RoundEngine, engine_round,
                               evaluate_global, evaluate_unseen_clients,
                               fedavg_aggregate, fedavg_round,
                               make_pipeline_round, make_sharded_engine_round,
                               make_sharded_round, pipeline_round,
                               run_federated_training, weighted_aggregate)
from repro.core.transforms import (DeltaTransform, GaussianNoise, L2Clip,
                                   StochasticQuantize, TransformStack,
                                   make_stack)
from repro.core.local_sgd import (LocalSGDConfig, OuterState, fedavg_outer,
                                  init_outer_state, outer_step)
from repro.core.losses import (accuracy, ew_mse, make_loss, mape, mse,
                               per_horizon_accuracy, rmse, weighted_ce)
from repro.core.sampling import SAMPLING_STRATEGIES, make_sampler
from repro.core.server_opt import (SERVER_OPTS, ServerState,
                                   init_server_state, server_update)

__all__ = ["aggregation", "clustering", "fedavg", "local_sgd", "losses",
           "sampling", "sarima", "server_opt", "transforms",
           "Aggregator", "FlatAggregator", "HierarchicalAggregator",
           "LocalAggregator", "make_aggregator",
           "DeltaTransform", "GaussianNoise", "L2Clip", "StochasticQuantize",
           "TransformStack", "make_stack",
           "FLResult", "RoundEngine", "engine_round", "evaluate_global",
           "evaluate_unseen_clients", "fedavg_aggregate", "fedavg_round",
           "make_pipeline_round", "make_sharded_engine_round",
           "make_sharded_round", "pipeline_round",
           "run_federated_training", "weighted_aggregate", "LocalSGDConfig",
           "OuterState", "fedavg_outer", "init_outer_state", "outer_step",
           "accuracy", "ew_mse", "make_loss", "mape", "mse",
           "per_horizon_accuracy", "rmse", "weighted_ce",
           "SAMPLING_STRATEGIES", "make_sampler", "SERVER_OPTS",
           "ServerState", "init_server_state", "server_update"]
