"""The paper's contribution: FedAvg with clustering + EW-MSE, generalized
into a pluggable federated round engine (sampling × aggregation weighting ×
server optimizer) and its extension to cross-pod local-SGD training."""
from repro.core import (clustering, fedavg, local_sgd, losses, sampling,
                        sarima, server_opt)
from repro.core.fedavg import (FLResult, RoundEngine, engine_round,
                               evaluate_global, evaluate_unseen_clients,
                               fedavg_aggregate, fedavg_round,
                               make_sharded_engine_round, make_sharded_round,
                               run_federated_training, weighted_aggregate)
from repro.core.local_sgd import (LocalSGDConfig, OuterState, fedavg_outer,
                                  init_outer_state, outer_step)
from repro.core.losses import (accuracy, ew_mse, make_loss, mape, mse,
                               per_horizon_accuracy, rmse, weighted_ce)
from repro.core.sampling import SAMPLING_STRATEGIES, make_sampler
from repro.core.server_opt import (SERVER_OPTS, ServerState,
                                   init_server_state, server_update)

__all__ = ["clustering", "fedavg", "local_sgd", "losses", "sampling",
           "sarima", "server_opt",
           "FLResult", "RoundEngine", "engine_round", "evaluate_global",
           "evaluate_unseen_clients", "fedavg_aggregate", "fedavg_round",
           "make_sharded_engine_round", "make_sharded_round",
           "run_federated_training", "weighted_aggregate", "LocalSGDConfig",
           "OuterState", "fedavg_outer", "init_outer_state", "outer_step",
           "accuracy", "ew_mse", "make_loss", "mape", "mse",
           "per_horizon_accuracy", "rmse", "weighted_ce",
           "SAMPLING_STRATEGIES", "make_sampler", "SERVER_OPTS",
           "ServerState", "init_server_state", "server_update"]
