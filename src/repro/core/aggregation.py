"""Aggregate stage of the federated pipeline (select -> local-update ->
transform -> **aggregate** -> server-update): pluggable cross-client
reduction topologies behind one tiny protocol.

An :class:`Aggregator` owns (a) the ``PartitionSpec`` that lays the
client-stacked round inputs out over the mesh and (b) the collective that
turns per-shard weighted sums into the global sum inside the round body.
The weighting math itself lives in ``core/fedavg.py::_weighted_sums`` and is
shared by every topology.

``flat`` (:class:`FlatAggregator`)
    The paper's §5.4 deployment collapsed to one collective: clients on a 1-D
    ``clients`` mesh axis, aggregation = a single ``psum`` of the (tiny)
    parameter tree — edge->cloud upload + cloud aggregation in one step.
``hierarchical`` (:class:`HierarchicalAggregator`)
    Two-level edge->region->cloud reduction over a 2-D ``(region, clients)``
    mesh: each region psums its own clients first (the regional edge
    aggregator — a Pi cluster head in the paper's §5.4 deployment), then one
    psum across regions combines the regional partials at the cloud.  Per-link
    traffic drops from N uploads into one cloud ingress to ``N/R`` per region
    + R partials upstream.  Because every per-client transform runs BEFORE the
    collective, the two topologies compute the same sum — identical to the
    flat path up to float summation order, bitwise when the reduction orders
    coincide.
``local`` (:class:`LocalAggregator`)
    The no-mesh (vmap, pseudo-distributed) execution path, where per-shard
    sums are already global: the collective is the identity.

**Linearity contract (mask cancellation).**  ``reduce`` MUST be a plain
linear sum of the per-shard values (psum / psum-of-psums / identity) —
no clipping, averaging, or reordering beyond float summation order.  The
secure-aggregation stage (``core/secure_agg.py``) relies on this: every
client's upload carries antisymmetric pairwise masks (``mask_ij =
-mask_ji``) whose sum over the dispatch cohort is zero, so the masks
cancel in ``reduce`` no matter how the cohort is sharded — each pair's two
halves may land on different shards (flat), different regions
(hierarchical), or the same vmap lane, and the cancellation is identical
up to float summation order (pinned by tests/test_privacy.py on all three
topologies).  An aggregator that broke linearity (e.g. a trimmed-mean
topology) would need masking disabled — validate eagerly if you add one.

This seam is what turns the remaining ROADMAP items into new
``Aggregator`` implementations rather than engine rewrites.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.analysis import taint
from repro.configs.base import AGGREGATORS, AggregationConfig, FLConfig

PyTree = Any


class Aggregator(Protocol):
    """Reduction topology for the aggregate stage."""

    @property
    def mesh_axes(self) -> Tuple[str, ...]:
        """Mesh axis names this topology reduces over (() = no mesh)."""
        ...

    def pspec(self) -> Optional[P]:
        """PartitionSpec sharding the leading (client) axis of round inputs."""
        ...

    def reduce(self, x: jax.Array) -> jax.Array:
        """Sum one per-shard array across all client shards.

        Must be a LINEAR sum (see the module's mask-cancellation contract):
        secure-aggregation masks cancel in this reduction.
        """
        ...


@dataclasses.dataclass(frozen=True)
class LocalAggregator:
    """vmap execution: sums are already global, the collective is identity."""

    @property
    def mesh_axes(self) -> Tuple[str, ...]:
        return ()

    def pspec(self) -> Optional[P]:
        return None

    def reduce(self, x):
        # identity collective, but still THE cross-client boundary of the
        # vmap path — flcheck checks sanitization here (production no-op)
        return taint.boundary(x)


@dataclasses.dataclass(frozen=True)
class FlatAggregator:
    """One-psum cloud aggregation over a 1-D ``clients`` mesh axis."""
    client_axis: str = "clients"

    @property
    def mesh_axes(self) -> Tuple[str, ...]:
        return (self.client_axis,)

    def pspec(self) -> P:
        return P(self.client_axis)

    def reduce(self, x):
        return jax.lax.psum(taint.boundary(x), self.client_axis)


@dataclasses.dataclass(frozen=True)
class HierarchicalAggregator:
    """Two-level edge->region->cloud reduction on a 2-D (region, clients) mesh.

    Round inputs shard their leading client axis over BOTH mesh axes
    (``P((region, clients))``); the reduction is a psum within each region
    (edge aggregation) followed by a psum across regions (cloud aggregation).
    """
    region_axis: str = "region"
    client_axis: str = "clients"

    @property
    def mesh_axes(self) -> Tuple[str, ...]:
        return (self.region_axis, self.client_axis)

    def pspec(self) -> P:
        return P((self.region_axis, self.client_axis))

    def reduce(self, x):
        regional = jax.lax.psum(taint.boundary(x),
                                self.client_axis)        # edge -> region
        return jax.lax.psum(regional, self.region_axis)  # region -> cloud


def make_aggregator(cfg: Union[FLConfig, AggregationConfig, str, None],
                    mesh=None) -> Aggregator:
    """Resolve the aggregate stage: config (or kind name) + mesh -> Aggregator.

    ``mesh=None`` always yields the :class:`LocalAggregator` (vmap path).
    With a mesh, the topology's axis names are validated against the mesh's
    eagerly, so a flat engine handed a 2-D mesh (or vice versa) fails at
    construction, not inside the jitted round.
    """
    if cfg is None:
        cfg = AggregationConfig()
    elif isinstance(cfg, FLConfig):
        cfg = cfg.aggregation_config
    elif isinstance(cfg, str):
        cfg = AggregationConfig(kind=cfg)

    if mesh is None:
        return LocalAggregator()
    agg: Aggregator = (FlatAggregator() if cfg.kind == "flat"
                       else HierarchicalAggregator())
    missing = [a for a in agg.mesh_axes if a not in mesh.axis_names]
    if missing or len(mesh.axis_names) != len(agg.mesh_axes):
        raise ValueError(
            f"{cfg.kind!r} aggregation needs mesh axes {agg.mesh_axes}, got "
            f"mesh axes {tuple(mesh.axis_names)} — build the mesh with "
            f"aggregation.make_mesh(cfg) or jax.make_mesh")
    return agg


def make_mesh(cfg: Union[AggregationConfig, FLConfig, None] = None,
              devices=None):
    """Build the device mesh an ``AggregationConfig`` asks for.

    Flat -> 1-D ``(clients,)`` over all devices.  Hierarchical -> 2-D
    ``(region, clients)`` with ``n_regions`` region groups (``n_regions=0``
    picks the largest divisor of the device count that is <= sqrt(devices),
    so an 8-device host becomes the 2x4 edge/region grid).
    """
    if cfg is None:
        cfg = AggregationConfig()
    elif isinstance(cfg, FLConfig):
        cfg = cfg.aggregation_config
    n_dev = len(jax.devices() if devices is None else devices)
    if cfg.kind == "flat":
        return jax.make_mesh((n_dev,), ("clients",))
    r = cfg.n_regions
    if r == 0:
        r = max(d for d in range(1, int(n_dev ** 0.5) + 1) if n_dev % d == 0)
    if n_dev % r:
        raise ValueError(f"n_regions={r} does not divide device count "
                         f"{n_dev}")
    return jax.make_mesh((r, n_dev // r), ("region", "clients"))
