"""Simulated client-latency model for semi-synchronous rounds
(``LatencyConfig``; consumed by ``core/async_engine.py``).

The paper's deployment trains on a Raspberry-Pi edge cluster where a round
is gated by its slowest client (70-100 s/round on Pi 4Bs, §5.5).  Edge-FL
work on load forecasting (arXiv:2201.11248) and lightweight FL
(arXiv:2404.03320) both argue that *wall-clock*-to-accuracy — not
rounds-to-accuracy — is the metric that matters there, so the engine drives
a simulated event clock from a per-client latency model:

    t_i = mult_i * (compute_s_per_window_epoch * n_windows_i * E
                    + payload_bytes / uplink_bytes_per_s)

Compute scales with the client's local work (windows x epochs — ragged
histories make slow clients for free), uplink with the post-quantize
payload size (``payload_bytes``), and ``mult_i`` is the pluggable straggler
draw (deterministic / lognormal / heavy-tail).  Draws are a pure function
of ``(seed, round, slot)`` — no shared rng state — so a simulated schedule
replays bit-exactly under a fixed seed.

**Calibration of the default constants** (``LatencyConfig``), anchored to
the paper's measured 70-100 s Pi-4B rounds (§5.5):

``compute_s_per_window_epoch = 3.2e-3``
    The paper's clients hold one year of 15-min smart-meter readings:
    365 x 96 = 35,040 samples.  After the 75:25 chronological train split
    and lookback-8/horizon-4 windowing, that is ~26,270 training windows
    per client-epoch.  A measured round (local training dominates on the
    Pi 4B) of 70-100 s therefore brackets the per-window-epoch cost at
    70/26,270 .. 100/26,270 = 2.7 .. 3.8 ms; the default 3.2 ms puts a
    jitter-free E=1 full-year round at 26,270 x 3.2e-3 ~= 84 s — the
    middle of the measured band.
``uplink_bytes_per_s = 1e6``
    The ~140k-param LSTM upload is 561 KB in fp32 (140 KB int8-quantized).
    At 1 MB/s — a deliberately conservative shared-WiFi/constrained edge
    uplink, NOT the Pi 4B's gigabit NIC — upload adds ~0.6 s, consistent
    with the paper's compute-dominated rounds while still letting the
    quantize transform show a visible wire win at scale.
``jitter = 0.5``
    A moderate default spread; §5.5's own 70-100 s spread across identical
    Pi 4Bs corresponds to a lognormal sigma of roughly
    ln(100/84) ~= 0.17-0.5 depending on how much of the spread is per-round
    vs per-device — benchmarks that study stragglers pass their own value
    explicitly.

``link_budget`` models the hierarchical per-level wire cost (region fan-in
vs cloud ingress) for ``bench_edge`` — the ROADMAP follow-up to PR 3's
edge->region->cloud aggregation.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from repro.configs.base import (STRAGGLER_DISTRIBUTIONS,  # noqa: F401 (re-export)
                                ChurnConfig, LatencyConfig)

# domain-separation tags for the event-clock rng streams: decorrelate
# latency/churn draws from every other consumer of FLConfig.seed (sampler,
# holdout, transform keys) and from each other
_LATENCY_STREAM = 0x1A7E               # straggler multipliers
_DROPOUT_STREAM = 0xD209               # mid-upload failure draws
_AVAIL_STREAM = 0xA7A1                 # per-round membership availability
_REUPLOAD_STREAM = 0x2E71              # retry / re-key re-upload latency


def payload_bytes(n_params: int, quantize_bits: int = 0,
                  audited_bytes: Optional[float] = None) -> float:
    """Uplink payload of one client update.

    ``audited_bytes`` — a statically audited byte count from the level-3
    flcheck cost auditor (``analysis/costs.py``: exact per-leaf wire
    encoding read off the traced round's boundary crossings) — is the
    source of truth when given; the closed-form below is the FALLBACK
    model: fp32, or ``quantize_bits``-bit ints when the quantize transform
    is on (per-leaf scale overhead is a few floats on a ~140k-param model —
    ignored; the auditor counts it and reports the delta as a tracked
    divergence).  The quantized wire SURVIVES secure-agg masking: ring
    masks live in the quantizer's integer ring mod 2^b
    (``core/secure_agg.py``), so masked uploads are charged the same
    ``quantize_bits``-bit payload as clear ones — the uplink the paper's
    scalability pitch needs (``RoundEngine`` passes ``quantize_bits``
    unchanged with masking on, and the auditor proves the format)."""
    if audited_bytes is not None:
        return float(audited_bytes)
    if quantize_bits:
        return math.ceil(n_params * quantize_bits / 8)
    return n_params * 4.0


def _slot_rngs(stream: int, seed: int, round_idx: int, slots, *extra):
    """One decorrelated ``np.random.Generator`` per slot, seeded by the full
    ``(stream, seed, round, slot, *extra)`` tuple — a draw is a pure function
    of the slot VALUE, never of its position in the dispatch ordering."""
    return [np.random.default_rng(np.random.SeedSequence(
        [int(stream), int(seed), int(round_idx), int(s),
         *(int(e) for e in extra)])) for s in np.asarray(slots, np.int64)]


class LatencyModel:
    """Per-round client finish-time + failure sampler (all host-side numpy).

    ``churn`` adds the failure-injection draws (mid-upload dropout,
    per-round membership availability) on their own rng streams; the
    default ``ChurnConfig()`` injects nothing.
    """

    def __init__(self, cfg: LatencyConfig, seed: int, payload: float,
                 churn: ChurnConfig = ChurnConfig()) -> None:
        self.cfg = cfg
        self.churn = churn
        self.seed = int(seed)
        self.uplink_s = float(payload) / cfg.uplink_bytes_per_s

    def _multipliers(self, round_idx: int, slots,
                     stream: int = _LATENCY_STREAM,
                     attempt: int = 0) -> np.ndarray:
        cfg = self.cfg
        slots = np.asarray(slots, np.int64)
        if cfg.distribution == "deterministic" or cfg.jitter == 0.0:
            return np.ones(len(slots))
        rngs = _slot_rngs(stream, self.seed, round_idx, slots, attempt)
        if cfg.distribution == "lognormal":
            return np.exp(cfg.jitter
                          * np.asarray([r.standard_normal() for r in rngs]))
        # heavy_tail: occasional extreme stalls (Pareto shape 1.5 has
        # infinite variance — exactly the regime where waiting for the max
        # is catastrophic but the k-th order statistic is tame)
        return 1.0 + cfg.jitter * np.asarray([r.pareto(1.5) for r in rngs])

    def times(self, round_idx: int, n_windows: np.ndarray, epochs: int,
              slots=None) -> np.ndarray:
        """Simulated seconds from dispatch to server arrival, one per slot.

        ``n_windows``: per-client local window counts (the same per-client
        sample counts that drive weighted aggregation).  ``slots``: the
        clients' GLOBAL dispatch slots — the straggler draw is seeded per
        ``(seed, round, slot)``, so it follows the client wherever it lands
        in the dispatch ordering (defaults to ``arange``: positional).
        """
        n_windows = np.asarray(n_windows, np.float64)
        if slots is None:
            slots = np.arange(len(n_windows))
        base = (self.cfg.compute_s_per_window_epoch * n_windows * epochs
                + self.uplink_s)
        return base * self._multipliers(round_idx, slots)

    def dropouts(self, round_idx: int, slots, attempt: int = 0) -> np.ndarray:
        """Mid-upload failure draws: True where the dispatched upload never
        arrives.  Pure function of ``(seed, round, slot, attempt)`` —
        ``attempt`` decorrelates a retry's fate from the original's."""
        slots = np.asarray(slots, np.int64)
        p = self.churn.dropout_prob
        if p <= 0.0 or len(slots) == 0:
            return np.zeros(len(slots), bool)
        rngs = _slot_rngs(_DROPOUT_STREAM, self.seed, round_idx, slots,
                          attempt)
        return np.asarray([r.uniform() < p for r in rngs])

    def available(self, round_idx: int, client_ids) -> np.ndarray:
        """Membership availability mask for one round: False where the
        member has (temporarily) left the fleet.  Pure function of
        ``(seed, round, client id)``, so a client's join/leave schedule is
        independent of who else is enrolled."""
        client_ids = np.asarray(client_ids, np.int64)
        p = self.churn.absent_prob
        if p <= 0.0 or len(client_ids) == 0:
            return np.ones(len(client_ids), bool)
        rngs = _slot_rngs(_AVAIL_STREAM, self.seed, round_idx, client_ids)
        return np.asarray([r.uniform() >= p for r in rngs])

    def reupload_times(self, round_idx: int, slots,
                       attempt: int = 1) -> np.ndarray:
        """Simulated seconds for a RE-upload (retry of an abandoned update,
        or a survivor's re-masked upload after a cohort re-key): the client
        already holds its transformed delta, so the cost is uplink only,
        times a fresh straggler draw on the re-upload stream."""
        slots = np.asarray(slots, np.int64)
        return self.uplink_s * self._multipliers(
            round_idx, slots, stream=_REUPLOAD_STREAM, attempt=attempt)


def link_budget(n_params: int, m_clients: int, n_regions: int,
                quantize_bits: int = 0,
                audited_up: Optional[float] = None) -> Dict[str, float]:
    """Per-level wire cost of one round's uploads, in bytes.

    ``flat``: all m client payloads land on the cloud link.  Hierarchical:
    each region's edge aggregator absorbs ~m/R client uploads (the regional
    fan-in) and forwards ONE fp32 partial upstream, so cloud ingress drops
    from m payloads to R — client quantization compresses the fan-in links,
    the region->cloud partials are already-aggregated floats.

    ``audited_up`` overrides the per-client UPLOAD payload with a
    statically audited byte count (see :func:`payload_bytes`); the
    region->cloud partials stay modeled fp32 — they are post-aggregation
    floats regardless of the client wire format.
    """
    if n_regions < 1:
        raise ValueError(f"n_regions must be >= 1, got {n_regions}")
    up = payload_bytes(n_params, quantize_bits, audited_bytes=audited_up)
    region_fanin = math.ceil(m_clients / n_regions) * up
    flat_ingress = m_clients * up
    cloud_ingress = (flat_ingress if n_regions == 1
                     else n_regions * payload_bytes(n_params, 0))
    return {"region_fanin_bytes": float(region_fanin),
            "cloud_ingress_bytes": float(cloud_ingress),
            "flat_cloud_ingress_bytes": float(flat_ingress)}
