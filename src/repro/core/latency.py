"""Simulated client-latency model for semi-synchronous rounds
(``LatencyConfig``; consumed by ``core/async_engine.py``).

The paper's deployment trains on a Raspberry-Pi edge cluster where a round
is gated by its slowest client (70-100 s/round on Pi 4Bs, §5.5).  Edge-FL
work on load forecasting (arXiv:2201.11248) and lightweight FL
(arXiv:2404.03320) both argue that *wall-clock*-to-accuracy — not
rounds-to-accuracy — is the metric that matters there, so the engine drives
a simulated event clock from a per-client latency model:

    t_i = mult_i * (compute_s_per_window_epoch * n_windows_i * E
                    + payload_bytes / uplink_bytes_per_s)

Compute scales with the client's local work (windows x epochs — ragged
histories make slow clients for free), uplink with the post-quantize
payload size (``payload_bytes``), and ``mult_i`` is the pluggable straggler
draw (deterministic / lognormal / heavy-tail).  Draws are a pure function
of ``(seed, round, slot)`` — no shared rng state — so a simulated schedule
replays bit-exactly under a fixed seed.

**Calibration of the default constants** (``LatencyConfig``), anchored to
the paper's measured 70-100 s Pi-4B rounds (§5.5):

``compute_s_per_window_epoch = 3.2e-3``
    The paper's clients hold one year of 15-min smart-meter readings:
    365 x 96 = 35,040 samples.  After the 75:25 chronological train split
    and lookback-8/horizon-4 windowing, that is ~26,270 training windows
    per client-epoch.  A measured round (local training dominates on the
    Pi 4B) of 70-100 s therefore brackets the per-window-epoch cost at
    70/26,270 .. 100/26,270 = 2.7 .. 3.8 ms; the default 3.2 ms puts a
    jitter-free E=1 full-year round at 26,270 x 3.2e-3 ~= 84 s — the
    middle of the measured band.
``uplink_bytes_per_s = 1e6``
    The ~140k-param LSTM upload is 561 KB in fp32 (140 KB int8-quantized).
    At 1 MB/s — a deliberately conservative shared-WiFi/constrained edge
    uplink, NOT the Pi 4B's gigabit NIC — upload adds ~0.6 s, consistent
    with the paper's compute-dominated rounds while still letting the
    quantize transform show a visible wire win at scale.
``jitter = 0.5``
    A moderate default spread; §5.5's own 70-100 s spread across identical
    Pi 4Bs corresponds to a lognormal sigma of roughly
    ln(100/84) ~= 0.17-0.5 depending on how much of the spread is per-round
    vs per-device — benchmarks that study stragglers pass their own value
    explicitly.

``link_budget`` models the hierarchical per-level wire cost (region fan-in
vs cloud ingress) for ``bench_edge`` — the ROADMAP follow-up to PR 3's
edge->region->cloud aggregation.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np

from repro.configs.base import (STRAGGLER_DISTRIBUTIONS,  # noqa: F401 (re-export)
                                LatencyConfig)

# domain-separation tag for the latency rng stream: decorrelates latency
# draws from every other consumer of FLConfig.seed (sampler, holdout, keys)
_LATENCY_STREAM = 0x1A7E


def payload_bytes(n_params: int, quantize_bits: int = 0) -> float:
    """Uplink payload of one client update: fp32, or ``quantize_bits``-bit
    ints when the quantize transform is on (per-leaf scale overhead is a few
    floats on a ~140k-param model — ignored).  Callers must pass
    ``quantize_bits=0`` when secure-agg masking is on: the float pairwise
    masks destroy the int8 wire format, so the masked upload is fp32
    regardless of the quantize stage (``RoundEngine`` does this)."""
    if quantize_bits:
        return math.ceil(n_params * quantize_bits / 8)
    return n_params * 4.0


class LatencyModel:
    """Per-round client finish-time sampler (all host-side numpy)."""

    def __init__(self, cfg: LatencyConfig, seed: int,
                 payload: float) -> None:
        self.cfg = cfg
        self.seed = int(seed)
        self.uplink_s = float(payload) / cfg.uplink_bytes_per_s

    def _multipliers(self, round_idx: int, n: int) -> np.ndarray:
        cfg = self.cfg
        if cfg.distribution == "deterministic" or cfg.jitter == 0.0:
            return np.ones(n)
        rng = np.random.default_rng(
            np.random.SeedSequence([_LATENCY_STREAM, self.seed,
                                    int(round_idx)]))
        if cfg.distribution == "lognormal":
            return np.exp(cfg.jitter * rng.standard_normal(n))
        # heavy_tail: occasional extreme stalls (Pareto shape 1.5 has
        # infinite variance — exactly the regime where waiting for the max
        # is catastrophic but the k-th order statistic is tame)
        return 1.0 + cfg.jitter * rng.pareto(1.5, size=n)

    def times(self, round_idx: int, n_windows: np.ndarray,
              epochs: int) -> np.ndarray:
        """Simulated seconds from dispatch to server arrival, one per slot.

        ``n_windows``: per-client local window counts (the same per-client
        sample counts that drive weighted aggregation).
        """
        n_windows = np.asarray(n_windows, np.float64)
        base = (self.cfg.compute_s_per_window_epoch * n_windows * epochs
                + self.uplink_s)
        return base * self._multipliers(round_idx, len(n_windows))


def link_budget(n_params: int, m_clients: int, n_regions: int,
                quantize_bits: int = 0) -> Dict[str, float]:
    """Per-level wire cost of one round's uploads, in bytes.

    ``flat``: all m client payloads land on the cloud link.  Hierarchical:
    each region's edge aggregator absorbs ~m/R client uploads (the regional
    fan-in) and forwards ONE fp32 partial upstream, so cloud ingress drops
    from m payloads to R — client quantization compresses the fan-in links,
    the region->cloud partials are already-aggregated floats.
    """
    if n_regions < 1:
        raise ValueError(f"n_regions must be >= 1, got {n_regions}")
    up = payload_bytes(n_params, quantize_bits)
    region_fanin = math.ceil(m_clients / n_regions) * up
    flat_ingress = m_clients * up
    cloud_ingress = (flat_ingress if n_regions == 1
                     else n_regions * payload_bytes(n_params, 0))
    return {"region_fanin_bytes": float(region_fanin),
            "cloud_ingress_bytes": float(cloud_ingress),
            "flat_cloud_ingress_bytes": float(flat_ingress)}
