"""Per-round client selection strategies (``FLConfig.sampling``).

The server picks ``m`` participants from a cluster's member list every round.
Under non-IID load data the selection scheme measurably shifts accuracy
(Briggs et al. 2021; Taik & Cherkaoui 2020), so it is pluggable:

``uniform``
    Paper Alg. 1: up to ``min(m, |members|)`` distinct members uniformly at
    random; when ``m`` exceeds the membership (e.g. mesh-forced round
    sizes), the remainder is filled with evenly-cycled duplicates — fresh
    shuffled passes over the membership, never a member k+2 times before
    every member appears k+1 times.
``weighted``
    Without-replacement sampling with probability proportional to a per-client
    weight vector (typically local sample counts) — biases rounds toward
    data-rich buildings.
``round_robin``
    Deterministic cyclic schedule: round ``t`` takes the next ``m`` members of
    a fixed seed-shuffled ordering, so every client participates equally
    often regardless of rng state — useful for reproducible ablations.

All samplers share one signature: ``sample(rng, members, m, round_idx,
weights=None) -> np.ndarray`` of exactly ``m`` client ids from ``members``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import numpy as np

# canonical name list lives with the configs (eager facade validation);
# re-exported here so `sampling.SAMPLING_STRATEGIES` keeps working
from repro.configs.base import SAMPLING_STRATEGIES, SamplingConfig

Sampler = Callable[..., np.ndarray]


def _pad(rng: np.random.Generator, sel: np.ndarray, members: np.ndarray,
         m: int, weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Pad a selection up to exactly m, preferring DISTINCT unselected members.

    Pool priority: (1) unselected members with nonzero weight (drawn without
    replacement, probability proportional to weight), (2) remaining
    unselected members (uniform, without replacement), (3) only once every
    member is already selected, evenly-cycled duplicate passes — fresh
    shuffles of the full membership — so no member appears k+2 times before
    every member appears k+1 times.  The old pad drew uniformly WITH
    replacement from ALL members, which could hand a weighted round to
    zero-weight clients and duplicate already-selected clients while
    distinct unselected members remained.
    """
    if len(sel) >= m:
        return sel[:m]
    out, need = [sel], m - len(sel)
    unsel = ~np.isin(members, sel)
    if weights is None:
        pools = [(unsel, None)]
    else:
        w = np.asarray(weights, np.float64)
        pools = [(unsel & (w > 0), w), (unsel & (w <= 0), None)]
    for mask, pw in pools:
        pool = members[mask]
        if need == 0 or len(pool) == 0:
            continue
        k = min(need, len(pool))
        p = None if pw is None else pw[mask] / pw[mask].sum()
        out.append(rng.choice(pool, size=k, replace=False, p=p))
        need -= k
    while need > 0:                    # everyone selected: cycle duplicates
        k = min(need, len(members))
        out.append(rng.permutation(members)[:k])
        need -= k
    return np.concatenate(out)


def uniform_sampler(rng: np.random.Generator, members: np.ndarray, m: int,
                    round_idx: int, weights: Optional[np.ndarray] = None
                    ) -> np.ndarray:
    sel = rng.choice(members, size=min(m, len(members)), replace=False)
    return _pad(rng, sel, members, m)


def weighted_sampler(rng: np.random.Generator, members: np.ndarray, m: int,
                     round_idx: int, weights: Optional[np.ndarray] = None
                     ) -> np.ndarray:
    if weights is None:
        return uniform_sampler(rng, members, m, round_idx)
    w = np.asarray(weights, np.float64)
    nonzero = int(np.count_nonzero(w))
    if nonzero == 0 or w.sum() <= 0:
        return uniform_sampler(rng, members, m, round_idx)
    # without-replacement draw can yield at most `nonzero` distinct clients;
    # the remainder pads from unselected members (nonzero-weight first) so
    # the exactly-m contract holds even when some clients carry zero weight
    # (e.g. no local windows)
    k = min(m, len(members), nonzero)
    sel = rng.choice(members, size=k, replace=False, p=w / w.sum())
    return _pad(rng, sel, members, m, weights=w)


def round_robin_sampler(rng: np.random.Generator, members: np.ndarray, m: int,
                        round_idx: int, weights: Optional[np.ndarray] = None,
                        *, seed: int = 0) -> np.ndarray:
    """Cyclic schedule over a seed-shuffled ordering of ``members``.

    The ordering must be FIXED across rounds (that is the whole point of
    round-robin), so it cannot come from the stateful per-round ``rng`` —
    it is derived from ``seed`` instead, which ``make_sampler`` wires to
    ``FLConfig.seed`` so the schedule actually follows the configured seed.
    The cyclic index keeps the exactly-``m`` contract even when
    ``m > len(members)`` (members repeat within a round).
    """
    n = len(members)
    order = np.random.default_rng(seed).permutation(n)
    idx = (round_idx * m + np.arange(m)) % n
    return members[order[idx]]


_SAMPLERS = {"uniform": uniform_sampler, "weighted": weighted_sampler,
             "round_robin": round_robin_sampler}


def make_sampler(strategy: Union[str, SamplingConfig], seed: int = 0
                 ) -> Sampler:
    """Resolve the select stage to a sampler callable.

    Accepts either a strategy name + ``seed`` (legacy) or a typed
    ``SamplingConfig`` (the ``FLConfig.sampling_config`` view).  ``seed``
    parameterizes schedule-type samplers (round_robin's fixed ordering);
    rng-driven samplers ignore it and use the per-call ``rng``.
    """
    if isinstance(strategy, SamplingConfig):
        strategy, seed = strategy.strategy, strategy.seed
    if strategy not in _SAMPLERS:
        raise ValueError(f"unknown sampling strategy {strategy!r}; expected "
                         f"one of {SAMPLING_STRATEGIES}")
    if strategy == "round_robin":
        return functools.partial(round_robin_sampler, seed=seed)
    return _SAMPLERS[strategy]
