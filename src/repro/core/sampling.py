"""Per-round client selection strategies (``FLConfig.sampling``).

The server picks ``m`` participants from a cluster's member list every round.
Under non-IID load data the selection scheme measurably shifts accuracy
(Briggs et al. 2021; Taik & Cherkaoui 2020), so it is pluggable:

``uniform``
    Paper Alg. 1: ``m`` distinct members uniformly at random (padded by
    resampling with replacement only when the mesh forces a larger ``m``
    than the cluster has members).
``weighted``
    Without-replacement sampling with probability proportional to a per-client
    weight vector (typically local sample counts) — biases rounds toward
    data-rich buildings.
``round_robin``
    Deterministic cyclic schedule: round ``t`` takes the next ``m`` members of
    a fixed seed-shuffled ordering, so every client participates equally
    often regardless of rng state — useful for reproducible ablations.

All samplers share one signature: ``sample(rng, members, m, round_idx,
weights=None) -> np.ndarray`` of exactly ``m`` client ids from ``members``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import numpy as np

# canonical name list lives with the configs (eager facade validation);
# re-exported here so `sampling.SAMPLING_STRATEGIES` keeps working
from repro.configs.base import SAMPLING_STRATEGIES, SamplingConfig

Sampler = Callable[..., np.ndarray]


def _pad(rng: np.random.Generator, sel: np.ndarray, members: np.ndarray,
         m: int) -> np.ndarray:
    """Pad a selection up to m (with replacement) when the cluster is small."""
    if len(sel) >= m:
        return sel[:m]
    return np.concatenate([sel, rng.choice(members, m - len(sel))])


def uniform_sampler(rng: np.random.Generator, members: np.ndarray, m: int,
                    round_idx: int, weights: Optional[np.ndarray] = None
                    ) -> np.ndarray:
    sel = rng.choice(members, size=min(m, len(members)), replace=False)
    return _pad(rng, sel, members, m)


def weighted_sampler(rng: np.random.Generator, members: np.ndarray, m: int,
                     round_idx: int, weights: Optional[np.ndarray] = None
                     ) -> np.ndarray:
    if weights is None:
        return uniform_sampler(rng, members, m, round_idx)
    w = np.asarray(weights, np.float64)
    nonzero = int(np.count_nonzero(w))
    if nonzero == 0 or w.sum() <= 0:
        return uniform_sampler(rng, members, m, round_idx)
    # without-replacement draw can yield at most `nonzero` distinct clients;
    # any remainder is padded uniformly so the contract (exactly m) holds
    # even when some clients carry zero weight (e.g. no local windows)
    k = min(m, len(members), nonzero)
    sel = rng.choice(members, size=k, replace=False, p=w / w.sum())
    return _pad(rng, sel, members, m)


def round_robin_sampler(rng: np.random.Generator, members: np.ndarray, m: int,
                        round_idx: int, weights: Optional[np.ndarray] = None,
                        *, seed: int = 0) -> np.ndarray:
    """Cyclic schedule over a seed-shuffled ordering of ``members``.

    The ordering must be FIXED across rounds (that is the whole point of
    round-robin), so it cannot come from the stateful per-round ``rng`` —
    it is derived from ``seed`` instead, which ``make_sampler`` wires to
    ``FLConfig.seed`` so the schedule actually follows the configured seed.
    The cyclic index keeps the exactly-``m`` contract even when
    ``m > len(members)`` (members repeat within a round).
    """
    n = len(members)
    order = np.random.default_rng(seed).permutation(n)
    idx = (round_idx * m + np.arange(m)) % n
    return members[order[idx]]


_SAMPLERS = {"uniform": uniform_sampler, "weighted": weighted_sampler,
             "round_robin": round_robin_sampler}


def make_sampler(strategy: Union[str, SamplingConfig], seed: int = 0
                 ) -> Sampler:
    """Resolve the select stage to a sampler callable.

    Accepts either a strategy name + ``seed`` (legacy) or a typed
    ``SamplingConfig`` (the ``FLConfig.sampling_config`` view).  ``seed``
    parameterizes schedule-type samplers (round_robin's fixed ordering);
    rng-driven samplers ignore it and use the per-call ``rng``.
    """
    if isinstance(strategy, SamplingConfig):
        strategy, seed = strategy.strategy, strategy.seed
    if strategy not in _SAMPLERS:
        raise ValueError(f"unknown sampling strategy {strategy!r}; expected "
                         f"one of {SAMPLING_STRATEGIES}")
    if strategy == "round_robin":
        return functools.partial(round_robin_sampler, seed=seed)
    return _SAMPLERS[strategy]
