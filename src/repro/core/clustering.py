"""K-means client clustering on privacy-coarsened summaries (paper §3.1).

Clients are clustered on their 273-dim daily-average consumption vectors
(``data.windows.daily_average_vector``).  Includes the elbow curve (inertia
vs k) and silhouette score used in §4.4 to justify k=4.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def kmeans(x: np.ndarray, k: int, *, n_iter: int = 100, seed: int = 0,
           n_init: int = 4) -> Tuple[np.ndarray, np.ndarray, float]:
    """Lloyd's K-means with k-means++ init; best of ``n_init`` restarts.

    x: (N, D). Returns (centroids (k, D), assignments (N,), inertia).
    """
    best = None
    for init in range(n_init):
        # SeedSequence([seed, init]) mixes injectively; seed + init collides
        # across (seed, init) pairs and correlates neighbouring seeds
        rng = np.random.default_rng(np.random.SeedSequence([seed, init]))
        cents = _kmeanspp(x, k, rng)
        assign = np.zeros(x.shape[0], np.int64)
        for _ in range(n_iter):
            d2 = ((x[:, None, :] - cents[None]) ** 2).sum(-1)   # (N, k)
            new_assign = d2.argmin(1)
            if (new_assign == assign).all() and _ > 0:
                break
            assign = new_assign
            for c in range(k):
                m = assign == c
                if m.any():
                    cents[c] = x[m].mean(0)
                else:                                   # re-seed empty cluster
                    cents[c] = x[rng.integers(x.shape[0])]
        inertia = float(((x - cents[assign]) ** 2).sum())
        if best is None or inertia < best[2]:
            best = (cents.copy(), assign.copy(), inertia)
    return best


def _kmeanspp(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    n = x.shape[0]
    cents = [x[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(((x[:, None, :] - np.stack(cents)[None]) ** 2).sum(-1), 1)
        p = d2 / max(d2.sum(), 1e-12)
        cents.append(x[rng.choice(n, p=p)])
    return np.stack(cents).astype(np.float64)


def assign(x: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Nearest-centroid assignment for held-out clients (§5.1 large test set)."""
    d2 = ((x[:, None, :] - centroids[None]) ** 2).sum(-1)
    return d2.argmin(1)


def elbow_curve(x: np.ndarray, ks, seed: int = 0) -> np.ndarray:
    """Inertia per k — the elbow plot of §4.4."""
    return np.array([kmeans(x, k, seed=seed)[2] for k in ks])


def silhouette_score(x: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (O(N²), fine for ≤ a few hundred clients)."""
    n = x.shape[0]
    d = np.sqrt(((x[:, None, :] - x[None]) ** 2).sum(-1))
    uniq = np.unique(labels)
    s = np.zeros(n)
    for i in range(n):
        same = labels == labels[i]
        same[i] = False
        a = d[i, same].mean() if same.any() else 0.0
        b = np.inf
        for c in uniq:
            if c == labels[i]:
                continue
            m = labels == c
            if m.any():
                b = min(b, d[i, m].mean())
        s[i] = 0.0 if max(a, b) == 0 or not np.isfinite(b) else (b - a) / max(a, b)
    return float(s.mean())
