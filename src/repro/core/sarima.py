"""Compact seasonal-ARIMA baseline (paper §4.3).

pmdarima is not available offline, so this is an in-repo SARIMA
(p,d,q)×(P,D,Q)_s fitter using the Hannan–Rissanen two-stage conditional
least-squares method:

  1. apply ordinary (d) and seasonal (D, period s) differencing;
  2. fit a long AR model by OLS to estimate innovations;
  3. regress the differenced series on its own lags (AR, seasonal AR) and on
     the estimated innovations' lags (MA, seasonal MA).

``auto_fit`` mimics auto-ARIMA by searching a small order grid with AIC.  The
paper's protocol is followed by ``rolling_forecast``: fit on 30 days, predict
forward, refit every 30 days.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Tuple

import numpy as np

from repro.data.synthetic import STEPS_PER_DAY


@dataclasses.dataclass
class SarimaModel:
    order: Tuple[int, int, int]
    seasonal: Tuple[int, int, int, int]
    ar: np.ndarray
    ma: np.ndarray
    sar: np.ndarray
    sma: np.ndarray
    intercept: float
    sigma2: float
    aic: float


def _difference(y: np.ndarray, d: int, D: int, s: int) -> np.ndarray:
    for _ in range(d):
        y = np.diff(y)
    for _ in range(D):
        y = y[s:] - y[:-s]
    return y


def _lagmat(y: np.ndarray, lags) -> np.ndarray:
    """Columns y[t-l] for each l in lags, rows t = max(lags)..T-1."""
    m = max(lags) if lags else 0
    return np.stack([y[m - l:len(y) - l] for l in lags], axis=1) \
        if lags else np.empty((len(y) - m, 0))


def fit(y: np.ndarray, order=(2, 0, 1), seasonal=(1, 1, 0, STEPS_PER_DAY)
        ) -> Optional[SarimaModel]:
    p, d, q = order
    P, D, Q, s = seasonal
    w = _difference(y.astype(np.float64), d, D, s)
    lag_ar = list(range(1, p + 1))
    lag_sar = [s * j for j in range(1, P + 1)]
    lag_ma = list(range(1, q + 1))
    lag_sma = [s * j for j in range(1, Q + 1)]
    m = max(lag_ar + lag_sar + lag_ma + lag_sma + [1])
    if len(w) < 3 * m + 10:
        return None

    # stage 1: long-AR innovations estimate
    k = min(max(2 * m, 10), len(w) // 4)
    Xl = _lagmat(w, list(range(1, k + 1)))
    yl = w[k:]
    beta, *_ = np.linalg.lstsq(np.c_[np.ones(len(yl)), Xl], yl, rcond=None)
    eps = np.concatenate([np.zeros(k), yl - np.c_[np.ones(len(yl)), Xl] @ beta])

    # stage 2: CSS regression on AR/SAR lags of w and MA/SMA lags of eps
    cols, names = [np.ones(len(w) - m)], ["c"]
    for l in lag_ar + lag_sar:
        cols.append(w[m - l:len(w) - l])
    for l in lag_ma + lag_sma:
        cols.append(eps[m - l:len(w) - l])
    X = np.stack(cols, axis=1)
    yt = w[m:]
    coef, *_ = np.linalg.lstsq(X, yt, rcond=None)
    resid = yt - X @ coef
    sigma2 = float(resid @ resid / max(len(resid), 1))
    n_par = len(coef)
    aic = len(resid) * np.log(max(sigma2, 1e-12)) + 2 * n_par
    i = 1
    ar = coef[i:i + p]; i += p
    sar = coef[i:i + P]; i += P
    ma = coef[i:i + q]; i += q
    sma = coef[i:i + Q]
    return SarimaModel(order, seasonal, ar, ma, sar, sma,
                       float(coef[0]), sigma2, float(aic))


def auto_fit(y: np.ndarray, s: int = STEPS_PER_DAY) -> SarimaModel:
    """Small-grid AIC search (auto-ARIMA stand-in)."""
    best = None
    for (p, q, P, D) in itertools.product((1, 2), (0, 1), (0, 1), (1,)):
        m = fit(y, (p, 0, q), (P, D, 0, s))
        if m is not None and (best is None or m.aic < best.aic):
            best = m
    if best is None:
        raise ValueError("series too short for SARIMA fit")
    return best


def forecast(model: SarimaModel, history: np.ndarray, steps: int) -> np.ndarray:
    """Recursive h-step forecast from the end of ``history`` (original scale)."""
    p, d, q = model.order
    P, D, Q, s = model.seasonal
    y = history.astype(np.float64)
    w_hist = _difference(y, d, D, s)
    # rebuild in-sample innovations for the MA terms
    m = max([1] + list(range(1, p + 1)) + [s * j for j in range(1, P + 1)]
            + list(range(1, q + 1)) + [s * j for j in range(1, Q + 1)])
    eps = np.zeros(len(w_hist))
    for t in range(m, len(w_hist)):
        eps[t] = w_hist[t] - _one_step(model, w_hist, eps, t)
    w_ext, eps_ext = list(w_hist), list(eps)
    for h in range(steps):
        t = len(w_ext)
        w_arr, e_arr = np.asarray(w_ext), np.asarray(eps_ext)
        w_next = _one_step(model, w_arr, e_arr, t)
        w_ext.append(w_next)
        eps_ext.append(0.0)
    w_fc = np.asarray(w_ext[len(w_hist):])
    return _undifference(y, w_fc, d, D, s)


def _one_step(model: SarimaModel, w: np.ndarray, eps: np.ndarray, t: int) -> float:
    p, _, q = model.order
    P, _, Q, s = model.seasonal
    v = model.intercept
    for j, a in enumerate(model.ar, 1):
        if t - j >= 0:
            v += a * w[t - j]
    for j, a in enumerate(model.sar, 1):
        if t - s * j >= 0:
            v += a * w[t - s * j]
    for j, b in enumerate(model.ma, 1):
        if t - j >= 0:
            v += b * eps[t - j]
    for j, b in enumerate(model.sma, 1):
        if t - s * j >= 0:
            v += b * eps[t - s * j]
    return float(v)


def _undifference(y: np.ndarray, w_fc: np.ndarray, d: int, D: int, s: int
                  ) -> np.ndarray:
    """Invert seasonal then ordinary differencing for the forecast path."""
    if d > 1 or D > 1:
        raise NotImplementedError
    # first invert seasonal differencing against the (possibly d-differenced) base
    base = np.diff(y) if d else y
    out = []
    hist = list(base)
    for wv in w_fc:
        val = wv + (hist[len(hist) - s] if D else 0.0)
        out.append(val)
        hist.append(val)
    if d:
        level = y[-1]
        out = list(np.cumsum(out) + level)
    return np.asarray(out)


def rolling_forecast(series: np.ndarray, lookahead: int = 4,
                     fit_days: int = 30, refit_days: int = 30,
                     horizon_days: int = 7) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §4.3 protocol: fit on 30 days, forecast, refit every 30 days.

    Returns (pred, actual), each (n, lookahead) — one row per forecast origin
    over ``horizon_days`` of evaluation after the initial fit window.
    """
    s = STEPS_PER_DAY
    fit_len = fit_days * s
    preds, actuals = [], []
    model = auto_fit(series[:fit_len])
    t = fit_len
    next_refit = fit_len + refit_days * s
    end = min(len(series) - lookahead, fit_len + horizon_days * s)
    while t < end:
        if t >= next_refit:
            model = auto_fit(series[t - fit_len:t])
            next_refit += refit_days * s
        preds.append(forecast(model, series[max(0, t - fit_len):t], lookahead))
        actuals.append(series[t:t + lookahead])
        t += lookahead
    return np.asarray(preds), np.asarray(actuals)
