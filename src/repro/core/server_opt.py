"""Pluggable server-side update rules for the federated round engine.

Each round the engine produces an aggregated client model ``w_agg`` (sample-
count-weighted average of the returned local models).  The server then treats

    g = w_global - w_agg            (the "pseudo-gradient", Reddi et al. 2021)

as a gradient estimate and applies one step of a server optimizer.  Selected
via ``FLConfig.server_opt``:

``fedavg``
    Uniform FedAvg (paper Alg. 1).  The engine aggregates with equal client
    weights and the server applies ``w <- w - server_lr * g`` (with
    ``server_lr=1`` this is exactly ``w <- w_agg``).  ``server_momentum > 0``
    turns this into FedAvgM (server momentum on the pseudo-gradient).
``fedavg_weighted``
    Same server step, but aggregation weights clients by their local sample
    counts (the classic McMahan et al. weighting for unbalanced data).
``fedprox``
    Weighted FedAvg aggregation + a proximal term ``mu/2 ||w - w_global||^2``
    added to each client's local objective (see ``core/client.py``;
    ``FLConfig.prox_mu``).  ``mu=0`` recovers FedAvg exactly.
``fedadam`` / ``fedyogi``
    Adaptive server optimizers (Reddi et al., "Adaptive Federated
    Optimization"): first/second moments of the pseudo-gradient, no bias
    correction; yogi uses the sign-damped second-moment update.  Tune
    ``server_lr`` / ``server_eps`` (paper defaults: lr ~1e-2..1, eps 1e-3).

All rules are pure pytree->pytree functions of ``(w_global, w_agg, state)``
and run *outside* the vmap / shard_map round body, so the two execution paths
share one server step (and aggregation inside the round stays one ``psum``).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, ServerOptConfig
# canonical name list lives with the configs (eager facade validation);
# re-exported here so `server_opt.SERVER_OPTS` keeps working
from repro.configs.base import SERVER_OPTS

# opts whose aggregation weights clients by local sample count
WEIGHTED_AGG_OPTS = ("fedavg_weighted", "fedprox", "fedadam", "fedyogi")


def as_server_config(cfg: Union[FLConfig, ServerOptConfig]) -> ServerOptConfig:
    """Normalize to the typed server-update stage config (facade-friendly)."""
    return cfg.server if isinstance(cfg, FLConfig) else cfg


class ServerState(NamedTuple):
    """Server optimizer state (zeros where a rule has no such moment)."""
    m: Any                      # first moment / momentum buffer
    v: Any                      # second moment (fedadam / fedyogi)
    t: jnp.ndarray              # step count


def uses_weighted_aggregation(flcfg: Union[FLConfig, ServerOptConfig]) -> bool:
    return as_server_config(flcfg).name in WEIGHTED_AGG_OPTS


def init_server_state(params) -> ServerState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return ServerState(m=jax.tree.map(zeros, params),
                       v=jax.tree.map(zeros, params),
                       t=jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("flcfg",))
def server_update(w_global, w_agg, state: ServerState,
                  flcfg: Union[FLConfig, ServerOptConfig]
                  ) -> Tuple[Any, ServerState]:
    """Apply one server step to the pseudo-gradient ``w_global - w_agg``.

    Accepts the flat ``FLConfig`` facade or the typed ``ServerOptConfig``
    stage view.  Returns ``(new_global_params, new_state)``.  Dispatch on the
    rule name happens at trace time (the config is static), so each rule
    compiles to its own minimal program.
    """
    cfg = as_server_config(flcfg)
    opt = cfg.name
    if opt not in SERVER_OPTS:
        raise ValueError(f"unknown server_opt {opt!r}; expected one of "
                         f"{SERVER_OPTS}")
    lr = cfg.lr
    g = jax.tree.map(lambda w, a: w - a, w_global, w_agg)
    t = state.t + 1

    if opt in ("fedavg", "fedavg_weighted", "fedprox"):
        if cfg.momentum > 0.0:             # FedAvgM
            m = jax.tree.map(lambda mm, gg: cfg.momentum * mm + gg,
                             state.m, g)
            new = jax.tree.map(lambda w, mm: w - lr * mm, w_global, m)
            return new, ServerState(m=m, v=state.v, t=t)
        if lr == 1.0:                      # exact Alg. 1: w <- w_agg
            return w_agg, ServerState(m=state.m, v=state.v, t=t)
        new = jax.tree.map(lambda w, gg: w - lr * gg, w_global, g)
        return new, ServerState(m=state.m, v=state.v, t=t)

    # adaptive rules (Reddi et al. 2021, no bias correction)
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, state.m, g)
    if opt == "fedadam":
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg,
                         state.v, g)
    else:                                  # fedyogi: sign-damped v update
        v = jax.tree.map(
            lambda vv, gg: vv - (1 - b2) * gg * gg * jnp.sign(vv - gg * gg),
            state.v, g)
    new = jax.tree.map(lambda w, mm, vv: w - lr * mm / (jnp.sqrt(vv) + eps),
                       w_global, m, v)
    return new, ServerState(m=m, v=v, t=t)
