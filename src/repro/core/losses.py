"""Loss functions (paper §3.3): MSE, EW-MSE, and the LM analogue.

EW-MSE(y, ŷ) = (1/N) Σ_i β^{i-1} (y_i − ŷ_i)²   with β ≥ 1; β=1 ⇒ MSE.

For the assigned LLM architectures the same idea transfers as a
*position-weighted cross-entropy*: later positions in the context window are
up-weighted by β^{i/S} (normalized so β=1 reduces to plain CE).  This is the
paper's "emphasize the hard, far-horizon targets" insight applied to
next-token prediction, exposed as ``weighted_ce``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def horizon_weights(horizon: int, beta: float, dtype=jnp.float32):
    """β^{i-1} for i = 1..N (paper's EW-MSE weights, unnormalized)."""
    return jnp.power(jnp.asarray(beta, dtype), jnp.arange(horizon, dtype=dtype))


def mse(pred, target):
    """Standard MSE over all elements. pred/target: (..., horizon)."""
    d = (pred - target).astype(jnp.float32)
    return jnp.mean(d * d)


def ew_mse(pred, target, beta: float = 2.0):
    """Exponentially weighted MSE (paper eq. §3.3.2).

    Weights the squared error at horizon step i by β^{i-1} and averages with
    1/N exactly as the paper writes it (NOT normalized by Σβ^{i-1}).
    """
    horizon = pred.shape[-1]
    w = horizon_weights(horizon, beta)
    d = (pred - target).astype(jnp.float32)
    return jnp.mean(d * d * w)


@functools.lru_cache(maxsize=None)
def make_loss(name: str, beta: float = 2.0):
    """Loss factory, cached on (name, beta) so repeated callers (e.g. one
    RoundEngine per sweep configuration) share ONE callable — and therefore
    one jit/shard_map trace of every round function keyed on it."""
    if name == "mse":
        return mse
    if name == "ew_mse":
        return lambda p, t: ew_mse(p, t, beta)
    raise ValueError(f"unknown loss {name!r}")


# ------------------------------------------------------------- LM analogue
def weighted_ce(logits, labels, beta: float = 1.0, mask=None):
    """Position-weighted cross entropy — the EW-MSE analogue for LM training.

    logits: (B, S, V); labels: (B, S) int32.  Position i in [0, S) gets weight
    β^{i/(S-1)} (so the last position is weighted β× the first); weights are
    normalized to mean 1 so the loss scale matches plain CE and β=1 is exact CE.
    """
    S = logits.shape[1]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if S > 1:
        w = jnp.power(beta, jnp.arange(S, dtype=jnp.float32) / (S - 1))
    else:
        w = jnp.ones((S,), jnp.float32)
    w = w / jnp.mean(w)
    wl = -ll * w[None, :]
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(wl * m) / jnp.maximum(jnp.sum(m * w[None, :]), 1.0)
    return jnp.mean(wl) / jnp.mean(w)


def chunked_weighted_ce(h, w_head, labels, beta: float = 1.0, mask=None,
                        chunk: int = 512):
    """``weighted_ce`` computed from hidden states, chunked over sequence.

    h: (B, S, d); w_head: (d, V).  Each chunk's logits + fp32 log-softmax are
    (B, chunk, V) transients and are REMATERIALIZED in the backward pass
    (jax.checkpoint), so peak memory never holds full-sequence fp32 logits —
    the difference between fitting and not fitting a 150k-vocab model step
    in 16 GB HBM.  Numerically identical to weighted_ce(logits, ...).
    """
    B, S, d = h.shape
    if S % chunk:
        chunk = S
    nc = S // chunk
    if S > 1:
        w_pos = jnp.power(beta, jnp.arange(S, dtype=jnp.float32) / (S - 1))
    else:
        w_pos = jnp.ones((S,), jnp.float32)
    m = (jnp.ones((B, S), jnp.float32) if mask is None
         else mask.astype(jnp.float32))

    hc = h.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    mc = (m * w_pos[None, :]).reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def one(args):
        hcc, lcc, mcc = args
        logits = jnp.einsum("bsd,dv->bsv", hcc, w_head.astype(hcc.dtype),
                            preferred_element_type=jnp.float32)
        from repro.sharding import constrain
        logits = constrain(logits, "batch", None, "act_vocab")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, lcc[..., None], axis=-1)[..., 0]
        return jnp.sum(-ll * mcc), jnp.sum(mcc)

    num, den = jax.lax.map(one, (hc, lc, mc))
    return jnp.sum(num) / jnp.maximum(jnp.sum(den), 1.0)


# ------------------------------------------------------------- metrics (§4.5)
# ONE epsilon for every MAPE-family metric, jnp and np paths alike
# (core.fedavg.evaluate_global imports it): near-zero actuals only occur in
# normalized [0, 1] space, where 1e-2 caps any single window's APE
# contribution at 100× its absolute error; kWh-space actuals are ≥ 0.16 so
# the guard never binds there.
MAPE_EPS = 1e-2


def rmse(pred, target):
    d = (pred - target).astype(jnp.float32)
    return jnp.sqrt(jnp.mean(d * d))


def mape(pred, target, eps: float = MAPE_EPS):
    """Mean absolute percentage error, in % (§4.5.2).

    Guards against division blow-up at near-zero actuals with ``eps`` in the
    denominator (the OpenEIA kWh minimum is 0.16 so this is benign there).
    """
    a = jnp.abs((target - pred) / jnp.maximum(jnp.abs(target), eps))
    return 100.0 * jnp.mean(a.astype(jnp.float32))


def accuracy(pred, target, eps: float = MAPE_EPS):
    """Accuracy = 100 − MAPE (§4.5.3), clipped to [0, 100]."""
    return jnp.clip(100.0 - mape(pred, target, eps), 0.0, 100.0)


def per_horizon_accuracy(pred, target, eps: float = MAPE_EPS):
    """Accuracy at each forecast step (paper Table 4 layout). (..., H) -> (H,)."""
    a = jnp.abs((target - pred) / jnp.maximum(jnp.abs(target), eps))
    m = 100.0 * jnp.mean(a.astype(jnp.float32).reshape(-1, pred.shape[-1]), axis=0)
    return jnp.clip(100.0 - m, 0.0, 100.0)
