"""Semi-synchronous buffered rounds (``AsyncConfig``, ``FLConfig.mode``).

Synchronous FedAvg waits for every selected client, so the slowest straggler
gates each round — on the paper's Pi cluster that is the wall-clock
bottleneck.  The semi-sync engine (FedBuff-style, Nguyen et al. 2022; see
PAPERS.md) instead:

1. **over-selects** ``m' = ceil(over_select * m)`` clients per round and
   dispatches them at the current simulated clock (``core/latency.py``
   assigns each a finish time: compute ∝ windows x epochs, uplink ∝
   post-quantize payload, pluggable straggler multiplier);
2. **flushes** the aggregate as soon as the first ``buffer_k`` pending
   updates arrive — the event clock advances to the buffer_k-th finish
   time, never to the straggler's;
3. **folds late arrivals** into whichever later round they land in, with
   staleness-discounted weights ``w_i * (1 + tau_i)^(-alpha)`` (tau =
   rounds late).  A stale delta was computed against the *dispatch-round*
   params, so the buffer stores deltas — already run through the per-client
   transform stack AT DISPATCH with the dispatch-round PRNG key, exactly
   like the sync round body, so the server's straggler buffer never holds
   raw fp32 updates — and the fold is
   ``w <- w + sum(w_tilde_i * delta_i) / sum(w_tilde_i)``, the pipeline's
   own ``_weighted_sums`` weighting fed staleness-discounted weights.

When a flush contains exactly this round's dispatch set and nothing is
buffered — always true for ``buffer_k = m'`` with zero-jitter latency —
the step routes through the engine's fused synchronous round, so that
configuration is **bit-identical** to ``mode="sync"`` on both the vmap and
shard_map execution paths (pinned by test).  The buffer itself lives at the
cloud server, so hierarchical topologies only affect the (unchanged)
client-update stage layout.

**Secure aggregation** (``SecureAggConfig``, ``AsyncConfig.cohort_atomic``):
pairwise masks are applied at dispatch, keyed by the DISPATCH round's shared
key, and cancel only over a complete dispatch cohort — so with masking on,
folds become cohort-ATOMIC: a round's updates wait in the buffer until every
member of that dispatch set has arrived, then fold as one group.  All
members of a late cohort share one staleness tau (current − dispatch round),
hence ONE discount factor, which scales every member's mask equally and
preserves cancellation.  A flush whose clock completes no cohort advances
time without a server step (``SemiSyncState.empty_flushes``).

**Fully-async pacing** (FedAsync-style) is the ``buffer_k=1`` corner: the
clock advances to the EARLIEST in-flight arrival and the server steps per
flush — benchmarked against sync/semi-sync by ``bench_scalability --mode
async``.

**Failure injection** (``ChurnConfig``, PR 6): with ``dropout_prob > 0``
the latency model marks some dispatched uploads as lost mid-flight
(``finish_time = inf`` — replayable per ``(seed, round, slot)``).  The
timeout sweep (:func:`_handle_timeouts`) runs at the top of every step:
plain semi-sync retries the client's retained delta (uplink-only cost, up
to ``max_retries``); cohort-atomic folds instead RE-KEY the whole cohort —
unarrived members are abandoned and the arrived survivors re-mask under the
next key generation restricted to the surviving slots, without the server
ever seeing a pre-mask delta (see ``secure_agg.mask_contribution``).  A
flush whose in-flight set is entirely lost advances nothing
(``empty_flushes``).  With ``dropout_prob == 0`` none of this machinery
runs and the schedule is bit-identical to the churn-free engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis import taint as taint_mod
from repro.configs.base import (AggregationConfig, AsyncConfig,
                                ForecasterConfig, SecureAggConfig,
                                TransformConfig)
from repro.core import aggregation as aggregation_mod
from repro.core import secure_agg as secure_agg_mod
from repro.core import server_opt as server_opt_mod
from repro.core import transforms as transforms_mod
from repro.core.client import local_update
from repro.sharding import shard_map

PyTree = Any


def staleness_discount(tau, alpha: float):
    """Weight multiplier for an update arriving ``tau`` rounds late:
    ``(1 + tau)^(-alpha)``.  Monotone non-increasing in tau; ``alpha = 0``
    disables the discount; a fresh update (tau = 0) is never discounted."""
    return (1.0 + np.asarray(tau, np.float64)) ** (-float(alpha))


# ------------------------------------------------------------ client stage
@functools.partial(jax.jit,
                   static_argnames=("cfg", "loss", "tcfg", "cell_impl",
                                    "scfg"))
def client_deltas(params, x, y, batch_idx, keys, lr, prox_mu,
                  cfg: ForecasterConfig, loss: Callable,
                  tcfg: TransformConfig = TransformConfig(),
                  cell_impl: str = "jnp",
                  scfg: "SecureAggConfig" = None, round_key=None,
                  w_full=None, slots=None):
    """Local-update + transform stages alone: per-client TRANSFORMED deltas
    ``stack(w_i - w_global)`` + losses, WITHOUT aggregation — the buffered
    server needs each client's contribution individually so it can release
    them on its own clock.  The transform stack runs here, at dispatch, for
    the same reason it runs inside the sync round body: only privatized /
    compressed deltas ever leave the client (the server's straggler buffer
    must not hold raw fp32 updates), and the simulated uplink charges the
    post-quantize payload.  ``keys``: (M, 2) dispatch-round transform keys.

    With secure aggregation, pairwise masks are applied HERE, at dispatch,
    keyed by the dispatch cohort's shared ``round_key`` and gated/scaled by
    the cohort weight vector ``w_full`` — so the buffer holds only masked
    uploads, and a cohort's masks cancel whenever the whole cohort is
    folded together (``AsyncConfig.cohort_atomic``).  ``slots`` carries the
    clients' GLOBAL dispatch slots on the shard_map path (None = local
    view, the vmap case).
    """
    from repro.core import fedavg as fedavg_mod
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
    # taint source (production no-op); the returned deltas ARE the uploads
    # the server's straggler buffer holds, so the exit of this function is
    # the shard boundary flcheck checks on the semi-sync fold path
    locals_ = taint_mod.tag_private(locals_)
    deltas = jax.tree.map(lambda l, g: l - g, locals_, params)
    stack = transforms_mod.make_stack(tcfg, scfg)
    if not stack.is_identity:
        deltas = fedavg_mod.apply_stack(stack, deltas, keys, slots=slots,
                                        w_full=w_full, round_key=round_key)
    return taint_mod.boundary(deltas), client_loss


@functools.lru_cache(maxsize=None)
def make_sharded_client_deltas(mesh, cfg: ForecasterConfig, loss: Callable,
                               tcfg: TransformConfig = TransformConfig(),
                               acfg: AggregationConfig = AggregationConfig(),
                               cell_impl: str = "jnp",
                               scfg: "SecureAggConfig" = None):
    """Mesh-sharded client stage: same layout as the fused pipeline round
    (clients over the 1-D axis, or the 2-D (region, clients) grid), but the
    per-client transformed deltas come back stacked instead of reduced —
    the transform stack still runs INSIDE the shard_map body, so only
    privatized/compressed deltas cross shard boundaries.

    With a cohort-aware stack (secure aggregation, or the clear shared-grid
    ring quantizer) the returned fn's signature grows the cohort context,
    mirroring ``fedavg.make_pipeline_round``:
    ``fn(params, x, y, batch_idx, keys, slots, w_full, round_key, lr,
    prox_mu)`` — global ``slots`` shard with the clients, the cohort weight
    vector and round key replicate.
    """
    agg = aggregation_mod.make_aggregator(acfg, mesh)
    pspec = agg.pspec()
    needs_ctx = transforms_mod.make_stack(tcfg, scfg).needs_cohort

    if not needs_ctx:
        def body(params, x, y, batch_idx, keys, lr, prox_mu):
            return client_deltas(params, x, y, batch_idx, keys, lr, prox_mu,
                                 cfg, loss, tcfg, cell_impl)

        return jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P(), pspec, pspec, pspec, pspec, P(), P()),
            out_specs=(pspec, pspec),
            check_vma=False))

    def secure_body(params, x, y, batch_idx, keys, slots, w_full, round_key,
                    lr, prox_mu):
        return client_deltas(params, x, y, batch_idx, keys, lr, prox_mu,
                             cfg, loss, tcfg, cell_impl, scfg, round_key,
                             w_full, slots)

    return jax.jit(shard_map(
        secure_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, pspec, pspec, P(), P(), P(),
                  P()),
        out_specs=(pspec, pspec),
        check_vma=False))


# --------------------------------------------------------- buffered server
@jax.jit
def buffered_aggregate(params, deltas, weights):
    """Fold a flushed buffer of (already-transformed) client deltas into the
    global model: ``w + sum(w_i * delta_i) / sum(w_i)``.

    deltas: client-stacked pytree (leading axis = arrivals, zero-padded);
    weights: (A,) staleness-discounted aggregation weights (0 marks pads,
    which then contribute nothing to either sum).  The weighting math is
    the pipeline's own ``_weighted_sums``.
    """
    from repro.core import fedavg as fedavg_mod
    sums, wsum = fedavg_mod._weighted_sums(deltas, weights)
    return jax.tree.map(lambda g, s: g + s / wsum, params, sums)


@jax.jit
def buffered_aggregate_preweighted(params, deltas, discounts, wsum):
    """Fold PRE-WEIGHTED uploads (float masked path: each delta is already
    ``w_i * delta_i + masks``): numerator weights are the staleness
    discounts ALONE — scaling a masked upload by anything non-uniform
    within its cohort would break mask cancellation, and its ``w_i`` is
    already inside — while the denominator ``wsum`` is the usual sum of
    discounted aggregation weights, supplied by the caller."""
    from repro.core import fedavg as fedavg_mod
    sums, _ = fedavg_mod._weighted_sums(deltas, discounts)
    return jax.tree.map(lambda g, s: g + s / wsum, params, sums)


@dataclasses.dataclass(eq=False)     # identity eq: deltas are array trees
class PendingUpdate:
    """One dispatched-but-not-yet-aggregated client update (host-side).
    ``delta`` is already transformed (clipped/noised/quantized at dispatch
    with the dispatch-round key) — the buffer never holds raw updates.

    ``finish_time = inf`` marks a mid-upload failure (``ChurnConfig``): the
    upload never arrives, and the timeout sweep (``_handle_timeouts``)
    eventually retries or abandons it.  ``retry_round`` is the round the
    update was (re)dispatched — the timeout baseline — and ``slot`` the
    client's dispatch slot, which keys its straggler/dropout draws and its
    position in the secure-agg mask cohort."""
    delta: PyTree                      # np arrays, computed at dispatch
    weight: float                      # base aggregation weight (pre-discount)
    loss: float                        # client's local training loss
    dispatch_round: int
    finish_time: float                 # simulated arrival (absolute seconds)
    slot: int = 0                      # global dispatch slot
    retries: int = 0                   # re-dispatch attempts so far
    retry_round: int = 0               # round of the latest (re)dispatch


def _tree_slice(tree, i: int):
    return jax.tree.map(lambda a: np.asarray(a[i]), tree)


def _ring_wrap_np(x: np.ndarray, bits: int) -> np.ndarray:
    """Host-side twin of ``transforms.ring_wrap``: reduce into the centered
    ring ``[-2^(b-1), 2^(b-1))`` (exact on float-encoded ints < 2^24)."""
    half = float(2 ** (bits - 1))
    return (np.mod(x + half, float(2 ** bits)) - half).astype(x.dtype)


def _stack_padded(pending: List[PendingUpdate], weights: np.ndarray):
    """Stack arrived updates into fixed-capacity (next-pow-2) batches so the
    jitted fold sees a bounded set of shapes (<= log2 traces)."""
    n = len(pending)
    cap = 1 << max(n - 1, 0).bit_length()
    deltas = jax.tree.map(
        lambda *xs: np.stack(xs + (np.zeros_like(xs[0]),) * (cap - n)),
        *[p.delta for p in pending])
    w = np.zeros(cap, np.float32)
    w[:n] = weights
    return deltas, w


class SemiSyncState:
    """The buffered server's host-side event state: pending updates + the
    simulated clock.  One per :class:`~repro.core.fedavg.RoundEngine`;
    reset between independent trainings (per cluster).

    ``cohort_sizes`` tracks how many REAL clients each dispatch round still
    has in the running — the bookkeeping cohort-atomic folds (secure
    aggregation) need to decide when a cohort is complete, decremented when
    a timeout abandons members.  ``cohort_w`` / ``cohort_gen`` carry each
    live cohort's current weight vector and re-key generation (dropout
    recovery re-masks survivors under generation g+1 with the dropped slots
    zeroed).  All three dicts are swept once no pending update references
    their round, so they stay O(live cohorts) on arbitrarily long runs.
    """

    def __init__(self) -> None:
        self.pending: List[PendingUpdate] = []
        self.clock = 0.0
        self.late_folds = 0            # stale updates folded so far
        self.max_staleness = 0         # largest tau seen
        self.cohort_sizes: dict = {}   # dispatch round -> # live dispatched
        self.cohort_w: dict = {}       # dispatch round -> (M,) weight vector
        self.cohort_gen: dict = {}     # dispatch round -> re-key generation
        # dispatch-time sum(base_w): the ring quantizer's shared grid is
        # normalized by it, so the fold's decode needs the ORIGINAL W even
        # after a re-key zeroes dropped slots in cohort_w
        self.cohort_W0: dict = {}      # dispatch round -> float
        self.empty_flushes = 0         # cohort-atomic flushes with no
        #                              # complete cohort (no server step)
        self.rekeys = 0                # cohort re-keys (dropout recovery)
        self.abandoned = 0             # updates dropped for good (timeout)

    def reset(self) -> None:
        self.__init__()

    def _sweep(self) -> None:
        """Drop cohort bookkeeping no pending update references (leak fix:
        entries used to accumulate forever in plain semi-sync mode)."""
        live = {p.dispatch_round for p in self.pending}
        for r in [r for r in self.cohort_sizes if r not in live]:
            self.cohort_sizes.pop(r)
            self.cohort_w.pop(r, None)
            self.cohort_gen.pop(r, None)
            self.cohort_W0.pop(r, None)

    # ---- checkpointing (fedavg.run_federated_training) -------------------
    def to_tree(self):
        """The full event state as a checkpointable pytree of numpy arrays
        (float64 scalars — the simulated clock and finish times round-trip
        exactly, which the bit-identical-resume pin needs)."""
        rounds = sorted(self.cohort_sizes)
        return {
            "clock": np.asarray([self.clock], np.float64),
            "counters": np.asarray(
                [self.late_folds, self.max_staleness, self.empty_flushes,
                 self.rekeys, self.abandoned], np.int64),
            "pending": [
                {"delta": p.delta,
                 "scalars": np.asarray(
                     [p.weight, p.loss, p.dispatch_round, p.finish_time,
                      p.slot, p.retries, p.retry_round], np.float64)}
                for p in self.pending],
            "cohort_rounds": np.asarray(rounds, np.int64),
            "cohort_sizes": np.asarray(
                [self.cohort_sizes[r] for r in rounds], np.int64),
            "cohort_gens": np.asarray(
                [self.cohort_gen.get(r, 0) for r in rounds], np.int64),
            "cohort_W0": np.asarray(
                [self.cohort_W0.get(r, 0.0) for r in rounds], np.float64),
            "cohort_w": (np.stack([np.asarray(self.cohort_w[r], np.float32)
                                   for r in rounds])
                         if rounds else np.zeros((0, 0), np.float32)),
        }

    @classmethod
    def from_tree(cls, tree) -> "SemiSyncState":
        ss = cls()
        ss.clock = float(np.asarray(tree["clock"]).reshape(-1)[0])
        (ss.late_folds, ss.max_staleness, ss.empty_flushes, ss.rekeys,
         ss.abandoned) = (int(v) for v in np.asarray(tree["counters"]))
        for entry in tree["pending"]:
            w, l, dr, ft, slot, rt, rr = (
                float(v) for v in np.asarray(entry["scalars"]))
            ss.pending.append(PendingUpdate(
                delta=jax.tree.map(np.asarray, entry["delta"]),
                weight=w, loss=l, dispatch_round=int(dr), finish_time=ft,
                slot=int(slot), retries=int(rt), retry_round=int(rr)))
        for i, r in enumerate(np.asarray(tree["cohort_rounds"], np.int64)):
            ss.cohort_sizes[int(r)] = int(tree["cohort_sizes"][i])
            ss.cohort_gen[int(r)] = int(tree["cohort_gens"][i])
            ss.cohort_w[int(r)] = np.asarray(tree["cohort_w"][i], np.float32)
            # pre-cohort_W0 checkpoints: the weight vector was never zeroed
            # before the field existed, so its sum is the dispatch-time W
            w0 = tree.get("cohort_W0")
            ss.cohort_W0[int(r)] = (float(w0[i]) if w0 is not None
                                    else float(ss.cohort_w[int(r)].sum()))
        return ss


def _handle_timeouts(engine, round_idx: int, stream: int) -> None:
    """Sweep the pending buffer for abandoned work (``ChurnConfig``): any
    update still unarrived ``timeout_rounds`` dispatches after its latest
    (re)dispatch is presumed lost — the server cannot distinguish a dropped
    upload from a merely slow one, so both are treated alike.

    *Plain semi-sync* (no cohort-atomic folds): the server asks the client to
    re-send its retained transformed delta — uplink-only cost on the re-upload
    latency stream, a fresh dropout draw per attempt, up to
    ``max_retries`` attempts, then the update is abandoned for good.

    *Cohort-atomic folds* (secure aggregation): a lost member means the
    cohort's pairwise masks can never cancel, so the whole cohort re-keys
    (Bonawitz-style recovery): unarrived members are abandoned, the
    surviving (arrived) members re-mask under the next key generation
    restricted to the surviving slots — via the mask-correction algebra of
    :func:`~repro.core.secure_agg.mask_contribution`, so the server never
    holds a pre-mask delta — and re-upload, charged on the re-upload latency
    stream.  Survivors therefore become in-flight again (their re-masked
    upload must arrive before the cohort can fold).  A cohort with no
    survivors is dropped entirely.  Without masking the same scheduling runs
    with no delta rewrite, which is what keeps the masked == clear pins
    valid under churn.
    """
    ss: SemiSyncState = engine.async_state
    churn = engine.latency.churn
    overdue = [p for p in ss.pending
               if p.finish_time > ss.clock
               and round_idx - p.retry_round >= churn.timeout_rounds]
    if not overdue:
        return

    if not engine.async_cfg.cohort_atomic:
        for p in overdue:
            if p.retries >= churn.max_retries:
                ss.pending.remove(p)
                ss.abandoned += 1
                continue
            p.retries += 1
            p.retry_round = round_idx
            re_t = float(engine.latency.reupload_times(
                round_idx, [p.slot], attempt=p.retries)[0])
            drop = bool(engine.latency.dropouts(
                round_idx, [p.slot], attempt=p.retries)[0])
            p.finish_time = float("inf") if drop else ss.clock + re_t
        ss._sweep()
        return

    # cohort-atomic: recover every cohort that lost a member
    ring = transforms_mod.make_stack(engine.transform,
                                     engine.secure).ring_spec
    masker = (secure_agg_mod.make_masker(
                  engine.secure, ring_bits=ring[0] if ring else 0)
              if engine.secure is not None else None)
    for r in sorted({p.dispatch_round for p in overdue}):
        cohort = [p for p in ss.pending if p.dispatch_round == r]
        lost = [p for p in cohort if p.finish_time > ss.clock]
        survivors = [p for p in cohort if p.finish_time <= ss.clock]
        for p in lost:
            ss.pending.remove(p)
        ss.abandoned += len(lost)
        if not survivors:
            # everyone lost: the cohort is gone (sweep drops its books)
            continue
        gen = ss.cohort_gen.get(r, 0)
        w_old = np.asarray(ss.cohort_w[r], np.float32)
        w_new = w_old.copy()
        w_new[[p.slot for p in lost]] = 0.0
        if masker is not None:
            old_key = engine.rekey_key(r, stream, gen)
            new_key = engine.rekey_key(r, stream, gen + 1)
            for p in survivors:
                old_m = jax.device_get(secure_agg_mod.mask_contribution(
                    masker, p.delta, p.slot, w_old, old_key))
                new_m = jax.device_get(secure_agg_mod.mask_contribution(
                    masker, p.delta, p.slot, w_new, new_key))
                if ring:
                    # exact ring algebra: wrap(v - old + new) == the upload
                    # the survivor would have produced under the new key
                    # (congruent mod 2^b; one reduction restores the wire)
                    p.delta = jax.tree.map(
                        lambda d, o, n: _ring_wrap_np(
                            np.asarray(d - o + n), ring[0]),
                        p.delta, old_m, new_m)
                else:
                    p.delta = jax.tree.map(
                        lambda d, o, n: np.asarray(d - o + n),
                        p.delta, old_m, new_m)
        # survivors re-upload their (re-masked) deltas: in-flight again,
        # with a fresh dropout draw — a failed re-upload triggers the next
        # generation's recovery at a later timeout
        slots = np.asarray([p.slot for p in survivors])
        re_t = engine.latency.reupload_times(round_idx, slots,
                                             attempt=gen + 1)
        drop = engine.latency.dropouts(round_idx, slots, attempt=gen + 1)
        for p, t, d in zip(survivors, re_t, drop):
            p.finish_time = float("inf") if d else ss.clock + float(t)
            p.retry_round = round_idx
            p.retries += 1
        ss.cohort_sizes[r] = len(survivors)
        ss.cohort_w[r] = w_new
        ss.cohort_gen[r] = gen + 1
        ss.rekeys += 1
        if engine.accountant is not None:
            # the re-keyed fold will carry only the survivors' noise
            # draws: shrink the central accountant's cohort (it keeps the
            # min over the run and re-prices retroactively — conservative;
            # no-op for per-client accounting)
            engine.accountant.observe_cohort(len(survivors))
    ss._sweep()


def semi_sync_step(engine, params, state, x, y, batch_idx, weights,
                   round_idx: int = 0, stream: int = 0):
    """One semi-synchronous round (``RoundEngine.step`` dispatches here).

    Same contract as the sync step — already-selected (over-selected) client
    data in, ``(params, server_state, loss)`` out — plus the simulated event
    clock advanced on ``engine.async_state``.  The reported loss is the
    discount-weighted mean local loss of the updates actually folded this
    round.
    """
    ss: SemiSyncState = engine.async_state
    acfg: AsyncConfig = engine.async_cfg
    ccfg = engine.flcfg.client_opt
    churn = engine.latency.churn
    if churn.faulty:
        # retry / re-key abandoned work BEFORE this round's dispatch, so a
        # recovered cohort can complete at this very flush
        _handle_timeouts(engine, round_idx, stream)
    w_in = np.asarray(weights, np.float32)
    real = np.flatnonzero(w_in > 0)    # mesh-padding duplicates excluded

    # -- dispatch: assign every real client a simulated finish time; a
    # mid-upload failure (ChurnConfig.dropout_prob) makes it infinite — the
    # upload simply never arrives, and only the timeout sweep notices
    times = engine.latency.times(round_idx, w_in[real], ccfg.local_epochs,
                                 slots=real)
    finish = ss.clock + times
    if churn.faulty:
        finish = np.where(engine.latency.dropouts(round_idx, real),
                          np.inf, finish)

    # -- flush point: clock advances to the k-th earliest arrival among
    # everything in flight (old stragglers + this round's dispatch); a
    # fractional threshold resolves against THIS round's dispatch size, so
    # it adapts to uneven cluster/holdout memberships.  Under cohort-atomic
    # folds the buffer can hold ARRIVED updates whose cohort is still
    # incomplete — those must not gate the clock (they'd pin it to past
    # arrival times forever), so the k-count sees only unarrived work.
    # Dropped uploads (finish = inf) can never gate it either.
    in_flight = [p.finish_time for p in ss.pending
                 if not acfg.cohort_atomic or p.finish_time > ss.clock]
    pend_finish = np.asarray(in_flight + list(finish))
    finite = pend_finish[np.isfinite(pend_finish)]
    if acfg.buffer_frac:
        k_cfg = max(1, int(np.ceil(acfg.buffer_frac * len(finish))))
    else:
        k_cfg = engine.buffer_k
    k = min(k_cfg, len(finite))
    have_flush = len(finite) > 0
    new_clock = (float(np.partition(finite, k - 1)[k - 1]) if have_flush
                 else ss.clock)
    arrive_now = finish <= new_clock

    if not ss.pending and bool(arrive_now.all()):
        # Complete flush of exactly this round's dispatch set, nothing
        # buffered: identical math to a synchronous round (all tau = 0),
        # so route through the fused sync path — this is what makes
        # semi_sync(buffer_k=m', zero jitter) bit-identical to sync.
        ss.clock = new_clock
        return engine._sync_step(params, state, x, y, batch_idx, weights,
                                 round_idx, stream)

    # -- slow path: compute every dispatched client's (transformed) delta
    # now — the simulation reveals them per the event clock — buffer, fold
    lr = jnp.float32(engine.flcfg.lr)
    mu = jnp.float32(engine.prox_mu)
    m = x.shape[0]
    keys = engine.round_keys(round_idx, m, stream)
    base_w = w_in if engine.weighted else (w_in > 0).astype(np.float32)
    if engine._client_fn is not None:
        if engine.needs_ctx:
            rk = engine.base_round_key(round_idx, stream)
            deltas, closs = engine._client_fn(
                params, x, y, batch_idx, keys, jnp.arange(m),
                jnp.asarray(base_w), rk, lr, mu)
        else:
            deltas, closs = engine._client_fn(params, x, y, batch_idx, keys,
                                              lr, mu)
    else:
        rk = (engine.base_round_key(round_idx, stream)
              if engine.needs_ctx else None)
        deltas, closs = client_deltas(params, x, y, batch_idx, keys, lr, mu,
                                      engine.fcfg, engine.loss,
                                      engine.transform, engine.cell_impl,
                                      engine.secure, rk,
                                      jnp.asarray(base_w))
    deltas = jax.device_get(deltas)
    closs = np.asarray(closs)
    for j, i in enumerate(real):
        ss.pending.append(PendingUpdate(
            delta=_tree_slice(deltas, int(i)), weight=float(base_w[i]),
            loss=float(closs[i]), dispatch_round=round_idx,
            finish_time=float(finish[j]), slot=int(i),
            retry_round=round_idx))
    ss.cohort_sizes[round_idx] = len(real)
    ss.cohort_w[round_idx] = np.asarray(base_w, np.float32).copy()
    ss.cohort_gen[round_idx] = 0
    ss.cohort_W0[round_idx] = float(np.asarray(base_w, np.float64).sum())

    if not have_flush:
        # EVERYTHING in flight is a dropped upload: nothing can arrive, so
        # buffer the dispatch, leave the clock alone, and wait for the
        # timeout sweep to retry / re-key
        ss.empty_flushes += 1
        return params, state, jnp.asarray(float("nan"))

    arrived = [p for p in ss.pending if p.finish_time <= new_clock]
    if acfg.cohort_atomic:
        # secure aggregation: a cohort's pairwise masks cancel only over
        # the COMPLETE dispatch set, so updates fold only when every member
        # of their dispatch round has arrived — the whole cohort then folds
        # as one group with one shared staleness tau (one shared discount,
        # which scales every member's mask equally).
        got = {}
        for p in arrived:
            got[p.dispatch_round] = got.get(p.dispatch_round, 0) + 1
        complete = {r for r, n in got.items()
                    if n == ss.cohort_sizes.get(r)}
        arrived = [p for p in arrived if p.dispatch_round in complete]
        if not arrived:
            # no complete cohort at this flush clock: advance time, keep
            # everything buffered, skip the server step entirely
            ss.clock = new_clock
            ss.empty_flushes += 1
            return params, state, jnp.asarray(float("nan"))
        # a complete cohort means EVERY live member arrived, so dropping by
        # dispatch round removes exactly the folded updates
        ss.pending = [p for p in ss.pending
                      if p.dispatch_round not in complete]
    else:
        ss.pending = [p for p in ss.pending if p.finish_time > new_clock]
    # the ring decode needs each folded cohort's grid geometry (dispatch
    # size M_r and dispatch-time weight sum W0_r); capture it BEFORE the
    # sweep drops the bookkeeping of fully folded cohorts
    cohort_meta = {r: (int(ss.cohort_w[r].shape[0]),
                       float(ss.cohort_W0[r]))
                   for r in {p.dispatch_round for p in arrived}}
    ss._sweep()
    ss.clock = new_clock

    tau = np.asarray([round_idx - p.dispatch_round for p in arrived])
    ss.late_folds += int((tau > 0).sum())
    ss.max_staleness = max(ss.max_staleness, int(tau.max(initial=0)))
    disc = staleness_discount(tau, acfg.staleness_alpha)
    eff_w = (np.asarray([p.weight for p in arrived]) * disc
             ).astype(np.float32)
    stack = transforms_mod.make_stack(engine.transform, engine.secure)
    ring = stack.ring_spec
    if ring is not None:
        # shared-grid ring uploads: decode per COHORT, host-side — wrap the
        # cohort's summed uploads back into the ring (exact integer mask
        # cancellation), rescale through its grid (scale * W0 recovers
        # sum(w_i * delta_i)), apply the cohort's shared staleness discount,
        # then divide by the usual discounted weight sum
        bits, sensitivity, headroom = ring
        num = jax.tree.map(lambda g: np.zeros_like(np.asarray(g)), params)
        for r in sorted(cohort_meta):
            members = [p for p in arrived if p.dispatch_round == r]
            m_r, w0_r = cohort_meta[r]
            s_r = transforms_mod.ring_scale(bits, sensitivity, m_r, headroom)
            d_r = float(staleness_discount(round_idx - r,
                                           acfg.staleness_alpha))
            coef = np.float32(d_r * s_r * w0_r)
            num = jax.tree.map(
                lambda a, *ds: a + coef * _ring_wrap_np(
                    np.sum(np.stack(ds), axis=0), bits),
                num, *[p.delta for p in members])
        denom = jnp.float32(eff_w.sum())
        w_agg = jax.tree.map(lambda g, s: g + jnp.asarray(s) / denom,
                             params, num)
    elif stack.pre_weighted:
        # float masked uploads already carry w_i: numerator weights are the
        # discounts alone (uniform within a cohort — anything else breaks
        # mask cancellation), denominator the discounted weight sum
        d_stack, disc_stack = _stack_padded(arrived,
                                            disc.astype(np.float32))
        w_agg = buffered_aggregate_preweighted(
            params, jax.tree.map(jnp.asarray, d_stack),
            jnp.asarray(disc_stack), jnp.float32(eff_w.sum()))
    else:
        d_stack, w_stack = _stack_padded(arrived, eff_w)
        w_agg = buffered_aggregate(params,
                                   jax.tree.map(jnp.asarray, d_stack),
                                   jnp.asarray(w_stack))
    losses = np.asarray([p.loss for p in arrived])
    loss = float(np.sum(eff_w * losses) / eff_w.sum())
    params, state = server_opt_mod.server_update(params, w_agg, state,
                                                 engine.flcfg.server)
    return params, state, jnp.asarray(loss)
