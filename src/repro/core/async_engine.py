"""Semi-synchronous buffered rounds (``AsyncConfig``, ``FLConfig.mode``).

Synchronous FedAvg waits for every selected client, so the slowest straggler
gates each round — on the paper's Pi cluster that is the wall-clock
bottleneck.  The semi-sync engine (FedBuff-style, Nguyen et al. 2022; see
PAPERS.md) instead:

1. **over-selects** ``m' = ceil(over_select * m)`` clients per round and
   dispatches them at the current simulated clock (``core/latency.py``
   assigns each a finish time: compute ∝ windows x epochs, uplink ∝
   post-quantize payload, pluggable straggler multiplier);
2. **flushes** the aggregate as soon as the first ``buffer_k`` pending
   updates arrive — the event clock advances to the buffer_k-th finish
   time, never to the straggler's;
3. **folds late arrivals** into whichever later round they land in, with
   staleness-discounted weights ``w_i * (1 + tau_i)^(-alpha)`` (tau =
   rounds late).  A stale delta was computed against the *dispatch-round*
   params, so the buffer stores deltas — already run through the per-client
   transform stack AT DISPATCH with the dispatch-round PRNG key, exactly
   like the sync round body, so the server's straggler buffer never holds
   raw fp32 updates — and the fold is
   ``w <- w + sum(w_tilde_i * delta_i) / sum(w_tilde_i)``, the pipeline's
   own ``_weighted_sums`` weighting fed staleness-discounted weights.

When a flush contains exactly this round's dispatch set and nothing is
buffered — always true for ``buffer_k = m'`` with zero-jitter latency —
the step routes through the engine's fused synchronous round, so that
configuration is **bit-identical** to ``mode="sync"`` on both the vmap and
shard_map execution paths (pinned by test).  The buffer itself lives at the
cloud server, so hierarchical topologies only affect the (unchanged)
client-update stage layout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import (AggregationConfig, AsyncConfig,
                                ForecasterConfig, TransformConfig)
from repro.core import aggregation as aggregation_mod
from repro.core import server_opt as server_opt_mod
from repro.core import transforms as transforms_mod
from repro.core.client import local_update
from repro.sharding import shard_map

PyTree = Any


def staleness_discount(tau, alpha: float):
    """Weight multiplier for an update arriving ``tau`` rounds late:
    ``(1 + tau)^(-alpha)``.  Monotone non-increasing in tau; ``alpha = 0``
    disables the discount; a fresh update (tau = 0) is never discounted."""
    return (1.0 + np.asarray(tau, np.float64)) ** (-float(alpha))


# ------------------------------------------------------------ client stage
@functools.partial(jax.jit,
                   static_argnames=("cfg", "loss", "tcfg", "cell_impl"))
def client_deltas(params, x, y, batch_idx, keys, lr, prox_mu,
                  cfg: ForecasterConfig, loss: Callable,
                  tcfg: TransformConfig = TransformConfig(),
                  cell_impl: str = "jnp"):
    """Local-update + transform stages alone: per-client TRANSFORMED deltas
    ``stack(w_i - w_global)`` + losses, WITHOUT aggregation — the buffered
    server needs each client's contribution individually so it can release
    them on its own clock.  The transform stack runs here, at dispatch, for
    the same reason it runs inside the sync round body: only privatized /
    compressed deltas ever leave the client (the server's straggler buffer
    must not hold raw fp32 updates), and the simulated uplink charges the
    post-quantize payload.  ``keys``: (M, 2) dispatch-round transform keys.
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
    deltas = jax.tree.map(lambda l, g: l - g, locals_, params)
    stack = transforms_mod.make_stack(tcfg)
    if not stack.is_identity:
        deltas = jax.vmap(stack)(deltas, keys)
    return deltas, client_loss


@functools.lru_cache(maxsize=None)
def make_sharded_client_deltas(mesh, cfg: ForecasterConfig, loss: Callable,
                               tcfg: TransformConfig = TransformConfig(),
                               acfg: AggregationConfig = AggregationConfig(),
                               cell_impl: str = "jnp"):
    """Mesh-sharded client stage: same layout as the fused pipeline round
    (clients over the 1-D axis, or the 2-D (region, clients) grid), but the
    per-client transformed deltas come back stacked instead of reduced —
    the transform stack still runs INSIDE the shard_map body, so only
    privatized/compressed deltas cross shard boundaries."""
    agg = aggregation_mod.make_aggregator(acfg, mesh)
    pspec = agg.pspec()

    def body(params, x, y, batch_idx, keys, lr, prox_mu):
        return client_deltas(params, x, y, batch_idx, keys, lr, prox_mu,
                             cfg, loss, tcfg, cell_impl)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, pspec, P(), P()),
        out_specs=(pspec, pspec),
        check_vma=False))


# --------------------------------------------------------- buffered server
@jax.jit
def buffered_aggregate(params, deltas, weights):
    """Fold a flushed buffer of (already-transformed) client deltas into the
    global model: ``w + sum(w_i * delta_i) / sum(w_i)``.

    deltas: client-stacked pytree (leading axis = arrivals, zero-padded);
    weights: (A,) staleness-discounted aggregation weights (0 marks pads,
    which then contribute nothing to either sum).  The weighting math is
    the pipeline's own ``_weighted_sums``.
    """
    from repro.core import fedavg as fedavg_mod
    sums, wsum = fedavg_mod._weighted_sums(deltas, weights)
    return jax.tree.map(lambda g, s: g + s / wsum, params, sums)


@dataclasses.dataclass
class PendingUpdate:
    """One dispatched-but-not-yet-aggregated client update (host-side).
    ``delta`` is already transformed (clipped/noised/quantized at dispatch
    with the dispatch-round key) — the buffer never holds raw updates."""
    delta: PyTree                      # np arrays, computed at dispatch
    weight: float                      # base aggregation weight (pre-discount)
    loss: float                        # client's local training loss
    dispatch_round: int
    finish_time: float                 # simulated arrival (absolute seconds)


def _tree_slice(tree, i: int):
    return jax.tree.map(lambda a: np.asarray(a[i]), tree)


def _stack_padded(pending: List[PendingUpdate], weights: np.ndarray):
    """Stack arrived updates into fixed-capacity (next-pow-2) batches so the
    jitted fold sees a bounded set of shapes (<= log2 traces)."""
    n = len(pending)
    cap = 1 << max(n - 1, 0).bit_length()
    deltas = jax.tree.map(
        lambda *xs: np.stack(xs + (np.zeros_like(xs[0]),) * (cap - n)),
        *[p.delta for p in pending])
    w = np.zeros(cap, np.float32)
    w[:n] = weights
    return deltas, w


class SemiSyncState:
    """The buffered server's host-side event state: pending updates + the
    simulated clock.  One per :class:`~repro.core.fedavg.RoundEngine`;
    reset between independent trainings (per cluster)."""

    def __init__(self) -> None:
        self.pending: List[PendingUpdate] = []
        self.clock = 0.0
        self.late_folds = 0            # stale updates folded so far
        self.max_staleness = 0         # largest tau seen

    def reset(self) -> None:
        self.__init__()


def semi_sync_step(engine, params, state, x, y, batch_idx, weights,
                   round_idx: int = 0, stream: int = 0):
    """One semi-synchronous round (``RoundEngine.step`` dispatches here).

    Same contract as the sync step — already-selected (over-selected) client
    data in, ``(params, server_state, loss)`` out — plus the simulated event
    clock advanced on ``engine.async_state``.  The reported loss is the
    discount-weighted mean local loss of the updates actually folded this
    round.
    """
    ss: SemiSyncState = engine.async_state
    acfg: AsyncConfig = engine.async_cfg
    ccfg = engine.flcfg.client_opt
    w_in = np.asarray(weights, np.float32)
    real = np.flatnonzero(w_in > 0)    # mesh-padding duplicates excluded

    # -- dispatch: assign every real client a simulated finish time
    times = engine.latency.times(round_idx, w_in[real], ccfg.local_epochs)
    finish = ss.clock + times

    # -- flush point: clock advances to the k-th earliest arrival among
    # everything in flight (old stragglers + this round's dispatch); a
    # fractional threshold resolves against THIS round's dispatch size, so
    # it adapts to uneven cluster/holdout memberships
    pend_finish = np.asarray([p.finish_time for p in ss.pending] +
                             list(finish))
    if acfg.buffer_frac:
        k_cfg = max(1, int(np.ceil(acfg.buffer_frac * len(finish))))
    else:
        k_cfg = engine.buffer_k
    k = min(k_cfg, len(pend_finish))
    new_clock = float(np.partition(pend_finish, k - 1)[k - 1])
    arrive_now = finish <= new_clock

    if not ss.pending and bool(arrive_now.all()):
        # Complete flush of exactly this round's dispatch set, nothing
        # buffered: identical math to a synchronous round (all tau = 0),
        # so route through the fused sync path — this is what makes
        # semi_sync(buffer_k=m', zero jitter) bit-identical to sync.
        ss.clock = new_clock
        return engine._sync_step(params, state, x, y, batch_idx, weights,
                                 round_idx, stream)

    # -- slow path: compute every dispatched client's (transformed) delta
    # now — the simulation reveals them per the event clock — buffer, fold
    lr = jnp.float32(engine.flcfg.lr)
    mu = jnp.float32(engine.prox_mu)
    keys = engine.round_keys(round_idx, x.shape[0], stream)
    if engine._client_fn is not None:
        deltas, closs = engine._client_fn(params, x, y, batch_idx, keys,
                                          lr, mu)
    else:
        deltas, closs = client_deltas(params, x, y, batch_idx, keys, lr, mu,
                                      engine.fcfg, engine.loss,
                                      engine.transform, engine.cell_impl)
    deltas = jax.device_get(deltas)
    closs = np.asarray(closs)
    base_w = w_in if engine.weighted else (w_in > 0).astype(np.float32)
    for j, i in enumerate(real):
        ss.pending.append(PendingUpdate(
            delta=_tree_slice(deltas, int(i)), weight=float(base_w[i]),
            loss=float(closs[i]), dispatch_round=round_idx,
            finish_time=float(finish[j])))

    arrived = [p for p in ss.pending if p.finish_time <= new_clock]
    ss.pending = [p for p in ss.pending if p.finish_time > new_clock]
    ss.clock = new_clock

    tau = np.asarray([round_idx - p.dispatch_round for p in arrived])
    ss.late_folds += int((tau > 0).sum())
    ss.max_staleness = max(ss.max_staleness, int(tau.max(initial=0)))
    eff_w = (np.asarray([p.weight for p in arrived])
             * staleness_discount(tau, acfg.staleness_alpha)
             ).astype(np.float32)
    d_stack, w_stack = _stack_padded(arrived, eff_w)
    w_agg = buffered_aggregate(params, jax.tree.map(jnp.asarray, d_stack),
                               jnp.asarray(w_stack))
    losses = np.asarray([p.loss for p in arrived])
    loss = float(np.sum(eff_w * losses) / eff_w.sum())
    params, state = server_opt_mod.server_update(params, w_agg, state,
                                                 engine.flcfg.server)
    return params, state, jnp.asarray(loss)
