"""Client-side local update (paper Alg. 1, ``ClientUpdate``).

E epochs of minibatch SGD on the client's private windows, expressed as a
fixed-shape ``lax.scan`` over precomputed minibatch indices so that the whole
client population can be vmapped / shard_mapped over the ``clients`` axis —
the TPU-native realization of "clients train in parallel".
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ForecasterConfig
from repro.models import forecaster


def sgd_step(params, batch, lr, cfg: ForecasterConfig, loss: Callable,
             cell_impl: str = "jnp"):
    l, g = jax.value_and_grad(forecaster.loss_fn)(params, batch, cfg, loss,
                                                  cell_impl)
    params = jax.tree.map(lambda w, gw: w - lr * gw, params, g)
    return params, l


@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def local_update(params, x, y, batch_idx, lr, cfg: ForecasterConfig,
                 loss: Callable, cell_impl: str = "jnp"):
    """Run the client's local schedule.

    params: global model (pytree); x: (n_win, L, 1); y: (n_win, H);
    batch_idx: (steps, B) int32. Returns (local params, mean local loss).
    """
    def step(p, idx):
        return sgd_step(p, {"x": x[idx], "y": y[idx]}, lr, cfg, loss, cell_impl)

    params, losses = jax.lax.scan(step, params, batch_idx)
    return params, jnp.mean(losses)
