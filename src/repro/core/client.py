"""Local-update stage of the federated pipeline (paper Alg. 1,
``ClientUpdate``): select -> **local-update** -> transform -> aggregate ->
server-update.

E epochs of minibatch SGD on the client's private windows, expressed as a
fixed-shape ``lax.scan`` over precomputed minibatch indices so that the whole
client population can be vmapped / shard_mapped over the ``clients`` axis —
the TPU-native realization of "clients train in parallel".  The stage's
schedule knobs (lr, E, B, loss, prox_mu) are carried by the typed
``configs.base.ClientOptConfig`` (the ``FLConfig.client_opt`` facade view);
the traced per-round values (lr, prox_mu) arrive as arguments so one jitted
round serves every schedule.

FedProx (Li et al. 2020) is supported via ``prox_mu``: the local objective
gains ``mu/2 ||w - w_global||^2`` anchored at the round's incoming global
params, realized as an extra ``mu * (w - w_global)`` gradient term.  With
``mu = 0`` the added term is exactly zero, so FedAvg semantics (and numerics)
are unchanged.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ForecasterConfig
from repro.models import forecaster


def sgd_step(params, batch, lr, cfg: ForecasterConfig, loss: Callable,
             cell_impl: str = "jnp", anchor=None, prox_mu=0.0):
    """One SGD step; ``anchor``/``prox_mu`` add the FedProx proximal gradient."""
    l, g = jax.value_and_grad(forecaster.loss_fn)(params, batch, cfg, loss,
                                                  cell_impl)
    if anchor is not None:
        g = jax.tree.map(lambda gw, w, a: gw + prox_mu * (w - a),
                         g, params, anchor)
    params = jax.tree.map(lambda w, gw: w - lr * gw, params, g)
    return params, l


@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def local_update(params, x, y, batch_idx, lr, cfg: ForecasterConfig,
                 loss: Callable, cell_impl: str = "jnp", prox_mu=0.0):
    """Run the client's local schedule.

    params: global model (pytree); x: (n_win, L, 1); y: (n_win, H);
    batch_idx: (steps, B) int32; prox_mu: FedProx strength (0 = plain FedAvg).
    Returns (local params, mean local loss).
    """
    anchor = params                      # round-start global model (FedProx)

    def step(p, idx):
        return sgd_step(p, {"x": x[idx], "y": y[idx]}, lr, cfg, loss,
                        cell_impl, anchor=anchor, prox_mu=prox_mu)

    params, losses = jax.lax.scan(step, params, batch_idx)
    return params, jnp.mean(losses)
