"""Secure aggregation via pairwise masking (``SecureAggConfig``).

The paper's privacy pitch is that raw consumption traces never leave the
edge — but through PR 4 the cloud still saw every individual client DELTA in
the clear (clipped/noised/quantized, yet per-client).  This module closes
that gap with the classic pairwise-masking construction (Bonawitz et al.,
"Practical Secure Aggregation"; see PAPERS.md): every pair of clients
``(i, j)`` in a dispatch cohort derives a SHARED mask from the cohort's
round key, client ``min(i,j)`` adds it and client ``max(i,j)`` subtracts it,
so each upload is individually high-variance noise while the masks cancel
exactly in the aggregator's sum.

**Weighted-contribution masking.**  The upload is the client's WEIGHTED
contribution with raw antisymmetric masks on top — never a ``1/w_i``-scaled
mask on the bare delta:

    float path:  y_i = w_i * T(delta_i) + sum_{j != i} sign(i,j) PRG(key_ij)
    ring path:   y_i = wrap_b( q_i      + sum_{j != i} sign(i,j) U(key_ij) )

so ``sum_i y_i = sum_i w_i * T(delta_i)`` (masks cancel pair-by-pair in the
UNWEIGHTED sum of uploads; the aggregator divides by ``W = sum_i w_i``
afterwards).  Mask strength on the wire is therefore independent of the
client's aggregation weight — a heavy client is masked exactly as hard as a
light one, closing the ``1/w_i`` secrecy gap documented in docs/privacy.md.

**Ring masking (quantize + mask).**  When the stack carries the shared-grid
ring quantizer (``transforms.StochasticQuantize(ring=True)`` — forced on
whenever masking and quantization are both enabled), the masker operates in
the quantizer's integer ring mod ``2^b``: ``q_i`` is the client's integer
grid value (its cohort-normalized weighted contribution, already carrying
``w_i / W``), the per-pair masks ``U(key_ij)`` are drawn UNIFORMLY over
``[0, 2^b)``, and the masked value is reduced back into the centered ring
(``transforms.ring_wrap``).  Wraparound makes each masked coordinate
information-theoretically uniform over the ring — one ``b``-bit symbol
per coordinate, so the wire stays ``int<b>+scale`` under masking — and
cancellation is EXACT integer arithmetic: the aggregator's ring-reduced sum
equals the unmasked sum bit-for-bit (``ring_wrap`` is a ring homomorphism
and each pair's masks sum to a multiple of ``2^b``).  The only residual
metadata is the shared public grid scale, which is derived from the
configured clip bound — it leaks no client's data (docs/privacy.md).

**Float masking (mask without quantize).**  Without an integer grid the
masks are Gaussian with scale ``mask_std`` on the weighted contribution;
cancellation is exact up to float rounding (two roundings per pair term),
which is why the float-path masked == clear pins are float-tolerance while
the ring-path pins are bitwise.  ``mask_std`` is ignored in ring mode —
uniform-over-the-ring is as masked as the wire format allows.

Key points of this implementation:

* **A cohort-aware ``DeltaTransform``.**  :class:`PairwiseMasker` registers
  at the END of the transform stack (clip -> noise -> quantize -> mask; see
  ``transforms.make_stack``) with its own stable PRNG tag.  Unlike the
  per-client transforms it needs cohort context — its own dispatch slot, the
  cohort's aggregation-weight vector, and the shared round key — passed as a
  :class:`CohortContext` by the stack.
* **Weight-0 pads are excluded.**  Mesh-divisibility pads enter the round
  with weight 0, so their uploads vanish from the sum — a mask shared with
  a pad could never cancel.  Pair masks are gated on BOTH endpoints having
  ``w > 0``, so the mask cohort is exactly the real dispatch set, and pad
  uploads are zeroed outright (a pad is a cycled DUPLICATE of a real
  client; sending its delta in the clear would leak that client's update).
* **Topology-independent.**  Mask generation is a pure function of
  ``(round key, slot pair)`` — no client-to-client communication — so each
  client computes its masks locally inside the vmap/shard_map round body and
  cancellation holds under the flat one-psum, the hierarchical
  edge->region->cloud psum pair, and the vmap path alike (the reduction is
  linear; see ``core/aggregation.py``).
* **Semi-sync cohorts.**  Masks are keyed by the DISPATCH round, so a
  cohort's masks cancel only when the whole cohort folds together; enabling
  secure aggregation forces ``AsyncConfig.cohort_atomic`` folds
  (``core/async_engine.py``), under which a late cohort folds as one group
  with one shared staleness discount — applied AFTER the ring decode on the
  ring path, and scaling every member's mask equally on the float path, so
  cancellation is preserved either way.

Simulation caveat (see docs/privacy.md): ring arithmetic is simulated with
float32-encoded integers (exact below 2^24), so the cancellation algebra,
the wire format, and the uniformity of masked uploads are all real; only
the storage type differs from a deployment's int8 buffers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, ClassVar, NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis import taint
from repro.configs.base import SecureAggConfig
from repro.core import transforms as transforms_mod

PyTree = Any

# domain-separation tag folded into the shared round key before the pair
# indices: pair keys can never collide with the per-client transform keys
# (which fold slot indices < m directly into the round key)
_PAIR_DOMAIN = 0x5EC0A6
# domain-separation tag for cohort RE-KEYS (dropout recovery): generation
# g > 0 of a cohort's shared key is fold_in(fold_in(base, _REKEY_DOMAIN), g),
# so a re-keyed cohort's masks can never collide with any dispatch round's
# gen-0 masks (round indices fold directly into the seed key)
_REKEY_DOMAIN = 0x2EC0DE


class CohortContext(NamedTuple):
    """Per-client view of the dispatch cohort, threaded to cohort-aware
    transforms by ``TransformStack``.

    ``slot``: this client's GLOBAL dispatch slot (scalar int32 — under
    shard_map the body only sees its local shard, so slots are passed in
    sharded alongside the client data).  ``weights``: the full (M,)
    aggregation-weight vector of the cohort (replicated across shards;
    weights are public — the server needs them to aggregate).  ``round_key``:
    the cohort's shared PRNG key (``RoundEngine.base_round_key``), identical
    for every member.
    """
    slot: jax.Array
    weights: jax.Array
    round_key: jax.Array


@dataclasses.dataclass(frozen=True)
class PairwiseMasker:
    """Cohort-aware ``DeltaTransform``: add the antisymmetric pairwise masks.

    For client ``i`` the total mask is ``sum_{j != i} sign(i,j) *
    draw(key_{ij})`` with ``key_{ij}`` derived from (round key, min(i,j),
    max(i,j)) — both endpoints derive the SAME draw and opposite signs.
    ``bits = 0`` is the float path (Gaussian draws scaled ``mask_std``,
    added to the weighted contribution ``w_i * delta_i``); ``bits = b > 0``
    is the ring path (draws uniform over ``[0, 2^b)``, added to the ring
    quantizer's integer grid and wrapped back into the centered ring — the
    input already carries its weight share, see the module docstring).
    Pairs are gated on both endpoints being real (``w > 0``).  Memory is
    O(params) per client: masks accumulate over cohort slots via
    ``lax.scan``, never materializing the (M, params) mask set.
    """
    mask_std: float = 1.0
    bits: int = 0                      # 0 = float masks; b = ring mod 2^b
    tag: ClassVar[int] = 3             # stable PRNG stream id (stack slot)
    needs_cohort: ClassVar[bool] = True
    is_masker: ClassVar[bool] = True   # stack predicate (pre-weighted sums)

    def __call__(self, delta: PyTree, key: jax.Array,
                 ctx: CohortContext) -> PyTree:
        del key                        # masks come from the SHARED round key
        w = ctx.weights
        i = ctx.slot
        base = jax.random.fold_in(ctx.round_key, _PAIR_DOMAIN)
        leaves, treedef = jax.tree.flatten(delta)
        ring = self.bits > 0

        def add_pair(acc, j):
            lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)
            pair_key = jax.random.fold_in(jax.random.fold_in(base, lo), hi)
            sign = jnp.where(i < j, 1.0, -1.0)
            gate = ((w[i] > 0) & (w[j] > 0) & (j != i))
            scale = 1.0 if ring else self.mask_std
            coef = (sign * gate * scale).astype(jnp.float32)
            ks = jax.random.split(pair_key, len(leaves))
            if ring:
                draws = [jax.random.randint(k, a.shape, 0, 2 ** self.bits
                                            ).astype(a.dtype)
                         for a, k in zip(acc, ks)]
            else:
                draws = [jax.random.normal(k, a.shape, a.dtype)
                         for a, k in zip(acc, ks)]
            acc = [a + coef * d for a, d in zip(acc, draws)]
            return acc, None

        zeros = [jnp.zeros_like(x) for x in leaves]
        masks, _ = jax.lax.scan(add_pair, zeros, jnp.arange(w.shape[0]))
        # pads (weight 0) upload ZERO — they can't join the mask cohort,
        # and their delta in the clear would leak the duplicated client's
        # update.  Their weight is 0, so the aggregate is unchanged.
        real_i = (w[i] > 0).astype(jnp.float32)
        if ring:
            # input is the ring quantizer's integer grid (already carries
            # w_i / W); uniform masks + wraparound make each coordinate
            # uniform over the ring, and cancellation is exact integers
            out = [real_i * transforms_mod.ring_wrap(x + mk, self.bits)
                   for x, mk in zip(leaves, masks)]
            wire = f"int{self.bits}+scale"
        else:
            # weighted-contribution masking: mask w_i * delta_i directly,
            # so upload secrecy never depends on the weight
            out = [real_i * (w[i] * x + mk) for x, mk in zip(leaves, masks)]
            wire = "float32"
        # taint marker (production no-op): this stage's flcheck label.  On
        # the ring path the declared wire encoding STAYS the quantizer's
        # int<b>+scale — masked coordinates are b-bit ring symbols — which
        # is exactly what the level-3 cost auditor proves end-to-end.  The
        # float path (no quantizer) ships fp32, same as its input.
        return taint.declassify(jax.tree.unflatten(treedef, out), "mask",
                                wire=wire)


@functools.partial(jax.jit, static_argnames=("masker",))
def mask_contribution(masker: PairwiseMasker, like: PyTree, slot, weights,
                      round_key) -> PyTree:
    """The mask-ONLY term of a masked upload: ``PairwiseMasker`` applied to
    a zero delta — ``real_i * sum_j sign * PRG(key_ij)`` for dispatch slot
    ``slot`` under cohort weights ``weights`` and shared key ``round_key``
    (ring-wrapped on the ring path).

    This is the algebraic basis of Bonawitz-style dropout recovery without
    the server ever holding a pre-mask delta: a survivor's re-keyed upload is

        y_i' = y_i - mask_contribution(old_key, w_old)
                   + mask_contribution(new_key, w_new)

    where ``w_new`` zeroes the dropped slots (on the ring path the rewrite
    is reduced back into the ring — exact ring subtraction, see
    ``async_engine._handle_timeouts``).  The subtraction replays the EXACT
    ops of the original masking (same scan, same pair keys), so the old
    mask cancels — bit-exactly in the ring, to one float rounding per leaf
    on the float path — and the new masks cancel over the surviving set in
    the aggregate as usual.  ``like`` only supplies shapes/dtypes.
    """
    ctx = CohortContext(jnp.asarray(slot, jnp.int32),
                        jnp.asarray(weights, jnp.float32), round_key)
    zeros = jax.tree.map(jnp.zeros_like, like)
    # the per-client key arg is unused by the masker (masks come from the
    # shared round key), but the signature wants one — feed it the round key
    # itself rather than forking an unrelated literal stream
    return masker(zeros, round_key, ctx)


def make_masker(cfg: SecureAggConfig, ring_bits: int = 0) -> PairwiseMasker:
    """Build the pairwise-masking stage a ``SecureAggConfig`` asks for.
    ``ring_bits`` (set by ``transforms.make_stack`` when the stack carries
    the ring quantizer) selects ring masking mod ``2^ring_bits``."""
    if not cfg.enabled:
        raise ValueError("make_masker called with secure aggregation "
                         "disabled (SecureAggConfig.enabled=False)")
    return PairwiseMasker(mask_std=cfg.mask_std, bits=int(ring_bits))
