"""Secure aggregation via pairwise masking (``SecureAggConfig``).

The paper's privacy pitch is that raw consumption traces never leave the
edge — but through PR 4 the cloud still saw every individual client DELTA in
the clear (clipped/noised/quantized, yet per-client).  This module closes
that gap with the classic pairwise-masking construction (Bonawitz et al.,
"Practical Secure Aggregation"; see PAPERS.md): every pair of clients
``(i, j)`` in a dispatch cohort derives a SHARED mask from the cohort's
round key, client ``min(i,j)`` adds it and client ``max(i,j)`` subtracts it,
so each upload is individually high-variance noise while the masks cancel
exactly in the aggregator's sum:

    y_i = T(delta_i) + (1/w_i) * sum_{j != i} sign(i,j) * PRG(key_{ij})
    sum_i w_i * y_i = sum_i w_i * T(delta_i)        (masks cancel)

Key points of this implementation:

* **A cohort-aware ``DeltaTransform``.**  :class:`PairwiseMasker` registers
  at the END of the transform stack (clip -> noise -> quantize -> mask; see
  ``transforms.make_stack``) with its own stable PRNG tag.  Unlike the
  per-client transforms it needs cohort context — its own dispatch slot, the
  cohort's aggregation-weight vector, and the shared round key — passed as a
  :class:`CohortContext` by the stack.
* **Masks cancel in the WEIGHTED sum.**  The aggregate is
  ``sum_i w_i * T(delta_i) / sum_i w_i``, so raw antisymmetric masks would
  NOT cancel under unequal weights.  Each client therefore scales its total
  mask by ``1/w_i`` (its own weight — the sample count the server already
  knows for weighted FedAvg), making the post-weighting mask contribution
  ``+mask_ij - mask_ij`` per pair.  Cancellation is exact up to float
  rounding (two roundings per pair term), which is why the masked == clear
  pins are float-tolerance, not bitwise.  Consequently ``mask_std`` is the
  mask scale on the client's *weighted* contribution ``w_i * y_i`` (the
  quantity the server actually sums); the raw upload ``y_i`` carries
  ``mask_std * sqrt(cohort-1) / w_i`` — under count-weighted aggregation,
  size ``mask_std`` relative to ``w * ||delta||``, not ``||delta||``.
  Under uniform aggregation (weights 0/1) the two coincide.
* **Weight-0 pads are excluded.**  Mesh-divisibility pads enter the round
  with weight 0, so their (weighted) uploads vanish from the sum — a mask
  shared with a pad could never cancel.  Pair masks are gated on BOTH
  endpoints having ``w > 0``, so the mask cohort is exactly the real
  dispatch set.
* **Topology-independent.**  Mask generation is a pure function of
  ``(round key, slot pair)`` — no client-to-client communication — so each
  client computes its masks locally inside the vmap/shard_map round body and
  cancellation holds under the flat one-psum, the hierarchical
  edge->region->cloud psum pair, and the vmap path alike (the reduction is
  linear; see ``core/aggregation.py``).
* **Semi-sync cohorts.**  Masks are keyed by the DISPATCH round, so a
  cohort's masks cancel only when the whole cohort folds together; enabling
  secure aggregation forces ``AsyncConfig.cohort_atomic`` folds
  (``core/async_engine.py``), under which a late cohort folds as one group
  with one shared staleness discount — the discount scales every member's
  mask equally, preserving cancellation.

Simulation caveat (see docs/privacy.md): real deployments mask in a finite
integer ring (mod ``2^b``) where the masked value is information-
theoretically uniform; we simulate additive masking in float32, which
demonstrates the cancellation algebra and its cost, not bit-level secrecy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, ClassVar, NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis import taint
from repro.configs.base import SecureAggConfig

PyTree = Any

# domain-separation tag folded into the shared round key before the pair
# indices: pair keys can never collide with the per-client transform keys
# (which fold slot indices < m directly into the round key)
_PAIR_DOMAIN = 0x5EC0A6
# domain-separation tag for cohort RE-KEYS (dropout recovery): generation
# g > 0 of a cohort's shared key is fold_in(fold_in(base, _REKEY_DOMAIN), g),
# so a re-keyed cohort's masks can never collide with any dispatch round's
# gen-0 masks (round indices fold directly into the seed key)
_REKEY_DOMAIN = 0x2EC0DE


class CohortContext(NamedTuple):
    """Per-client view of the dispatch cohort, threaded to cohort-aware
    transforms by ``TransformStack``.

    ``slot``: this client's GLOBAL dispatch slot (scalar int32 — under
    shard_map the body only sees its local shard, so slots are passed in
    sharded alongside the client data).  ``weights``: the full (M,)
    aggregation-weight vector of the cohort (replicated across shards;
    weights are public — the server needs them to aggregate).  ``round_key``:
    the cohort's shared PRNG key (``RoundEngine.base_round_key``), identical
    for every member.
    """
    slot: jax.Array
    weights: jax.Array
    round_key: jax.Array


@dataclasses.dataclass(frozen=True)
class PairwiseMasker:
    """Cohort-aware ``DeltaTransform``: add the antisymmetric pairwise masks.

    For client ``i`` the total mask is ``sum_{j != i} sign(i,j) * mask_std *
    N(key_{ij})`` with ``key_{ij}`` derived from (round key, min(i,j),
    max(i,j)) — both endpoints derive the SAME draw and opposite signs.
    Pairs are gated on both endpoints being real (``w > 0``), and the total
    is scaled by ``1/w_i`` so the masks cancel in the weighted aggregator
    sum (see module docstring).  Memory is O(params) per client: masks
    accumulate over cohort slots via ``lax.scan``, never materializing the
    (M, params) mask set.
    """
    mask_std: float = 1.0
    tag: ClassVar[int] = 3             # stable PRNG stream id (stack slot)
    needs_cohort: ClassVar[bool] = True

    def __call__(self, delta: PyTree, key: jax.Array,
                 ctx: CohortContext) -> PyTree:
        del key                        # masks come from the SHARED round key
        w = ctx.weights
        i = ctx.slot
        base = jax.random.fold_in(ctx.round_key, _PAIR_DOMAIN)
        leaves, treedef = jax.tree.flatten(delta)

        def add_pair(acc, j):
            lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)
            pair_key = jax.random.fold_in(jax.random.fold_in(base, lo), hi)
            sign = jnp.where(i < j, 1.0, -1.0)
            gate = ((w[i] > 0) & (w[j] > 0) & (j != i))
            coef = (sign * gate * self.mask_std).astype(jnp.float32)
            ks = jax.random.split(pair_key, len(leaves))
            acc = [a + coef * jax.random.normal(k, a.shape, a.dtype)
                   for a, k in zip(acc, ks)]
            return acc, None

        zeros = [jnp.zeros_like(x) for x in leaves]
        masks, _ = jax.lax.scan(add_pair, zeros, jnp.arange(w.shape[0]))
        # scale by 1/w_i so the weighted sum sees the raw antisymmetric
        # masks.  Weight-0 pads are CYCLED DUPLICATES of real clients
        # (fedavg mesh-divisibility padding): they can't join the mask
        # cohort (their masks would never cancel), so their upload must be
        # ZEROED, not sent in the clear — a pad slot leaking its
        # duplicate's delta unmasked would hand the server exactly the
        # per-client view masking exists to prevent.  Their weight is 0,
        # so the aggregate is unchanged.
        real_i = (w[i] > 0).astype(jnp.float32)
        inv_w = jnp.where(w[i] > 0, 1.0 / jnp.maximum(w[i], 1e-30), 0.0)
        out = [real_i * (x + mk * inv_w) for x, mk in zip(leaves, masks)]
        # taint marker (production no-op): this stage's flcheck label.  The
        # wire declaration re-WIDENS the upload: float pairwise masks do not
        # fit any integer grid, so a masked upload ships fp32 even when the
        # quantize stage ran first — the tracked divergence the level-3
        # cost auditor reports against latency.payload_bytes (ring masking
        # on the quantizer's grid is the ROADMAP buy-back).
        return taint.declassify(jax.tree.unflatten(treedef, out), "mask",
                                wire="float32")


@functools.partial(jax.jit, static_argnames=("masker",))
def mask_contribution(masker: PairwiseMasker, like: PyTree, slot, weights,
                      round_key) -> PyTree:
    """The mask-ONLY term of a masked upload: ``PairwiseMasker`` applied to
    a zero delta, i.e. ``real_i * mask_i / w_i`` for dispatch slot ``slot``
    under cohort weights ``weights`` and shared key ``round_key``.

    This is the algebraic basis of Bonawitz-style dropout recovery without
    the server ever holding a pre-mask delta: a survivor's re-keyed upload is

        y_i' = y_i - mask_contribution(old_key, w_old)
                   + mask_contribution(new_key, w_new)

    where ``w_new`` zeroes the dropped slots.  The subtraction replays the
    EXACT ops of the original masking (same scan, same pair keys), so the old
    mask cancels to one float rounding per leaf, and the new masks cancel
    over the surviving set in the weighted aggregate as usual.  ``like`` only
    supplies shapes/dtypes.
    """
    ctx = CohortContext(jnp.asarray(slot, jnp.int32),
                        jnp.asarray(weights, jnp.float32), round_key)
    zeros = jax.tree.map(jnp.zeros_like, like)
    # the per-client key arg is unused by the masker (masks come from the
    # shared round key), but the signature wants one — feed it the round key
    # itself rather than forking an unrelated literal stream
    return masker(zeros, round_key, ctx)


def make_masker(cfg: SecureAggConfig) -> PairwiseMasker:
    """Build the pairwise-masking stage a ``SecureAggConfig`` asks for."""
    if not cfg.enabled:
        raise ValueError("make_masker called with secure aggregation "
                         "disabled (SecureAggConfig.enabled=False)")
    return PairwiseMasker(mask_std=cfg.mask_std)
