"""Local-SGD / DiLoCo-style periodic aggregation — the paper's FedAvg schedule
as a *scalable cross-pod training feature* (DESIGN.md §2).

Observation: FedAvg ≡ local SGD with an H-step communication period.  On a
multi-pod mesh we exploit it where the links are slowest: gradients are
all-reduced every step only WITHIN a pod (fast ICI); parameters are averaged
ACROSS pods (slow inter-pod links) only every H inner steps, optionally passed
through an outer Nesterov optimizer (DiLoCo).  This divides the cross-pod
collective-bytes term of the roofline by ~H.

Usage inside a pjit/shard_map program over mesh ("pod", "data", "model"):

    inner:  grads = psum(grads, ("data",))          # NOT "pod"
    every H steps:
            params = outer_step(anchor, params, outer_state, axis="pod")
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import shard_map


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    inner_steps: int = 20          # H: steps between cross-pod syncs
    outer_lr: float = 0.7          # DiLoCo outer learning rate
    outer_momentum: float = 0.9    # Nesterov momentum on the outer delta
    nesterov: bool = True


class OuterState(NamedTuple):
    anchor: Any                    # params at the last sync (the "global" model)
    momentum: Any                  # outer momentum buffer


def init_outer_state(params) -> OuterState:
    return OuterState(anchor=params,
                      momentum=jax.tree.map(jnp.zeros_like, params))


def outer_step(params, state: OuterState, cfg: LocalSGDConfig,
               axis: str = "pod") -> Tuple[Any, OuterState]:
    """Cross-pod sync: average the per-pod parameter drift and apply it to the
    anchor with an outer Nesterov optimizer.  Must run inside shard_map with
    ``axis`` bound.  With outer_lr=1, momentum=0 this is exactly FedAvg over
    pods (paper Alg. 1 line: w ← mean(w_i))."""
    delta = jax.tree.map(lambda p, a: a - p, params, state.anchor)  # anchor - local
    delta = jax.tree.map(lambda d: jax.lax.pmean(d, axis), delta)
    m = jax.tree.map(
        lambda mom, d: cfg.outer_momentum * mom + d, state.momentum, delta)
    if cfg.nesterov:
        upd = jax.tree.map(lambda mom, d: cfg.outer_momentum * mom + d, m, delta)
    else:
        upd = m
    new_anchor = jax.tree.map(lambda a, u: a - cfg.outer_lr * u,
                              state.anchor, upd)
    return new_anchor, OuterState(anchor=new_anchor, momentum=m)


def fedavg_outer(params, axis: str = "pod"):
    """Plain FedAvg across pods (outer_lr=1, no momentum)."""
    return jax.tree.map(lambda p: jax.lax.pmean(p, axis), params)


def make_sharded_outer(mesh, cfg: LocalSGDConfig, axis: str = "pod"):
    """Jitted cross-pod sync: ``sync(stacked_local_params, outer_state) ->
    (new_anchor, new_state)``.

    ``stacked_local_params`` carries one (possibly divergent) parameter tree
    per pod on a leading axis of size ``mesh.shape[axis]``; that axis is
    sharded over ``axis`` so each pod sees only its own slice, and the
    cross-pod ``pmean`` inside :func:`outer_step` does the actual averaging.
    The outer state and returned anchor are replicated (version-portable via
    ``repro.sharding.shard_map``)."""
    def body(stacked_local_params, state):
        mine = jax.tree.map(lambda w: w[0], stacked_local_params)
        return outer_step(mine, state, cfg, axis)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                             out_specs=(P(), P()), check_vma=False))
