"""Delta-transform stage of the federated pipeline (select -> local-update ->
**transform(deltas)** -> aggregate -> server-update).

Each transform is a pure function of ONE client's update delta
``w_i - w_global`` (a pytree) plus a per-client PRNG key, applied INSIDE the
round body (vmapped over the client axis, before the aggregation collective) —
so on the mesh path the deltas that cross the wire are already clipped /
noised / quantized, exactly like a real edge deployment where the raw local
model never leaves the device.

Knob -> literature map (see PAPERS.md):

``TransformConfig.clip_norm`` (C)
    Per-client L2 clip ``delta * min(1, C / ||delta||_2)`` — the sensitivity
    bound of DP-FedAvg, and the clip step of privacy-preserving DER
    forecasting (arXiv:2107.03248); also tames client drift on non-IID load
    data.  The ROADMAP "secure-agg / DP hooks" item plugs in here.
``TransformConfig.noise_multiplier`` (z)
    Gaussian mechanism: add ``N(0, (z*C)^2)`` per coordinate to the clipped
    delta (C falls back to 1 when clipping is off).  With clip + noise the
    per-round release is the standard Gaussian-mechanism privitization of
    each client's contribution (arXiv:2107.03248 §III).
``TransformConfig.quantize_bits`` (b)
    Stochastic b-bit integer quantize/dequantize (per-leaf max-abs scaling,
    unbiased stochastic rounding).  Models the int8 uplink compression that
    lightweight FL for load forecasting uses to cut edge upload cost
    (arXiv:2404.03320) — b=8 is a 4x wire reduction vs float32.  We simulate
    the wire format (quantize then dequantize) so aggregation math stays in
    float.

``TransformConfig.quantize_ring``
    Shared-grid RING quantizer — the wire format secure aggregation masks
    in (forced on whenever masking + quantization are both enabled, and
    available standalone as the bit-exact clear comparator).  Instead of a
    per-leaf data-dependent scale, every cohort member quantizes its
    cohort-normalized weighted contribution ``(w_i / W) * delta_i`` onto
    ONE public grid ``s = sensitivity / levels`` (sensitivity = clip norm,
    falling back to 1), with
    ``levels = floor((2^(b-1) - 1 - M) / (1 + 4z))`` reserving ``M`` grid
    steps of stochastic-rounding headroom plus a 4-sigma margin for the DP
    noise tail so the cohort's integer sum provably fits the ring without
    truncating the Gaussian.  The output is the integer grid itself (not a
    dequantized float): the aggregator sums uploads UNWEIGHTED, reduces the
    sum into the ring, and rescales — see ``fedavg._pipeline_body``.

``SecureAggConfig.enabled``
    Pairwise masking (``core/secure_agg.py``): antisymmetric per-pair masks
    derived from the cohort's shared round key, added LAST in the stack so
    the upload that crosses the wire is individually noise but the masks
    cancel in the aggregator sum — actual secure aggregation on top of the
    DP/compression stack.  With the quantize stage on the masker operates
    in the ring mod ``2^b`` (uniform integer masks, exact wraparound
    cancellation); otherwise it adds Gaussian masks to the weighted float
    upload.  It is a *cohort-aware* transform: the stack threads it a
    :class:`~repro.core.secure_agg.CohortContext` (own slot, cohort
    weights, shared round key) in addition to the per-client key.

Transforms compose as a :class:`TransformStack` in the fixed order
clip -> noise -> quantize -> mask (sensitivity bound first, privacy second,
compression third, wire masking last).  The empty stack is the identity and
keeps the round bit-identical to the pre-transform engine
(``core/fedavg.py`` routes identity stacks through the legacy aggregation
math).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import taint
from repro.configs.base import SecureAggConfig, TransformConfig

PyTree = Any


class DeltaTransform(Protocol):
    """One per-client delta transform: ``(delta_tree, key) -> delta_tree``.

    Implementations must be hashable (frozen dataclasses) so a stack can be
    a static jit argument, and must be vmap-safe (pure jnp + jax.random).
    ``tag`` is the transform's STABLE key-derivation id (see
    :class:`TransformStack`): unique per transform kind, never reused.
    """

    tag: ClassVar[int]

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree: ...


def global_l2_norm(tree: PyTree) -> jax.Array:
    """L2 norm over ALL leaves of a pytree (one client's delta)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ------------------------------------------------------------ ring helpers
# Noise-tail margin of the shared ring grid, in per-coordinate noise
# standard deviations: a noised contribution is kept un-truncated out to
# this many sigma (residual clipped mass 2*Phi(-4) ~ 6e-5 per coordinate).
RING_NOISE_TAIL_SIGMAS: float = 4.0


def ring_levels(bits: int, cohort: int, noise_headroom: float = 0.0) -> int:
    """Grid levels of the shared ring quantizer:
    ``floor((2^(bits-1) - 1 - M) / (1 + noise_headroom))``.

    The ``M`` reserved steps are stochastic-rounding headroom — each cohort
    member's rounding can overshoot its weight share by at most one grid
    step.  ``noise_headroom`` (``RING_NOISE_TAIL_SIGMAS * z`` when the DP
    noise stage is on, else 0) additionally reserves a multiplicative
    noise-tail margin: client ``i``'s per-coordinate Gaussian noise has std
    ``frac_i * z * levels`` grid steps, so its cap grows to
    ``frac_i * levels * (1 + noise_headroom)`` — signal plus
    ``RING_NOISE_TAIL_SIGMAS`` sigma of noise.  Without the margin the cap
    would truncate the noise at ~``1/z`` sigma, biasing the aggregate and
    voiding the full-std Gaussian premise the DP accountant prices.  The
    cohort's integer sum stays bounded by
    ``levels * (1 + noise_headroom) + M <= 2^(bits-1) - 1``, so the ring
    decode ``wrap(sum)`` is exact, never an aliased wraparound.
    """
    levels = int((2 ** (bits - 1) - 1 - int(cohort))
                 / (1.0 + float(noise_headroom)))
    if levels < 1:
        raise ValueError(
            f"dispatch cohort of {cohort} does not fit the int{bits} ring "
            f"with noise headroom {float(noise_headroom):.3g}: need "
            f"(2^{bits - 1} - 1 - cohort) / (1 + headroom) >= 1 — widen "
            "the quantize bits or lower dp_noise")
    return levels


def ring_scale(bits: int, sensitivity: float, cohort: int,
               noise_headroom: float = 0.0) -> float:
    """Public grid step of the shared ring quantizer (one float for the
    whole cohort — the +4-byte wire scale field, and the only residual
    metadata a masked upload carries)."""
    return float(sensitivity) / ring_levels(bits, cohort, noise_headroom)


def ring_wrap(x, bits: int):
    """Reduce integer-valued ``x`` into the centered ring
    ``[-2^(bits-1), 2^(bits-1) - 1]`` (i.e. mod ``2^bits``).  Exact for
    float32-encoded integers below 2^24 — the simulation's stand-in for
    int arithmetic that overflows by construction."""
    half = float(2 ** (bits - 1))
    return jnp.mod(x + half, float(2 ** bits)) - half


@dataclasses.dataclass(frozen=True)
class L2Clip:
    """Scale the whole delta so its global L2 norm is at most ``clip_norm``."""
    clip_norm: float
    tag: ClassVar[int] = 0             # stable PRNG stream id (no randomness)

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree:
        norm = global_l2_norm(delta)
        factor = jnp.minimum(1.0, self.clip_norm / jnp.maximum(norm, 1e-12))
        # taint marker (production no-op): this stage's flcheck label
        return taint.declassify(jax.tree.map(lambda x: x * factor, delta),
                                "clip")


@dataclasses.dataclass(frozen=True)
class GaussianNoise:
    """Add per-coordinate ``N(0, sigma^2)`` noise (Gaussian mechanism)."""
    sigma: float
    tag: ClassVar[int] = 1             # stable PRNG stream id

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree:
        leaves, treedef = jax.tree.flatten(delta)
        keys = jax.random.split(key, len(leaves))
        noised = [x + self.sigma * jax.random.normal(k, x.shape, x.dtype)
                  for x, k in zip(leaves, keys)]
        # taint marker (production no-op): this stage's flcheck label
        return taint.declassify(jax.tree.unflatten(treedef, noised), "noise")


@dataclasses.dataclass(frozen=True)
class StochasticQuantize:
    """Unbiased ``bits``-bit integer quantization, two grids:

    *Adaptive (default, ``ring=False``)*: each leaf is scaled by
    ``max|x| / (2^(bits-1) - 1)`` to the signed integer grid, stochastically
    rounded (``floor(x/s + u)``, ``u ~ U[0,1)`` — exact in expectation),
    then dequantized.  Round-trip error is bounded by one grid step ``s``
    per coordinate; an all-zero leaf round-trips to zero.

    *Ring (``ring=True``, cohort-aware)*: every cohort member quantizes its
    cohort-normalized weighted contribution ``(w_i / W) * x`` onto ONE
    public grid ``s = sensitivity / ring_levels(bits, M, noise_headroom)``
    and returns the INTEGER grid values themselves (float32-encoded ints),
    clipped to this client's widened weight share
    ``floor((w_i/W) * levels * (1 + noise_headroom)) + 1`` — the
    per-client cap that bounds the cohort's integer sum inside the ring
    while leaving ``RING_NOISE_TAIL_SIGMAS`` sigma of room for the DP
    noise tail (``noise_headroom = RING_NOISE_TAIL_SIGMAS * z``; see
    ``ring_levels``).  This is the grid secure-agg masks live on
    (``core/secure_agg.py``); the aggregator decodes with ``ring_wrap`` +
    ``ring_scale`` (``fedavg._pipeline_body``).  A data-INdependent grid
    means the wire scale leaks only the configured clip bound, not any
    client's delta magnitude.
    """
    bits: int = 8
    ring: bool = False
    sensitivity: float = 1.0           # ring grid bound (clip norm, or 1)
    noise_headroom: float = 0.0        # ring noise-tail margin (k * z)
    tag: ClassVar[int] = 2             # stable PRNG stream id

    @property
    def needs_cohort(self) -> bool:
        return self.ring               # ring grid needs (slot, weights)

    def __call__(self, delta: PyTree, key: jax.Array, ctx=None) -> PyTree:
        leaves, treedef = jax.tree.flatten(delta)
        keys = jax.random.split(key, len(leaves))
        out = []
        if self.ring:
            levels = ring_levels(self.bits, ctx.weights.shape[0],
                                 self.noise_headroom)
            scale = self.sensitivity / levels
            w = ctx.weights
            frac = w[ctx.slot] / jnp.maximum(jnp.sum(w), 1e-30)
            # widened cap: weight share plus the reserved noise-tail margin
            cap = float(levels) * (1.0 + self.noise_headroom)
            qmax = jnp.floor(frac * cap) + 1.0
            for x, k in zip(leaves, keys):
                u = jax.random.uniform(k, x.shape)
                q = jnp.clip(jnp.floor(frac * x / scale + u), -qmax, qmax)
                out.append(q.astype(x.dtype))
        else:
            levels = float(2 ** (self.bits - 1) - 1)   # int8 -> 127
            for x, k in zip(leaves, keys):
                sc = jnp.max(jnp.abs(x)) / levels
                safe = jnp.maximum(sc, jnp.finfo(jnp.float32).tiny)
                u = jax.random.uniform(k, x.shape)
                q = jnp.clip(jnp.floor(x / safe + u), -levels, levels)
                out.append((q * safe).astype(x.dtype))
        # taint marker (production no-op): this stage's flcheck label.  The
        # wire declaration is what the level-3 cost auditor reads off the
        # boundary: the values above STAND FOR an int<bits> grid + one fp32
        # scale per leaf on the real uplink (adaptive: simulated-dequantize
        # floats; ring: the shared-grid integers themselves).
        return taint.declassify(jax.tree.unflatten(treedef, out), "quantize",
                                wire=f"int{self.bits}+scale")


@dataclasses.dataclass(frozen=True)
class TransformStack:
    """Ordered composition of delta transforms; hashable, so jit-static.

    Each stage gets a decorrelated sub-key ``fold_in(key, t.tag)`` of the
    per-client key, so noise and stochastic rounding never share bits.  The
    fold-in uses the transform's STABLE per-kind ``tag`` — NOT its position
    in the stack — so toggling one stage (e.g. turning ``clip_norm`` off)
    cannot silently shift another stage's random stream: a DP-noise draw is
    the same bits with or without clipping/quantization around it.

    Cohort-aware transforms (``needs_cohort = True``, e.g. the pairwise
    masker) additionally receive the cohort context — calling a stack that
    contains one without ``ctx`` raises, so a secure-agg stack can never
    silently run unmasked.
    """
    transforms: Tuple[DeltaTransform, ...] = ()

    @property
    def is_identity(self) -> bool:
        return not self.transforms

    @property
    def needs_cohort(self) -> bool:
        """True when any member transform needs the dispatch-cohort context
        (slot / weights / shared round key) — see ``core/secure_agg.py``."""
        return any(getattr(t, "needs_cohort", False) for t in self.transforms)

    @property
    def ring_spec(self):
        """``(bits, sensitivity, noise_headroom)`` of the shared-grid ring
        quantizer when the stack carries one, else None — the engine's
        signal to decode the aggregate with ``ring_wrap``/``ring_scale``
        (the decode grid must be sized with the SAME noise headroom the
        encoder reserved)."""
        for t in self.transforms:
            if isinstance(t, StochasticQuantize) and t.ring:
                return (t.bits, t.sensitivity, t.noise_headroom)
        return None

    @property
    def pre_weighted(self) -> bool:
        """True when uploads already carry their aggregation weight — the
        ring quantizer folds in ``w_i / W``, the masker folds in ``w_i``
        (weighted-contribution masking) — so the aggregator must sum them
        UNWEIGHTED (weighting twice would double-count)."""
        return self.ring_spec is not None or any(
            getattr(t, "is_masker", False) for t in self.transforms)

    def __call__(self, delta: PyTree, key: jax.Array, ctx=None) -> PyTree:
        seen: dict = {}
        for t in self.transforms:
            occ = seen.get(t.tag, 0)   # same-kind repeats get fresh streams
            seen[t.tag] = occ + 1
            sub = jax.random.fold_in(jax.random.fold_in(key, t.tag), occ)
            if getattr(t, "needs_cohort", False):
                if ctx is None:
                    raise ValueError(
                        f"{type(t).__name__} needs the dispatch-cohort "
                        "context (slot/weights/round key); call the stack "
                        "with ctx=CohortContext(...)")
                delta = t(delta, sub, ctx)
            else:
                delta = t(delta, sub)
        return delta


def make_stack(cfg: TransformConfig,
               secure: Optional[SecureAggConfig] = None) -> TransformStack:
    """Build the clip -> noise -> quantize -> mask stack selected by a
    ``TransformConfig`` (+ optional ``SecureAggConfig``), the
    ``FLConfig.transform`` / ``FLConfig.secure`` facade views."""
    ts = []
    secure_on = secure is not None and secure.enabled
    sensitivity = cfg.clip_norm if cfg.clip_norm > 0.0 else 1.0
    # masking + quantization compose in the quantizer's integer ring: the
    # shared-grid ring quantizer is forced on so the masks have an integer
    # grid to be uniform over (and the wire stays int<b>+scale)
    ring = bool(cfg.quantize_bits) and (cfg.quantize_ring or secure_on)
    if cfg.clip_norm > 0.0:
        ts.append(L2Clip(cfg.clip_norm))
    if cfg.noise_multiplier > 0.0:
        ts.append(GaussianNoise(cfg.noise_multiplier * sensitivity))
    if cfg.quantize_bits:
        ts.append(StochasticQuantize(
            cfg.quantize_bits, ring=ring,
            sensitivity=sensitivity if ring else 1.0,
            # ring grids reserve k-sigma of room for the DP noise tail so
            # the per-client cap does not truncate the Gaussian (which
            # would bias the sum and void the accountant's premise)
            noise_headroom=(RING_NOISE_TAIL_SIGMAS * cfg.noise_multiplier
                            if ring else 0.0)))
    if secure_on:
        from repro.core import secure_agg  # late: secure_agg is a leaf module
        ts.append(secure_agg.make_masker(
            secure, ring_bits=cfg.quantize_bits if ring else 0))
    return TransformStack(tuple(ts))
