"""Delta-transform stage of the federated pipeline (select -> local-update ->
**transform(deltas)** -> aggregate -> server-update).

Each transform is a pure function of ONE client's update delta
``w_i - w_global`` (a pytree) plus a per-client PRNG key, applied INSIDE the
round body (vmapped over the client axis, before the aggregation collective) —
so on the mesh path the deltas that cross the wire are already clipped /
noised / quantized, exactly like a real edge deployment where the raw local
model never leaves the device.

Knob -> literature map (see PAPERS.md):

``TransformConfig.clip_norm`` (C)
    Per-client L2 clip ``delta * min(1, C / ||delta||_2)`` — the sensitivity
    bound of DP-FedAvg, and the clip step of privacy-preserving DER
    forecasting (arXiv:2107.03248); also tames client drift on non-IID load
    data.  The ROADMAP "secure-agg / DP hooks" item plugs in here.
``TransformConfig.noise_multiplier`` (z)
    Gaussian mechanism: add ``N(0, (z*C)^2)`` per coordinate to the clipped
    delta (C falls back to 1 when clipping is off).  With clip + noise the
    per-round release is the standard Gaussian-mechanism privitization of
    each client's contribution (arXiv:2107.03248 §III).
``TransformConfig.quantize_bits`` (b)
    Stochastic b-bit integer quantize/dequantize (per-leaf max-abs scaling,
    unbiased stochastic rounding).  Models the int8 uplink compression that
    lightweight FL for load forecasting uses to cut edge upload cost
    (arXiv:2404.03320) — b=8 is a 4x wire reduction vs float32.  We simulate
    the wire format (quantize then dequantize) so aggregation math stays in
    float.

``SecureAggConfig.enabled``
    Pairwise masking (``core/secure_agg.py``): antisymmetric per-pair masks
    derived from the cohort's shared round key, added LAST in the stack so
    the upload that crosses the wire is individually noise but the masks
    cancel in the aggregator sum — actual secure aggregation on top of the
    DP/compression stack.  It is a *cohort-aware* transform: the stack
    threads it a :class:`~repro.core.secure_agg.CohortContext` (own slot,
    cohort weights, shared round key) in addition to the per-client key.

Transforms compose as a :class:`TransformStack` in the fixed order
clip -> noise -> quantize -> mask (sensitivity bound first, privacy second,
compression third, wire masking last).  The empty stack is the identity and
keeps the round bit-identical to the pre-transform engine
(``core/fedavg.py`` routes identity stacks through the legacy aggregation
math).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import taint
from repro.configs.base import SecureAggConfig, TransformConfig

PyTree = Any


class DeltaTransform(Protocol):
    """One per-client delta transform: ``(delta_tree, key) -> delta_tree``.

    Implementations must be hashable (frozen dataclasses) so a stack can be
    a static jit argument, and must be vmap-safe (pure jnp + jax.random).
    ``tag`` is the transform's STABLE key-derivation id (see
    :class:`TransformStack`): unique per transform kind, never reused.
    """

    tag: ClassVar[int]

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree: ...


def global_l2_norm(tree: PyTree) -> jax.Array:
    """L2 norm over ALL leaves of a pytree (one client's delta)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


@dataclasses.dataclass(frozen=True)
class L2Clip:
    """Scale the whole delta so its global L2 norm is at most ``clip_norm``."""
    clip_norm: float
    tag: ClassVar[int] = 0             # stable PRNG stream id (no randomness)

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree:
        norm = global_l2_norm(delta)
        factor = jnp.minimum(1.0, self.clip_norm / jnp.maximum(norm, 1e-12))
        # taint marker (production no-op): this stage's flcheck label
        return taint.declassify(jax.tree.map(lambda x: x * factor, delta),
                                "clip")


@dataclasses.dataclass(frozen=True)
class GaussianNoise:
    """Add per-coordinate ``N(0, sigma^2)`` noise (Gaussian mechanism)."""
    sigma: float
    tag: ClassVar[int] = 1             # stable PRNG stream id

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree:
        leaves, treedef = jax.tree.flatten(delta)
        keys = jax.random.split(key, len(leaves))
        noised = [x + self.sigma * jax.random.normal(k, x.shape, x.dtype)
                  for x, k in zip(leaves, keys)]
        # taint marker (production no-op): this stage's flcheck label
        return taint.declassify(jax.tree.unflatten(treedef, noised), "noise")


@dataclasses.dataclass(frozen=True)
class StochasticQuantize:
    """Unbiased ``bits``-bit integer quantize/dequantize, per-leaf scaling.

    Each leaf is scaled by ``max|x| / (2^(bits-1) - 1)`` to the signed integer
    grid, stochastically rounded (``floor(x/s + u)``, ``u ~ U[0,1)`` — exact
    in expectation), then dequantized.  Round-trip error is bounded by one
    grid step ``s`` per coordinate; an all-zero leaf round-trips to zero.
    """
    bits: int = 8
    tag: ClassVar[int] = 2             # stable PRNG stream id

    def __call__(self, delta: PyTree, key: jax.Array) -> PyTree:
        levels = float(2 ** (self.bits - 1) - 1)       # int8 -> 127
        leaves, treedef = jax.tree.flatten(delta)
        keys = jax.random.split(key, len(leaves))
        out = []
        for x, k in zip(leaves, keys):
            scale = jnp.max(jnp.abs(x)) / levels
            safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
            u = jax.random.uniform(k, x.shape)
            q = jnp.clip(jnp.floor(x / safe + u), -levels, levels)
            out.append((q * safe).astype(x.dtype))
        # taint marker (production no-op): this stage's flcheck label.  The
        # wire declaration is what the level-3 cost auditor reads off the
        # boundary: the simulated-dequantize floats above STAND FOR an
        # int<bits> grid + one fp32 scale per leaf on the real uplink.
        return taint.declassify(jax.tree.unflatten(treedef, out), "quantize",
                                wire=f"int{self.bits}+scale")


@dataclasses.dataclass(frozen=True)
class TransformStack:
    """Ordered composition of delta transforms; hashable, so jit-static.

    Each stage gets a decorrelated sub-key ``fold_in(key, t.tag)`` of the
    per-client key, so noise and stochastic rounding never share bits.  The
    fold-in uses the transform's STABLE per-kind ``tag`` — NOT its position
    in the stack — so toggling one stage (e.g. turning ``clip_norm`` off)
    cannot silently shift another stage's random stream: a DP-noise draw is
    the same bits with or without clipping/quantization around it.

    Cohort-aware transforms (``needs_cohort = True``, e.g. the pairwise
    masker) additionally receive the cohort context — calling a stack that
    contains one without ``ctx`` raises, so a secure-agg stack can never
    silently run unmasked.
    """
    transforms: Tuple[DeltaTransform, ...] = ()

    @property
    def is_identity(self) -> bool:
        return not self.transforms

    @property
    def needs_cohort(self) -> bool:
        """True when any member transform needs the dispatch-cohort context
        (slot / weights / shared round key) — see ``core/secure_agg.py``."""
        return any(getattr(t, "needs_cohort", False) for t in self.transforms)

    def __call__(self, delta: PyTree, key: jax.Array, ctx=None) -> PyTree:
        seen: dict = {}
        for t in self.transforms:
            occ = seen.get(t.tag, 0)   # same-kind repeats get fresh streams
            seen[t.tag] = occ + 1
            sub = jax.random.fold_in(jax.random.fold_in(key, t.tag), occ)
            if getattr(t, "needs_cohort", False):
                if ctx is None:
                    raise ValueError(
                        f"{type(t).__name__} needs the dispatch-cohort "
                        "context (slot/weights/round key); call the stack "
                        "with ctx=CohortContext(...)")
                delta = t(delta, sub, ctx)
            else:
                delta = t(delta, sub)
        return delta


def make_stack(cfg: TransformConfig,
               secure: Optional[SecureAggConfig] = None) -> TransformStack:
    """Build the clip -> noise -> quantize -> mask stack selected by a
    ``TransformConfig`` (+ optional ``SecureAggConfig``), the
    ``FLConfig.transform`` / ``FLConfig.secure`` facade views."""
    ts = []
    if cfg.clip_norm > 0.0:
        ts.append(L2Clip(cfg.clip_norm))
    if cfg.noise_multiplier > 0.0:
        sensitivity = cfg.clip_norm if cfg.clip_norm > 0.0 else 1.0
        ts.append(GaussianNoise(cfg.noise_multiplier * sensitivity))
    if cfg.quantize_bits:
        ts.append(StochasticQuantize(cfg.quantize_bits))
    if secure is not None and secure.enabled:
        from repro.core import secure_agg  # late: secure_agg is a leaf module
        ts.append(secure_agg.make_masker(secure))
    return TransformStack(tuple(ts))
