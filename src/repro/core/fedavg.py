"""Federated round engine (paper Alg. 1, generalized) — one explicit pipeline
of typed stages, executed pseudo-distributed (vmap) or mesh-sharded
(shard_map):

    select -> local-update -> transform(deltas) -> aggregate -> server-update

*select* picks the round's participants (``core/sampling.py``,
``SamplingConfig``); each selected client runs ``ClientUpdate`` — E local
epochs of minibatch SGD, optionally FedProx-regularized (``core/client.py``,
``ClientOptConfig``); each client's update delta ``w_i - w_global`` passes
through the *transform* stack — per-client L2 clip -> Gaussian DP noise ->
stochastic int quantize (``core/transforms.py``, ``TransformConfig``) —
INSIDE the round body, before any collective, so on the mesh path only
privatized/compressed deltas ever cross shard boundaries; *aggregate* reduces
the sample-count-weighted deltas through a pluggable topology
(``core/aggregation.py``, ``AggregationConfig``: flat one-psum, or
hierarchical edge->region->cloud over a 2-D (region, clients) mesh); finally
the server applies a *server optimizer* to the pseudo-gradient
``w_global - w_agg`` (``core/server_opt.py``, ``ServerOptConfig``) outside
the round body, shared bit-for-bit by both execution paths.

Uniform FedAvg (``w <- (1/|s|) Σ w_i``) is the default configuration of that
pipeline, not a special code path — and with the identity transform stack the
engine routes through the exact legacy aggregation math, so default-config
runs are bit-identical to the pre-pipeline engine (pinned by regression
test).  Local epochs run with NO cross-client communication, which is
precisely what makes FedAvg cheaper on the wire than synchronous
data-parallel SGD.

Engine selection is driven entirely by the ``FLConfig`` facade::

    FLConfig(server_opt="fedadam", server_lr=0.05, sampling="weighted",
             dp_clip=1.0, dp_noise=0.5, quantize_bits=8,
             aggregation="hierarchical", n_regions=2, ...)

whose typed stage views (``.sampling_config``, ``.client_opt``,
``.transform``, ``.aggregation_config``, ``.server``) are validated eagerly
at construction.

Round PACING is orthogonal to the stage pipeline: ``FLConfig.mode`` selects
synchronous rounds (default — the slowest selected client gates the round on
the simulated event clock, ``core/latency.py``) or semi-synchronous buffered
rounds (``core/async_engine.py`` — over-select, flush at the ``buffer_k``-th
arrival, fold stragglers later with staleness-discounted weights).
``RoundEngine.step`` dispatches on the mode; ``FLResult.sim_times`` reports
the simulated wall clock either way.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import checkpoint as checkpoint_mod
from repro.analysis import taint as taint_mod
from repro.configs.base import (AggregationConfig, FLConfig, ForecasterConfig,
                                SecureAggConfig, TransformConfig)
from repro.core import aggregation as aggregation_mod
from repro.core import clustering, losses as losses_mod
from repro.core import privacy as privacy_mod
from repro.core import sampling as sampling_mod
from repro.core import secure_agg as secure_agg_mod
from repro.core import server_opt as server_opt_mod
from repro.core import transforms as transforms_mod
from repro.core.client import local_update
from repro.data import partition, windows
from repro.models import forecaster
from repro.sharding import shard_map


# ------------------------------------------------------------- aggregation
def fedavg_aggregate(stacked_params):
    """Uniformly average a client-stacked param tree (leading axis = clients)."""
    return jax.tree.map(lambda w: jnp.mean(w, axis=0), stacked_params)


def _weighted_sums(stacked_params, weights):
    """Per-shard weighted sums: the ONE place the weighting math lives.

    Returns (tree of Σ_i weight_i * w_i, Σ_i weight_i).  Both execution
    paths build their average from this — the vmap path divides directly,
    the shard_map path psums numerator and denominator first — so any
    future change to the weighting (clipping, DP noise, ...) applies to
    both automatically.
    """
    def ws(w):
        wt = weights.reshape((-1,) + (1,) * (w.ndim - 1))
        return jnp.sum(w * wt, axis=0)

    return jax.tree.map(ws, stacked_params), jnp.sum(weights)


def weighted_aggregate(stacked_params, weights):
    """Weighted average of a client-stacked tree; weights: (M,) float."""
    sums, wsum = _weighted_sums(stacked_params, weights)
    return jax.tree.map(lambda s: s / wsum, sums)


# ------------------------------------------------------------ vmap execution
@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def fedavg_round(params, x, y, batch_idx, lr, cfg: ForecasterConfig,
                 loss: Callable, cell_impl: str = "jnp"):
    """One uniform-FedAvg round over M clients (pseudo-distributed, back-compat).

    x: (M, n_win, L, 1); y: (M, n_win, H); batch_idx: (M, steps, B).
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl)
    return fedavg_aggregate(locals_), jnp.mean(client_loss)


@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def engine_round(params, x, y, batch_idx, weights, lr, prox_mu,
                 cfg: ForecasterConfig, loss: Callable,
                 cell_impl: str = "jnp"):
    """Generalized round: weighted aggregation + optional FedProx clients.

    weights: (M,) aggregation weights (sample counts; pass ones for uniform);
    prox_mu: FedProx proximal strength (0 = plain local SGD).  Returns
    ``(w_agg, weighted mean client loss)`` — the server step is applied by
    the caller (``RoundEngine.step``).
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
    w_agg = weighted_aggregate(locals_, weights)
    loss_mean = jnp.sum(weights * client_loss) / jnp.sum(weights)
    return w_agg, loss_mean


# ------------------------------------------------------- shard_map execution
def make_sharded_round(mesh, cfg: ForecasterConfig, loss: Callable,
                       client_axis: str = "clients", cell_impl: str = "jnp"):
    """Uniform-FedAvg round with clients sharded over a mesh axis (back-compat).

    ``round_fn(params, x, y, batch_idx, lr)`` — see
    :func:`make_sharded_engine_round` for the weighted / FedProx variant.
    """
    def round_body(params, x, y, batch_idx, lr):
        locals_, client_loss = jax.vmap(
            local_update, in_axes=(None, 0, 0, 0, None, None, None, None))(
            params, x, y, batch_idx, lr, cfg, loss, cell_impl)
        summed = jax.tree.map(
            lambda w: jax.lax.psum(jnp.sum(w, axis=0), client_axis), locals_)
        n = jax.lax.psum(x.shape[0], client_axis)
        new_params = jax.tree.map(lambda w: w / n, summed)
        loss_mean = jax.lax.psum(jnp.sum(client_loss), client_axis) / n
        return new_params, loss_mean

    pspec = P(client_axis)
    return jax.jit(shard_map(
        round_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, P()),
        out_specs=(P(), P()),
        check_vma=False))


@functools.lru_cache(maxsize=None)
def make_sharded_engine_round(mesh, cfg: ForecasterConfig, loss: Callable,
                              client_axis: str = "clients",
                              cell_impl: str = "jnp"):
    """Generalized sharded round; aggregation stays ONE psum of the param tree.

    lru_cached on (mesh, cfg, loss, ...) so every engine configuration with
    the same execution geometry shares one jitted round — the server
    optimizer lives outside the round body and costs no recompile.

    ``round_fn(params, x, y, batch_idx, weights, lr, prox_mu)`` with the
    client-stacked args (x, y, batch_idx, weights) sharded over
    ``client_axis``.  Each shard locally weight-sums its clients' params, the
    cross-shard reduction is a single ``psum``, and the weight normalizer is
    one scalar ``psum`` — identical math to :func:`engine_round`.
    """
    def round_body(params, x, y, batch_idx, weights, lr, prox_mu):
        locals_, client_loss = jax.vmap(
            local_update,
            in_axes=(None, 0, 0, 0, None, None, None, None, None))(
            params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
        sums, wsum_local = _weighted_sums(locals_, weights)
        wsum = jax.lax.psum(wsum_local, client_axis)
        w_agg = jax.tree.map(
            lambda s: jax.lax.psum(s, client_axis) / wsum, sums)
        loss_mean = jax.lax.psum(jnp.sum(weights * client_loss),
                                 client_axis) / wsum
        return w_agg, loss_mean

    pspec = P(client_axis)
    return jax.jit(shard_map(
        round_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, pspec, P(), P()),
        out_specs=(P(), P()),
        check_vma=False))


# ------------------------------------------------------- pipeline execution
def apply_stack(stack, deltas, keys, *, slots=None, w_full=None,
                round_key=None):
    """Transform a client-stacked delta tree through ``stack`` (vmapped).

    Cohort-aware stacks (secure aggregation) additionally thread each
    client its :class:`~repro.core.secure_agg.CohortContext`: its GLOBAL
    dispatch slot, the cohort's full weight vector, and the shared round
    key.  On the vmap path ``slots``/``w_full`` default to the local view
    (which IS the cohort); shard_map callers must pass the global ones.
    """
    if not stack.needs_cohort:
        return jax.vmap(stack)(deltas, keys)
    if round_key is None:
        raise ValueError("cohort-aware transform stack needs the shared "
                         "round_key (engine.base_round_key)")
    if w_full is None:
        raise ValueError("cohort-aware transform stack needs the cohort "
                         "weight vector w_full")
    if slots is None:
        slots = jnp.arange(w_full.shape[0])

    def one(delta, key, slot):
        ctx = secure_agg_mod.CohortContext(slot, w_full, round_key)
        return stack(delta, key, ctx)

    return jax.vmap(one)(deltas, keys, slots)


def _pipeline_body(params, x, y, batch_idx, weights, keys, lr, prox_mu, *,
                   cfg: ForecasterConfig, loss: Callable, cell_impl: str,
                   tcfg: TransformConfig, agg: "aggregation_mod.Aggregator",
                   scfg: Optional[SecureAggConfig] = None, round_key=None,
                   slots=None, w_full=None):
    """Shared local-update -> transform -> aggregate stages of one round.

    Runs inside vmap (``agg = LocalAggregator``) or inside the shard_map body
    (``agg`` = flat / hierarchical), so both execution paths and every
    topology share ONE implementation of the stage math.  With the identity
    transform stack the raw local models are aggregated through exactly the
    legacy ops (bit-identical to the pre-pipeline engine); with transforms
    the per-client deltas are transformed BEFORE the collective and the
    aggregate is rebuilt as ``w_global + avg(transformed deltas)``.  With
    secure aggregation the stack is cohort-aware: the extra
    ``round_key`` / ``slots`` / ``w_full`` args feed the pairwise masker,
    whose masks cancel in ``agg.reduce`` (a linear sum — the aggregator
    contract, see ``core/aggregation.py``).

    Pre-weighted stacks (``stack.pre_weighted``: ring quantizer and/or
    masker) fold each client's aggregation-weight share into its OWN upload
    — the ring quantizer grids ``(w_i / W) * delta_i``, the float masker
    ships ``w_i * delta_i + masks`` — so the aggregate here is the
    UNWEIGHTED sum of uploads divided by ``W`` (re-weighting a masked
    upload would break mask cancellation).  On the ring path the reduced
    sum is additionally wrapped back into the centered ring (exact — each
    pair's masks sum to a multiple of ``2^b``) and decoded through the
    shared public grid scale: ``params + scale * wrap(sum of uploads)``.
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
    # taint source (production no-op): per-client local models — and the
    # deltas derived from them — are the private values flcheck tracks to
    # the aggregation boundary.  client_loss is deliberately NOT tagged:
    # the weighted scalar loss release is the accepted disclosure
    # documented in docs/privacy.md.
    locals_ = taint_mod.tag_private(locals_)
    stack = transforms_mod.make_stack(tcfg, scfg)
    if stack.is_identity:
        sums, wsum_local = _weighted_sums(locals_, weights)
        wsum = agg.reduce(wsum_local)
        w_agg = jax.tree.map(lambda s: agg.reduce(s) / wsum, sums)
    else:
        deltas = jax.tree.map(lambda l, g: l - g, locals_, params)
        w_cohort = weights if w_full is None else w_full
        deltas = apply_stack(stack, deltas, keys, slots=slots,
                             w_full=w_cohort, round_key=round_key)
        if stack.pre_weighted:
            # uploads already carry their weight share — sum UNWEIGHTED
            sums = jax.tree.map(lambda d: jnp.sum(d, axis=0), deltas)
            wsum = agg.reduce(jnp.sum(weights))
            ring = stack.ring_spec
            if ring is not None:
                bits, sensitivity, headroom = ring
                scale = transforms_mod.ring_scale(bits, sensitivity,
                                                  w_cohort.shape[0],
                                                  headroom)
                w_agg = jax.tree.map(
                    lambda g, s: g + scale * transforms_mod.ring_wrap(
                        agg.reduce(s), bits),
                    params, sums)
            else:
                w_agg = jax.tree.map(lambda g, s: g + agg.reduce(s) / wsum,
                                     params, sums)
        else:
            sums, wsum_local = _weighted_sums(deltas, weights)
            wsum = agg.reduce(wsum_local)
            w_agg = jax.tree.map(lambda g, s: g + agg.reduce(s) / wsum,
                                 params, sums)
    loss_mean = agg.reduce(jnp.sum(weights * client_loss)) / wsum
    return w_agg, loss_mean


@functools.partial(jax.jit,
                   static_argnames=("cfg", "loss", "tcfg", "cell_impl",
                                    "scfg"))
def pipeline_round(params, x, y, batch_idx, weights, keys, lr, prox_mu,
                   cfg: ForecasterConfig, loss: Callable,
                   tcfg: TransformConfig, cell_impl: str = "jnp",
                   scfg: Optional[SecureAggConfig] = None, round_key=None):
    """Full pipeline round, pseudo-distributed (vmap) execution.

    ``keys``: (M, 2) uint32 per-client PRNG keys feeding the transform stack
    (unused — and traced away — when the stack is the identity).  With
    secure aggregation (``scfg.enabled``) the shared ``round_key`` seeds the
    pairwise masks; slots and cohort weights are the local view.  Returns
    ``(w_agg, weighted mean client loss)``; the server stage is applied by
    the caller (``RoundEngine.step``).
    """
    return _pipeline_body(params, x, y, batch_idx, weights, keys, lr, prox_mu,
                          cfg=cfg, loss=loss, cell_impl=cell_impl, tcfg=tcfg,
                          agg=aggregation_mod.LocalAggregator(), scfg=scfg,
                          round_key=round_key)


@functools.lru_cache(maxsize=None)
def make_pipeline_round(mesh, cfg: ForecasterConfig, loss: Callable,
                        tcfg: TransformConfig = TransformConfig(),
                        acfg: AggregationConfig = AggregationConfig(),
                        cell_impl: str = "jnp",
                        scfg: Optional[SecureAggConfig] = None):
    """Mesh-sharded pipeline round for any aggregation topology.

    The aggregator supplies both the input layout (flat: clients on a 1-D
    axis; hierarchical: leading client axis split over the 2-D
    (region, clients) grid) and the in-body collective (one psum, or
    edge->region->cloud psum pair).  lru_cached on the full execution
    geometry so every engine sharing (mesh, cfg, loss, transform, topology)
    reuses one jitted round.

    ``round_fn(params, x, y, batch_idx, weights, keys, lr, prox_mu)``.
    With a cohort-aware stack (secure aggregation, or the clear ring
    quantizer) the signature grows the cohort context —
    ``round_fn(params, x, y, batch_idx, weights, keys, slots, w_full,
    round_key, lr, prox_mu)`` — where ``slots`` (global dispatch slot ids)
    shards alongside the client data and ``w_full``/``round_key`` are
    replicated: each shard's clients mask against the WHOLE cohort, and the
    masks cancel in the cross-shard reduction.
    """
    agg = aggregation_mod.make_aggregator(acfg, mesh)
    pspec = agg.pspec()
    # the extended (slots, w_full, round_key) signature is needed whenever
    # the stack wants cohort context — masking, but also the clear ring
    # quantizer (quantize_ring without masking), whose shared grid is a
    # function of the cohort weight vector
    needs_ctx = transforms_mod.make_stack(tcfg, scfg).needs_cohort

    if not needs_ctx:
        def round_body(params, x, y, batch_idx, weights, keys, lr, prox_mu):
            return _pipeline_body(params, x, y, batch_idx, weights, keys, lr,
                                  prox_mu, cfg=cfg, loss=loss,
                                  cell_impl=cell_impl, tcfg=tcfg, agg=agg)

        return jax.jit(shard_map(
            round_body, mesh=mesh,
            in_specs=(P(), pspec, pspec, pspec, pspec, pspec, P(), P()),
            out_specs=(P(), P()),
            check_vma=False))

    def secure_body(params, x, y, batch_idx, weights, keys, slots, w_full,
                    round_key, lr, prox_mu):
        return _pipeline_body(params, x, y, batch_idx, weights, keys, lr,
                              prox_mu, cfg=cfg, loss=loss,
                              cell_impl=cell_impl, tcfg=tcfg, agg=agg,
                              scfg=scfg, round_key=round_key, slots=slots,
                              w_full=w_full)

    return jax.jit(shard_map(
        secure_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, pspec, pspec, pspec, P(), P(),
                  P(), P()),
        out_specs=(P(), P()),
        check_vma=False))


# ------------------------------------------------------------- round engine
class RoundEngine:
    """Composable federated round: select -> local update -> transform ->
    aggregate -> server update.

    Owns the jitted pipeline round for ONE execution path (vmap when
    ``mesh is None``, shard_map otherwise) plus the server-optimizer state,
    so round logic is unit-testable without running full training::

        engine = RoundEngine(fcfg, flcfg)          # or mesh=mesh
        params, state = engine.init(jax.random.PRNGKey(flcfg.seed))
        sel = engine.select(rng, members, m, round_idx, member_weights)
        params, state, loss = engine.step(params, state, x[sel], y[sel],
                                          bidx, counts[sel], round_idx)

    Every pluggable stage is bound from the ``FLConfig`` facade's typed
    views; hierarchical aggregation additionally requires the mesh to carry
    the (region, clients) axis pair (``aggregation.make_mesh``).
    """

    def __init__(self, fcfg: ForecasterConfig, flcfg: FLConfig, *,
                 loss: Optional[Callable] = None, mesh=None,
                 cell_impl: str = "jnp",
                 audited_payload: Optional[float] = None):
        # stage names/knobs were validated eagerly by the FLConfig facade
        self.fcfg, self.flcfg = fcfg, flcfg
        ccfg = flcfg.client_opt
        self.loss = loss if loss is not None else losses_mod.make_loss(
            ccfg.loss, ccfg.beta)
        self.mesh, self.cell_impl = mesh, cell_impl
        self.sampler = sampling_mod.make_sampler(flcfg.sampling_config)
        # proximal term only under fedprox (prox_mu is ignored otherwise)
        self.prox_mu = ccfg.prox_mu if flcfg.server_opt == "fedprox" else 0.0
        self.weighted = server_opt_mod.uses_weighted_aggregation(flcfg)
        self.transform = flcfg.transform
        # secure aggregation (pairwise masking) + privacy accounting
        self.secure = flcfg.secure if flcfg.secure.enabled else None
        # cohort-aware stack (masking and/or the shared-grid ring
        # quantizer): the round fns take the extended (slots, w_full,
        # round_key) signature
        self.needs_ctx = transforms_mod.make_stack(
            self.transform, self.secure).needs_cohort
        self.accountant: Optional[privacy_mod.PrivacyAccountant] = None
        if mesh is None:
            if flcfg.aggregation_config.kind != "flat":
                raise ValueError(
                    f"aggregation={flcfg.aggregation!r} requires a mesh "
                    "(build one with aggregation.make_mesh); the vmap path "
                    "has no reduction topology")
            self._sharded = None
        else:
            self._sharded = make_pipeline_round(
                mesh, fcfg, self.loss, self.transform,
                flcfg.aggregation_config, cell_impl=cell_impl,
                scfg=self.secure)
        # ---- round pacing (sync vs semi-sync buffered) -------------------
        # the latency model is host-side only: under mode="sync" it just
        # tracks a simulated wall clock and never touches the round math
        from repro.core import async_engine, latency as latency_mod
        self.async_cfg = flcfg.async_config
        # ring masking keeps the quantized wire under secure aggregation
        # (masks live in the quantizer's integer ring), so masked uploads
        # are charged their true int<b>+scale bytes whenever quantization
        # is on — the link budget no longer re-widens to fp32 for masking.
        # audited_payload (the flcheck level-3 auditor's statically derived
        # byte count, analysis/costs.py) overrides the formula when given.
        wire_bits = flcfg.quantize_bits
        self.latency = latency_mod.LatencyModel(
            self.async_cfg.latency, flcfg.seed,
            latency_mod.payload_bytes(fcfg.num_params(), wire_bits,
                                      audited_bytes=audited_payload),
            churn=flcfg.churn)
        self.async_state = async_engine.SemiSyncState()
        self._client_fn = None
        if self.async_cfg.mode == "semi_sync":
            m_prime = self.dispatch_m(flcfg.clients_per_round)
            # buffer_frac resolves per round in semi_sync_step; buffer_k is
            # absolute (0 = wait for all dispatched)
            self.buffer_k = self.async_cfg.buffer_k or m_prime
            if self.async_cfg.buffer_k > m_prime:
                raise ValueError(
                    f"buffer_k={self.buffer_k} exceeds the dispatch size "
                    f"m'={m_prime} (= ceil(over_select * clients_per_round))"
                    " — the flush could never trigger; use buffer_frac for "
                    "a threshold relative to the actual round size")
            if mesh is not None:
                self._client_fn = async_engine.make_sharded_client_deltas(
                    mesh, fcfg, self.loss, flcfg.transform,
                    flcfg.aggregation_config, cell_impl=cell_impl,
                    scfg=self.secure)
        else:
            self.buffer_k = 0

    def dispatch_m(self, m: int, n_members: Optional[int] = None) -> int:
        """Per-round dispatch size: ``m`` under sync, the over-selected
        ``m' = ceil(over_select * m)`` (capped at the membership) under
        semi-sync."""
        if self.async_cfg.mode != "semi_sync":
            return m
        m_prime = int(np.ceil(self.async_cfg.over_select * m))
        return m_prime if n_members is None else min(m_prime, n_members)

    @property
    def sim_time(self) -> float:
        """Simulated wall-clock seconds consumed so far (event clock)."""
        return self.async_state.clock

    def reset_pacing(self) -> None:
        """Drop buffered stragglers + rewind the simulated clock (call
        between independent trainings, e.g. per cluster)."""
        self.async_state.reset()

    def init(self, key):
        """Fresh global params + server-optimizer state."""
        params = forecaster.init_forecaster(key, self.fcfg)
        return params, server_opt_mod.init_server_state(params)

    def select(self, rng, members: np.ndarray, m: int, round_idx: int,
               weights: Optional[np.ndarray] = None) -> np.ndarray:
        """Pick this round's m participants (``FLConfig.sampling``)."""
        return self.sampler(rng, np.asarray(members), m, round_idx, weights)

    def base_round_key(self, round_idx: int, stream: int = 0):
        """The dispatch cohort's SHARED round key: every member can derive
        it (in a real deployment, from the round's key-agreement), and the
        pairwise secure-agg masks are a pure function of it + the slot
        pair, so clients need no pairwise communication to agree on masks.
        """
        rk = jax.random.fold_in(jax.random.PRNGKey(self.flcfg.seed), stream)
        return jax.random.fold_in(rk, round_idx)

    def rekey_key(self, round_idx: int, stream: int = 0,
                  generation: int = 0):
        """The shared cohort key at dropout-recovery generation ``g``
        (``core/async_engine._handle_timeouts``): generation 0 is the
        dispatch key itself (``base_round_key``); after a timeout the
        survivors re-mask under ``fold_in(fold_in(base, _REKEY_DOMAIN), g)``
        — derivable by every survivor from the round's key agreement, and
        domain-separated so no generation's masks collide with any dispatch
        round's."""
        rk = self.base_round_key(round_idx, stream)
        if generation == 0:
            return rk
        return jax.random.fold_in(
            jax.random.fold_in(rk, secure_agg_mod._REKEY_DOMAIN),
            generation)

    def round_keys(self, round_idx: int, m: int, stream: int = 0):
        """Per-client transform keys for one round: deterministic in
        (``FLConfig.seed``, ``stream``, round index, selection slot), so DP
        noise and stochastic rounding replay exactly under a fixed seed.

        ``stream`` decorrelates concurrent trainings sharing one seed (the
        driver passes the cluster id) — without it, two clusters' round-t
        slot-i clients would draw the SAME Gaussian noise, and the
        difference of their released aggregates would cancel the DP noise.
        """
        rk = self.base_round_key(round_idx, stream)
        return jax.vmap(jax.random.fold_in, (None, 0))(rk, jnp.arange(m))

    def attach_accountant(self, n_members: int, dispatch_m: int) -> None:
        """(Re)bind the (eps, delta) accountant for one training run:
        sampling rate ``q = dispatch_m / n_members`` (the over-selected
        dispatch size under semi-sync — those clients' data is used).
        Called by the driver per cluster; ``engine.step`` composes one
        mechanism invocation per dispatch/flush.

        Central (``central:secure-agg``) accounting of the masked sum
        (aggregate Gaussian ``z_eff = z * sqrt(cohort)`` — ``privacy.
        secure_agg_accountant``) applies only when the protocol really
        reduces the server's view to the uniform cohort sum: RING masking
        (information-theoretically hiding; float Gaussian masks are not)
        AND uniform aggregation (a weighted sum concentrates sensitivity
        on heavy clients faster than it concentrates noise).  Otherwise
        the engine falls back to per-client accounting — sound, since the
        per-client multiplier never depended on the sum — and surfaces the
        reason as ``central_fallback_reason`` in the report.
        """
        q = min(1.0, dispatch_m / max(n_members, 1))
        if self.secure is not None:
            stack = transforms_mod.make_stack(self.transform, self.secure)
            gate = privacy_mod.central_gate_reason(
                ring=stack.ring_spec is not None, weighted=self.weighted)
            if gate is None:
                self.accountant = privacy_mod.secure_agg_accountant(
                    self.transform, self.flcfg.privacy, q,
                    secure_enabled=True, cohort=dispatch_m)
                return
            self.accountant = privacy_mod.make_accountant(
                self.transform, self.flcfg.privacy, q)
            self.accountant.central_fallback_reason = gate
            return
        self.accountant = privacy_mod.make_accountant(
            self.transform, self.flcfg.privacy, q)

    def step(self, params, state, x, y, batch_idx, weights,
             round_idx: int = 0, stream: int = 0):
        """One full round on already-selected client data.

        x: (M, n_win, L, 1); y: (M, n_win, H); batch_idx: (M, steps, B);
        weights: (M,) per-client sample counts — zero marks mesh-padding
        duplicates, which are excluded from aggregation AND loss on both the
        uniform and weighted paths.  ``round_idx`` / ``stream`` seed the
        per-client transform keys (only consumed when a transform stack is
        configured).  Returns ``(new params, new server state, round loss)``.

        Dispatches on ``FLConfig.mode``: ``sync`` (default) waits for every
        client — the round's simulated cost is the slowest client's latency;
        ``semi_sync`` routes through the staleness-weighted buffered server
        (``core/async_engine.py``), where M is the over-selected ``m'``.
        """
        if self.accountant is not None:
            # one dispatch = one subsampled-Gaussian invocation (each
            # semi-sync step dispatches one cohort and flushes once).  The
            # central accountant prices the sum at the REAL client count —
            # pads and absent members contribute no noise draw (no-op for
            # per-client accountants)
            self.accountant.observe_cohort(
                int((np.asarray(weights) > 0).sum()))
            self.accountant.step()
        if self.async_cfg.mode == "semi_sync":
            from repro.core import async_engine
            return async_engine.semi_sync_step(
                self, params, state, x, y, batch_idx, weights, round_idx,
                stream)
        # sync: the straggler gates the round — advance the simulated clock
        # by the max client latency (host-side; the round math is untouched)
        w_np = np.asarray(weights, np.float32)
        real = np.flatnonzero(w_np > 0)
        times = self.latency.times(round_idx, w_np[real],
                                   self.flcfg.client_opt.local_epochs,
                                   slots=real)
        self.async_state.clock += float(times.max(initial=0.0))
        return self._sync_step(params, state, x, y, batch_idx, weights,
                               round_idx, stream)

    def _sync_step(self, params, state, x, y, batch_idx, weights,
                   round_idx: int = 0, stream: int = 0):
        """The synchronous fused round (select-free part of paper Alg. 1);
        also the semi-sync fast path when a flush is a complete, fresh
        dispatch set (identical math — all staleness tau = 0)."""
        w = jnp.asarray(weights, jnp.float32)
        if not self.weighted:             # uniform aggregation (pads stay 0)
            w = (w > 0).astype(jnp.float32)
        lr = jnp.float32(self.flcfg.lr)
        mu = jnp.float32(self.prox_mu)
        m = x.shape[0]
        keys = self.round_keys(round_idx, m, stream)
        rk = (self.base_round_key(round_idx, stream)
              if self.needs_ctx else None)
        if self._sharded is not None:
            if self.needs_ctx:
                # slots shard with the clients; the cohort weight vector and
                # round key replicate so every shard masks vs the whole set
                w_agg, loss = self._sharded(params, x, y, batch_idx, w, keys,
                                            jnp.arange(m), w, rk, lr, mu)
            else:
                w_agg, loss = self._sharded(params, x, y, batch_idx, w, keys,
                                            lr, mu)
        else:
            w_agg, loss = pipeline_round(params, x, y, batch_idx, w, keys,
                                         lr, mu, self.fcfg, self.loss,
                                         self.transform, self.cell_impl,
                                         self.secure, rk)
        params, state = server_opt_mod.server_update(params, w_agg, state,
                                                     self.flcfg.server)
        return params, state, loss


# ------------------------------------------------------------------ driver
@dataclasses.dataclass
class FLResult:
    params: Dict
    loss_history: np.ndarray
    cluster_centroids: Optional[np.ndarray] = None
    cluster_assignments: Optional[np.ndarray] = None  # (N,); -1 = held out
    heldout_clients: Optional[np.ndarray] = None
    sim_times: Optional[np.ndarray] = None  # (T,) simulated seconds at each
    #                                       # round's end (latency model)
    eps_history: Optional[np.ndarray] = None  # (T,) running accountant eps
    #                                       # after each round (inf when the
    #                                       # accountant is disabled)
    privacy: Optional[Dict] = None          # final accountant report
    #                                       # (core/privacy.py::report)


def time_to_target(res: FLResult, target: float) -> float:
    """Simulated seconds until ``res.loss_history`` first reaches ``target``
    — the wall-clock-to-accuracy readout for comparing round-pacing modes.
    Returns ``nan`` when the run never got there (e.g. diverged)."""
    hit = np.flatnonzero(res.loss_history <= target)
    return float(res.sim_times[hit[0]]) if len(hit) else float("nan")


def final_loss(res: FLResult) -> float:
    """Last FINITE entry of the loss history — under cohort-atomic
    semi-sync pacing (secure aggregation) a flush that completes no cohort
    records ``nan``, so drivers comparing pacing modes must anchor their
    common target here, not at ``loss_history[-1]``."""
    finite = res.loss_history[np.isfinite(res.loss_history)]
    return float(finite[-1]) if len(finite) else float("nan")


def _seed_rngs(seed: int):
    """Independent (holdout, round) rng streams.

    ``SeedSequence.spawn`` derives decorrelated child streams from one root
    seed, so the holdout permutation can NOT replay as the first round's
    client selection (which it did when both were ``default_rng(seed)``).
    """
    hold_ss, round_ss = np.random.SeedSequence(seed).spawn(2)
    return np.random.default_rng(hold_ss), np.random.default_rng(round_ss)


def _as_provider(data, fcfg: ForecasterConfig) -> windows.ClientWindowProvider:
    if isinstance(data, windows.ClientWindowProvider):
        return data
    # in-memory sources window each client at most once: the raw series are
    # already resident, so caching all N clients costs no more than the old
    # materialize-everything path did, and full-participation configs
    # (clients_per_round == N) would thrash any smaller LRU every round
    return windows.ClientWindowProvider.from_series(
        data, fcfg.lookback, fcfg.horizon, cache_size=len(data))


def _restore_async_state(flat, n_pending: int, params):
    """Rebuild a ``SemiSyncState`` from a checkpoint's flat array view
    (keys under ``cur/async/``); ``params`` supplies the delta tree
    structure (a buffered delta has exactly the param tree's shape)."""
    from repro.core import async_engine
    delta_like = jax.tree.map(np.asarray, params)
    tree = {
        "clock": flat["cur/async/clock"],
        "counters": flat["cur/async/counters"],
        "pending": [
            {"delta": jax.tree.map(
                np.asarray, checkpoint_mod.unflatten_like(
                    delta_like, flat,
                    prefix=f"cur/async/pending/{i}/delta/")),
             "scalars": flat[f"cur/async/pending/{i}/scalars"]}
            for i in range(n_pending)],
        "cohort_rounds": flat["cur/async/cohort_rounds"],
        "cohort_sizes": flat["cur/async/cohort_sizes"],
        "cohort_gens": flat["cur/async/cohort_gens"],
        "cohort_w": flat["cur/async/cohort_w"],
    }
    # dispatch-time weight sums (ring-decode geometry); absent in
    # pre-ring checkpoints — from_tree then falls back to sum(cohort_w)
    if "cur/async/cohort_W0" in flat:
        tree["cohort_W0"] = flat["cur/async/cohort_W0"]
    return async_engine.SemiSyncState.from_tree(tree)


def run_federated_training(all_series, fcfg: ForecasterConfig,
                           flcfg: FLConfig, *, mesh=None,
                           log_every: int = 0,
                           checkpoint_path=None, checkpoint_every: int = 1,
                           resume: bool = True,
                           stop_after_rounds: Optional[int] = None
                           ) -> Dict[int, FLResult]:
    """Full Alg. 1 via the round engine: optional client holdout, optional
    clustering, then per-cluster federated training.

    all_series: (N, T) raw kWh (one row per client), a ragged list of (T_i,)
    series, or a ``windows.ClientWindowProvider`` — everything is routed
    through the provider, so each round fetches/normalizes/windows ONLY the
    ``m`` selected clients (host→device traffic O(m), never O(N)).  When
    ``flcfg.holdout_frac > 0`` that fraction of clients is excluded from
    training entirely (unseen-client generalization split; their indices are
    reported on every ``FLResult.heldout_clients``).  Returns
    {cluster_id: FLResult}; cluster_id = -1 when clustering is off.

    **Checkpoint/resume** (``checkpoint_path``): every ``checkpoint_every``
    rounds the FULL engine state — params, server-optimizer moments, the
    semi-sync pending buffer (deltas, weights, finish times, cohort re-key
    bookkeeping), the event clock, the RDP accountant, the driver's rng —
    is written to one ``.npz``; an existing checkpoint (same config —
    enforced by fingerprint) resumes the run and reproduces the remaining
    loss/eps/sim histories BIT-identically to the uninterrupted run (pinned
    by regression test).  Holdout split, clustering, and selection replay
    deterministically from the seed, so only genuinely mutable state is
    stored.  ``stop_after_rounds`` ends the call after that many executed
    rounds (a graceful kill, for tests and budgeted jobs) — the returned
    dict then holds the partial current cluster.
    """
    provider = _as_provider(all_series, fcfg)
    holdout_rng, rng = _seed_rngs(flcfg.seed)
    if mesh is None and flcfg.aggregation_config.kind != "flat":
        # hierarchical aggregation implies mesh execution; build the
        # (region, clients) grid the config asks for over all devices
        mesh = aggregation_mod.make_mesh(flcfg.aggregation_config)
    engine = RoundEngine(fcfg, flcfg, mesh=mesh)
    ccfg = flcfg.client_opt
    steps = partition.local_steps(provider.n_win_max, ccfg.batch_size,
                                  ccfg.local_epochs)

    n_total = provider.n_clients
    train_ids, held_ids = partition.holdout_clients(
        holdout_rng, n_total, flcfg.holdout_frac)
    if len(train_ids) == 0:
        raise ValueError(
            f"holdout_frac={flcfg.holdout_frac} leaves no training clients "
            f"(n_clients={n_total})")
    # Per-client sample counts: aggregation + sampling weights.  With ragged
    # histories these differ across clients, which is exactly when
    # fedavg_weighted / weighted sampling depart from uniform.
    counts = provider.train_counts.astype(np.float32)
    n_dev = 1 if mesh is None else int(
        np.prod([mesh.shape[a] for a in mesh.axis_names]))

    # -------- optional privacy-preserving clustering (server side, Alg. 1)
    if flcfg.n_clusters > 1:
        z = provider.daily_summary(train_ids, flcfg.cluster_days)
        cents, train_assigns, _ = clustering.kmeans(z, flcfg.n_clusters,
                                                    seed=flcfg.seed)
        groups = {cid: train_ids[m] for cid, m in
                  partition.cluster_partition(train_assigns).items()}
        # report assignments in FULL client index space (-1 = held out)
        assigns = np.full(n_total, -1, train_assigns.dtype)
        assigns[train_ids] = train_assigns
    else:
        cents, assigns = None, None
        groups = {-1: train_ids}

    # -------- resume: load the full engine snapshot when one exists
    ckpt_flat = ckpt_meta = None
    if checkpoint_path is not None and resume and \
            checkpoint_mod._normalize(checkpoint_path).exists():
        ckpt_flat, ckpt_meta = checkpoint_mod.load_arrays(checkpoint_path)
        if ckpt_meta.get("flcfg") != repr(flcfg):
            raise ValueError(
                f"checkpoint {checkpoint_path} was written by a different "
                "FLConfig — resuming would silently change the run; delete "
                "it or pass resume=False")

    results: Dict[int, FLResult] = {}
    # finished clusters' accountant states: the central accountant's min
    # observed cohort is run history (churn re-keys), not derivable from
    # the configs, so resume must restore it rather than recompose from
    # the round count alone
    done_acct: Dict[int, Dict] = {}
    executed = 0

    def _save(cid, params, sstate, hist, sim_hist, eps_hist, t_done):
        tree = {
            "cur": {"params": params,
                    "server": {"m": sstate.m, "v": sstate.v, "t": sstate.t},
                    "async": engine.async_state.to_tree(),
                    "hist": np.asarray(hist, np.float64),
                    "sim": np.asarray(sim_hist, np.float64),
                    "eps": np.asarray(eps_hist, np.float64)},
            "done": {str(dc): {
                "params": results[dc].params,
                "hist": np.asarray(results[dc].loss_history, np.float64),
                "sim": np.asarray(results[dc].sim_times, np.float64),
                "eps": np.asarray(results[dc].eps_history, np.float64)}
                for dc in results},
        }
        meta = {"version": 1, "flcfg": repr(flcfg), "cluster": int(cid),
                "rounds_done": int(t_done),
                # publish generation for serving-registry pollers
                # (checkpoint.latest): the GLOBAL executed-round counter,
                # monotone across clusters, unlike per-cluster rounds_done
                "generation": int(executed),
                "done": [int(dc) for dc in results],
                "rng": rng.bit_generator.state,
                "accountant": engine.accountant.state_dict(),
                "done_accountants": {str(dc): done_acct[dc]
                                     for dc in results},
                "n_pending": len(engine.async_state.pending)}
        checkpoint_mod.save(checkpoint_path, tree, metadata=meta)

    for cid, members in groups.items():
        # fold_in, NOT PRNGKey(seed + cid): additive seeds collide across
        # runs ((seed, cid+1) == (seed+1, cid) would share every init draw)
        key = jax.random.fold_in(jax.random.PRNGKey(flcfg.seed),
                                 cid if cid >= 0 else 0)
        params, sstate = engine.init(key)
        engine.reset_pacing()          # per-cluster event clock + buffer
        hist, sim_hist, eps_hist = [], [], []
        m = min(flcfg.clients_per_round, len(members))
        # semi-sync over-selects m' >= m; sync dispatches exactly m
        m_sel = engine.dispatch_m(m, len(members))
        # (eps, delta) accounting for THIS cluster's mechanism: sampling
        # rate = dispatch size / cluster membership, stepped per flush
        engine.attach_accountant(len(members), m_sel)
        t0 = 0
        if ckpt_meta is not None and int(cid) in ckpt_meta["done"]:
            # finished before the kill: rebuild its result from the snapshot
            # (the privacy report needs the saved accountant state — the
            # central mode's min observed cohort is run history; pre-churn
            # checkpoints fall back to recomposing from the round count —
            # and centroids/holdout were recomputed above from the seed)
            pref = f"done/{cid}/"
            engine.accountant.load_state(
                ckpt_meta.get("done_accountants", {}).get(
                    str(cid), {"rounds": flcfg.rounds}))
            done_acct[cid] = engine.accountant.state_dict()
            results[cid] = FLResult(
                jax.device_get(checkpoint_mod.unflatten_like(
                    params, ckpt_flat, prefix=pref + "params/")),
                np.asarray(ckpt_flat[pref + "hist"]),
                cents, assigns, held_ids if len(held_ids) else None,
                sim_times=np.asarray(ckpt_flat[pref + "sim"]),
                eps_history=np.asarray(ckpt_flat[pref + "eps"]),
                privacy=engine.accountant.report())
            continue
        if ckpt_meta is not None and int(cid) == int(ckpt_meta["cluster"]):
            # mid-cluster kill point: restore the live engine state and the
            # driver rng, then continue the round loop where it stopped
            params = checkpoint_mod.unflatten_like(params, ckpt_flat,
                                                   prefix="cur/params/")
            sstate = server_opt_mod.ServerState(
                m=checkpoint_mod.unflatten_like(sstate.m, ckpt_flat,
                                                prefix="cur/server/m/"),
                v=checkpoint_mod.unflatten_like(sstate.v, ckpt_flat,
                                                prefix="cur/server/v/"),
                t=jnp.asarray(ckpt_flat["cur/server/t"], jnp.int32))
            engine.async_state = _restore_async_state(
                ckpt_flat, int(ckpt_meta["n_pending"]), params)
            engine.accountant.load_state(ckpt_meta["accountant"])
            rng.bit_generator.state = ckpt_meta["rng"]
            hist = [float(v) for v in ckpt_flat["cur/hist"]]
            sim_hist = [float(v) for v in ckpt_flat["cur/sim"]]
            eps_hist = [float(v) for v in ckpt_flat["cur/eps"]]
            t0 = int(ckpt_meta["rounds_done"])
        if (engine.async_cfg.mode == "semi_sync"
                and engine.async_cfg.buffer_k >= m_sel > 0
                and engine.async_cfg.buffer_k):
            # an absolute threshold the round can never fill waits for the
            # slowest straggler — legal, but the user should know
            print(f"[cluster {cid}] semi_sync: buffer_k="
                  f"{engine.async_cfg.buffer_k} >= dispatch size {m_sel} — "
                  "every flush waits for all (sync pacing); use buffer_frac "
                  "for a round-size-relative threshold")
        # mesh divisibility: round UP and pad the selection (never train
        # fewer clients than configured); pads are cycled duplicates that
        # enter the round with weight 0, so the math is unchanged
        m_run = -(-m_sel // n_dev) * n_dev
        stopped = False
        for t in range(t0, flcfg.rounds):
            # membership churn: absent members sit this round out (pure
            # function of (seed, round, client id) — replayable).  If the
            # whole cluster is absent, fall back to full membership rather
            # than dispatch nothing.  Shapes stay fixed at m_run: a smaller
            # selection just grows the zero-weight padding.
            avail = members
            if engine.latency.churn.absent_prob > 0.0:
                mask = engine.latency.available(t, members)
                if mask.any():
                    avail = members[mask]
            sel = engine.select(rng, avail, min(m_sel, len(avail)), t,
                                counts[avail])
            bidx = partition.ragged_minibatch_indices(
                rng, counts[sel], steps, ccfg.batch_size)
            pad_idx = np.resize(np.arange(len(sel)), m_run)
            x, y, c_sel = provider.round_batch(sel[pad_idx])
            w = c_sel.copy()
            w[len(sel):] = 0.0                        # mask padding clients
            params, sstate, l = engine.step(
                params, sstate, jnp.asarray(x), jnp.asarray(y),
                jnp.asarray(bidx[pad_idx]), w, round_idx=t,
                stream=cid if cid >= 0 else 0)
            hist.append(float(l))
            sim_hist.append(engine.sim_time)
            eps_hist.append(engine.accountant.epsilon())
            if log_every and (t + 1) % log_every == 0:
                eps = eps_hist[-1]
                eps_s = f" eps {eps:.2f}" if np.isfinite(eps) else ""
                print(f"[cluster {cid}] round {t+1}/{flcfg.rounds} "
                      f"loss {hist[-1]:.5f} sim_t {sim_hist[-1]:.1f}s{eps_s}")
            executed += 1
            stopped = (stop_after_rounds is not None
                       and executed >= stop_after_rounds)
            if checkpoint_path is not None and (
                    (t + 1) % max(checkpoint_every, 1) == 0
                    or t + 1 == flcfg.rounds or stopped):
                _save(cid, params, sstate, hist, sim_hist, eps_hist, t + 1)
            if stopped:
                break
        results[cid] = FLResult(jax.device_get(params), np.array(hist),
                                cents, assigns,
                                held_ids if len(held_ids) else None,
                                sim_times=np.array(sim_hist),
                                eps_history=np.array(eps_hist),
                                privacy=engine.accountant.report())
        done_acct[cid] = engine.accountant.state_dict()
        if stopped:
            break
    return results


# ------------------------------------------------------------------ eval
@functools.partial(jax.jit, static_argnames=("cfg", "cell_impl"))
def _predict(params, x, cfg, cell_impl="jnp"):
    return forecaster.forecast(params, x, cfg, cell_impl)


class MetricAccumulator:
    """Streaming RMSE / MAPE / Accuracy (§4.5) over window batches.

    Accumulates sufficient statistics (Σ squared error, Σ APE, per-horizon
    Σ APE, counts) so million-window evaluations never hold predictions for
    more than one batch; ``result()`` matches the formerly-monolithic
    ``evaluate_global`` math exactly.  The APE epsilon is the ONE shared
    ``losses.MAPE_EPS``, pinning jnp- and np-path metric parity.
    """

    def __init__(self, horizon: int):
        self.sse = 0.0
        self.ape_sum = np.zeros(horizon, np.float64)
        self.rows = 0

    def update(self, pred: np.ndarray, y: np.ndarray):
        """pred/y: (n, H) in the space metrics should be computed in."""
        d = (pred - y).astype(np.float64)
        self.sse += float((d * d).sum())
        ape = np.abs((y - pred) /
                     np.maximum(np.abs(y), losses_mod.MAPE_EPS))
        self.ape_sum += ape.sum(axis=0, dtype=np.float64)
        self.rows += pred.shape[0]

    def result(self) -> Dict[str, float]:
        if self.rows == 0:
            raise ValueError("no evaluation windows accumulated (empty ids "
                             "or 0-client provider)")
        h = len(self.ape_sum)
        mean_ape = self.ape_sum.sum() / (self.rows * h)
        per_h = 100.0 - 100.0 * self.ape_sum / self.rows
        return {
            "rmse": float(np.sqrt(self.sse / (self.rows * h))),
            "mape": float(100.0 * mean_ape),
            "accuracy": float(np.clip(100.0 - 100.0 * mean_ape, 0, 100)),
            "per_horizon_accuracy": np.clip(per_h, 0, 100),
        }


def _predict_denorm(params, x, cfg, stats=None, batch: int = 8192):
    """Predict a flat window batch in device sub-batches; de-normalize to kWh
    when per-row (lo, hi) ``stats`` are given.  Returns (pred, y-transform).

    Sub-batches are zero-padded up to the next power of two so the jitted
    forecaster sees a bounded set of shapes (≤ log2(batch) traces total) —
    without this, ragged streamed eval presents a fresh remainder shape
    almost every client chunk and XLA recompiles per chunk.
    """
    n = x.shape[0]
    preds = []
    for i in range(0, n, batch):
        xb = x[i:i + batch]
        nb = xb.shape[0]
        nb_pad = 1 << max(nb - 1, 0).bit_length()      # next power of two
        if nb_pad > nb:
            xb = np.concatenate(
                [xb, np.zeros((nb_pad - nb,) + xb.shape[1:], xb.dtype)])
        preds.append(np.asarray(_predict(params, jnp.asarray(xb),
                                         cfg))[:nb])
    pred = np.concatenate(preds)
    if stats is None:
        return pred, lambda y: y
    return (windows.denormalize(pred, stats),
            lambda y: windows.denormalize(y, stats))


def evaluate_global(params, x_test: np.ndarray, y_test: np.ndarray,
                    cfg: ForecasterConfig, stats=None,
                    batch: int = 8192) -> Dict[str, float]:
    """Evaluate on (possibly huge) held-out window sets, streamed in batches.

    x_test: (n, L, 1); y_test: (n, H) — normalized per building.  ``stats`` is
    the per-row (lo, hi) min/max pair (broadcastable to (n, 1)); when given,
    MAPE/Accuracy are computed in DE-normalized kWh space, as the paper does —
    commercial base load keeps actual kWh well away from zero, which is what
    makes MAPE-based accuracy meaningful.
    Returns RMSE / MAPE / Accuracy (§4.5) + per-horizon accuracy (Table 4).
    """
    acc = MetricAccumulator(cfg.horizon)
    pred, to_space = _predict_denorm(params, x_test, cfg, stats, batch)
    acc.update(pred, to_space(y_test))
    return acc.result()


def evaluate_unseen_clients(params, series, cfg: ForecasterConfig,
                            batch: int = 8192, ids=None,
                            clients_per_chunk: int = 64) -> Dict[str, float]:
    """Unseen-CLIENT generalization (paper §5.4): run the full windowing
    pipeline on buildings never seen in training and score their *test*
    windows in kWh space.  ``series`` is (n_held, T) raw kWh, a ragged list,
    or a ``ClientWindowProvider`` (then ``ids`` restricts which clients to
    score).  Clients stream through in chunks, so arbitrarily large held-out
    populations evaluate in O(chunk) memory."""
    provider = _as_provider(series, cfg)
    acc = MetricAccumulator(cfg.horizon)
    for x, y, stats in provider.iter_test_flat(ids, clients_per_chunk):
        pred, to_space = _predict_denorm(params, x, cfg, stats, batch)
        acc.update(pred, to_space(y))
    return acc.result()
