"""Federated round engine (paper Alg. 1, generalized) — pseudo-distributed
(vmap) and mesh-sharded (shard_map) execution of the same round schedule.

One round: the server *selects* clients (``core/sampling.py``), broadcasts
global params; each selected client runs ``ClientUpdate`` (E local epochs of
minibatch SGD, optionally FedProx-regularized — ``core/client.py``); the
server *aggregates* the returned models with per-client sample-count weights
and applies a *server optimizer* to the pseudo-gradient ``w_global - w_agg``
(``core/server_opt.py``).  Uniform FedAvg (``w <- (1/|s|) Σ w_i``) is the
default configuration of that pipeline, not a special code path.

The mesh-sharded path places clients on the ``clients`` (= data) mesh axis via
``shard_map``; aggregation is then a single ``psum`` of the (tiny) parameter
tree — the paper's edge→cloud upload + cloud aggregation collapsed into one
collective.  Local epochs run with NO cross-client communication, which is
precisely what makes FedAvg cheaper on the wire than synchronous
data-parallel SGD.  The server step runs *outside* the round body, so the
vmap and shard_map paths share it bit-for-bit.

Engine selection is driven entirely by ``FLConfig``::

    FLConfig(server_opt="fedadam", server_lr=0.05, sampling="weighted", ...)

with ``server_opt ∈ {fedavg, fedavg_weighted, fedprox, fedadam, fedyogi}``
and ``sampling ∈ {uniform, weighted, round_robin}``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, losses as losses_mod
from repro.core import sampling as sampling_mod
from repro.core import server_opt as server_opt_mod
from repro.core.client import local_update
from repro.data import partition, windows
from repro.models import forecaster
from repro.sharding import shard_map


# ------------------------------------------------------------- aggregation
def fedavg_aggregate(stacked_params):
    """Uniformly average a client-stacked param tree (leading axis = clients)."""
    return jax.tree.map(lambda w: jnp.mean(w, axis=0), stacked_params)


def _weighted_sums(stacked_params, weights):
    """Per-shard weighted sums: the ONE place the weighting math lives.

    Returns (tree of Σ_i weight_i * w_i, Σ_i weight_i).  Both execution
    paths build their average from this — the vmap path divides directly,
    the shard_map path psums numerator and denominator first — so any
    future change to the weighting (clipping, DP noise, ...) applies to
    both automatically.
    """
    def ws(w):
        wt = weights.reshape((-1,) + (1,) * (w.ndim - 1))
        return jnp.sum(w * wt, axis=0)

    return jax.tree.map(ws, stacked_params), jnp.sum(weights)


def weighted_aggregate(stacked_params, weights):
    """Weighted average of a client-stacked tree; weights: (M,) float."""
    sums, wsum = _weighted_sums(stacked_params, weights)
    return jax.tree.map(lambda s: s / wsum, sums)


# ------------------------------------------------------------ vmap execution
@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def fedavg_round(params, x, y, batch_idx, lr, cfg: ForecasterConfig,
                 loss: Callable, cell_impl: str = "jnp"):
    """One uniform-FedAvg round over M clients (pseudo-distributed, back-compat).

    x: (M, n_win, L, 1); y: (M, n_win, H); batch_idx: (M, steps, B).
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl)
    return fedavg_aggregate(locals_), jnp.mean(client_loss)


@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def engine_round(params, x, y, batch_idx, weights, lr, prox_mu,
                 cfg: ForecasterConfig, loss: Callable,
                 cell_impl: str = "jnp"):
    """Generalized round: weighted aggregation + optional FedProx clients.

    weights: (M,) aggregation weights (sample counts; pass ones for uniform);
    prox_mu: FedProx proximal strength (0 = plain local SGD).  Returns
    ``(w_agg, weighted mean client loss)`` — the server step is applied by
    the caller (``RoundEngine.step``).
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
    w_agg = weighted_aggregate(locals_, weights)
    loss_mean = jnp.sum(weights * client_loss) / jnp.sum(weights)
    return w_agg, loss_mean


# ------------------------------------------------------- shard_map execution
def make_sharded_round(mesh, cfg: ForecasterConfig, loss: Callable,
                       client_axis: str = "clients", cell_impl: str = "jnp"):
    """Uniform-FedAvg round with clients sharded over a mesh axis (back-compat).

    ``round_fn(params, x, y, batch_idx, lr)`` — see
    :func:`make_sharded_engine_round` for the weighted / FedProx variant.
    """
    def round_body(params, x, y, batch_idx, lr):
        locals_, client_loss = jax.vmap(
            local_update, in_axes=(None, 0, 0, 0, None, None, None, None))(
            params, x, y, batch_idx, lr, cfg, loss, cell_impl)
        summed = jax.tree.map(
            lambda w: jax.lax.psum(jnp.sum(w, axis=0), client_axis), locals_)
        n = jax.lax.psum(x.shape[0], client_axis)
        new_params = jax.tree.map(lambda w: w / n, summed)
        loss_mean = jax.lax.psum(jnp.sum(client_loss), client_axis) / n
        return new_params, loss_mean

    pspec = P(client_axis)
    return jax.jit(shard_map(
        round_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, P()),
        out_specs=(P(), P()),
        check_vma=False))


@functools.lru_cache(maxsize=None)
def make_sharded_engine_round(mesh, cfg: ForecasterConfig, loss: Callable,
                              client_axis: str = "clients",
                              cell_impl: str = "jnp"):
    """Generalized sharded round; aggregation stays ONE psum of the param tree.

    lru_cached on (mesh, cfg, loss, ...) so every engine configuration with
    the same execution geometry shares one jitted round — the server
    optimizer lives outside the round body and costs no recompile.

    ``round_fn(params, x, y, batch_idx, weights, lr, prox_mu)`` with the
    client-stacked args (x, y, batch_idx, weights) sharded over
    ``client_axis``.  Each shard locally weight-sums its clients' params, the
    cross-shard reduction is a single ``psum``, and the weight normalizer is
    one scalar ``psum`` — identical math to :func:`engine_round`.
    """
    def round_body(params, x, y, batch_idx, weights, lr, prox_mu):
        locals_, client_loss = jax.vmap(
            local_update,
            in_axes=(None, 0, 0, 0, None, None, None, None, None))(
            params, x, y, batch_idx, lr, cfg, loss, cell_impl, prox_mu)
        sums, wsum_local = _weighted_sums(locals_, weights)
        wsum = jax.lax.psum(wsum_local, client_axis)
        w_agg = jax.tree.map(
            lambda s: jax.lax.psum(s, client_axis) / wsum, sums)
        loss_mean = jax.lax.psum(jnp.sum(weights * client_loss),
                                 client_axis) / wsum
        return w_agg, loss_mean

    pspec = P(client_axis)
    return jax.jit(shard_map(
        round_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, pspec, P(), P()),
        out_specs=(P(), P()),
        check_vma=False))


# ------------------------------------------------------------- round engine
class RoundEngine:
    """Composable federated round: select → local update → aggregate → server.

    Owns the jitted round function for ONE execution path (vmap when
    ``mesh is None``, shard_map otherwise) plus the server-optimizer state,
    so round logic is unit-testable without running full training::

        engine = RoundEngine(fcfg, flcfg)          # or mesh=mesh
        params, state = engine.init(jax.random.PRNGKey(0))
        sel = engine.select(rng, members, m, round_idx, member_weights)
        params, state, loss = engine.step(params, state, x[sel], y[sel],
                                          bidx, counts[sel])
    """

    def __init__(self, fcfg: ForecasterConfig, flcfg: FLConfig, *,
                 loss: Optional[Callable] = None, mesh=None,
                 cell_impl: str = "jnp"):
        if flcfg.server_opt not in server_opt_mod.SERVER_OPTS:
            raise ValueError(f"unknown server_opt {flcfg.server_opt!r}")
        self.fcfg, self.flcfg = fcfg, flcfg
        self.loss = loss if loss is not None else losses_mod.make_loss(
            flcfg.loss, flcfg.beta)
        self.mesh, self.cell_impl = mesh, cell_impl
        self.sampler = sampling_mod.make_sampler(flcfg.sampling)
        # proximal term only under fedprox (prox_mu is ignored otherwise)
        self.prox_mu = flcfg.prox_mu if flcfg.server_opt == "fedprox" else 0.0
        self.weighted = server_opt_mod.uses_weighted_aggregation(flcfg)
        self._sharded = None if mesh is None else make_sharded_engine_round(
            mesh, fcfg, self.loss, cell_impl=cell_impl)

    def init(self, key):
        """Fresh global params + server-optimizer state."""
        params = forecaster.init_forecaster(key, self.fcfg)
        return params, server_opt_mod.init_server_state(params)

    def select(self, rng, members: np.ndarray, m: int, round_idx: int,
               weights: Optional[np.ndarray] = None) -> np.ndarray:
        """Pick this round's m participants (``FLConfig.sampling``)."""
        return self.sampler(rng, np.asarray(members), m, round_idx, weights)

    def step(self, params, state, x, y, batch_idx, weights):
        """One full round on already-selected client data.

        x: (M, n_win, L, 1); y: (M, n_win, H); batch_idx: (M, steps, B);
        weights: (M,) per-client sample counts.  Returns
        ``(new params, new server state, round loss)``.
        """
        w = jnp.asarray(weights, jnp.float32)
        if not self.weighted:             # uniform aggregation
            w = jnp.ones_like(w)
        lr = jnp.float32(self.flcfg.lr)
        mu = jnp.float32(self.prox_mu)
        if self._sharded is not None:
            w_agg, loss = self._sharded(params, x, y, batch_idx, w, lr, mu)
        else:
            w_agg, loss = engine_round(params, x, y, batch_idx, w, lr, mu,
                                       self.fcfg, self.loss, self.cell_impl)
        params, state = server_opt_mod.server_update(params, w_agg, state,
                                                     self.flcfg)
        return params, state, loss


# ------------------------------------------------------------------ driver
@dataclasses.dataclass
class FLResult:
    params: Dict
    loss_history: np.ndarray
    cluster_centroids: Optional[np.ndarray] = None
    cluster_assignments: Optional[np.ndarray] = None  # (N,); -1 = held out
    heldout_clients: Optional[np.ndarray] = None


def run_federated_training(all_series: np.ndarray, fcfg: ForecasterConfig,
                           flcfg: FLConfig, *, mesh=None,
                           log_every: int = 0) -> Dict[int, FLResult]:
    """Full Alg. 1 via the round engine: optional client holdout, optional
    clustering, then per-cluster federated training.

    all_series: (N, T) raw kWh, one row per client.  When
    ``flcfg.holdout_frac > 0`` that fraction of clients is excluded from
    training entirely (unseen-client generalization split; their indices are
    reported on every ``FLResult.heldout_clients``).  Returns
    {cluster_id: FLResult}; cluster_id = -1 when clustering is off.
    """
    rng = np.random.default_rng(flcfg.seed)
    engine = RoundEngine(fcfg, flcfg, mesh=mesh)
    data = windows.batched_client_windows(all_series, fcfg.lookback,
                                          fcfg.horizon)
    x_tr, y_tr = data["x_train"], data["y_train"]   # (N, n_win, L, 1), (N, n_win, H)
    n_win = x_tr.shape[1]
    steps = partition.local_steps(n_win, flcfg.batch_size, flcfg.local_epochs)

    n_total = all_series.shape[0]
    train_ids, held_ids = partition.holdout_clients(
        np.random.default_rng(flcfg.seed), n_total, flcfg.holdout_frac)
    if len(train_ids) == 0:
        raise ValueError(
            f"holdout_frac={flcfg.holdout_frac} leaves no training clients "
            f"(n_clients={n_total})")
    # Per-client sample counts: aggregation + sampling weights.  NOTE: every
    # synthetic client has a full year of history, so counts are equal and
    # fedavg_weighted / weighted sampling coincide with uniform HERE — the
    # weighting becomes material with variable-length client histories
    # (real deployments, future ragged-window loaders).
    counts = np.full(n_total, n_win, np.float32)

    # -------- optional privacy-preserving clustering (server side, Alg. 1)
    if flcfg.n_clusters > 1:
        z = windows.daily_average_vector(all_series[train_ids],
                                         flcfg.cluster_days)
        cents, train_assigns, _ = clustering.kmeans(z, flcfg.n_clusters,
                                                    seed=flcfg.seed)
        groups = {cid: train_ids[m] for cid, m in
                  partition.cluster_partition(train_assigns).items()}
        # report assignments in FULL client index space (-1 = held out)
        assigns = np.full(n_total, -1, train_assigns.dtype)
        assigns[train_ids] = train_assigns
    else:
        cents, assigns = None, None
        groups = {-1: train_ids}

    results: Dict[int, FLResult] = {}
    for cid, members in groups.items():
        key = jax.random.PRNGKey(flcfg.seed + (cid if cid >= 0 else 0))
        params, sstate = engine.init(key)
        hist = []
        m = min(flcfg.clients_per_round, len(members))
        if mesh is not None:                         # pad to mesh divisibility
            n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
            m = max(n_dev, (m // n_dev) * n_dev)
        for t in range(flcfg.rounds):
            sel = engine.select(rng, members, m, t, counts[members])
            bidx = rng.integers(0, n_win, size=(len(sel), steps,
                                                flcfg.batch_size))
            params, sstate, l = engine.step(
                params, sstate, jnp.asarray(x_tr[sel]), jnp.asarray(y_tr[sel]),
                jnp.asarray(bidx), counts[sel])
            hist.append(float(l))
            if log_every and (t + 1) % log_every == 0:
                print(f"[cluster {cid}] round {t+1}/{flcfg.rounds} "
                      f"loss {hist[-1]:.5f}")
        results[cid] = FLResult(jax.device_get(params), np.array(hist),
                                cents, assigns,
                                held_ids if len(held_ids) else None)
    return results


# ------------------------------------------------------------------ eval
@functools.partial(jax.jit, static_argnames=("cfg", "cell_impl"))
def _predict(params, x, cfg, cell_impl="jnp"):
    return forecaster.forecast(params, x, cfg, cell_impl)


def evaluate_global(params, x_test: np.ndarray, y_test: np.ndarray,
                    cfg: ForecasterConfig, stats=None,
                    batch: int = 8192) -> Dict[str, float]:
    """Evaluate on (possibly huge) held-out window sets, streamed in batches.

    x_test: (n, L, 1); y_test: (n, H) — normalized per building.  ``stats`` is
    the per-row (lo, hi) min/max pair (broadcastable to (n, 1)); when given,
    MAPE/Accuracy are computed in DE-normalized kWh space, as the paper does —
    commercial base load keeps actual kWh well away from zero, which is what
    makes MAPE-based accuracy meaningful.
    Returns RMSE / MAPE / Accuracy (§4.5) + per-horizon accuracy (Table 4).
    """
    n = x_test.shape[0]
    preds = []
    for i in range(0, n, batch):
        preds.append(np.asarray(_predict(params, jnp.asarray(x_test[i:i + batch]),
                                         cfg)))
    pred = np.concatenate(preds)
    y = y_test
    if stats is not None:
        lo, hi = stats
        scale = np.maximum(hi - lo, 1e-9)
        pred = pred * scale + lo
        y = y * scale + lo
    eps = 1e-2
    ape = np.abs((y - pred) / np.maximum(np.abs(y), eps))
    per_h = 100.0 - 100.0 * ape.mean(0)
    return {
        "rmse": float(np.sqrt(((pred - y) ** 2).mean())),
        "mape": float(100.0 * ape.mean()),
        "accuracy": float(np.clip(100.0 - 100.0 * ape.mean(), 0, 100)),
        "per_horizon_accuracy": np.clip(per_h, 0, 100),
    }


def evaluate_unseen_clients(params, series: np.ndarray,
                            cfg: ForecasterConfig,
                            batch: int = 8192) -> Dict[str, float]:
    """Unseen-CLIENT generalization (paper §5.4): run the full windowing
    pipeline on buildings never seen in training and score their *test*
    windows in kWh space.  series: (n_held, T) raw kWh."""
    data = windows.batched_client_windows(series, cfg.lookback, cfg.horizon)
    x, y, stats = windows.flatten_test_windows(data)
    return evaluate_global(params, x, y, cfg, stats=stats, batch=batch)
