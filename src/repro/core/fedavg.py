"""FedAvg engine (paper Alg. 1) — pseudo-distributed (vmap) and mesh-sharded
(shard_map) execution of the same round schedule.

One round: the server broadcasts global params; each of the M selected clients
runs ``ClientUpdate`` (E local epochs of minibatch SGD); the server averages
the returned models: ``w ← (1/|s|) Σ w_i``.

The mesh-sharded path places clients on the ``clients`` (= data) mesh axis via
``shard_map``; FedAvg aggregation is then a single ``psum`` — the paper's
edge→cloud upload + cloud aggregation collapsed into one collective.  Local
epochs run with NO cross-client communication, which is precisely what makes
FedAvg cheaper on the wire than synchronous data-parallel SGD.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, losses as losses_mod
from repro.core.client import local_update
from repro.data import partition, windows
from repro.models import forecaster


def fedavg_aggregate(stacked_params):
    """Average a client-stacked param tree (leading axis = clients)."""
    return jax.tree.map(lambda w: jnp.mean(w, axis=0), stacked_params)


# ------------------------------------------------------------ vmap execution
@functools.partial(jax.jit, static_argnames=("cfg", "loss", "cell_impl"))
def fedavg_round(params, x, y, batch_idx, lr, cfg: ForecasterConfig,
                 loss: Callable, cell_impl: str = "jnp"):
    """One synchronous round over M clients (pseudo-distributed).

    x: (M, n_win, L, 1); y: (M, n_win, H); batch_idx: (M, steps, B).
    """
    locals_, client_loss = jax.vmap(
        local_update, in_axes=(None, 0, 0, 0, None, None, None, None))(
        params, x, y, batch_idx, lr, cfg, loss, cell_impl)
    return fedavg_aggregate(locals_), jnp.mean(client_loss)


# ------------------------------------------------------- shard_map execution
def make_sharded_round(mesh, cfg: ForecasterConfig, loss: Callable,
                       client_axis: str = "clients", cell_impl: str = "jnp"):
    """FedAvg round with clients sharded over a mesh axis.

    Each mesh slot holds a contiguous shard of the selected clients; local
    training is collective-free; the FedAvg average is ONE psum of the
    (tiny) parameter tree per round.
    """
    def round_body(params, x, y, batch_idx, lr):
        locals_, client_loss = jax.vmap(
            local_update, in_axes=(None, 0, 0, 0, None, None, None, None))(
            params, x, y, batch_idx, lr, cfg, loss, cell_impl)
        summed = jax.tree.map(
            lambda w: jax.lax.psum(jnp.sum(w, axis=0), client_axis), locals_)
        n = jax.lax.psum(x.shape[0], client_axis)
        new_params = jax.tree.map(lambda w: w / n, summed)
        loss_mean = jax.lax.psum(jnp.sum(client_loss), client_axis) / n
        return new_params, loss_mean

    pspec = P(client_axis)
    return jax.jit(jax.shard_map(
        round_body, mesh=mesh,
        in_specs=(P(), pspec, pspec, pspec, P()),
        out_specs=(P(), P()),
        check_vma=False))


# ------------------------------------------------------------------ driver
@dataclasses.dataclass
class FLResult:
    params: Dict
    loss_history: np.ndarray
    cluster_centroids: Optional[np.ndarray] = None
    cluster_assignments: Optional[np.ndarray] = None


def run_federated_training(all_series: np.ndarray, fcfg: ForecasterConfig,
                           flcfg: FLConfig, *, mesh=None,
                           log_every: int = 0) -> Dict[int, FLResult]:
    """Full Alg. 1: optional clustering, then per-cluster FedAvg training.

    all_series: (N, T) raw kWh, one row per client.  Returns
    {cluster_id: FLResult}; cluster_id = -1 when clustering is off.
    """
    rng = np.random.default_rng(flcfg.seed)
    loss = losses_mod.make_loss(flcfg.loss, flcfg.beta)
    data = windows.batched_client_windows(all_series, fcfg.lookback, fcfg.horizon)
    x_tr, y_tr = data["x_train"], data["y_train"]       # (N, n_win, L, 1), (N, n_win, H)
    n_win = x_tr.shape[1]
    steps = partition.local_steps(n_win, flcfg.batch_size, flcfg.local_epochs)

    # -------- optional privacy-preserving clustering (server side, Alg. 1)
    if flcfg.n_clusters > 1:
        z = windows.daily_average_vector(all_series, flcfg.cluster_days)
        cents, assigns, _ = clustering.kmeans(z, flcfg.n_clusters, seed=flcfg.seed)
        groups = partition.cluster_partition(assigns)
    else:
        cents, assigns = None, None
        groups = {-1: np.arange(all_series.shape[0])}

    round_fn = None
    if mesh is not None:
        round_fn = make_sharded_round(mesh, fcfg, loss)

    results: Dict[int, FLResult] = {}
    for cid, members in groups.items():
        key = jax.random.PRNGKey(flcfg.seed + (cid if cid >= 0 else 0))
        params = forecaster.init_forecaster(key, fcfg)
        hist = []
        m = min(flcfg.clients_per_round, len(members))
        if mesh is not None:                             # pad to mesh divisibility
            n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
            m = max(n_dev, (m // n_dev) * n_dev)
        for t in range(flcfg.rounds):
            sel = members[partition.sample_clients(rng, len(members), m)]
            if len(sel) < m:                             # sample w/ replacement pad
                sel = np.concatenate([sel, rng.choice(members, m - len(sel))])
            bidx = rng.integers(0, n_win, size=(len(sel), steps, flcfg.batch_size))
            args = (params, jnp.asarray(x_tr[sel]), jnp.asarray(y_tr[sel]),
                    jnp.asarray(bidx), jnp.float32(flcfg.lr))
            if round_fn is not None:
                params, l = round_fn(*args)
            else:
                params, l = fedavg_round(*args, fcfg, loss)
            hist.append(float(l))
            if log_every and (t + 1) % log_every == 0:
                print(f"[cluster {cid}] round {t+1}/{flcfg.rounds} "
                      f"loss {hist[-1]:.5f}")
        results[cid] = FLResult(jax.device_get(params), np.array(hist),
                                cents, assigns)
    return results


# ------------------------------------------------------------------ eval
@functools.partial(jax.jit, static_argnames=("cfg", "cell_impl"))
def _predict(params, x, cfg, cell_impl="jnp"):
    return forecaster.forecast(params, x, cfg, cell_impl)


def evaluate_global(params, x_test: np.ndarray, y_test: np.ndarray,
                    cfg: ForecasterConfig, stats=None,
                    batch: int = 8192) -> Dict[str, float]:
    """Evaluate on (possibly huge) held-out window sets, streamed in batches.

    x_test: (n, L, 1); y_test: (n, H) — normalized per building.  ``stats`` is
    the per-row (lo, hi) min/max pair (broadcastable to (n, 1)); when given,
    MAPE/Accuracy are computed in DE-normalized kWh space, as the paper does —
    commercial base load keeps actual kWh well away from zero, which is what
    makes MAPE-based accuracy meaningful.
    Returns RMSE / MAPE / Accuracy (§4.5) + per-horizon accuracy (Table 4).
    """
    n = x_test.shape[0]
    preds = []
    for i in range(0, n, batch):
        preds.append(np.asarray(_predict(params, jnp.asarray(x_test[i:i + batch]),
                                         cfg)))
    pred = np.concatenate(preds)
    y = y_test
    if stats is not None:
        lo, hi = stats
        scale = np.maximum(hi - lo, 1e-9)
        pred = pred * scale + lo
        y = y * scale + lo
    eps = 1e-2
    ape = np.abs((y - pred) / np.maximum(np.abs(y), eps))
    per_h = 100.0 - 100.0 * ape.mean(0)
    return {
        "rmse": float(np.sqrt(((pred - y) ** 2).mean())),
        "mape": float(100.0 * ape.mean()),
        "accuracy": float(np.clip(100.0 - 100.0 * ape.mean(), 0, 100)),
        "per_horizon_accuracy": np.clip(per_h, 0, 100),
    }
