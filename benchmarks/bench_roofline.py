"""Roofline reader — renders EXPERIMENTS.md §Roofline from the dry-run
artifacts in experiments/dryrun/ (run `python -m repro.launch.dryrun --all`
first; see MULTI-POD DRY-RUN in the README)."""
from __future__ import annotations

from pathlib import Path

from repro.launch import roofline


def main(dir_=None):
    if dir_ is None:
        dir_ = ("experiments/dryrun_optimized"
                if Path("experiments/dryrun_optimized").exists()
                else "experiments/dryrun")
    if not Path(dir_).exists() or not list(Path(dir_).glob("*.json")):
        print("# no dry-run artifacts found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
        return []
    recs = roofline.load_records(dir_)
    print(f"# roofline terms from {len(recs)} dry-run artifacts "
          "(TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)")
    print(roofline.table(recs))
    doms = {}
    for r in recs:
        t = roofline.terms(r)
        doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
    print(f"# dominant-term histogram: {doms}")
    return recs


if __name__ == "__main__":
    main()
