"""Beyond-paper transfer: the paper's EW-MSE idea applied to LM training.

EW-MSE up-weights far-horizon forecast errors (§3.3.2).  The LM analogue
(`core.losses.weighted_ce`, β>1) up-weights late context positions — the
"long-range" targets of next-token prediction.  This bench trains a reduced
qwen-family decoder on the structured Zipf stream with β ∈ {1, 2} and
reports the per-position-quartile eval loss: β>1 shifts capacity toward
late positions exactly as EW-MSE shifts it toward far horizons.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.data import tokens
from repro.models import transformer as tf


def run(beta: float, steps: int = 40, seed: int = 0):
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = tf.init_model(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    opt = optim.adam()
    step = jax.jit(tf.make_train_step(cfg, opt, beta=beta,
                                      dtype=jnp.float32))
    st = opt.init(params)
    for i in range(steps):
        b = tokens.make_lm_batch(cfg, 8, 128, seed=1000 + i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, st, m = step(params, st, batch, 3e-3)
    # eval: per-position CE on held-out stream
    b = tokens.make_lm_batch(cfg, 16, 128, seed=9_999)
    logits, _, _ = tf.forward(params, {"tokens": jnp.asarray(b["tokens"])},
                              cfg, dtype=jnp.float32, remat=False)
    logp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(logp, jnp.asarray(b["labels"])[..., None],
                             -1)[..., 0]
    per_pos = -np.asarray(ll).mean(0)                    # (S,)
    quart = per_pos.reshape(4, -1).mean(1)
    return float(m["loss"]), quart


def main():
    rows = []
    print("# EW loss transferred to LM training (reduced qwen, 40 steps)")
    print("beta,final_train_loss,eval_ce_q1,eval_ce_q2,eval_ce_q3,eval_ce_q4")
    for beta in (1.0, 2.0):
        loss, quart = run(beta)
        print(f"{beta},{loss:.3f}," + ",".join(f"{q:.3f}" for q in quart))
        rows.append((beta, quart))
    d_late = rows[0][1][3] - rows[1][1][3]
    d_early = rows[0][1][0] - rows[1][1][0]
    print(f"# β=2 improves late-position CE by {d_late:+.3f} vs β=1 "
          f"(early-position delta {d_early:+.3f}) — the paper's far-horizon "
          "emphasis, transferred")
    return rows


if __name__ == "__main__":
    main()
