"""Paper §5.5 — Raspberry-Pi edge-cluster envelope, simulated.

The paper trains 30 clients on Pi 4Bs: 70–100 s/round, 560 KB model
transfer/round, 450 MB client memory.  This container has no Pi cluster, so
we (a) run the same FL code path under a single-core CPU budget and measure
per-round wall time, (b) compute bytes-on-wire analytically from the actual
parameter count (download + upload per client per round), and (c) report
peak RSS of the training process.

Also reports the hierarchical PER-LEVEL link budgets (``latency.link_budget``,
ROADMAP follow-up to PR 3's edge->region->cloud aggregation): region fan-in
(clients/region uploads absorbed by each Pi cluster head) vs cloud ingress
(one already-aggregated fp32 partial per region), with and without int8
delta quantization on the client uplinks.
"""
from __future__ import annotations

import resource
import time

import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg, latency
from repro.data import synthetic


def main():
    n_clients, rounds = 30, 10
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    flcfg = FLConfig(n_clients=n_clients, clients_per_round=n_clients,
                     rounds=rounds, lr=0.05, loss="ew_mse", n_clusters=0)
    series = synthetic.generate_buildings("CA", list(range(n_clients)),
                                          days=90)
    t0 = time.time()
    res = fedavg.run_federated_training(series, fcfg, flcfg)[-1]
    total = time.time() - t0
    per_round = total / rounds

    n_params = fcfg.num_params()
    wire_kb = n_params * 4 * 2 / 1024                    # down + up, fp32
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024

    print("# §5.5 — edge-cluster envelope (simulated; paper values: "
          "70–100 s/round on Pi 4B, 560 KB transfer, 450 MB RSS)")
    print("metric,ours,paper")
    print(f"per_round_s,{per_round:.2f},70-100 (Pi 4B; ours is a single "
          "x86 core running ALL 30 clients)")
    print(f"model_params,{n_params},~140k (560KB/4B)")
    print(f"wire_kb_per_client_round,{wire_kb:.0f},560")
    print(f"client_rss_mb,{rss_mb:.0f},450")
    print(f"final_loss,{res.loss_history[-1]:.5f},~1e-3")
    assert np.isfinite(res.loss_history).all()

    # ---- audited vs modeled upload bytes (flcheck level-3 cost auditor):
    # the audited numbers are read off the traced round's boundary
    # crossings (exact per-leaf wire encoding), the modeled ones are the
    # latency.payload_bytes closed form the engine charges
    from repro.analysis import costs
    from repro.configs.base import SecureAggConfig, TransformConfig
    tc_q8 = TransformConfig(clip_norm=1.0, quantize_bits=8)
    audit_rows = [
        ("fp32", costs.audit_upload(fcfg, TransformConfig(clip_norm=1.0))),
        ("int8", costs.audit_upload(fcfg, tc_q8)),
        ("int8+masked", costs.audit_upload(fcfg, tc_q8,
                                           SecureAggConfig(enabled=True))),
    ]
    print("\n# audited vs modeled upload bytes/client "
          "(flcheck --cost; audited = traced wire format, proved)")
    print("config,wire,audited_bytes,modeled_bytes,divergence")
    for name, a in audit_rows:
        div = ";".join(f"{d['kind']}{d['bytes']:+d}B"
                       for d in a["divergences"]) or "-"
        print(f"{name},{a['wire']},{a['audited_bytes']},"
              f"{a['modeled_bytes']},{div}")
    print("# masked uploads re-widen to fp32 (float pairwise masks destroy "
          "the int8 grid) — the tracked regression the ROADMAP secure-agg "
          "hardening item buys back")
    audited_q8 = audit_rows[1][1]["audited_bytes"]

    # ---- hierarchical per-level link budgets (upload direction, per round)
    print(f"\n# per-level link budgets — {n_clients} clients/round, "
          f"{n_params} params (regions=1 is the flat edge->cloud topology; "
          "bits=8 rows use the AUDITED int8 upload payload)")
    print("regions,quantize_bits,region_fanin_kb,cloud_ingress_kb,"
          "cloud_vs_flat")
    budgets = []
    for r in (1, 2, 3, 5):
        for bits in (0, 8):
            b = latency.link_budget(n_params, n_clients, r, bits,
                                    audited_up=audited_q8 if bits else None)
            flat = b["flat_cloud_ingress_bytes"]
            print(f"{r},{bits},{b['region_fanin_bytes']/1024:.0f},"
                  f"{b['cloud_ingress_bytes']/1024:.0f},"
                  f"{b['cloud_ingress_bytes']/flat:.2f}x")
            budgets.append((r, bits, b))
    print("# regional edge aggregation shrinks cloud ingress from m client "
          "payloads to R fp32 partials; quantization compresses the "
          "region fan-in links on top")
    return [("per_round_s", per_round), ("wire_kb", wire_kb),
            ("rss_mb", rss_mb),
            ("audited_int8_bytes", audited_q8),
            ("cloud_ingress_kb_r5",
             budgets[-1][2]["cloud_ingress_bytes"] / 1024)]


if __name__ == "__main__":
    main()
