"""Paper §5.4 — scalability: a model trained on few buildings generalizes to
a much larger unseen population with no client-side retraining.

``--server-opt`` adds the round-engine axis: run the same scalability sweep
under any (or ``all``) of the pluggable server optimizers to see how
aggregation weighting / adaptive server steps hold up on unseen clients.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks._common import scale
from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import fedavg
from repro.core.server_opt import SERVER_OPTS
from repro.data import synthetic, windows

# adaptive rules need a small server step; sgd-type rules use the exact
# Alg. 1 step (server_lr=1)
DEFAULT_SERVER_LR = {"fedadam": 0.05, "fedyogi": 0.05}


def run_axis(state: str, server_opt: str, prox_mu: float = 0.0):
    sc = scale()
    server_lr = DEFAULT_SERVER_LR.get(server_opt, 1.0)
    rows = []
    # train ONCE in-process (the metrics cache stores no params, so going
    # through run_fl here would just train the same config twice)
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    flcfg = FLConfig(n_clients=sc["clients"], clients_per_round=sc["clients"],
                     rounds=sc["rounds"], lr=0.05, loss="ew_mse",
                     n_clusters=0, server_opt=server_opt,
                     server_lr=server_lr, prox_mu=prox_mu)
    series = synthetic.generate_buildings(state, list(range(sc["clients"])),
                                          days=sc["days"])
    res = fedavg.run_federated_training(series, fcfg, flcfg)[-1]

    print(f"# §5.4 reproduction [{server_opt}] — train on {sc['clients']} "
          "buildings, deploy to N unseen buildings (no retraining)")
    print("server_opt,n_heldout,accuracy_pct,rmse,eval_s,forecasts_per_s")
    for n in (50, 200, 800):
        ids = list(range(20_000, 20_000 + n))
        held = synthetic.generate_buildings(state, ids, days=sc["days"])
        data = windows.batched_client_windows(held, fcfg.lookback,
                                              fcfg.horizon)
        x, y, stats = windows.flatten_test_windows(data)
        t0 = time.time()
        m = fedavg.evaluate_global(res.params, x, y, fcfg, stats=stats)
        dt = time.time() - t0
        print(f"{server_opt},{n},{m['accuracy']:.2f},{m['rmse']:.3f},"
              f"{dt:.1f},{len(x)/dt:.0f}")
        rows.append((n, m["accuracy"]))
    accs = [a for _, a in rows]
    print(f"# accuracy stays within {max(accs)-min(accs):.2f} pp across a "
          f"{rows[-1][0]//rows[0][0]}× larger population — the paper's "
          "generalization claim")
    return rows


def main(state="CA", server_opt="fedavg", prox_mu=0.0):
    opts = SERVER_OPTS if server_opt == "all" else (server_opt,)
    return {opt: run_axis(state, opt, prox_mu) for opt in opts}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA")
    ap.add_argument("--server-opt", default="fedavg",
                    choices=SERVER_OPTS + ("all",))
    ap.add_argument("--prox-mu", type=float, default=0.0)
    args = ap.parse_args()
    main(args.state, args.server_opt, args.prox_mu)
