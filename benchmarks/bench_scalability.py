"""Paper §5.4 — scalability along two axes.

**Unseen-population axis** (default): a model trained on few buildings
generalizes to a much larger unseen population with no client-side
retraining.  ``--server-opt`` runs the sweep under any (or ``all``) of the
pluggable server optimizers.

**Client-count axis** (``--clients N``): federated training over N
synthetic clients through the streaming ``ClientWindowProvider`` — per
round only the ``m`` selected clients are generated/normalized/windowed,
so the full (N, n_win, L, 1) tensor is NEVER materialized and N=10k+ runs
on a laptop.  Reports rounds/s vs N on the (8 virtual) device mesh.

**Pipeline axes** (compose with ``--clients``): ``--dp-clip C`` /
``--dp-noise z`` / ``--quantize b`` switch on the delta-transform stack
(per-client L2 clip -> Gaussian DP noise -> stochastic b-bit quantize,
applied inside the round body before the collective) and ``--hier`` swaps
the flat one-psum aggregation for the two-level edge->region->cloud
reduction over a 2-D (``--regions``, clients) mesh.  Reports rounds/s per
ladder point plus the accuracy/MAPE delta vs the untransformed flat
baseline at the top point — the cost of privacy + compression in both
wall-clock and forecast quality.

**Secure-aggregation axis** (``--secure-agg``, composes with ``--clients``):
pairwise-masked uploads (``core/secure_agg.py`` — each client's delta
crosses the wire as individually-uniform noise whose masks cancel in the
aggregator sum).  The top ladder point additionally trains the same config
with masking OFF and reports the rounds/s + held-out MAPE overhead of
masking vs clear.  When ``--dp-clip``/``--dp-noise`` are also set, the
(eps, delta) accountant's report (``core/privacy.py``) is printed for every
trained variant.

**Round-pacing axis** (``--mode semi_sync`` / ``--mode async``):
semi-synchronous buffered rounds vs the synchronous baseline under
simulated stragglers (``--stragglers lognormal|heavy_tail``).  All modes
train under the SAME latency model (compute ∝ windows x epochs, uplink ∝
payload bytes); sync pays the per-round max — the straggler gates the
round — while semi-sync over-selects ``--over-select * m`` clients, flushes
at the ``--buffer-k``-th arrival, and staleness-discounts late folds
(``--staleness-alpha``).  ``--mode async`` additionally runs the
fully-asynchronous (FedAsync-style) corner — ``buffer_k=1``: the clock
advances to the EARLIEST in-flight arrival and the server steps per
flush — reported alongside sync and semi-sync.  Reports simulated
wall-clock to the common target loss plus held-out MAPE for every mode —
wall-clock-to-accuracy, the metric that matters at the edge
(arXiv:2201.11248, arXiv:2404.03320).

**Fault-tolerance axis** (``--churn p1,p2,...``): the same semi-sync config
trained at each mid-upload dropout rate (``ChurnConfig`` — lost uploads are
re-dispatched after ``--timeout-rounds``; with ``--secure-agg`` a loss
re-keys the whole cohort, Bonawitz-style).  Reports held-out MAPE +
simulated wall-clock degradation vs the churn-free run.

  python benchmarks/bench_scalability.py --clients 10000
  python benchmarks/bench_scalability.py --clients 1000 --hier --dp-clip 1.0
  python benchmarks/bench_scalability.py --clients 1000 \
      --dp-clip 1.0 --dp-noise 0.5 --quantize 8 --hier --regions 2
  python benchmarks/bench_scalability.py --clients 1000 \
      --dp-clip 1.0 --dp-noise 0.5 --secure-agg
  python benchmarks/bench_scalability.py --clients 500 --rounds 12 \
      --mode semi_sync --stragglers lognormal --over-select 1.5
  python benchmarks/bench_scalability.py --clients 500 --rounds 12 \
      --mode async --stragglers heavy_tail
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

# 8 virtual CPU devices for the client-count axis, BEFORE jax initializes
# (a pre-set XLA_FLAGS, e.g. from test.sh, wins)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from benchmarks._common import scale
from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import aggregation, fedavg
from repro.core.server_opt import SERVER_OPTS
from repro.data import synthetic
from repro.data.windows import ClientWindowProvider

# adaptive rules need a small server step; sgd-type rules use the exact
# Alg. 1 step (server_lr=1)
DEFAULT_SERVER_LR = {"fedadam": 0.05, "fedyogi": 0.05}


def run_axis(state: str, server_opt: str, prox_mu: float = 0.0):
    sc = scale()
    server_lr = DEFAULT_SERVER_LR.get(server_opt, 1.0)
    rows = []
    # train ONCE in-process (the metrics cache stores no params, so going
    # through run_fl here would just train the same config twice)
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    flcfg = FLConfig(n_clients=sc["clients"], clients_per_round=sc["clients"],
                     rounds=sc["rounds"], lr=0.05, loss="ew_mse",
                     n_clusters=0, server_opt=server_opt,
                     server_lr=server_lr, prox_mu=prox_mu)
    series = synthetic.generate_buildings(state, list(range(sc["clients"])),
                                          days=sc["days"])
    res = fedavg.run_federated_training(series, fcfg, flcfg)[-1]

    print(f"# §5.4 reproduction [{server_opt}] — train on {sc['clients']} "
          "buildings, deploy to N unseen buildings (no retraining)")
    print("server_opt,n_heldout,accuracy_pct,rmse,eval_s,forecasts_per_s")
    for n in (50, 200, 800):
        ids = range(20_000, 20_000 + n)
        # streaming provider: held-out buildings generate + evaluate in
        # chunks, never materializing the population
        prov = ClientWindowProvider.from_synthetic(
            state, ids, fcfg.lookback, fcfg.horizon, days=sc["days"])
        t0 = time.time()
        m = fedavg.evaluate_unseen_clients(res.params, prov, fcfg)
        dt = time.time() - t0
        n_fc = int(prov.test_counts.sum())
        print(f"{server_opt},{n},{m['accuracy']:.2f},{m['rmse']:.3f},"
              f"{dt:.1f},{n_fc/dt:.0f}")
        rows.append((n, m["accuracy"]))
    accs = [a for _, a in rows]
    print(f"# accuracy stays within {max(accs)-min(accs):.2f} pp across a "
          f"{rows[-1][0]//rows[0][0]}× larger population — the paper's "
          "generalization claim")
    return rows


def run_scaling(state: str, max_clients: int, rounds: int = 3,
                clients_per_round: int = 32, days: int = 120, seed: int = 0,
                smoke: bool = False, dp_clip: float = 0.0,
                dp_noise: float = 0.0, quantize: int = 0, hier: bool = False,
                regions: int = 0, secure: bool = False,
                mask_std: float = 1.0):
    """rounds/s vs total client count N through the streaming provider.

    ``dp_clip`` / ``dp_noise`` / ``quantize`` configure the delta-transform
    stack, ``hier`` the edge->region->cloud aggregation, and ``secure``
    pairwise-masked uploads; when any is set, the top ladder point also
    trains the untransformed flat baseline and reports the accuracy
    (100-MAPE) delta — plus, under ``secure``, the masked-vs-clear
    rounds/s + MAPE overhead.  ``smoke`` runs the single top ladder point
    with no compile warmup — a regression canary for the streaming path,
    not a measurement.
    """
    import jax
    n_dev = len(jax.devices())
    hier = hier or regions > 0             # --regions implies --hier
    pipeline_on = bool(dp_clip or dp_noise or quantize or hier or secure)
    pipe = dict(dp_clip=dp_clip, dp_noise=dp_noise, quantize_bits=quantize,
                aggregation="hierarchical" if hier else "flat",
                n_regions=regions if hier else 0, secure_agg=secure,
                secure_mask_std=mask_std)
    mesh = aggregation.make_mesh(FLConfig(**pipe).aggregation_config)
    mesh_desc = ("x".join(str(mesh.shape[a]) for a in mesh.axis_names)
                 + " (" + ", ".join(mesh.axis_names) + ")")
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    ladder = [max_clients] if smoke else sorted(
        {n for n in (100, 1000, 10_000, 100_000) if n < max_clients}
        | {max_clients})
    print(f"# client-count scaling — streaming ClientWindowProvider, "
          f"{n_dev}-device mesh ({mesh_desc}), m={clients_per_round}/round, "
          f"{rounds} rounds, {days}-day histories")
    if pipeline_on:
        sec = (f"on (pairwise masking, std={mask_std:g})" if secure
               else "off")
        print(f"# delta transforms: clip={dp_clip} noise={dp_noise} "
              f"quantize={quantize}b; aggregation={pipe['aggregation']}; "
              f"secure_agg={sec}")
    print("n_clients,rounds,m_per_round,train_s,rounds_per_s,final_loss")
    rows = []
    res = None
    for i, n in enumerate(ladder):
        prov = ClientWindowProvider.from_synthetic(
            state, range(n), fcfg.lookback, fcfg.horizon, days=days)
        flcfg = FLConfig(n_clients=n, clients_per_round=clients_per_round,
                         rounds=rounds, lr=0.05, loss="ew_mse", n_clusters=0,
                         server_opt="fedavg_weighted", seed=seed, **pipe)
        if i == 0 and not smoke:
            # absorb jit compile outside the timed ladder (shapes are
            # N-independent, so one trace serves every N)
            fedavg.run_federated_training(
                prov, fcfg, dataclasses.replace(flcfg, rounds=1), mesh=mesh)
        t0 = time.time()
        res = fedavg.run_federated_training(prov, fcfg, flcfg, mesh=mesh)[-1]
        dt = time.time() - t0
        rows.append((n, rounds / dt))
        print(f"{n},{rounds},{clients_per_round},{dt:.2f},{rounds/dt:.2f},"
              f"{res.loss_history[-1]:.5f}")
    print("# per-round cost is O(m + model), flat in N — the provider only "
          "touches selected clients")
    if res is not None and res.privacy is not None:
        from repro.core import privacy as privacy_mod
        print("# " + privacy_mod.format_report(res.privacy))
    if secure:
        _report_secure_overhead(state, ladder[-1], rounds, clients_per_round,
                                days, seed, fcfg, pipe, mesh, res,
                                rows[-1][1], smoke)
    if pipeline_on and not smoke:
        _report_pipeline_delta(state, ladder[-1], rounds, clients_per_round,
                               days, seed, fcfg, res)
    return rows


def _report_secure_overhead(state, n, rounds, clients_per_round, days, seed,
                            fcfg, pipe, mesh, res_masked, masked_rps,
                            smoke=False):
    """Cost of pairwise masking at the top ladder point: train the SAME
    config with masking off (same transforms, topology, seed) and report
    rounds/s + held-out MAPE for both — masks cancel in the sum, so the
    MAPE delta should be float noise while rounds/s pays the O(m^2 * params)
    mask generation."""
    clear = dict(pipe, secure_agg=False)
    if clear.get("quantize_bits"):
        # with quantize on, masking uses the shared-grid ring quantizer;
        # the honest clear comparator is the same grid unmasked — the runs
        # are then bit-identical, not merely float-close
        clear["quantize_ring"] = True
    prov = ClientWindowProvider.from_synthetic(
        state, range(n), fcfg.lookback, fcfg.horizon, days=days)
    flcfg = FLConfig(n_clients=n, clients_per_round=clients_per_round,
                     rounds=rounds, lr=0.05, loss="ew_mse", n_clusters=0,
                     server_opt="fedavg_weighted", seed=seed, **clear)
    if not smoke:
        # the masked ladder timing was warmed up (its jit trace keys on
        # scfg); give the clear variant the same courtesy or its timing
        # eats a fresh XLA compile and the overhead factor reads backwards
        # (under --smoke both variants run cold, which is symmetric enough
        # for a canary)
        fedavg.run_federated_training(
            prov, fcfg, dataclasses.replace(flcfg, rounds=1), mesh=mesh)
    t0 = time.time()
    res_clear = fedavg.run_federated_training(prov, fcfg, flcfg,
                                              mesh=mesh)[-1]
    clear_rps = rounds / (time.time() - t0)
    held = ClientWindowProvider.from_synthetic(
        state, range(n, n + (5 if smoke else 50)), fcfg.lookback,
        fcfg.horizon, days=days)
    m_mask = fedavg.evaluate_unseen_clients(res_masked.params, held, fcfg)
    m_clear = fedavg.evaluate_unseen_clients(res_clear.params, held, fcfg)
    print("variant,rounds_per_s,heldout_mape_pct")
    print(f"clear,{clear_rps:.2f},{m_clear['mape']:.2f}")
    print(f"masked,{masked_rps:.2f},{m_mask['mape']:.2f}")
    print(f"# secure-agg overhead at n={n}: "
          f"{clear_rps / max(masked_rps, 1e-9):.2f}x slower rounds, "
          f"{m_mask['mape'] - m_clear['mape']:+.3f} pp MAPE (masks cancel "
          "in the aggregate — bit-exact on the quantized ring wire, float "
          "rounding on the float path)")
    # audited wire cost of masking (flcheck level-3 cost auditor): ring
    # masking lives in the quantizer's integer ring, so the masked upload
    # ships the SAME wire as the clear one — assert it, don't just print it
    from repro.analysis import costs
    masked_flcfg = FLConfig(n_clients=n, clients_per_round=clients_per_round,
                            rounds=rounds, lr=0.05, loss="ew_mse",
                            n_clusters=0, server_opt="fedavg_weighted",
                            seed=seed, **pipe)
    a_clear = costs.audit_upload(fcfg, flcfg.transform)
    a_mask = costs.audit_upload(fcfg, masked_flcfg.transform,
                                masked_flcfg.secure)
    print("variant,wire,audited_bytes_per_client,modeled_bytes_per_client")
    print(f"clear,{a_clear['wire']},{a_clear['audited_bytes']},"
          f"{a_clear['modeled_bytes']}")
    print(f"masked,{a_mask['wire']},{a_mask['audited_bytes']},"
          f"{a_mask['modeled_bytes']}")
    assert a_mask["audited_bytes"] == a_clear["audited_bytes"], (
        f"masked upload ({a_mask['wire']}, {a_mask['audited_bytes']} B) "
        f"diverged from the clear wire ({a_clear['wire']}, "
        f"{a_clear['audited_bytes']} B) — the masker re-widened the ring "
        "(masked_fp32_regression; see tools/flcheck --cost)")
    print(f"# masking adds 0 wire bytes: masked == clear at "
          f"{a_mask['audited_bytes']} B/client/round "
          f"({a_mask['wire']} — ring masks live in the quantizer's grid)")


def _report_pipeline_delta(state, n, rounds, clients_per_round, days, seed,
                           fcfg, res_pipe):
    """Accuracy/MAPE cost of the configured transforms + topology: compare
    the pipeline model against the untransformed flat baseline (same N,
    rounds, seed) on a small held-out population."""
    base_mesh = aggregation.make_mesh()
    prov = ClientWindowProvider.from_synthetic(
        state, range(n), fcfg.lookback, fcfg.horizon, days=days)
    flcfg = FLConfig(n_clients=n, clients_per_round=clients_per_round,
                     rounds=rounds, lr=0.05, loss="ew_mse", n_clusters=0,
                     server_opt="fedavg_weighted", seed=seed)
    res_base = fedavg.run_federated_training(prov, fcfg, flcfg,
                                             mesh=base_mesh)[-1]
    # held-out ids start right AFTER the training population so the report
    # stays out-of-sample at every ladder size
    held = ClientWindowProvider.from_synthetic(
        state, range(n, n + 50), fcfg.lookback, fcfg.horizon, days=days)
    m_pipe = fedavg.evaluate_unseen_clients(res_pipe.params, held, fcfg)
    m_base = fedavg.evaluate_unseen_clients(res_base.params, held, fcfg)
    print("variant,mape_pct,accuracy_pct")
    print(f"baseline(flat),{m_base['mape']:.2f},{m_base['accuracy']:.2f}")
    print(f"pipeline,{m_pipe['mape']:.2f},{m_pipe['accuracy']:.2f}")
    print(f"# transform/topology cost: {m_pipe['mape']-m_base['mape']:+.2f} "
          f"pp MAPE vs untransformed flat baseline (50 held-out buildings)")


def run_pacing(state: str, n_clients: int, rounds: int,
               clients_per_round: int, days: int, seed: int,
               stragglers: str, jitter: float, over_select: float,
               buffer_k: int, staleness_alpha: float,
               smoke: bool = False, include_async: bool = False,
               dp_clip: float = 0.0, dp_noise: float = 0.0,
               quantize: int = 0, secure: bool = False,
               mask_std: float = 1.0):
    """Round-pacing modes under stragglers: simulated wall-clock to the
    common target loss + held-out MAPE.

    ``sync`` vs ``semi_sync`` always; ``include_async`` adds the
    fully-asynchronous (FedAsync-style) corner the ROADMAP called out as
    now-trivial: ``buffer_k=1`` — every flush fires at the FIRST in-flight
    arrival and the server steps per flush, so no update ever waits for a
    peer (late ones fold with the staleness discount)."""
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    prov = ClientWindowProvider.from_synthetic(
        state, range(n_clients), fcfg.lookback, fcfg.horizon, days=days)
    # buffer_k=0 on the CLI means "flush at m of the over-selected m'"
    # (the semi-sync sweet spot), not the engine's wait-for-all default
    bk = buffer_k or clients_per_round
    # the transform/privacy knobs apply to EVERY pacing mode (with secure
    # aggregation, semi-sync/async folds become cohort-atomic) — silently
    # dropping them here would report a clear run as a masked one
    common = dict(n_clients=n_clients, clients_per_round=clients_per_round,
                  rounds=rounds, lr=0.05, loss="ew_mse", n_clusters=0,
                  server_opt="fedavg_weighted", seed=seed,
                  stragglers=stragglers, straggler_jitter=jitter,
                  dp_clip=dp_clip, dp_noise=dp_noise, quantize_bits=quantize,
                  secure_agg=secure, secure_mask_std=mask_std)
    if dp_clip or dp_noise or quantize or secure:
        print(f"# pacing with transforms: clip={dp_clip} noise={dp_noise} "
              f"quantize={quantize}b secure_agg={'on' if secure else 'off'}"
              + (" (cohort-atomic folds)" if secure else ""))
    configs = [("sync", FLConfig(**common)),
               ("semi_sync", FLConfig(**common, mode="semi_sync",
                                      over_select=over_select, buffer_k=bk,
                                      staleness_alpha=staleness_alpha))]
    if include_async:
        configs.append(
            ("async", FLConfig(**common, mode="semi_sync",
                               over_select=over_select, buffer_k=1,
                               staleness_alpha=staleness_alpha)))
    res = {mode: fedavg.run_federated_training(prov, fcfg, cfg)[-1]
           for mode, cfg in configs}
    # common target: the worst of the final (finite) losses — every mode
    # reached it, so "time to target" is well-defined for each
    target = max(fedavg.final_loss(r) for r in res.values())
    held = ClientWindowProvider.from_synthetic(
        state, range(n_clients, n_clients + (5 if smoke else 50)),
        fcfg.lookback, fcfg.horizon, days=days)
    print(f"# round pacing — {n_clients} clients, m={clients_per_round}"
          f"/round (semi_sync dispatches m'={int(np.ceil(over_select * clients_per_round))}, "
          f"flush at k={bk}; async flushes at k=1, per-arrival server "
          f"steps; alpha={staleness_alpha}), {rounds} rounds, "
          f"stragglers={stragglers} jitter={jitter}")
    print("mode,rounds,final_loss,sim_wall_s,sim_s_to_target,"
          "heldout_mape_pct,heldout_accuracy_pct")
    rows = []
    for mode, r in res.items():
        met = fedavg.evaluate_unseen_clients(r.params, held, fcfg)
        t_tgt = fedavg.time_to_target(r, target)
        print(f"{mode},{rounds},{fedavg.final_loss(r):.5f},"
              f"{r.sim_times[-1]:.1f},{t_tgt:.1f},{met['mape']:.2f},"
              f"{met['accuracy']:.2f}")
        rows.append((mode, t_tgt, met["mape"]))
    speedup = rows[0][1] / rows[1][1]
    print(f"# semi_sync reaches the target loss in {rows[1][1]:.1f} "
          f"simulated s vs sync's {rows[0][1]:.1f} s ({speedup:.2f}x) — "
          "stragglers no longer gate the round")
    if include_async:
        print(f"# fully-async (buffer_k=1): {rows[2][1]:.1f} s to target, "
              f"held-out MAPE {rows[2][2]:.2f}% vs semi_sync's "
              f"{rows[1][2]:.2f}% — per-arrival steps trade freshness for "
              "staleness-discounted noise")
    return rows


def run_churn(state: str, n_clients: int, rounds: int,
              clients_per_round: int, days: int, seed: int,
              stragglers: str, jitter: float, over_select: float,
              buffer_k: int, staleness_alpha: float, churn_rates,
              timeout_rounds: int = 2, smoke: bool = False,
              dp_clip: float = 0.0, dp_noise: float = 0.0,
              quantize: int = 0, secure: bool = False,
              mask_std: float = 1.0):
    """Fault-tolerance axis (``--churn``): the SAME semi-sync config trained
    at each dropout rate, reporting held-out MAPE + simulated wall-clock
    degradation vs the churn-free run.

    Each dispatched upload is lost with probability p (replayable per
    ``(seed, round, slot)``); the engine re-dispatches abandoned work after
    ``timeout_rounds`` rounds — with ``--secure-agg``, a loss re-keys the
    whole cohort (survivors re-mask under the surviving set), so this axis
    also measures the Bonawitz-style recovery cost on the wire clock.
    """
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    prov = ClientWindowProvider.from_synthetic(
        state, range(n_clients), fcfg.lookback, fcfg.horizon, days=days)
    held = ClientWindowProvider.from_synthetic(
        state, range(n_clients, n_clients + (5 if smoke else 50)),
        fcfg.lookback, fcfg.horizon, days=days)
    bk = buffer_k or clients_per_round
    common = dict(n_clients=n_clients, clients_per_round=clients_per_round,
                  rounds=rounds, lr=0.05, loss="ew_mse", n_clusters=0,
                  server_opt="fedavg_weighted", seed=seed,
                  stragglers=stragglers, straggler_jitter=jitter,
                  mode="semi_sync", over_select=over_select, buffer_k=bk,
                  staleness_alpha=staleness_alpha,
                  timeout_rounds=timeout_rounds,
                  dp_clip=dp_clip, dp_noise=dp_noise, quantize_bits=quantize,
                  secure_agg=secure, secure_mask_std=mask_std)
    print(f"# client churn — {n_clients} clients, m={clients_per_round}"
          f"/round (m'={int(np.ceil(over_select * clients_per_round))}, "
          f"flush at k={bk}), {rounds} rounds, stragglers={stragglers} "
          f"jitter={jitter}, timeout={timeout_rounds} rounds, secure_agg="
          f"{'on (cohort re-key on loss)' if secure else 'off (retry)'}")
    print("dropout_prob,final_loss,folds,empty_flushes,sim_wall_s,"
          "wall_vs_clean,heldout_mape_pct,mape_vs_clean_pp")
    rows, base_wall, base_mape = [], None, None
    for p in churn_rates:
        cfg = FLConfig(**dict(common, dropout_prob=float(p)))
        res = fedavg.run_federated_training(prov, fcfg, cfg)[-1]
        met = fedavg.evaluate_unseen_clients(res.params, held, fcfg)
        wall = float(res.sim_times[-1])
        folds = int(np.isfinite(res.loss_history).sum())
        if base_wall is None:
            base_wall, base_mape = wall, met["mape"]
        print(f"{p:g},{fedavg.final_loss(res):.5f},{folds},"
              f"{rounds - folds},{wall:.1f},"
              f"{wall / max(base_wall, 1e-9):.2f}x,{met['mape']:.2f},"
              f"{met['mape'] - base_mape:+.2f}")
        rows.append((float(p), wall, met["mape"]))
    worst = rows[-1]
    print(f"# churn cost at p={worst[0]:g}: "
          f"{worst[1] / max(base_wall, 1e-9):.2f}x the clean run's simulated "
          f"wall clock, {worst[2] - base_mape:+.2f} pp held-out MAPE — "
          "re-dispatch/re-key keeps the run trainable, while lost uploads "
          "surface as empty flushes (no-progress rounds) and re-upload time")
    return rows


def main(state="CA", server_opt="fedavg", prox_mu=0.0, clients=None,
         rounds=3, clients_per_round=32, days=120, smoke=False,
         dp_clip=0.0, dp_noise=0.0, quantize=0, hier=False, regions=0,
         mode="sync", stragglers="lognormal", jitter=1.0, over_select=1.5,
         buffer_k=0, staleness_alpha=0.5, seed=0, secure_agg=False,
         mask_std=1.0, churn="", timeout_rounds=2):
    if churn:
        rates = [float(p) for p in str(churn).split(",")]
        return run_churn(state, clients or 200, rounds, clients_per_round,
                         days, seed, stragglers, jitter, over_select,
                         buffer_k, staleness_alpha, rates,
                         timeout_rounds=timeout_rounds, smoke=smoke,
                         dp_clip=dp_clip, dp_noise=dp_noise,
                         quantize=quantize, secure=secure_agg,
                         mask_std=mask_std)
    if mode in ("semi_sync", "async"):
        return run_pacing(state, clients or 200, rounds,
                          clients_per_round, days, seed, stragglers,
                          jitter, over_select, buffer_k, staleness_alpha,
                          smoke=smoke, include_async=(mode == "async"),
                          dp_clip=dp_clip, dp_noise=dp_noise,
                          quantize=quantize, secure=secure_agg,
                          mask_std=mask_std)
    if clients:
        return run_scaling(state, clients, rounds, clients_per_round, days,
                           seed=seed, smoke=smoke, dp_clip=dp_clip,
                           dp_noise=dp_noise, quantize=quantize, hier=hier,
                           regions=regions, secure=secure_agg,
                           mask_std=mask_std)
    opts = SERVER_OPTS if server_opt == "all" else (server_opt,)
    return {opt: run_axis(state, opt, prox_mu) for opt in opts}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--state", default="CA")
    ap.add_argument("--server-opt", default="fedavg",
                    choices=SERVER_OPTS + ("all",))
    ap.add_argument("--prox-mu", type=float, default=0.0)
    ap.add_argument("--clients", type=int, default=0,
                    help="run the client-count scaling axis up to N total "
                         "clients (streaming provider; 0 = §5.4 axis)")
    ap.add_argument("--rounds", type=int, default=3,
                    help="timed rounds per ladder point (scaling axis)")
    ap.add_argument("--clients-per-round", type=int, default=32)
    ap.add_argument("--days", type=int, default=120,
                    help="per-client history length (scaling axis)")
    ap.add_argument("--smoke", action="store_true",
                    help="single ladder point, no warmup (CI canary)")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="per-client delta L2 clip norm C (0 = off)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="Gaussian DP noise multiplier z (std = z*C; 0 = off)")
    ap.add_argument("--quantize", type=int, default=0,
                    help="stochastic b-bit delta quantization (0 = off)")
    ap.add_argument("--hier", action="store_true",
                    help="hierarchical edge->region->cloud aggregation over "
                         "a 2-D (region, clients) mesh")
    ap.add_argument("--regions", type=int, default=0,
                    help="# of regions (implies --hier; 0 = auto from "
                         "devices)")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-masked uploads (secure aggregation); "
                         "reports masked-vs-clear overhead at the top "
                         "ladder point")
    ap.add_argument("--mask-std", type=float, default=1.0,
                    help="per-pair secure-agg mask scale")
    ap.add_argument("--mode", default="sync",
                    choices=("sync", "semi_sync", "async"),
                    help="round pacing: semi_sync = buffered "
                         "staleness-weighted rounds vs the sync baseline; "
                         "async additionally runs the fully-async "
                         "buffer_k=1 per-arrival corner")
    ap.add_argument("--stragglers", default="lognormal",
                    choices=("deterministic", "lognormal", "heavy_tail"),
                    help="simulated client-latency distribution")
    ap.add_argument("--jitter", type=float, default=1.0,
                    help="straggler spread (lognormal sigma / pareto scale)")
    ap.add_argument("--over-select", type=float, default=1.5,
                    help="semi_sync dispatch factor: m' = ceil(f * m)")
    ap.add_argument("--buffer-k", type=int, default=0,
                    help="flush after k arrivals (0 = m, i.e. "
                         "--clients-per-round)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="late-update weight discount (1+tau)^-alpha")
    ap.add_argument("--churn", default="",
                    help="comma-separated dropout rates (e.g. 0,0.1,0.3): "
                         "run the fault-tolerance axis — held-out MAPE + "
                         "simulated wall-clock degradation vs dropout rate "
                         "under semi-sync re-dispatch (with --secure-agg: "
                         "cohort re-key recovery)")
    ap.add_argument("--timeout-rounds", type=int, default=2,
                    help="dispatches without arrival before abandoned work "
                         "is retried / its cohort re-keyed (churn axis)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(args.state, args.server_opt, args.prox_mu, args.clients,
         args.rounds, args.clients_per_round, args.days, args.smoke,
         args.dp_clip, args.dp_noise, args.quantize, args.hier, args.regions,
         args.mode, args.stragglers, args.jitter, args.over_select,
         args.buffer_k, args.staleness_alpha, args.seed, args.secure_agg,
         args.mask_std, args.churn, args.timeout_rounds)
