"""Paper §5.4 — scalability: a model trained on few buildings generalizes to
a much larger unseen population with no client-side retraining."""
from __future__ import annotations

import time

import numpy as np

from benchmarks._common import run_fl, scale
from repro.configs.base import ForecasterConfig
from repro.core import fedavg
from repro.data import synthetic, windows


def main(state="CA"):
    sc = scale()
    rows = []
    # train once (cached), then stress the evaluation population size
    base = run_fl(state=state, cell="lstm", loss="ew_mse")
    # re-train quickly to get params in memory (cache stores metrics only)
    from repro.configs.base import FLConfig
    fcfg = ForecasterConfig(cell="lstm", hidden_dim=64)
    flcfg = FLConfig(n_clients=sc["clients"], clients_per_round=sc["clients"],
                     rounds=sc["rounds"], lr=0.05, loss="ew_mse",
                     n_clusters=0)
    series = synthetic.generate_buildings(state, list(range(sc["clients"])),
                                          days=sc["days"])
    res = fedavg.run_federated_training(series, fcfg, flcfg)[-1]

    print(f"# §5.4 reproduction — train on {sc['clients']} buildings, "
          "deploy to N unseen buildings (no retraining)")
    print("n_heldout,accuracy_pct,rmse,eval_s,forecasts_per_s")
    for n in (50, 200, 800):
        ids = list(range(20_000, 20_000 + n))
        held = synthetic.generate_buildings(state, ids, days=sc["days"])
        data = windows.batched_client_windows(held, fcfg.lookback,
                                              fcfg.horizon)
        x, y, stats = windows.flatten_test_windows(data)
        t0 = time.time()
        m = fedavg.evaluate_global(res.params, x, y, fcfg, stats=stats)
        dt = time.time() - t0
        print(f"{n},{m['accuracy']:.2f},{m['rmse']:.3f},{dt:.1f},"
              f"{len(x)/dt:.0f}")
        rows.append((n, m["accuracy"]))
    accs = [a for _, a in rows]
    print(f"# accuracy stays within {max(accs)-min(accs):.2f} pp across a "
          f"{rows[-1][0]//rows[0][0]}× larger population — the paper's "
          "generalization claim")
    return rows


if __name__ == "__main__":
    main()
