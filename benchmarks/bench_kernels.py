"""Kernel micro-bench: fused Pallas cells / flash attention vs jnp reference.

On CPU the Pallas kernels run in INTERPRET mode, so wall-clock here measures
the reference path's cost and validates the kernels' numerics at bench
shapes; the structural win of the fused cell (no HBM round-trip between the
matmuls and the gates) is reported as bytes-moved, which is
hardware-independent.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention


def _time(f, *a, n=20):
    f(*a)[0].block_until_ready() if isinstance(f(*a), tuple) else \
        f(*a).block_until_ready()
    t0 = time.time()
    for _ in range(n):
        r = f(*a)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.time() - t0) / n * 1e6


def main():
    rows = []
    r = np.random.default_rng(0)
    print("# kernel validation + HBM-traffic model (B=batch, H=hidden)")
    print("kernel,shape,max_err,ref_us,hbm_bytes_fused,hbm_bytes_unfused")
    for B, H in ((64, 64), (256, 128)):
        x = jnp.asarray(r.normal(size=(B, 8)), jnp.float32)
        h = jnp.asarray(r.normal(size=(B, H)), jnp.float32)
        c = jnp.asarray(r.normal(size=(B, H)), jnp.float32)
        p = {"wx": jnp.asarray(r.normal(size=(8, 4 * H)) * .2, jnp.float32),
             "wh": jnp.asarray(r.normal(size=(H, 4 * H)) * .2, jnp.float32),
             "b": jnp.zeros((4 * H,), jnp.float32)}
        h1, c1 = ops.lstm_cell_fused(x, h, c, p)
        h2, c2 = ref.lstm_cell_ref(x, h, c, p["wx"], p["wh"], p["b"])
        err = float(jnp.abs(h1 - h2).max())
        us = _time(lambda: ref.lstm_cell_ref(x, h, c, p["wx"], p["wh"],
                                             p["b"]))
        # fused: read x,h,c,W; write h',c'.  unfused: + (B,4H) preact x3
        fused = 4 * (B * 8 + 2 * B * H + 8 * 4 * H + H * 4 * H + 4 * H
                     + 2 * B * H)
        unfused = fused + 4 * 3 * (B * 4 * H)
        print(f"lstm_cell,B{B}xH{H},{err:.2e},{us:.0f},{fused},{unfused}")
        rows.append(("lstm_cell", err))

    q = jnp.asarray(r.normal(size=(2, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(r.normal(size=(2, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(r.normal(size=(2, 512, 2, 64)), jnp.float32)
    o1 = flash_attention(q, k, v, interpret=True)
    o2 = ref.flash_attention_ref(q, k, v)
    err = float(jnp.abs(o1 - o2).max())
    us = _time(lambda: ref.flash_attention_ref(q, k, v))
    # flash: O(S) memory; ref materializes (B,S,H,S) scores
    s_flash = 4 * (3 * 2 * 512 * 8 * 64 + 2 * 512 * 8 * 64)
    s_ref = s_flash + 4 * (2 * 512 * 8 * 512)
    print(f"flash_attention,B2xS512xH8/2,{err:.2e},{us:.0f},{s_flash},{s_ref}")
    rows.append(("flash_attention", err))
    return rows


if __name__ == "__main__":
    main()
