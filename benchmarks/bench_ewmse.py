"""Paper Table 4 + Fig. 3 — MSE vs EW-MSE per 15-min horizon × 3 states."""
from __future__ import annotations

from benchmarks._common import run_fl


def main():
    rows = []
    print("# Table 4 reproduction — accuracy per horizon step, MSE vs EW-MSE"
          " (LSTM, no clustering)")
    print("state,loss,acc_15min,acc_30min,acc_45min,acc_60min,avg_acc,rmse")
    for state in ("CA", "FLO", "RI"):
        for loss in ("mse", "ew_mse"):
            r = run_fl(state=state, cell="lstm", loss=loss)
            m = r["metrics"]
            ph = m["per_horizon_accuracy"]
            print(f"{state},{loss}," + ",".join(f"{a:.2f}" for a in ph)
                  + f",{m['accuracy']:.2f},{m['rmse']:.3f}")
            rows.append((state, loss, ph, m["accuracy"], m["rmse"]))
    for state in ("CA", "FLO", "RI"):
        mse = next(r for r in rows if r[0] == state and r[1] == "mse")
        ew = next(r for r in rows if r[0] == state and r[1] == "ew_mse")
        print(f"# {state}: EW-MSE avg Δ = {ew[3]-mse[3]:+.2f} pp "
              f"(60-min Δ = {ew[2][-1]-mse[2][-1]:+.2f} pp); paper: "
              "EW-MSE better at every horizon")
    return rows


if __name__ == "__main__":
    main()
