"""Shared FL benchmark harness: one federated training run + held-out eval,
with an on-disk metrics cache so overlapping benches (e.g. LSTM×EW-MSE×CA
appears in Tables 3, 4 and Fig. 4) train once.

Scale note: the paper trains 100 clients × 500 rounds on a full year.  The
CPU-budgeted benches default to 24 clients × 50 rounds × 180 days, which
reproduces every qualitative effect (clustering gains, EW-MSE gains, horizon
decay, scalability to unseen buildings) at ~2 min/config.  Set
REPRO_BENCH_SCALE=paper to run closer to paper scale.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from pathlib import Path

import numpy as np

from repro.configs.base import FLConfig, ForecasterConfig
from repro.core import clustering, fedavg
from repro.data import synthetic, windows

CACHE_DIR = Path("experiments/bench_cache")

SCALES = {
    "fast": dict(clients=16, rounds=25, days=120, heldout=40),
    "default": dict(clients=24, rounds=50, days=180, heldout=60),
    "paper": dict(clients=100, rounds=500, days=365, heldout=1000),
}


def scale():
    return SCALES[os.environ.get("REPRO_BENCH_SCALE", "default")]


def _key(**kw):
    return hashlib.sha1(json.dumps(kw, sort_keys=True).encode()).hexdigest()[:16]


def run_fl(state="CA", cell="lstm", loss="ew_mse", beta=2.0, clusters=0,
           clients=None, rounds=None, days=None, heldout=None, seed=0,
           lr=0.05, hidden=64, server_opt="fedavg", server_lr=1.0,
           prox_mu=0.0, sampling="uniform", use_cache=True):
    """Train (or fetch cached) + evaluate. Returns a metrics dict.

    ``server_opt`` / ``server_lr`` / ``prox_mu`` / ``sampling`` select the
    round engine's server optimizer and client-selection scheme (see
    ``repro.core.server_opt`` / ``repro.core.sampling``); they are part of
    the cache key, so each engine configuration trains once.
    """
    sc = scale()
    clients = clients or sc["clients"]
    rounds = rounds or sc["rounds"]
    days = days or sc["days"]
    heldout = heldout or sc["heldout"]
    kw = dict(state=state, cell=cell, loss=loss, beta=beta, clusters=clusters,
              clients=clients, rounds=rounds, days=days, heldout=heldout,
              seed=seed, lr=lr, hidden=hidden, server_opt=server_opt,
              server_lr=server_lr, prox_mu=prox_mu, sampling=sampling)
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    cpath = CACHE_DIR / f"{_key(**kw)}.json"
    if use_cache and cpath.exists():
        return json.loads(cpath.read_text())

    t0 = time.time()
    fcfg = ForecasterConfig(cell=cell, hidden_dim=hidden)
    flcfg = FLConfig(n_clients=clients, clients_per_round=clients,
                     rounds=rounds, lr=lr, loss=loss, beta=beta,
                     n_clusters=clusters, seed=seed,
                     cluster_days=min(273, int(days * 0.75)),
                     server_opt=server_opt, server_lr=server_lr,
                     prox_mu=prox_mu, sampling=sampling)
    train_series = synthetic.generate_buildings(state, list(range(clients)),
                                                days=days)
    results = fedavg.run_federated_training(train_series, fcfg, flcfg)

    held = synthetic.generate_buildings(
        state, list(range(10_000, 10_000 + heldout)), days=days)
    data = windows.batched_client_windows(held, fcfg.lookback, fcfg.horizon)
    x, y, stats = windows.flatten_test_windows(data)

    out = {"config": kw, "train_s": round(time.time() - t0, 1),
           "final_train_loss": float(list(results.values())[0]
                                     .loss_history[-1])}
    if clusters:
        z = windows.daily_average_vector(held, flcfg.cluster_days)
        assign = clustering.assign(z, results[0].cluster_centroids)
        n_win = data["x_test"].shape[1]
        per_cluster = {}
        for cid, res in results.items():
            m = np.repeat(assign == cid, n_win)
            if not m.any():
                continue
            met = fedavg.evaluate_global(res.params, x[m], y[m], fcfg,
                                         stats=(stats[0][m], stats[1][m]))
            per_cluster[str(cid)] = _clean(met)
        out["per_cluster"] = per_cluster
        out["avg_of_clusters"] = float(np.mean(
            [v["accuracy"] for v in per_cluster.values()]))
        # the global model's per-cluster accuracy (Table 2's F^A column)
        gres = run_fl(**{**kw, "clusters": 0}, use_cache=use_cache)
        out["global_accuracy"] = gres["metrics"]["accuracy"]
    else:
        out["metrics"] = _clean(fedavg.evaluate_global(
            list(results.values())[0].params, x, y, fcfg, stats=stats))
    out["eval_s"] = round(time.time() - t0 - out["train_s"], 1)
    cpath.write_text(json.dumps(out, indent=1))
    return out


def _clean(met):
    return {k: (np.asarray(v).tolist() if hasattr(v, "tolist") else float(v))
            for k, v in met.items()}


def heldout_eval(params_result, state, fcfg, ids, days):
    """Streamed held-out eval: buildings generate + window on demand, so the
    held-out population size is bounded by disk-free patience, not RAM."""
    prov = windows.ClientWindowProvider.from_synthetic(
        state, ids, fcfg.lookback, fcfg.horizon, days=days)
    return fedavg.evaluate_unseen_clients(params_result, prov, fcfg)
