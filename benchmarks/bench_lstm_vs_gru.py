"""Paper Fig. 4 — LSTM vs GRU × MSE/EW-MSE × 3 states (held-out accuracy)."""
from __future__ import annotations

from benchmarks._common import run_fl


def main():
    rows = []
    print("# Fig. 4 reproduction — avg held-out accuracy")
    print("state,cell,loss,accuracy_pct,rmse")
    for state in ("CA", "FLO", "RI"):
        for cell in ("lstm", "gru"):
            for loss in ("mse", "ew_mse"):
                r = run_fl(state=state, cell=cell, loss=loss)
                m = r["metrics"]
                print(f"{state},{cell},{loss},{m['accuracy']:.2f},"
                      f"{m['rmse']:.3f}")
                rows.append((state, cell, loss, m["accuracy"]))
    for state in ("CA", "FLO", "RI"):
        g = {(c, l): a for s, c, l, a in rows if s == state}
        print(f"# {state}: LSTM EW-MSE gain {g[('lstm','ew_mse')]-g[('lstm','mse')]:+.2f} pp, "
              f"GRU EW-MSE gain {g[('gru','ew_mse')]-g[('gru','mse')]:+.2f} pp "
              "(paper: LSTM benefits more from EW-MSE)")
    return rows


if __name__ == "__main__":
    main()
