"""Paper Fig. 5 — EW-MSE β ablation (β=1 ⇒ plain MSE)."""
from __future__ import annotations

from benchmarks._common import run_fl


def main():
    rows = []
    print("# Fig. 5 reproduction — accuracy vs beta (LSTM, EW-MSE)")
    print("state,beta,accuracy_pct")
    for state in ("CA", "FLO", "RI"):
        for beta in (1.0, 2.0, 3.0, 4.0):
            loss = "mse" if beta == 1.0 else "ew_mse"
            r = run_fl(state=state, cell="lstm", loss=loss, beta=beta)
            acc = r["metrics"]["accuracy"]
            print(f"{state},{beta},{acc:.2f}")
            rows.append((state, beta, acc))
    for state in ("CA", "FLO", "RI"):
        accs = {b: a for s, b, a in rows if s == state}
        best = max(accs, key=accs.get)
        print(f"# {state}: best β = {best} ({accs[best]:.2f}%); "
              f"β=1 gives {accs[1.0]:.2f}% — paper: every β>1 beats β=1")
    return rows


if __name__ == "__main__":
    main()
